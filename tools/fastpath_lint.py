#!/usr/bin/env python
"""Fast-path invariant analyzer CLI.

Layer 1 (default) is pure-AST: no jax import, runs in milliseconds::

    PYTHONPATH=src python tools/fastpath_lint.py            # lint src/repro
    PYTHONPATH=src python tools/fastpath_lint.py --select FP001,FP003 path/

Layer 2 (``--trace``) imports the real engine and verifies donation
aliasing, decode-body purity, and compile-count boundedness against the
lowered executables (CPU XLA; a few seconds)::

    PYTHONPATH=src JAX_PLATFORMS=cpu python tools/fastpath_lint.py --trace

Exit status: 0 clean, 1 findings / stale allows / trace violations.
``--summary`` appends a markdown findings table to ``$GITHUB_STEP_SUMMARY``
(or a file given with ``--summary-file``).
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis.lint import Report, lint_paths  # noqa: E402


def summary_table(report: Report, traced: list[str] | None) -> str:
    lines = [
        "### fastpath lint",
        "",
        "| rule | findings | allowed (audited) |",
        "|------|----------|-------------------|",
    ]
    for rule, c in sorted(report.counts().items()):
        lines.append(f"| {rule} | {c['findings']} | {c['allowed']} |")
    if report.errors:
        lines.append(f"| FP000 (stale/malformed allows) | {len(report.errors)} | — |")
    if traced is not None:
        status = "clean" if not traced else f"{len(traced)} violation(s)"
        lines.append("")
        lines.append(f"**trace verifier (layer 2):** {status}")
        lines.extend(f"- {p}" for p in traced)
    lines.append("")
    return "\n".join(lines)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="*", default=None, help="files/dirs to lint")
    ap.add_argument("--select", help="comma-separated rule IDs (default: all)")
    ap.add_argument(
        "--trace", action="store_true",
        help="also run the jaxpr/executable-level verifier (imports jax)",
    )
    ap.add_argument(
        "--summary", action="store_true",
        help="append a markdown table to $GITHUB_STEP_SUMMARY",
    )
    ap.add_argument("--summary-file", help="write the markdown table here")
    args = ap.parse_args(argv)

    paths = args.paths or [
        os.path.join(os.path.dirname(__file__), "..", "src", "repro")
    ]
    select = set(args.select.split(",")) if args.select else None
    report = lint_paths(paths, select=select)

    for f in report.findings:
        print(f)
    for f in report.errors:
        print(f)

    traced = None
    if args.trace:
        from repro.analysis.trace_verify import verify_all

        traced = verify_all()
        for p in traced:
            print(f"trace: {p}")

    n_allowed = len(report.allowed)
    n_bad = len(report.findings) + len(report.errors) + len(traced or [])
    print(
        f"fastpath lint: {len(report.findings)} finding(s), "
        f"{len(report.errors)} allow error(s), {n_allowed} audited allow(s)"
        + (f", {len(traced)} trace violation(s)" if traced is not None else "")
    )

    out = summary_table(report, traced)
    if args.summary_file:
        with open(args.summary_file, "w") as fh:
            fh.write(out)
    if args.summary and os.environ.get("GITHUB_STEP_SUMMARY"):
        with open(os.environ["GITHUB_STEP_SUMMARY"], "a") as fh:
            fh.write(out)

    return 1 if n_bad else 0


if __name__ == "__main__":
    sys.exit(main())
