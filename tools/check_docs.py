"""Docs rot check: the fenced snippets in README.md, docs/serving.md and
docs/analysis.md must actually run, and the links between the markdown files
must resolve.

Docs that cannot break are docs nobody trusts, so CI executes them:

* every fenced ```python block is executed in a fresh namespace with
  ``src/`` on ``sys.path`` (``--compile-only`` downgrades to a syntax/
  compile check for fast local runs — the tier-1 test uses it; CI runs the
  real thing);
* every relative markdown link ``[text](path)`` must point at a file that
  exists (http(s) and pure-anchor links are skipped);
* ``git ls-files`` must not contain compiled bytecode (``.pyc`` /
  ``__pycache__``) — the tracked-bytecode regression this repo has already
  shipped once.

Run from anywhere: ``python tools/check_docs.py [--compile-only]``.
"""
from __future__ import annotations

import argparse
import re
import subprocess
import sys
from pathlib import Path
from typing import List, Tuple

REPO = Path(__file__).resolve().parent.parent
DOCS = [
    REPO / "README.md",
    REPO / "docs" / "serving.md",
    REPO / "docs" / "analysis.md",
]

FENCE = re.compile(r"^```python\s*$(.*?)^```\s*$", re.M | re.S)
# [text](target) — but not images ![..](..) and not inline code
LINK = re.compile(r"(?<!\!)\[[^\]]+\]\(([^)\s]+)\)")


def python_blocks(path: Path) -> List[Tuple[int, str]]:
    """(starting line, source) for every fenced ```python block."""
    text = path.read_text()
    out = []
    for m in FENCE.finditer(text):
        line = text[: m.start()].count("\n") + 2  # first line inside the fence
        out.append((line, m.group(1)))
    return out


def _rel(path: Path) -> Path:
    return path.relative_to(REPO) if path.is_relative_to(REPO) else path


def check_snippets(path: Path, *, compile_only: bool) -> List[str]:
    errors = []
    for line, src in python_blocks(path):
        name = f"{_rel(path)}:{line}"
        try:
            code = compile(src, name, "exec")
            if not compile_only:
                exec(code, {"__name__": f"doc_snippet_{line}"})  # noqa: S102
        except Exception as e:  # noqa: BLE001 — any failure is doc rot
            errors.append(f"{name}: snippet failed: {type(e).__name__}: {e}")
    return errors


def check_links(path: Path) -> List[str]:
    errors = []
    for m in LINK.finditer(path.read_text()):
        target = m.group(1).split("#")[0]
        if not target or target.startswith(("http://", "https://", "mailto:")):
            continue
        if not (path.parent / target).exists():
            errors.append(
                f"{_rel(path)}: broken relative link -> {m.group(1)}"
            )
    return errors


def check_no_tracked_bytecode() -> List[str]:
    files = subprocess.run(
        ["git", "ls-files"], cwd=REPO, capture_output=True, text=True, check=True
    ).stdout.splitlines()
    bad = [f for f in files if f.endswith(".pyc") or "__pycache__" in f]
    return [f"tracked bytecode: {f} (add to .gitignore and git rm --cached)" for f in bad]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--compile-only", action="store_true",
                    help="compile snippets without executing them (fast local "
                         "check; CI executes for real)")
    args = ap.parse_args(argv)

    sys.path.insert(0, str(REPO / "src"))
    errors: List[str] = []
    for doc in DOCS:
        if not doc.exists():
            errors.append(f"missing doc: {_rel(doc)}")
            continue
        n = len(python_blocks(doc))
        print(f"{_rel(doc)}: {n} python snippet(s), "
              f"{'compiling' if args.compile_only else 'executing'}")
        errors += check_snippets(doc, compile_only=args.compile_only)
        errors += check_links(doc)
    errors += check_no_tracked_bytecode()

    for e in errors:
        print(f"FAIL  {e}")
    if errors:
        print(f"{len(errors)} docs check(s) failed")
        return 1
    print("docs check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
