"""End-to-end training driver: a ~100M-parameter model for a few hundred steps.

This is the assignment's training-side E2E example: a real (not reduced)
granite-style decoder scaled to ~100M params, synthetic corpus, AdamW +
cosine, checkpointing every 50 steps, loss curve printed.  ~20-40 min on 1
CPU core at the default 200 steps; pass --steps 20 for a quick look.

Run: PYTHONPATH=src python examples/train_100m.py --steps 200
"""
import argparse
import dataclasses
import json
import time

import jax

from repro.configs import get_config
from repro.models import model as M
from repro.training import DataConfig, Trainer, TrainerConfig


def model_100m():
    base = get_config("granite-8b")
    return dataclasses.replace(
        base,
        name="granite-100m",
        n_layers=8,
        d_model=640,
        n_heads=10,
        n_kv_heads=2,
        d_head=64,
        d_ff=2560,
        vocab_size=49_152,
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/spad_train_100m")
    args = ap.parse_args()

    cfg = model_100m()
    n_params = sum(
        x.size for x in jax.tree.leaves(M.init_params(jax.random.PRNGKey(0), cfg))
    )
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                      global_batch=args.batch, seed=0)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=50,
                         ckpt_dir=args.ckpt_dir, base_lr=6e-4,
                         warmup=max(args.steps // 10, 5))
    tr = Trainer(cfg, dcfg, tcfg, seed=0)
    if tr.resume():
        print(f"resumed from step {tr.step}")
    t0 = time.time()
    tr.run()
    for h in tr.history[:: max(len(tr.history) // 20, 1)]:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  lr {h['lr']:.2e}")
    dt = time.time() - t0
    print(json.dumps({
        "params_m": round(n_params / 1e6, 1),
        "steps": tr.step,
        "final_loss": round(tr.history[-1]["loss"], 4),
        "tokens_per_s": round(args.batch * args.seq * len(tr.history) / dt, 1),
    }))


if __name__ == "__main__":
    main()
