"""Cluster provisioning + adaptive reallocation walkthrough (paper §4/§7).

Provisions a SPAD cluster for the coding workload, then demonstrates the
paper's longevity claim: the same hardware is logically reallocated when the
workload flips to conversation, and the sustainable rate is re-derived.

Run: PYTHONPATH=src python examples/provisioning.py [--rate 30]
"""
import argparse

from repro.configs import get_config
from repro.core import DECODE_CHIP, H100, PREFILL_CHIP, Parallelism
from repro.core.cluster import SLOS, ModelPerf
from repro.core.provision import best_realloc_split, max_rate, provision_disagg
from repro.core.trace import CODING, CONVERSATION


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate", type=float, default=30.0)
    ap.add_argument("--duration", type=float, default=30.0)
    args = ap.parse_args()

    bloom = get_config("bloom-176b")
    par = Parallelism(tp=8)
    h100 = ModelPerf(H100, bloom, par)
    p = ModelPerf(PREFILL_CHIP, bloom, par)
    d = ModelPerf(DECODE_CHIP, bloom, par)
    slo = SLOS["normal"]

    print(f"== provisioning for coding @ {args.rate} req/s ==")
    homo = provision_disagg(name="splitwise-homo", prefill_perf=h100, decode_perf=h100,
                            workload=CODING, rate=args.rate, slo=slo, ref_perf=h100,
                            duration=args.duration)
    spad = provision_disagg(name="spad", prefill_perf=p, decode_perf=d,
                            workload=CODING, rate=args.rate, slo=slo, ref_perf=h100,
                            duration=args.duration)
    print(f"homogeneous H100: {homo.describe()}  cost={homo.norm_cost:.1f}")
    print(f"SPAD            : {spad.describe()}  cost={spad.norm_cost:.1f} "
          f"({(1-spad.norm_cost/homo.norm_cost):.0%} cheaper)")

    n_p = spad.prefill[0].n
    n_d = spad.decode[0].n
    print(f"\n== workload flips to conversation: reallocate {n_p}P+{n_d}D ==")
    design, rate = best_realloc_split(
        name="realloc", perf_p_prefill=p, perf_p_decode=p,
        perf_d_prefill=d, perf_d_decode=d,
        n_p_machines=n_p, n_d_machines=n_d,
        workload=CONVERSATION, slo=slo, ref_perf=h100, duration=args.duration,
    )
    print(f"best reallocation: {design.describe()}")
    print(f"sustainable conversation rate: {rate:.0f} req/s "
          f"(no hardware purchased — the paper's longevity claim)")


if __name__ == "__main__":
    main()
