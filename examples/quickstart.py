"""Quickstart: the three layers of the repo in ~60 lines.

1. The paper's chip models: cost/TDP of the SPAD chips vs an H100.
2. The analytical cluster story: provision a small SPAD cluster for a trace.
3. The executable JAX layer: generate tokens through the disaggregated
   prefill/decode server on a reduced architecture.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

# ---- 1. chips (paper Table 3) --------------------------------------------
from repro.core import DECODE_CHIP, H100, PREFILL_CHIP
from repro.core.hardware import die_area_mm2, hw_cost, tdp_w

print("== SPAD chips vs H100 ==")
for chip in (PREFILL_CHIP, DECODE_CHIP, H100):
    print(
        f"{chip.name:12s} {chip.tensor_flops/1e15:5.2f} PFLOP/s "
        f"{chip.mem_bw/1e12:5.2f} TB/s  {die_area_mm2(chip):4.0f} mm^2  "
        f"${hw_cost(chip):6.0f}  {tdp_w(chip):4.0f} W"
    )

# ---- 2. provisioning (paper Table 4, miniature) ---------------------------
from repro.configs import get_config
from repro.core import Parallelism
from repro.core.cluster import SLOS, ModelPerf
from repro.core.provision import provision_disagg
from repro.core.trace import CONVERSATION

bloom = get_config("bloom-176b")
par = Parallelism(tp=8)
h100 = ModelPerf(H100, bloom, par)
design = provision_disagg(
    name="spad",
    prefill_perf=ModelPerf(PREFILL_CHIP, bloom, par),
    decode_perf=ModelPerf(DECODE_CHIP, bloom, par),
    workload=CONVERSATION,
    rate=20,
    slo=SLOS["normal"],
    ref_perf=h100,
    duration=20,
)
print(f"\n== provisioning (conversation @ 20 req/s) ==\n"
      f"SPAD design: {design.describe()}  "
      f"cost={design.norm_cost:.1f} H100-machines-equivalent, tdp={design.norm_tdp:.1f}")

# ---- 3. disaggregated serving (executable) --------------------------------
# Prefill batches same-bucket prompts; decode keeps its whole state (KV
# caches, tokens, positions, PRNG key) on device and runs fused multi-token
# blocks — the software twin of the paper's Prefill-Chip/Decode-Chip split.
from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import DecodeEngine, DisaggregatedServer, GenRequest, PrefillEngine

cfg = reduced(ARCHS["qwen1.5-4b"])
params = M.init_params(jax.random.PRNGKey(0), cfg)
server = DisaggregatedServer(
    [PrefillEngine(params, cfg)],
    [DecodeEngine(params, cfg, max_slots=4, max_len=128, decode_block=8)],
)
rng = np.random.default_rng(0)
for i in range(4):
    server.submit(GenRequest(i, rng.integers(0, cfg.vocab_size, size=16), max_new_tokens=8))
results = server.run()
print("\n== disaggregated generation (reduced qwen1.5-4b) ==")
for rid, toks in sorted(results.items()):
    print(f"request {rid}: {toks}")
