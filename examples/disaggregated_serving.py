"""End-to-end disaggregated serving driver (the paper's architecture, live).

Spins up 2 prefill engines + 2 decode engines on a reduced architecture,
replays a miniature Poisson trace through them, and reports TTFT / TBT
percentiles — the executable twin of the cluster simulator used for the
paper's Tables 4-8.

Run: PYTHONPATH=src python examples/disaggregated_serving.py [--arch X]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import DecodeEngine, DisaggregatedServer, GenRequest, PrefillEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=30.0, help="req/s arrival rate")
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--decode-block", type=int, default=4,
                    help="fused decode steps per host sync; tokens arrive in "
                         "blocks of this size, so TBT is measured per block")
    args = ap.parse_args()

    cfg = reduced(ARCHS[args.arch])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    server = DisaggregatedServer(
        [PrefillEngine(params, cfg) for _ in range(2)],
        [DecodeEngine(params, cfg, max_slots=4, max_len=256,
                      decode_block=args.decode_block, seed=i) for i in range(2)],
    )
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    t_start = time.perf_counter()
    ttft, tbt = {}, []

    submitted = 0
    first_token_seen = set()
    token_times = {}
    while True:
        now = time.perf_counter() - t_start
        while submitted < args.requests and arrivals[submitted] <= now:
            prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 48)))
            server.submit(GenRequest(submitted, prompt, max_new_tokens=args.max_new))
            token_times[submitted] = [arrivals[submitted]]
            submitted += 1
        before = {r.rid: len(r.tokens) for r in server.all_requests.values()}
        progressed = bool(server.queue or server.waiting or any(d.requests for d in server.decodes))
        if not progressed and submitted >= args.requests:
            break
        # one scheduling + decode round
        server.run_round()
        now = time.perf_counter() - t_start
        for r in server.all_requests.values():
            n_new = len(r.tokens) - before.get(r.rid, 0)
            if n_new > 0:
                if r.rid not in first_token_seen:
                    first_token_seen.add(r.rid)
                    ttft[r.rid] = now - arrivals[r.rid]
                for _ in range(n_new):
                    token_times[r.rid].append(now)
        if submitted < args.requests:
            time.sleep(max(0.0, arrivals[submitted] - (time.perf_counter() - t_start)))

    for rid, ts in token_times.items():
        tbt.extend(np.diff(ts[1:]))
    done = [r for r in server.all_requests.values() if r.done]
    print(f"arch={cfg.name} completed={len(done)}/{args.requests}")
    if ttft:
        print(f"TTFT  p50={np.percentile(list(ttft.values()), 50)*1e3:.0f}ms "
              f"p90={np.percentile(list(ttft.values()), 90)*1e3:.0f}ms")
    if tbt:
        print(f"TBT   p50={np.percentile(tbt, 50)*1e3:.0f}ms "
              f"p90={np.percentile(tbt, 90)*1e3:.0f}ms")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
