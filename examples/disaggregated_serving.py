"""End-to-end disaggregated serving driver (the paper's architecture, live).

Spins up 2 prefill engines + 2 decode engines on a reduced architecture,
replays a miniature Poisson trace through them, and reports TTFT / TBT
percentiles — the executable twin of the cluster simulator used for the
paper's Tables 4-8.

Run: PYTHONPATH=src python examples/disaggregated_serving.py [--arch X]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    PrefillEngine,
    make_scheduler,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=30.0, help="req/s arrival rate")
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--decode-block", type=int, default=4,
                    help="fused decode steps per host sync; tokens arrive in "
                         "blocks of this size, so TBT is measured per block")
    ap.add_argument("--scheduler", default="fcfs",
                    choices=["fcfs", "kv-aware", "priority"],
                    help="admission policy (kv-aware reorders by reserved-"
                         "page footprint; priority preempts via page swap)")
    ap.add_argument("--swap", action="store_true",
                    help="priority policy: preempt low-priority requests via "
                         "page-level swap (switches decode to the paged KV "
                         "cache)")
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="chunked prefill: prompts longer than this split "
                         "into page-aligned chunks whose KV streams into the "
                         "decode pool between other requests' prefills "
                         "(switches decode to the paged KV cache); must be a "
                         "multiple of 16, the page size")
    args = ap.parse_args()
    if args.swap and args.scheduler != "priority":
        ap.error("--swap requires --scheduler priority (only the priority "
                 "policy preempts)")
    if args.chunk_tokens is not None and args.chunk_tokens % 16:
        ap.error("--chunk-tokens must be a multiple of 16 (the page size)")

    cfg = reduced(ARCHS[args.arch])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    paged = args.swap or args.scheduler == "kv-aware" or args.chunk_tokens is not None
    server = DisaggregatedServer(
        [PrefillEngine(params, cfg, chunk_tokens=args.chunk_tokens)
         for _ in range(2)],
        [DecodeEngine(params, cfg, max_slots=4, max_len=256,
                      decode_block=args.decode_block, seed=i,
                      paged=paged, page_size=16) for i in range(2)],
        scheduler=make_scheduler(args.scheduler, swap=args.swap),
    )
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(1.0 / args.rate, args.requests))
    t_start = time.perf_counter()
    ttft, tbt = {}, []

    submitted = 0
    first_token_seen = set()
    token_times = {}
    while True:
        now = time.perf_counter() - t_start
        while submitted < args.requests and arrivals[submitted] <= now:
            prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 48)))
            prio = 1 if (args.scheduler == "priority" and submitted % 4 == 0) else 0
            server.submit(GenRequest(submitted, prompt, max_new_tokens=args.max_new,
                                     priority=prio))
            token_times[submitted] = [arrivals[submitted]]
            submitted += 1
        before = {r.rid: len(r.tokens) for r in server.all_requests.values()}
        # pending() also covers swapped-out (preempted) requests, which hold
        # no slot but are very much still in flight
        if not server.pending() and submitted >= args.requests:
            break
        # one scheduling + decode round
        server.run_round()
        now = time.perf_counter() - t_start
        for r in server.all_requests.values():
            n_new = len(r.tokens) - before.get(r.rid, 0)
            if n_new > 0:
                if r.rid not in first_token_seen:
                    first_token_seen.add(r.rid)
                    ttft[r.rid] = now - arrivals[r.rid]
                for _ in range(n_new):
                    token_times[r.rid].append(now)
        if submitted < args.requests:
            time.sleep(max(0.0, arrivals[submitted] - (time.perf_counter() - t_start)))

    for rid, ts in token_times.items():
        tbt.extend(np.diff(ts[1:]))
    done = [r for r in server.all_requests.values() if r.done]
    print(f"arch={cfg.name} completed={len(done)}/{args.requests}")
    if ttft:
        print(f"TTFT  p50={np.percentile(list(ttft.values()), 50)*1e3:.0f}ms "
              f"p90={np.percentile(list(ttft.values()), 90)*1e3:.0f}ms")
    if tbt:
        print(f"TBT   p50={np.percentile(tbt, 50)*1e3:.0f}ms "
              f"p90={np.percentile(tbt, 90)*1e3:.0f}ms")
    sched = server.scheduler
    waits = sorted(sched.queue_wait_rounds.values())
    if waits:
        print(f"sched={sched.name} queue-wait rounds "
              f"p50={np.percentile(waits, 50):.1f} "
              f"p90={np.percentile(waits, 90):.1f} "
              f"preemptions={sched.stats['preemptions']}")
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
