from .partitioning import (  # noqa: F401
    DEFAULT_RULES,
    constrain,
    resolve_spec,
    spec_tree,
    tree_shardings,
)
