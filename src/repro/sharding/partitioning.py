"""Logical-axis partitioner (t5x-style rules with divisibility fallbacks).

Every parameter / activation / cache tensor is annotated with a tuple of
*logical* axis names (one per dim).  ``resolve_spec`` maps those to a
``PartitionSpec`` for a concrete mesh using an ordered candidate list per
logical axis, assigning each mesh axis at most once per tensor and skipping
candidates whose size does not divide the dim (e.g. 4 KV heads on a 16-way
"model" axis fall through to sharding ``head_dim`` instead).
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Logical = Optional[str]
AxesTuple = Tuple[Logical, ...]

# Ordered mesh-axis candidates per logical axis.  Each candidate is a tuple of
# mesh axis names (sharded over their product).  Absent mesh axes are dropped
# from a candidate before use (so ("pod","data") degrades to ("data",) on a
# single-pod mesh).
DEFAULT_RULES: Dict[str, Sequence[Tuple[str, ...]]] = {
    # data-parallel / FSDP axes
    "batch": [("pod", "data")],
    "seq": [("pod", "data"), ("data",)],  # used for long-context KV sharding
    "embed": [("data",)],  # FSDP weight sharding
    # tensor-parallel axes
    "vocab": [("model",)],
    "heads": [("model",)],
    "kv_heads": [("model",)],
    "head_dim": [("model",)],
    "mlp": [("model",)],
    "expert": [("model",)],
    "q_lora": [("model",)],
    "kv_lora": [],  # replicated: small, contracted immediately
    "ssm_inner": [("model",)],
    "ssm_heads": [("model",)],
    "ssm_state": [],
    "conv_ch": [("model",)],
    # attention activation axes (constrained explicitly inside the layers)
    "q_groups": [("model",)],  # grouped-query dim of q after (KV, G) reshape
    "kv_seq": [],  # sequence dim of the KV cache during decode
    # never sharded
    "conv": [],  # depthwise-conv taps (size d_conv, tiny)
    "layers": [],
    "pattern": [],
    "pos": [],
    "capacity": [],
    "group": [("pod", "data")],  # MoE dispatch groups
}

# ---------------------------------------------------------------------------
# Profiles (the §Perf hillclimb lives here)
# ---------------------------------------------------------------------------

# Beyond-paper optimized rules for PREFILL / TRAIN steps:
#  * never shard head_dim — an indivisible kv_heads falling through to
#    head_dim is what forces GSPMD "involuntary full rematerialization"
#    (K/V get all-gathered inside every q-chunk iteration);
#  * indivisible head counts replicate instead (pair with TP head padding).
OPT_PREFILL_RULES: Dict[str, Sequence[Tuple[str, ...]]] = {
    **DEFAULT_RULES,
    "head_dim": [],
    "q_lora": [],
}

# Beyond-paper optimized rules for DECODE (serve) steps: split-K attention.
# The KV cache shards along *sequence* over the model axis so every chip
# streams 1/|model| of the cache (decode is bandwidth-bound — the paper's
# own Decode-Chip argument); q/scores replicate over heads (tiny), the
# softmax/AV reductions over the sharded seq dim are small all-reduces.
OPT_DECODE_RULES: Dict[str, Sequence[Tuple[str, ...]]] = {
    **DEFAULT_RULES,
    "head_dim": [],
    "q_lora": [],
    "kv_heads": [],
    "q_groups": [],
    "heads": [],
    "kv_seq": [("model",)],
    "seq": [("model",), ("data",)],
}

_ACTIVE_RULES: Dict[str, Sequence[Tuple[str, ...]]] = DEFAULT_RULES


def active_rules() -> Dict[str, Sequence[Tuple[str, ...]]]:
    return _ACTIVE_RULES


@contextmanager
def rules_profile(rules: Dict[str, Sequence[Tuple[str, ...]]]):
    """Activate a rules profile for code traced within (jit traces eagerly)."""
    global _ACTIVE_RULES
    prev = _ACTIVE_RULES
    _ACTIVE_RULES = rules
    try:
        yield
    finally:
        _ACTIVE_RULES = prev


def _present(candidate: Tuple[str, ...], mesh_axes: Dict[str, int]) -> Tuple[str, ...]:
    return tuple(a for a in candidate if a in mesh_axes)


def resolve_spec(
    axes: AxesTuple,
    shape: Tuple[int, ...],
    mesh: Mesh,
    rules: Optional[Dict[str, Sequence[Tuple[str, ...]]]] = None,
) -> P:
    """Map logical axes -> PartitionSpec with first-fit divisibility."""
    rules = rules or _ACTIVE_RULES
    mesh_axes = dict(mesh.shape)  # works for Mesh and AbstractMesh
    used: set = set()
    out = []
    assert len(axes) == len(shape), (axes, shape)
    for name, size in zip(axes, shape, strict=False):
        assigned = None
        if name is not None:
            for cand in rules.get(name, []):
                cand = _present(cand, mesh_axes)
                if not cand or any(a in used for a in cand):
                    continue
                total = math.prod(mesh_axes[a] for a in cand)
                if total > 1 and size % total == 0:
                    assigned = cand if len(cand) > 1 else cand[0]
                    used.update(cand)
                    break
        out.append(assigned)
    # trim trailing Nones for a tidy spec
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """Build a pytree of NamedShardings mirroring ``shape_tree``.

    ``axes_tree`` must have the same structure with AxesTuple leaves.
    ``shape_tree`` leaves must expose ``.shape``.
    """

    def _one(axes: AxesTuple, arr) -> NamedSharding:
        return NamedSharding(mesh, resolve_spec(tuple(axes), tuple(arr.shape), mesh, rules))

    return jax.tree.map(
        _one, axes_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        )
    )


def constrain(x, axes: AxesTuple, rules=None):
    """with_sharding_constraint by logical axes, using the ambient mesh and
    the active rules profile."""
    mesh = get_abstract_mesh_or_none()
    if mesh is None:
        return x
    spec = resolve_spec(tuple(axes), tuple(x.shape), mesh, rules or _ACTIVE_RULES)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def get_abstract_mesh_or_none():
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is None or m.empty:
            return None
        return m
    except Exception:
        return None


def spec_tree(axes_tree, shape_tree, mesh: Mesh, rules=None):
    """Like tree_shardings but returns raw PartitionSpecs."""

    def _one(axes, arr):
        return resolve_spec(tuple(axes), tuple(arr.shape), mesh, rules)

    return jax.tree.map(
        _one, axes_tree, shape_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x
        )
    )
