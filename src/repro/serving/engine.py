"""Serving engines: the device-resident fast path.

``PrefillEngine`` / ``DecodeEngine`` / ``DisaggregatedServer`` implement the
paper's serving architecture in JAX: prefill runs on one engine (in
production: a Prefill-Chip pod / mesh), the KV cache is handed off, and
decode proceeds with continuous batching on another engine (Decode-Chip
pod).  ``MonolithicEngine`` is the co-located baseline (same machine runs
both phases) used by tests and the quickstart example.

The hot path mirrors the paper's hardware story in software:

* **Decode is memory-bound** -> all decode state (KV caches, last tokens,
  positions, active mask, PRNG key) lives on device in one
  ``kvcache.DecodeState`` pytree.  The jitted step donates the state
  (``donate_argnums``) so the cache is updated in place — KV bytes are
  touched once per token instead of re-materialized — and a fused
  ``lax.scan`` over ``decode_block`` steps emits a ``[k, max_slots]`` token
  block so the host syncs once per block, not once per token.  EOS /
  max-token bookkeeping is applied on the host against the returned block.

* **Prefill is compute-bound** -> prompts are padded to power-of-two-ish
  length buckets (``_bucket``) with in-kernel masking via a ``true_len``
  argument threaded down to the attention / SSM mixers, and same-bucket
  requests are stacked into ``[B, S]`` batches (``prefill_batch``) so the
  compute side sees big tiles.  The jit cache is keyed per (bucket, batch)
  instead of per exact prompt length: compile count is bounded by the
  bucket list, not the workload.

Engines are deliberately synchronous and single-host here (the distributed
versions are built in ``repro/launch`` via jit+shardings over the production
mesh); the scheduling logic — slots, admission, continuous batching,
bucketed batched prefill — is the real thing.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M
from . import kvcache
from .sampling import SamplingParams, sample

DEFAULT_BUCKETS = (64, 128, 256, 512, 1024, 2048, 4096)


@dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # outputs
    tokens: List[int] = field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest bucket holding ``n``; raises past the largest bucket.

    The old next-power-of-two fallback silently minted a fresh jit key per
    oversized length (unbounded compile cache) and let prompts that cannot
    fit any decode slot fail only at admit time — servers now reject such
    prompts up front in ``submit()``."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"prompt length {n} exceeds the largest prefill bucket {buckets[-1]}; "
        f"extend `buckets` or reject the request at submit()"
    )


class SchedulerExhausted(RuntimeError):
    """``run(max_steps=...)`` ran out of scheduling rounds with work left.

    Carries what finished (``done``: rid -> tokens) and what did not
    (``unfinished``: rids still queued / waiting / decoding) instead of
    silently dropping in-flight requests.  Server state is left intact, so
    calling ``run()`` again resumes where it stopped."""

    def __init__(self, msg: str, done: Dict[int, List[int]], unfinished: List[int]):
        super().__init__(msg)
        self.done = done
        self.unfinished = unfinished


# ---------------------------------------------------------------------------
# Prefill engine
# ---------------------------------------------------------------------------


class PrefillEngine:
    """Runs prompt prefill: bucketed lengths, batched same-bucket requests.

    The jit cache (``_fns``) is keyed by (padded length, padded batch), so
    with bucketing on, compiles are bounded by the bucket list regardless of
    how many distinct prompt lengths the workload serves.  ``bucketed=False``
    restores the seed behaviour (one compile per exact prompt length) for
    benchmarking the difference.
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        sampling: SamplingParams = SamplingParams(),
        *,
        bucketed: bool = True,
        buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
    ):
        self.params = params
        self.cfg = cfg
        self.sampling = sampling
        self.bucketed = bucketed
        self.buckets = buckets
        self._fns: Dict[Tuple[int, int], Any] = {}  # (S_padded, B_padded) -> jitted

    @property
    def n_compiles(self) -> int:
        """Number of distinct (length, batch) shapes compiled so far."""
        return len(self._fns)

    def _pad_len(self, S: int) -> int:
        return _bucket(S, self.buckets) if self.bucketed else S

    def _fn(self, S: int, B: int):
        key = (S, B)
        if key not in self._fns:
            cfg, sampling = self.cfg, self.sampling

            def f(p, toks, tl, k):
                logits, caches, _ = M.prefill(p, toks, cfg, true_len=tl)
                return sample(logits, k, sampling), caches

            self._fns[key] = jax.jit(f)
        return self._fns[key]

    def prefill_batch(
        self, reqs: List[GenRequest], key, *, pad_to: Optional[int] = None
    ) -> Tuple[List[int], Any, List[int]]:
        """Prefill same-bucket requests stacked to [B, S_bucket].

        Returns (first_tokens, kv_batch, true_lens); ``kv_batch`` keeps the
        batch axis — admit slices per-request rows out on device
        (``kvcache.slice_request``).  ``pad_to`` right-pads the batch with
        dummy rows (true_len=0) so the jit cache sees one batch size per
        bucket.
        """
        true_lens = [len(r.prompt) for r in reqs]
        S = self._pad_len(max(true_lens))
        B = max(pad_to or len(reqs), len(reqs))
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, : true_lens[i]] = np.asarray(r.prompt, np.int32)
        tl = np.zeros((B,), np.int32)
        tl[: len(reqs)] = true_lens
        first, caches = self._fn(S, B)(
            self.params, jnp.asarray(toks), jnp.asarray(tl), key
        )
        first = np.asarray(first)
        return [int(first[i]) for i in range(len(reqs))], caches, true_lens

    def prefill(self, req: GenRequest, key) -> Tuple[int, Any, int]:
        """Single-request prefill.  Returns (first_token, kv_pack, true_len).

        In unbucketed (seed-compatibility) mode the prompt runs at its exact
        length with no masking, matching the seed engine bit for bit.
        """
        if not self.bucketed:
            S = len(req.prompt)
            toks = np.asarray(req.prompt, np.int32)[None, :]

            def f(p, t, k):
                logits, caches, _ = M.prefill(p, t, self.cfg)
                return sample(logits, k, self.sampling), caches

            # B=0 marks the maskless legacy closure (3 args) so it can never
            # collide with a (S, 1) prefill_batch entry (4 args)
            key_ = (S, 0)
            if key_ not in self._fns:
                self._fns[key_] = jax.jit(f)
            tok, caches = self._fns[key_](self.params, jnp.asarray(toks), key)
            return int(np.asarray(tok)[0]), caches, S
        firsts, caches, tls = self.prefill_batch([req], key)
        return firsts[0], caches, tls[0]


# ---------------------------------------------------------------------------
# Decode engine (continuous batching over slots, device-resident state)
# ---------------------------------------------------------------------------


class DecodeEngine:
    """Continuous-batching decode over ``max_slots`` cache rows.

    All per-step state is the device-resident ``kvcache.DecodeState``; the
    host keeps only request bookkeeping (``SlotState``, the request dict).
    ``step_block(k)`` runs k fused decode steps in one jitted ``lax.scan``
    (one dispatch, one host sync for the whole ``[k, max_slots]`` token
    block); the state argument is donated so the KV cache updates in place.
    ``decode_block=1, donate=False`` reproduces the seed engine's
    step-at-a-time, copy-per-step behaviour for benchmarking.

    The engine owns its sampling PRNG key (inside ``DecodeState``), split
    once per decode step — so token streams are bit-identical between
    ``step_block(k)`` and k calls of ``step_block(1)`` under a fixed seed.

    ``paged=True`` switches the KV cache to the paged layout
    (``kvcache.PagedDecodeState``): attention slabs become page pools shared
    across slots, each slot holds a block table, and pages are allocated on
    demand inside the fused decode scan by the device-resident allocator.
    Admission becomes KV-capacity aware: a request needs a free slot AND
    enough unreserved pages for its prompt plus a growth reservation
    (max_new_tokens + the decode-block overshoot margin), so ``max_slots``
    can exceed what slab HBM would allow and short requests no longer pin
    ``max_len`` positions each.  Token streams are bit-identical to the slab
    engine under a fixed seed (same math, same PRNG stream).
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        max_slots: int = 8,
        max_len: int = 512,
        sampling: SamplingParams = SamplingParams(),
        decode_block: int = 8,
        donate: bool = True,
        seed: int = 0,
        paged: bool = False,
        page_size: int = 16,
        n_pages: Optional[int] = None,
    ):
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.sampling = sampling
        self.decode_block = max(1, decode_block)
        self.donate = donate
        self.paged = paged
        self.slots = kvcache.SlotState(max_slots, max_len)
        # fold_in a tag so the decode sampling stream is never the same
        # threefry stream as a server/prefill PRNGKey(seed) chain
        key = jax.random.fold_in(jax.random.PRNGKey(seed), 1)
        if paged:
            if max_len % page_size:
                raise ValueError(f"max_len {max_len} not a multiple of page_size {page_size}")
            self.page_size = page_size
            self.pages_per_slot = max_len // page_size
            # default pool: the slab engine's HBM budget, in pages
            self.n_pages = n_pages if n_pages is not None else max_slots * self.pages_per_slot
            self._reserved = [0] * max_slots  # pages reserved per slot (host mirror)
            self.state: Any = kvcache.init_paged_decode_state(
                cfg, max_slots, max_len, page_size, self.n_pages, key
            )
        else:
            self.state = kvcache.init_decode_state(cfg, max_slots, max_len, key)
        self.requests: Dict[int, GenRequest] = {}
        self._block_fns: Dict[int, Any] = {}  # k -> jitted fused block
        self._admit_fns: Dict[Tuple[int, int], Any] = {}  # (L1, B) -> jitted admit
        self._release = self._jit(
            kvcache.paged_release if paged else self._release_impl
        )

    # -- jitted state transitions (all donate the DecodeState) --------------

    def _jit(self, f, donate_state_argnum: int = 0):
        if self.donate:
            return jax.jit(f, donate_argnums=(donate_state_argnum,))
        return jax.jit(f)

    @staticmethod
    def _release_impl(state: kvcache.DecodeState, keep) -> kvcache.DecodeState:
        """Deactivate all slots freed this block in one dispatch (keep [S] bool)."""
        return state._replace(active=state.active & keep)

    def _block_fn(self, k: int):
        if k not in self._block_fns:
            cfg, sampling, max_len = self.cfg, self.sampling, self.max_len

            if self.paged:
                ps, n_pg = self.page_size, self.pages_per_slot
                rows = jnp.arange(self.max_slots)

                def blk(params, state: kvcache.PagedDecodeState):
                    # On-demand page allocation, hoisted to block granularity:
                    # the k steps of this block write positions [pos, pos+k)
                    # per slot, so each slot crosses at most k // ps + 1 page
                    # boundaries — map those pages up front (the admission
                    # reservation guarantees free pages exist).  Still one
                    # dispatch, zero host syncs.
                    owner, bt = state.page_owner, state.block_tables
                    first = ((state.positions + ps - 1) // ps) * ps
                    for j in range(k // ps + 1):
                        b_pos = first + j * ps
                        need = state.active & (b_pos < state.positions + k) & (
                            b_pos < max_len
                        )
                        owner, new_pages = kvcache.alloc_decode_pages(owner, need)
                        # scatter fresh pages into the needing slots' table rows
                        # only; other rows aim at column n_pg and are dropped
                        cur = jnp.where(need, b_pos // ps, n_pg)
                        bt = bt.at[rows, cur].set(new_pages, mode="drop")

                    # Gather the slab-layout view of the pools ONCE, run the k
                    # steps against it (byte-for-byte the slab scan body, so
                    # per-step cost and token streams match the slab engine),
                    # then write the block's fresh positions back to the pool.
                    # The view is transient within this jitted block.
                    pos0 = state.positions
                    active = state.active
                    view = kvcache.paged_gather_view(state.caches, bt, cfg)

                    def one(carry, _):
                        view, tokens, positions, key = carry
                        key, sub = jax.random.split(key)
                        logits, view = M.decode_step(
                            params, tokens, view, positions, cfg
                        )
                        nxt = sample(logits, sub, sampling)
                        nxt = jnp.where(active, nxt, tokens)
                        # overshoot guard: stop advancing at max_len (see slab path)
                        positions = jnp.where(
                            active & (positions < max_len), positions + 1, positions
                        )
                        return (view, nxt, positions, key), nxt

                    (view, tokens, positions, key), toks = jax.lax.scan(
                        one, (view, state.tokens, pos0, state.key), None, length=k
                    )
                    caches = kvcache.paged_writeback(
                        state.caches, view, bt, pos0, k, cfg
                    )
                    return (
                        kvcache.PagedDecodeState(
                            caches, bt, owner, tokens, positions, active, key
                        ),
                        toks,  # [k, max_slots]
                    )
            else:

                def blk(params, state: kvcache.DecodeState):
                    def one(st: kvcache.DecodeState, _):
                        key, sub = jax.random.split(st.key)
                        logits, caches = M.decode_step(
                            params, st.tokens, st.caches, st.positions, cfg
                        )
                        nxt = sample(logits, sub, sampling)
                        # inactive slots keep emitting their old token (masked on host)
                        nxt = jnp.where(st.active, nxt, st.tokens)
                        # overshoot guard: a slot whose request finished mid-block
                        # stays active until the post-block release; freeze its
                        # position at max_len so the KV write (masked `== pos`)
                        # and the page lookup in the paged twin never run past
                        # the cache — no garbage writes, no unbounded positions
                        positions = jnp.where(
                            st.active & (st.positions < max_len),
                            st.positions + 1, st.positions,
                        )
                        return (
                            kvcache.DecodeState(caches, nxt, positions, st.active, key),
                            nxt,
                        )

                    state, toks = jax.lax.scan(one, state, None, length=k)
                    return state, toks  # toks [k, max_slots]

            self._block_fns[k] = self._jit(blk, donate_state_argnum=1)
        return self._block_fns[k]

    def _admit_fn(self, kv_pack):
        B = jax.tree.leaves(kv_pack)[0].shape[1]
        # the attention leaves' cache length identifies the bucket
        L1 = max(
            (a.shape[2] for a in jax.tree.leaves(kv_pack) if a.ndim >= 3), default=0
        )
        key = (L1, B)
        if key not in self._admit_fns:
            cfg = self.cfg

            if self.paged:
                ps = self.page_size

                def adm(state: kvcache.PagedDecodeState, kv, b, slot, token, pos):
                    single = kvcache.slice_request(kv, b)
                    return kvcache.paged_admit(
                        state, single, slot, token, pos, cfg, page_size=ps
                    )
            else:

                def adm(state: kvcache.DecodeState, kv, b, slot, token, pos):
                    single = kvcache.slice_request(kv, b)
                    caches = kvcache.insert_request(state.caches, single, slot, cfg)
                    return kvcache.DecodeState(
                        caches=caches,
                        tokens=state.tokens.at[slot].set(token),
                        positions=state.positions.at[slot].set(pos),
                        active=state.active.at[slot].set(True),
                        key=state.key,
                    )

            self._admit_fns[key] = self._jit(adm)
        return self._admit_fns[key]

    # -- admission capacity (KV-capacity-aware for the paged cache) ---------

    def _pages_needed(self, true_len: int, max_new_tokens: int) -> int:
        """Pages to reserve at admit: the prompt + every decode write the
        request can make, including up to ``decode_block - 1`` overshoot
        steps after it finishes mid-block, capped at ``max_len``."""
        cap = min(true_len + max_new_tokens + self.decode_block - 2, self.max_len)
        cap = max(cap, true_len)
        return -(-cap // self.page_size)

    @property
    def free_pages(self) -> int:
        """Unreserved pages (host mirror; only meaningful when paged)."""
        return self.n_pages - sum(self._reserved) if self.paged else 0

    def can_ever_admit(self, true_len: int, max_new_tokens: int) -> bool:
        """Whether this request could be admitted to an EMPTY engine."""
        if true_len + max_new_tokens > self.max_len:
            return False
        if self.paged and self._pages_needed(true_len, max_new_tokens) > self.n_pages:
            return False
        return True

    def can_admit(self, true_len: int, max_new_tokens: int) -> bool:
        """Whether admission would succeed right now: a free slot AND (paged)
        enough unreserved pages for prompt + growth reservation."""
        if not self.can_ever_admit(true_len, max_new_tokens):
            return False
        if self.slots.n_active >= self.max_slots:
            return False
        if self.paged and self._pages_needed(true_len, max_new_tokens) > self.free_pages:
            return False
        return True

    # -- public API ---------------------------------------------------------

    def admit(
        self,
        req: GenRequest,
        kv_pack,
        first_token: int,
        true_len: int,
        *,
        batch_index: int = 0,
    ) -> Optional[int]:
        """Insert a prefilled request into a free slot (the KV handoff).

        ``kv_pack`` may be a batched prefill pack; ``batch_index`` selects
        the row, sliced out on device inside the jitted admit.  Returns None
        when the engine is momentarily full (no slot, or — paged — not enough
        unreserved pages); raises when the request can never fit."""
        if true_len + req.max_new_tokens > self.max_len:
            raise ValueError(f"request {req.rid} needs {true_len + req.max_new_tokens} > max_len")
        if self.paged:
            need = self._pages_needed(true_len, req.max_new_tokens)
            if need > self.n_pages:
                raise ValueError(
                    f"request {req.rid} needs {need} pages > pool of {self.n_pages}"
                )
            if need > self.free_pages:
                return None
        slot = self.slots.alloc(req.rid)
        if slot is None:
            return None
        if self.paged:
            self._reserved[slot] = need
        self.state = self._admit_fn(kv_pack)(
            self.state,
            kv_pack,
            jnp.int32(batch_index),
            jnp.int32(slot),
            jnp.int32(first_token),
            jnp.int32(true_len),
        )
        self.slots.lengths[slot] = true_len
        self.requests[req.rid] = req
        req.tokens.append(first_token)
        return slot

    def _auto_block(self) -> int:
        rem = [
            req.max_new_tokens - len(req.tokens)
            for req in self.requests.values()
        ]
        return max(1, min(self.decode_block, max(rem, default=1)))

    def step_block(self, k: Optional[int] = None) -> List[Tuple[int, int]]:
        """Run ``k`` fused decode steps (default: auto-sized <= decode_block).

        One jitted dispatch, one host sync.  Returns the accepted
        (rid, token) pairs; EOS / max-token bookkeeping happens here on the
        host against the returned block, and finished slots are released on
        device afterwards."""
        if self.slots.n_active == 0:
            return []
        k = k if k is not None else self._auto_block()
        if self.paged and k > self.decode_block:
            # the page reservation only covers decode_block-1 overshoot steps
            raise ValueError(f"paged step_block k={k} > decode_block={self.decode_block}")
        self.state, toks = self._block_fn(k)(self.params, self.state)
        block = np.asarray(toks)  # [k, max_slots] — the one host sync
        out: List[Tuple[int, int]] = []
        freed: List[int] = []
        for slot, rid in enumerate(self.slots.request_ids):
            if rid is None:
                continue
            req = self.requests[rid]
            for j in range(k):
                tok = int(block[j, slot])
                req.tokens.append(tok)
                self.slots.lengths[slot] += 1
                out.append((rid, tok))
                if len(req.tokens) >= req.max_new_tokens or (
                    req.eos_id is not None and tok == req.eos_id
                ):
                    req.done = True
                    self.slots.free(slot)
                    freed.append(slot)
                    del self.requests[rid]
                    break
        if freed:
            keep = np.ones((self.max_slots,), bool)
            keep[freed] = False
            if self.paged:
                for s in freed:
                    self._reserved[s] = 0
            self.state = self._release(self.state, jnp.asarray(keep))
        return out

    def step(self) -> List[Tuple[int, int]]:
        """One decode iteration (seed-compatible granularity)."""
        return self.step_block(1)


# ---------------------------------------------------------------------------
# Disaggregated server (the paper's architecture)
# ---------------------------------------------------------------------------


class DisaggregatedServer:
    """Prefill pool -> KV handoff -> decode pool, continuous batching.

    Each scheduling round drains one same-bucket BATCH of queued prompts per
    round (greedy: the oldest request picks the bucket, then every queued
    request in that bucket joins up to ``max_prefill_batch``), admits
    waiting requests into decode slots, and runs one fused decode block per
    decode engine.

    ``transfer`` is the KV handoff hook: identity on single host; on a real
    cluster it is the pod-to-pod device transfer (see launch/serve.py).
    """

    def __init__(
        self,
        prefill_engines: List[PrefillEngine],
        decode_engines: List[DecodeEngine],
        *,
        transfer=lambda kv: kv,
        seed: int = 0,
        max_prefill_batch: int = 8,
    ):
        self.prefills = prefill_engines
        self.decodes = decode_engines
        self.transfer = transfer
        self.key = jax.random.PRNGKey(seed)
        self.max_prefill_batch = max(1, max_prefill_batch)
        self.queue: List[GenRequest] = []
        # (req, kv_batch, batch_index, first_token, true_len)
        self.waiting: List[Tuple[GenRequest, Any, int, int, int]] = []
        self.all_requests: Dict[int, GenRequest] = {}
        self.peak_active = 0  # max concurrent decode requests seen (for benchmarks)
        self._rr = 0

    def submit(self, req: GenRequest):
        """Queue a request, rejecting up front what the cluster can never
        serve: prompts past the largest prefill bucket (the old path minted an
        unbounded jit key per oversized length) and prompt+max_new combinations
        no decode engine has capacity for (the old path blew up only at admit)."""
        n = len(req.prompt)
        limits = [e.buckets[-1] for e in self.prefills if e.bucketed]
        if limits and n > min(limits):
            raise ValueError(
                f"request {req.rid}: prompt length {n} exceeds the largest "
                f"prefill bucket {min(limits)}"
            )
        if req.max_new_tokens > 1 and not any(
            d.can_ever_admit(n, req.max_new_tokens) for d in self.decodes
        ):
            cap = max(d.max_len for d in self.decodes)
            raise ValueError(
                f"request {req.rid}: prompt {n} + max_new_tokens "
                f"{req.max_new_tokens} exceeds every decode engine's capacity "
                f"(max_len {cap})"
            )
        self.queue.append(req)
        self.all_requests[req.rid] = req

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def _take_bucket_group(self, buckets) -> List[GenRequest]:
        """Pop the oldest request's bucket-mates (greedy same-bucket batch)."""
        want = _bucket(len(self.queue[0].prompt), buckets)
        group, rest = [], []
        for r in self.queue:
            if len(group) < self.max_prefill_batch and _bucket(len(r.prompt), buckets) == want:
                group.append(r)
            else:
                rest.append(r)
        self.queue = rest
        return group

    def _pending(self) -> bool:
        return bool(
            self.queue or self.waiting or any(d.requests for d in self.decodes)
        )

    def run_round(self):
        """One scheduling round: batched prefill, admit, fused decode blocks."""
        # 1) one same-bucket prefill batch per round (round-robin engines).
        # Gate on free decode capacity: each waiting entry pins its whole
        # padded batch pack on device, so prefilling ahead of slots the
        # decode pool can't absorb only accumulates dead KV buffers.
        free_slots = sum(d.max_slots - d.slots.n_active for d in self.decodes)
        if self.queue and len(self.waiting) < max(free_slots, 1):
            eng = self.prefills[self._rr % len(self.prefills)]
            self._rr += 1
            group = (
                self._take_bucket_group(eng.buckets)
                if eng.bucketed
                else [self.queue.pop(0)]
            )
            pad_to = self.max_prefill_batch if eng.bucketed else None
            toks, kvb, tls = eng.prefill_batch(group, self._next_key(), pad_to=pad_to)
            kvb = self.transfer(kvb)  # KV handoff (pod-to-pod in production)
            for i, req in enumerate(group):
                if req.max_new_tokens <= 1:
                    req.tokens.append(toks[i])
                    req.done = True
                else:
                    self.waiting.append((req, kvb, i, toks[i], tls[i]))
        # 2) admit waiting requests into decode engines with capacity (a free
        # slot and, for paged engines, enough unreserved KV pages) — most
        # spare capacity first
        still = []
        for req, kvb, bi, tok, true_len in self.waiting:
            cands = [
                d for d in self.decodes if d.can_admit(true_len, req.max_new_tokens)
            ]
            admitted = False
            if cands:
                dec = max(cands, key=lambda d: d.max_slots - d.slots.n_active)
                admitted = dec.admit(req, kvb, tok, true_len, batch_index=bi) is not None
            if not admitted:
                still.append((req, kvb, bi, tok, true_len))
        self.waiting = still
        self.peak_active = max(
            self.peak_active, sum(d.slots.n_active for d in self.decodes)
        )
        # 3) one fused decode block everywhere
        for dec in self.decodes:
            dec.step_block()

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive to completion.  Raises ``SchedulerExhausted`` (carrying the
        finished and unfinished request ids) if ``max_steps`` rounds pass with
        requests still in flight, instead of silently dropping them."""
        steps = 0
        while self._pending() and steps < max_steps:
            steps += 1
            self.run_round()
        if self._pending():
            done = {rid: r.tokens for rid, r in self.all_requests.items() if r.done}
            unfinished = sorted(
                rid for rid, r in self.all_requests.items() if not r.done
            )
            raise SchedulerExhausted(
                f"hit max_steps={max_steps} with {len(unfinished)} request(s) "
                f"unfinished: {unfinished[:8]}{'...' if len(unfinished) > 8 else ''}",
                done=done,
                unfinished=unfinished,
            )
        return {rid: r.tokens for rid, r in self.all_requests.items() if r.done}


class MonolithicEngine:
    """Co-located baseline: one engine interleaves prefill and decode."""

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int = 8, max_len: int = 512,
                 sampling: SamplingParams = SamplingParams(), seed: int = 0,
                 decode_block: int = 8, paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None):
        self.prefill = PrefillEngine(params, cfg, sampling)
        self.decode = DecodeEngine(params, cfg, max_slots=max_slots, max_len=max_len,
                                   sampling=sampling, seed=seed, decode_block=decode_block,
                                   paged=paged, page_size=page_size, n_pages=n_pages)
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[GenRequest] = []
        self.all_requests: Dict[int, GenRequest] = {}

    def submit(self, req: GenRequest):
        n = len(req.prompt)
        if self.prefill.bucketed and n > self.prefill.buckets[-1]:
            raise ValueError(
                f"request {req.rid}: prompt length {n} exceeds the largest "
                f"prefill bucket {self.prefill.buckets[-1]}"
            )
        if req.max_new_tokens > 1 and not self.decode.can_ever_admit(
            n, req.max_new_tokens
        ):
            raise ValueError(
                f"request {req.rid}: prompt {n} + max_new_tokens "
                f"{req.max_new_tokens} exceeds decode capacity (max_len "
                f"{self.decode.max_len})"
            )
        self.queue.append(req)
        self.all_requests[req.rid] = req

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or self.decode.requests) and steps < max_steps:
            steps += 1
            if self.queue:
                req = self.queue[0]
                if self.decode.can_admit(len(req.prompt), req.max_new_tokens) or (
                    req.max_new_tokens <= 1
                ):
                    self.queue.pop(0)
                    tok, kv, true_len = self.prefill.prefill(req, self._next_key())
                    if req.max_new_tokens <= 1:
                        req.tokens.append(tok)
                        req.done = True
                    else:
                        self.decode.admit(req, kv, tok, true_len)
            self.decode.step_block()
        if self.queue or self.decode.requests:
            done = {rid: r.tokens for rid, r in self.all_requests.items() if r.done}
            unfinished = sorted(
                rid for rid, r in self.all_requests.items() if not r.done
            )
            raise SchedulerExhausted(
                f"hit max_steps={max_steps} with {len(unfinished)} request(s) "
                f"unfinished: {unfinished[:8]}{'...' if len(unfinished) > 8 else ''}",
                done=done,
                unfinished=unfinished,
            )
        return {rid: r.tokens for rid, r in self.all_requests.items() if r.done}
