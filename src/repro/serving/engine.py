"""Serving engines: the device-resident fast path.

``PrefillEngine`` / ``DecodeEngine`` / ``DisaggregatedServer`` implement the
paper's serving architecture in JAX: prefill runs on one engine (in
production: a Prefill-Chip pod / mesh), the KV cache is handed off, and
decode proceeds with continuous batching on another engine (Decode-Chip
pod).  ``MonolithicEngine`` is the co-located baseline (same machine runs
both phases) used by tests and the quickstart example.

The hot path mirrors the paper's hardware story in software:

* **Decode is memory-bound** -> all decode state (KV caches, last tokens,
  positions, active mask, PRNG key) lives on device in one
  ``kvcache.DecodeState`` pytree.  The jitted step donates the state
  (``donate_argnums``) so the cache is updated in place — KV bytes are
  touched once per token instead of re-materialized — and a fused
  ``lax.scan`` over ``decode_block`` steps emits a ``[k, max_slots]`` token
  block so the host syncs once per block, not once per token.  EOS /
  max-token bookkeeping is applied on the host against the returned block.

* **Prefill is compute-bound** -> prompts are padded to power-of-two-ish
  length buckets (``_bucket``) with in-kernel masking via a ``true_len``
  argument threaded down to the attention / SSM mixers, and same-bucket
  requests are stacked into ``[B, S]`` batches (``prefill_batch``) so the
  compute side sees big tiles.  The jit cache is keyed per (bucket, batch)
  instead of per exact prompt length: compile count is bounded by the
  bucket list, not the workload.

Engines are deliberately synchronous and single-host here (the distributed
versions are built in ``repro/launch`` via jit+shardings over the production
mesh); the scheduling logic — slots, admission, continuous batching,
bucketed batched prefill — is the real thing.  Scheduling POLICY (queue
ordering, admission ordering, preemption) is pluggable: see
``serving.scheduler`` for the FCFS / KV-aware / priority policies and the
page-level swap machinery behind preemption.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M
from . import kvcache
from .config import DEFAULT_BUCKETS, EngineConfig  # noqa: F401  (re-export)
from .faults import FaultInjector, FaultPlan, TransientFault
from .prefix_cache import PrefixIndex, chunk_hashes
from .sampling import SamplingParams, sample
from .scheduler import FCFSScheduler, Scheduler, SwappedRequest, WaitingEntry

# terminal request statuses (GenRequest.status): every request submitted to a
# server ends in exactly one of these — results carry the status instead of
# an exception, so one failing request cannot take down a batch
STATUS_PENDING = "PENDING"      # not terminal: still moving through the system
STATUS_FINISHED = "FINISHED"    # completed normally (EOS / max_new_tokens)
STATUS_CANCELLED = "CANCELLED"  # caller cancelled (server.cancel)
STATUS_DEADLINE = "DEADLINE"    # missed its deadline_rounds / ttft_deadline
STATUS_FAILED = "FAILED"        # a faulted lifecycle seam burned its retries
STATUS_SHED = "SHED"            # load-shedding policy dropped it under overload
TERMINAL_STATUSES = frozenset(
    {STATUS_FINISHED, STATUS_CANCELLED, STATUS_DEADLINE, STATUS_FAILED,
     STATUS_SHED}
)


@dataclass
class GenRequest:
    """One generation request, mutated in place as it moves through the
    server: ``tokens`` accumulates the emitted stream (the prefill's first
    token included) and ``done`` flips when EOS or ``max_new_tokens`` is
    reached.  ``prompt`` is treated as immutable after ``submit()`` — the
    prefix-cache chunk hashes and the chunked-prefill cursor both memoize
    against it."""

    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # scheduling: higher wins under PriorityScheduler (FIFO within a class);
    # FCFS / KV-aware policies ignore it
    priority: int = 0
    # deadlines, in scheduling ROUNDS from submit (None = none): the server
    # cancels the request with terminal status DEADLINE once it has waited
    # `deadline_rounds` rounds without finishing, or `ttft_deadline` rounds
    # without emitting its first token
    deadline_rounds: Optional[int] = None
    ttft_deadline: Optional[int] = None
    # outputs
    tokens: List[int] = field(default_factory=list)
    done: bool = False
    # terminal status (see STATUS_*): PENDING while in flight; set exactly
    # once when `done` flips
    status: str = STATUS_PENDING


@dataclass
class PrefixMatch:
    """A prompt's hit against a decode engine's prefix index.

    pages     physical pool pages of the matched page-aligned prefix
    n_shared  == len(pages) logical pages covered
    hashes    chain hashes for ALL full prompt chunks (drives registration of
              the not-yet-cached chunks after admit)
    tail      True iff the kv_pack handed to ``admit`` holds ONLY the
              uncached tail (its first page is logical page n_shared).  It
              describes the PACK, not the model: ``match_prefix`` always
              returns False, and the scheduler flips it after actually
              running a tail-only prefill.  Passing a full-prompt pack with
              tail=True would scatter prompt-head K/V onto tail pages.
    """

    pages: List[int]
    n_shared: int
    hashes: List[bytes]
    tail: bool = False


@dataclass
class ChunkPrefillState:
    """Host-side cursor of one in-progress chunked prefill.

    The request stays IN THE SCHEDULER QUEUE between chunks (a resumable
    partial-prefill entry — policies see it in ``order`` and can interleave
    other work between its chunks); this object carries everything the next
    chunk needs:

    req           the request being prefilled chunk by chunk
    engine        the routed paged decode engine — fixed at chunk 0, since
                  the streamed pages are physical ids in ITS pool
    chunk_tokens  chunk quantum (page-aligned; from the prefill engine)
    pos           prompt tokens already computed (matched + appended pages,
                  always a page multiple until the final chunk)
    matched       physical pages taken from the prefix index at chunk 0
                  (prefix-cache skip: cached chunks are never recomputed);
                  pinned, not chunk-held — the index keeps them alive
    pages         pages appended so far, each holding one +1 "chunk hold"
                  ref (dropped after the final admit maps them)
    carry         hybrid models: the previous chunk's {conv, ssm} state per
                  mamba pattern position (device, B=1); None for attn-only
    hashes        full-prompt chunk hashes (admit-time registration of the
                  streamed pages; empty without a prefix cache)
    """

    req: GenRequest
    engine: "DecodeEngine"
    chunk_tokens: int
    pos: int = 0
    matched: List[int] = field(default_factory=list)
    pages: List[int] = field(default_factory=list)
    carry: Any = None
    hashes: List[bytes] = field(default_factory=list)

    @property
    def all_pages(self) -> List[int]:
        return self.matched + self.pages


def _bucket(n: int, buckets=DEFAULT_BUCKETS) -> int:
    """Smallest bucket holding ``n``; raises past the largest bucket.

    The old next-power-of-two fallback silently minted a fresh jit key per
    oversized length (unbounded compile cache) and let prompts that cannot
    fit any decode slot fail only at admit time — servers now reject such
    prompts up front in ``submit()``."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(
        f"prompt length {n} exceeds the largest prefill bucket {buckets[-1]}; "
        f"extend `buckets` or reject the request at submit()"
    )


@dataclass
class RequestOutcome:
    """Structured per-request result snapshot (``server.outcomes()``).

    status   terminal STATUS_* (or PENDING for a request still in flight)
    stage    where in the lifecycle the request sits right now: one of
             ``queued`` / ``chunking`` / ``waiting`` / ``decoding`` /
             ``swapped`` / ``done`` (terminal)
    tokens   the emitted stream so far (complete iff stage == "done")
    """

    rid: int
    status: str
    stage: str
    tokens: List[int]


class SchedulerExhausted(RuntimeError):
    """``run(max_steps=...)`` ran out of scheduling rounds with work left.

    Carries structured per-request outcomes instead of silently dropping
    in-flight requests: ``statuses`` maps every submitted rid to a
    ``RequestOutcome`` (status + lifecycle stage + tokens so far);
    ``done`` / ``unfinished`` are the legacy quick views (rid -> tokens for
    finished work, sorted unfinished rids).  Server state is left intact,
    so calling ``run()`` again resumes where it stopped."""

    def __init__(self, msg: str, done: Dict[int, List[int]], unfinished: List[int],
                 statuses: Optional[Dict[int, RequestOutcome]] = None):
        super().__init__(msg)
        self.done = done
        self.unfinished = unfinished
        self.statuses: Dict[int, RequestOutcome] = statuses or {}


class RequestHandle:
    """What ``submit()`` returns: follow ONE request without juggling its rid
    against ``outcomes()``.

    The handle is a thin view over the owner (a ``DisaggregatedServer`` or a
    ``serving.router.Router``) — it holds no state of its own beyond the rid,
    so handle-path and rid-path operations are the SAME code underneath
    (``cancel()`` delegates to ``owner.cancel(rid)``, ``status()`` reads the
    same request record ``outcomes()`` snapshots) and stay bit-exact with
    each other by construction.

    ``result()`` and ``stream()`` DRIVE the owner's scheduling rounds (the
    engines are synchronous); rounds are global, so driving through one
    handle advances every in-flight request.  ``stream()`` yields tokens as
    the per-round decode blocks land — the async per-token front door in
    ``serving.api`` is built on the same cursor logic.
    """

    __slots__ = ("rid", "_owner")

    def __init__(self, rid: int, owner):
        self.rid = rid
        self._owner = owner

    def __repr__(self) -> str:
        return f"RequestHandle(rid={self.rid}, status={self.status()!r})"

    @property
    def request(self) -> GenRequest:
        return self._owner.all_requests[self.rid]

    def status(self) -> str:
        """Current STATUS_* (terminal, or PENDING while in flight)."""
        req = self.request
        if req.done and req.status == STATUS_PENDING:
            return STATUS_FINISHED  # finished through a direct-engine path
        return req.status

    def done(self) -> bool:
        return self.request.done

    def tokens(self) -> List[int]:
        """The stream so far (complete iff ``done()``)."""
        return list(self.request.tokens)

    def outcome(self) -> RequestOutcome:
        """Structured snapshot, identical to ``owner.outcomes()[rid]``."""
        return RequestOutcome(
            rid=self.rid, status=self.status(),
            stage=self._owner._stage_of(self.rid), tokens=self.tokens(),
        )

    def cancel(self, *, status: str = STATUS_CANCELLED) -> bool:
        """Delegates to ``owner.cancel(rid)`` — bit-exact with the rid path."""
        return self._owner.cancel(self.rid, status=status)

    def result(self, max_rounds: int = 10_000) -> List[int]:
        """Drive rounds until THIS request is terminal; return its tokens.

        Raises ``SchedulerExhausted`` (same resume contract as ``run()``)
        if ``max_rounds`` pass first."""
        rounds = 0
        while not self.request.done and rounds < max_rounds:
            rounds += 1
            self._owner.run_round()
        req = self.request
        if not req.done:
            raise SchedulerExhausted(
                f"request {self.rid} still {self._owner._stage_of(self.rid)} "
                f"after {max_rounds} rounds",
                done={r: q.tokens for r, q in self._owner.all_requests.items()
                      if q.done},
                unfinished=sorted(r for r, q in self._owner.all_requests.items()
                                  if not q.done),
                statuses=self._owner.outcomes(),
            )
        return list(req.tokens)

    def stream(self, max_rounds: int = 10_000):
        """Per-token generator over the per-round decode blocks: drives one
        round whenever no unread token is buffered, yields each new token.
        Ends when the request reaches ANY terminal status (a cancelled /
        expired stream is truncated, not erased — check ``status()``).
        Tokens are read from the host-side request record (the sanctioned
        per-block readback already paid for them; no extra device sync)."""
        emitted, rounds = 0, 0
        req = self.request
        while True:
            while emitted < len(req.tokens):
                tok = req.tokens[emitted]
                emitted += 1
                yield tok
            if req.done:
                return
            if rounds >= max_rounds:
                raise SchedulerExhausted(
                    f"request {self.rid} stream stalled after {max_rounds} rounds",
                    done={r: q.tokens for r, q in self._owner.all_requests.items()
                          if q.done},
                    unfinished=sorted(
                        r for r, q in self._owner.all_requests.items() if not q.done
                    ),
                    statuses=self._owner.outcomes(),
                )
            rounds += 1
            self._owner.run_round()


# ---------------------------------------------------------------------------
# Prefill engine
# ---------------------------------------------------------------------------


class PrefillEngine:
    """Runs prompt prefill: bucketed lengths, batched same-bucket requests.

    The jit cache (``_fns``) is keyed by (padded length, padded batch), so
    with bucketing on, compiles are bounded by the bucket list regardless of
    how many distinct prompt lengths the workload serves.  ``bucketed=False``
    restores the seed behaviour (one compile per exact prompt length) for
    benchmarking the difference.

    ``chunk_tokens`` enables **chunked prefill** (Sarathi-style): the server
    splits prompts longer than this threshold into successive
    ``prefill_chunk`` calls — each attending [all previously appended KV ‖
    current chunk] at absolute positions through the prefix-offset path, so
    chunk *i* is bit-identical to the same slice of a monolithic prefill —
    and streams each chunk's K/V into a paged decode engine's pool
    (``DecodeEngine.append_chunk``) instead of holding the whole prompt's
    cache until admit.  Must be a multiple of the target engine's page size
    (chunk boundaries are page-aligned) and, for hybrid models, of the SSM
    chunk size (so the carried conv/SSD state resumes on an internal scan
    boundary and stays bit-exact).  ``None`` (default) keeps prefill
    monolithic.
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        sampling: Optional[SamplingParams] = None,
        *,
        config: Optional[EngineConfig] = None,
        bucketed: bool = True,
        buckets: Tuple[int, ...] = DEFAULT_BUCKETS,
        chunk_tokens: Optional[int] = None,
    ):
        # ``config`` is the canonical constructor path; the loose kwargs are
        # a compatibility shim (deprecated — new call sites should pass an
        # EngineConfig; router/api layers accept only the config object)
        if config is not None:
            pa = config.prefill_args()
            sampling = pa["sampling"]
            bucketed = pa["bucketed"]
            buckets = pa["buckets"]
            chunk_tokens = pa["chunk_tokens"]
        self.params = params
        self.cfg = cfg
        self.sampling = sampling if sampling is not None else SamplingParams()
        self.bucketed = bucketed
        self.buckets = buckets
        if chunk_tokens is not None:
            if chunk_tokens <= 0:
                raise ValueError(f"chunk_tokens must be positive, got {chunk_tokens}")
            if cfg.ssm is not None and any(
                m == "mamba" for m, _ in cfg.block_pattern
            ) and chunk_tokens % cfg.ssm.chunk_size:
                raise ValueError(
                    f"chunk_tokens {chunk_tokens} must be a multiple of the SSM "
                    f"chunk size {cfg.ssm.chunk_size}: the carried SSD state is "
                    f"bit-exact only when chunk boundaries land on internal "
                    f"scan-chunk boundaries"
                )
        self.chunk_tokens = chunk_tokens
        # observability for benchmarks: the largest single prefill dispatch
        # (padded tokens) bounds how long anything can be stuck behind one
        # prefill call — the head-of-line quantum chunking exists to shrink
        self.stats = {"calls": 0, "max_call_tokens": 0, "chunk_calls": 0}
        self._fns: Dict[Tuple[int, int], Any] = {}  # (S_padded, B_padded) -> jitted

    @property
    def n_compiles(self) -> int:
        """Number of distinct (length, batch) shapes compiled so far."""
        return len(self._fns)

    def _pad_len(self, S: int) -> int:
        return _bucket(S, self.buckets) if self.bucketed else S

    def _fn(self, S: int, B: int):
        key = (S, B)
        if key not in self._fns:
            cfg, sampling = self.cfg, self.sampling

            def f(p, toks, tl, k):
                logits, caches, _ = M.prefill(p, toks, cfg, true_len=tl)
                return sample(logits, k, sampling), caches

            self._fns[key] = jax.jit(f)
        return self._fns[key]

    def prefill_batch(
        self, reqs: List[GenRequest], key, *, pad_to: Optional[int] = None,
        prefix=None,
    ) -> Tuple[List[int], Any, List[int]]:
        """Prefill same-bucket requests stacked to [B, S_bucket].

        Returns (first_tokens, kv_batch, true_lens); ``kv_batch`` keeps the
        batch axis — admit slices per-request rows out on device
        (``kvcache.slice_request``).  ``pad_to`` right-pads the batch with
        dummy rows (true_len=0) so the jit cache sees one batch size per
        bucket.

        ``prefix`` = (prefix_pack, shared_lens) switches to prefix-offset
        (tail-only) prefill: row i runs only ``prompt[shared_lens[i]:]`` at
        absolute positions ``shared_lens[i] + j``, attending the cached
        prefix K/V in ``prefix_pack`` ([R, B, Lp, ...] attn leaves, gathered
        from a paged decode engine's pool).  ``shared_lens`` are page-chunk
        aligned and always leave >= 1 tail token (the logits position must be
        recomputed).  The returned ``true_lens`` are still the FULL prompt
        lengths (admit positions); the kv pack covers the tail only.
        """
        if prefix is None:
            shared_lens = [0] * len(reqs)
        else:
            _, shared_lens = prefix
        full_lens = [len(r.prompt) for r in reqs]
        tails = [n - s for n, s in zip(full_lens, shared_lens, strict=False)]
        S = self._pad_len(max(tails))
        B = max(pad_to or len(reqs), len(reqs))
        toks = np.zeros((B, S), np.int32)
        for i, r in enumerate(reqs):
            # fastpath: allow[FP001] host prompt coercion (numpy in, no device readback)
            toks[i, : tails[i]] = np.asarray(r.prompt[shared_lens[i] :], np.int32)
        tl = np.zeros((B,), np.int32)
        tl[: len(reqs)] = tails
        self.stats["calls"] += 1
        self.stats["max_call_tokens"] = max(self.stats["max_call_tokens"], S)
        if prefix is None:
            first, caches = self._fn(S, B)(
                self.params, jnp.asarray(toks), jnp.asarray(tl), key
            )
        else:
            pack = prefix[0]
            Lp = self._pack_len(pack)
            plen = np.zeros((B,), np.int32)
            plen[: len(reqs)] = shared_lens
            first, caches = self._prefix_fn(S, B, Lp)(
                self.params, jnp.asarray(toks), jnp.asarray(tl), key,
                pack, jnp.asarray(plen),
            )
        first = np.asarray(first)  # fastpath: allow[FP001] first-token readback, once per prefill batch
        return [int(first[i]) for i in range(len(reqs))], caches, full_lens

    def _pack_len(self, pack) -> int:
        """Prefix length (positions) of a prefix-KV pack: the seq axis of the
        ATTENTION entries only.  Mamba entries — present when a chunked
        hybrid carries {conv, ssm} state — have fixed-size leaves whose dim 2
        is unrelated to sequence length and must not key the jit cache."""
        Lp = 0
        for i, (mixer, _) in enumerate(self.cfg.block_pattern):
            if mixer == "attn" and pack[i] is not None:
                Lp = max(
                    Lp, max(a.shape[2] for a in jax.tree.leaves(pack[i]))
                )
        return Lp

    def _prefix_fn(self, S: int, B: int, Lp: int):
        key = (S, B, Lp)
        if key not in self._fns:
            cfg, sampling = self.cfg, self.sampling

            def f(p, toks, tl, k, pkv, plen):
                logits, caches, _ = M.prefill(
                    p, toks, cfg, true_len=tl, prefix_kv=pkv, prefix_len=plen
                )
                return sample(logits, k, sampling), caches

            self._fns[key] = jax.jit(f)
        return self._fns[key]

    def prefill_chunk(
        self, req: GenRequest, key, *, pos: int, n_tokens: int, prefix=None,
        pad_to: Optional[int] = None,
    ) -> Tuple[int, Any]:
        """Prefill tokens [pos, pos + n_tokens) of ``req``'s prompt.

        ``prefix`` = (prefix_pack, mamba carry aside) is the same
        (pack, shared_lens) pair ``prefill_batch`` takes: the pack holds the
        K/V of everything already appended (gathered from the target decode
        engine's pool, trash-padded past ``pos``) plus, for hybrid models,
        the carried conv/SSD state from the previous chunk.  Runs through the
        prefix-offset path at absolute positions, so the chunk's outputs —
        including the final chunk's first-token logits — are bit-identical to
        the same slice of a monolithic prefill.  Returns
        (sampled_token, kv_pack); the token is meaningful only for the FINAL
        chunk (intermediate callers pass a dummy key and discard it), the
        kv_pack covers this chunk only (mamba entries: the carry after it).

        ``pad_to`` batch-pads the call.  The server passes its
        ``max_prefill_batch`` for the FINAL chunk only: sampled tokens depend
        on the batch shape (one categorical draw covers the padded batch), so
        the first token is bit-identical to a monolithic prefill exactly when
        both run at the same row and padding — intermediate chunks discard
        their token and stay at B=1.
        """
        sub = GenRequest(
            # fastpath: allow[FP001] host prompt slice (numpy in, no device readback)
            req.rid, np.asarray(req.prompt[: pos + n_tokens], np.int32),
            req.max_new_tokens,
        )
        self.stats["chunk_calls"] += 1
        toks, kvb, _ = self.prefill_batch(
            [sub], key, pad_to=pad_to,
            prefix=None if prefix is None else (prefix, [pos]),
        )
        return toks[0], kvb

    def prefill_chunk_group(
        self, items: List[Tuple[GenRequest, int]], n_tokens: int, key, *,
        prefix=None, pad_to: Optional[int] = None,
    ) -> Any:
        """Prefill ONE ``n_tokens`` chunk for EACH of several chunked
        requests in a single batched dispatch (unified batching).

        ``items`` = [(req, pos)]: row i runs ``req.prompt[pos, pos +
        n_tokens)`` at absolute positions against its own streamed-prefix
        row of ``prefix`` — the per-row ``shared_lens`` machinery
        ``prefill_batch`` already has for prefix-matched groups.  Every row
        is a NON-final chunk by contract (the final chunk's first-token
        sample must replay the serial pad/key schedule bit for bit, so
        finals never ride), hence the sampled tokens are discarded and the
        caller passes the fixed dummy chunk key.  Returns the kv pack
        (batch axis = padded rows; the caller appends row i via
        ``append_chunk(..., batch_index=i)``)."""
        subs = [
            GenRequest(
                # fastpath: allow[FP001] host prompt slice (numpy in, no device readback)
                r.rid, np.asarray(r.prompt[: pos + n_tokens], np.int32),
                r.max_new_tokens,
            )
            for r, pos in items
        ]
        self.stats["chunk_calls"] += 1
        self.stats["chunk_rows"] = self.stats.get("chunk_rows", 0) + len(items)
        _, kvb, _ = self.prefill_batch(
            subs, key, pad_to=pad_to,
            prefix=None if prefix is None else (prefix, [pos for _, pos in items]),
        )
        return kvb

    def prefill(self, req: GenRequest, key) -> Tuple[int, Any, int]:
        """Single-request prefill.  Returns (first_token, kv_pack, true_len).

        In unbucketed (seed-compatibility) mode the prompt runs at its exact
        length with no masking, matching the seed engine bit for bit.
        """
        if not self.bucketed:
            S = len(req.prompt)
            toks = np.asarray(req.prompt, np.int32)[None, :]  # fastpath: allow[FP001] host prompt coercion

            def f(p, t, k):
                logits, caches, _ = M.prefill(p, t, self.cfg)
                return sample(logits, k, self.sampling), caches

            # B=0 marks the maskless legacy closure (3 args) so it can never
            # collide with a (S, 1) prefill_batch entry (4 args)
            key_ = (S, 0)
            if key_ not in self._fns:
                # fastpath: allow[FP003] seed-compat mode deliberately compiles per exact length
                self._fns[key_] = jax.jit(f)
            tok, caches = self._fns[key_](self.params, jnp.asarray(toks), key)
            # fastpath: allow[FP001] first-token readback (once per prefill, seed-compat path)
            return int(np.asarray(tok)[0]), caches, S
        firsts, caches, tls = self.prefill_batch([req], key)
        return firsts[0], caches, tls[0]


# ---------------------------------------------------------------------------
# Decode engine (continuous batching over slots, device-resident state)
# ---------------------------------------------------------------------------


class DecodeEngine:
    """Continuous-batching decode over ``max_slots`` cache rows.

    All per-step state is the device-resident ``kvcache.DecodeState``; the
    host keeps only request bookkeeping (``SlotState``, the request dict).
    ``step_block(k)`` runs k fused decode steps in one jitted ``lax.scan``
    (one dispatch, one host sync for the whole ``[k, max_slots]`` token
    block); the state argument is donated so the KV cache updates in place.
    ``decode_block=1, donate=False`` reproduces the seed engine's
    step-at-a-time, copy-per-step behaviour for benchmarking.

    The engine owns its sampling PRNG key (inside ``DecodeState``), split
    once per decode step — so token streams are bit-identical between
    ``step_block(k)`` and k calls of ``step_block(1)`` under a fixed seed.

    ``paged=True`` switches the KV cache to the paged layout
    (``kvcache.PagedDecodeState``): attention slabs become page pools shared
    across slots, each slot holds a block table, and pages are allocated on
    demand inside the fused decode scan by the device-resident refcounted
    allocator.  Admission becomes KV-capacity aware: a request needs a free
    slot AND enough unreserved pages for its prompt plus a growth reservation
    (max_new_tokens + the decode-block overshoot margin), so ``max_slots``
    can exceed what slab HBM would allow and short requests no longer pin
    ``max_len`` positions each.  Token streams are bit-identical to the slab
    engine under a fixed seed (same math, same PRNG stream).

    ``prefix_cache=True`` (paged only) adds refcounted prefix sharing: prompt
    pages are registered in a host-side chained-hash index
    (``prefix_cache.PrefixIndex``) holding a +1 device refcount per cached
    page, and a request whose prompt shares a page-aligned prefix with a
    cached one maps the cached physical pages into its block table instead of
    recomputing and rewriting them — the reservation then counts only the NEW
    pages (tail + growth), prefill runs only on the uncached tail (attention-
    only models; hybrids recompute but still share pages), and the fused
    decode block performs copy-on-write before writing any page with
    ``refs > 1``.  Streams stay bit-identical to the unshared paged engine.
    ``fork()`` clones a live request into a new slot at zero KV cost
    (best-of-n); the branches diverge through COW.
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        config: Optional[EngineConfig] = None,
        max_slots: int = 8,
        max_len: int = 512,
        sampling: Optional[SamplingParams] = None,
        decode_block: int = 8,
        donate: bool = True,
        seed: int = 0,
        paged: bool = False,
        page_size: int = 16,
        n_pages: Optional[int] = None,
        prefix_cache: bool = False,
        kv_dtype: str = "fp32",
    ):
        # ``config`` is the canonical constructor path; the loose kwargs are
        # a compatibility shim (deprecated — new call sites should pass an
        # EngineConfig; router/api layers accept only the config object)
        if config is not None:
            da = config.decode_args()
            max_slots, max_len = da["max_slots"], da["max_len"]
            sampling, decode_block = da["sampling"], da["decode_block"]
            donate, seed, paged = da["donate"], da["seed"], da["paged"]
            page_size, n_pages = da["page_size"], da["n_pages"]
            prefix_cache = da["prefix_cache"]
            kv_dtype = da["kv_dtype"]
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.sampling = sampling if sampling is not None else SamplingParams()
        self.decode_block = max(1, decode_block)
        self.donate = donate
        self.paged = paged
        self.prefix_cache = bool(paged and prefix_cache)
        if kv_dtype not in kvcache.KV_DTYPES:
            raise ValueError(f"kv_dtype must be one of {kvcache.KV_DTYPES}, got {kv_dtype!r}")
        if kv_dtype != "fp32" and not paged:
            raise ValueError("kv_dtype='int8' requires paged=True")
        self.kv_dtype = kv_dtype
        # fault injection (tests/chaos benches): the owning server shares its
        # FaultInjector here; None = every lifecycle seam succeeds normally
        self.faults: Optional[FaultInjector] = None
        self.slots = kvcache.SlotState(max_slots, max_len)
        # fold_in a tag so the decode sampling stream is never the same
        # threefry stream as a server/prefill PRNGKey(seed) chain
        key = jax.random.fold_in(jax.random.PRNGKey(seed), 1)
        if paged:
            if max_len % page_size:
                raise ValueError(f"max_len {max_len} not a multiple of page_size {page_size}")
            self.page_size = page_size
            self.pages_per_slot = max_len // page_size
            # default pool: the slab engine's HBM budget, in pages
            self.n_pages = n_pages if n_pages is not None else max_slots * self.pages_per_slot
            # host mirrors for the refcounted allocator: _href mirrors the
            # device refcounts of ADMIT-TIME pages (slot holds + cache holds;
            # decode-time growth/COW allocations are covered by _growth);
            # page truth stays on device in state.page_refs.
            self._href = np.zeros(self.n_pages, np.int64)
            self._growth = [0] * max_slots  # outstanding decode-time allocation allowance
            self._slot_new = [0] * max_slots  # non-shared pages mapped at admit
            self._slot_pages: List[List[int]] = [[] for _ in range(max_slots)]
            # admits whose page-id readback is deferred to the next natural
            # host sync: (slot, n_need) pairs plus the synchronous count of
            # their fresh pages (keeps ``free_pages`` exact without a sync)
            self._pending_admits: List[Tuple[int, int]] = []
            self._pending_fresh = 0
            # device-resident constants for the plain (unshared) admit: the
            # shared-page plumbing degenerates to fixed arrays there, and
            # re-uploading them per admit costs more than the admit compute
            self._plain_shared = jnp.full((self.pages_per_slot,), self.n_pages,
                                          jnp.int32)
            self._plain_regmask = jnp.zeros((self.pages_per_slot,), bool)
            self._zero_i32 = jnp.int32(0)
            self._tail_ok = all(m == "attn" for m, _ in cfg.block_pattern)
            self._is_hybrid = any(m == "mamba" for m, _ in cfg.block_pattern)
            self.prefix: Optional[PrefixIndex] = (
                PrefixIndex(page_size) if self.prefix_cache else None
            )
            self._pins: Dict[int, List[int]] = {}  # rid -> pinned prefix pages
            # page -> in-flight chunk-hold count (audit's third refcount
            # term; _href already mirrors these for capacity math)
            self._chunk_holds: Dict[int, int] = {}
            self._gather_fns: Dict[Tuple[int, int], Any] = {}
            self._append_fns: Dict[Tuple[int, int, int], Any] = {}  # (L1, B, n_alloc)
            self._fork_fn = None
            # flips permanently on the first fork(): from then on decode
            # blocks must carry the copy-on-write machinery (new jit keys)
            self._fork_used = False
            # admission stats: per-request entries live only while the
            # request does (pruned at release — a long-running server must
            # not grow without bound); `stats` keeps the cumulative totals
            # benchmarks read after a workload drains
            self.admit_new_pages: Dict[int, int] = {}
            self.admit_shared_pages: Dict[int, int] = {}
            self.stats = {"admits": 0, "new_pages": 0, "shared_pages": 0}
            self.state: Any = kvcache.init_paged_decode_state(
                cfg, max_slots, max_len, page_size, self.n_pages, key,
                kv_dtype=kv_dtype,
            )
        else:
            self.state = kvcache.init_decode_state(cfg, max_slots, max_len, key)
        self.requests: Dict[int, GenRequest] = {}
        self._block_fns: Dict[int, Any] = {}  # k -> jitted fused block
        self._admit_fns: Dict[Tuple[int, int], Any] = {}  # (L1, B) -> jitted admit
        self._release = self._jit(
            kvcache.paged_release if paged else self._release_impl
        )

    # -- jitted state transitions (all donate the DecodeState) --------------

    def _jit(self, f, donate_state_argnum: int = 0):
        if self.donate:
            return jax.jit(f, donate_argnums=(donate_state_argnum,))
        return jax.jit(f)

    @staticmethod
    def _release_impl(state: kvcache.DecodeState, keep) -> kvcache.DecodeState:
        """Deactivate all slots freed this block in one dispatch (keep [S] bool)."""
        return state._replace(active=state.active & keep)

    def _block_fn(self, k: int, n_pg_eff: Optional[int] = None):
        # paged jit keys are (k, n_pg_eff): k <= decode_block and n_pg_eff is
        # a power-of-two page bucket (see step_block), so the cache stays
        # bounded by decode_block * log2(pages_per_slot) entries, never by
        # exact sequence lengths
        if self.paged:
            n_eff = n_pg_eff if n_pg_eff is not None else self.pages_per_slot
            # COW machinery is only needed when two holders can share a
            # page a decode step writes: a prefix index, or fork() clones.
            # Chunk holds never need it (the hold and the slot belong to
            # the SAME request; an in-place tail write is what it wants),
            # so plain paged serving compiles a leaner block.
            cow = self.prefix_cache or self._fork_used
            fn_key: Any = (k, n_eff, cow)
        else:
            n_eff = 0
            cow = False
            fn_key = k
        if fn_key not in self._block_fns:
            cfg, sampling, max_len = self.cfg, self.sampling, self.max_len

            if self.paged:
                ps, n_pg = self.page_size, self.pages_per_slot
                rows = jnp.arange(self.max_slots)

                def blk(params, state: kvcache.PagedDecodeState):
                    pos0 = state.positions
                    active = state.active
                    # Copy-on-write first: any page this block will write
                    # (positions [pos0, pos0+k) of a writing slot) that is
                    # shared (refs > 1) gets a fresh page; the writer's table
                    # entry is redirected, the shared count decremented, and
                    # the shared page's BYTES copied onto the fresh page —
                    # the view-free scan below reads pages directly, so the
                    # prefix must already live on the copy.
                    will_write = active & (pos0 < max_len)
                    scales = state.scales
                    if cow:
                        if scales is not None:
                            refs, bt, caches, scales = kvcache.cow_redirect(
                                state.page_refs, state.block_tables, pos0,
                                will_write, k, ps, caches=state.caches, cfg=cfg,
                                scales=scales,
                            )
                        else:
                            refs, bt, caches = kvcache.cow_redirect(
                                state.page_refs, state.block_tables, pos0,
                                will_write, k, ps, caches=state.caches, cfg=cfg,
                            )
                    else:
                        refs, bt, caches = (
                            state.page_refs, state.block_tables, state.caches
                        )
                    # On-demand page allocation, hoisted to block granularity:
                    # the k steps of this block write positions [pos, pos+k)
                    # per slot, so each slot crosses at most k // ps + 1 page
                    # boundaries — map those pages up front (the admission
                    # reservation guarantees free pages exist).  Still one
                    # dispatch, zero host syncs.
                    first = ((pos0 + ps - 1) // ps) * ps
                    for j in range(k // ps + 1):
                        b_pos = first + j * ps
                        need = active & (b_pos < pos0 + k) & (b_pos < max_len)
                        refs, new_pages = kvcache.alloc_decode_pages(refs, need)
                        # scatter fresh pages into the needing slots' table rows
                        # only; other rows aim at column n_pg and are dropped
                        cur = jnp.where(need, b_pos // ps, n_pg)
                        bt = bt.at[rows, cur].set(new_pages, mode="drop")

                    # View-free scan: decode_step reads K/V straight off the
                    # page pools through the POST-COW tables and scatters the
                    # fresh token into (page, offset) — no transient
                    # slab-sized view, no whole-page writeback.  bt_eff
                    # truncates the attended pages to the longest active
                    # sequence this block can reach (n_eff from step_block):
                    # pages past it are either unmapped (trash) or belong to
                    # positions the mask already excludes, and masked scores
                    # exp to exactly 0.0, so the bound is bit-invisible while
                    # the per-step gather shrinks from max_len to n_eff * ps
                    # positions.
                    bt_eff = bt[:, :n_eff]

                    def one(carry, _):
                        caches, scales, tokens, positions, key = carry
                        key, sub = jax.random.split(key)
                        if scales is not None:
                            logits, caches, scales = M.decode_step(
                                params, tokens, caches, positions, cfg,
                                block_tables=bt_eff, scales=scales,
                            )
                        else:
                            logits, caches = M.decode_step(
                                params, tokens, caches, positions, cfg,
                                block_tables=bt_eff,
                            )
                        nxt = sample(logits, sub, sampling)
                        nxt = jnp.where(active, nxt, tokens)
                        # overshoot guard: stop advancing at max_len (see slab path)
                        positions = jnp.where(
                            active & (positions < max_len), positions + 1, positions
                        )
                        return (caches, scales, nxt, positions, key), nxt

                    (caches, scales, tokens, positions, key), toks = jax.lax.scan(
                        one, (caches, scales, state.tokens, pos0, state.key),
                        None, length=k,
                    )
                    return (
                        kvcache.PagedDecodeState(
                            caches, bt, refs, tokens, positions, active, key,
                            scales=scales,
                        ),
                        toks,  # [k, max_slots]
                    )
            else:

                def blk(params, state: kvcache.DecodeState):
                    def one(st: kvcache.DecodeState, _):
                        key, sub = jax.random.split(st.key)
                        logits, caches = M.decode_step(
                            params, st.tokens, st.caches, st.positions, cfg
                        )
                        nxt = sample(logits, sub, sampling)
                        # inactive slots keep emitting their old token (masked on host)
                        nxt = jnp.where(st.active, nxt, st.tokens)
                        # overshoot guard: a slot whose request finished mid-block
                        # stays active until the post-block release; freeze its
                        # position at max_len so the KV write (masked `== pos`)
                        # and the page lookup in the paged twin never run past
                        # the cache — no garbage writes, no unbounded positions
                        positions = jnp.where(
                            st.active & (st.positions < max_len),
                            st.positions + 1, st.positions,
                        )
                        return (
                            kvcache.DecodeState(caches, nxt, positions, st.active, key),
                            nxt,
                        )

                    state, toks = jax.lax.scan(one, state, None, length=k)
                    return state, toks  # toks [k, max_slots]

            self._block_fns[fn_key] = self._jit(blk, donate_state_argnum=1)
        return self._block_fns[fn_key]

    def _admit_fn(self, kv_pack):
        B = jax.tree.leaves(kv_pack)[0].shape[1]
        # the attention leaves' cache length identifies the bucket
        L1 = max(
            (a.shape[2] for a in jax.tree.leaves(kv_pack) if a.ndim >= 3), default=0
        )
        key = (L1, B)
        if key not in self._admit_fns:
            cfg = self.cfg

            if self.paged:
                ps = self.page_size

                def adm(state: kvcache.PagedDecodeState, kv, b, slot, token, pos,
                        shared_pages, n_shared, reg_mask, pack_page0):
                    single = kvcache.slice_request(kv, b)
                    return kvcache.paged_admit(
                        state, single, slot, token, pos, cfg, page_size=ps,
                        shared_pages=shared_pages, n_shared=n_shared,
                        reg_mask=reg_mask, pack_page0=pack_page0,
                    )
            else:

                def adm(state: kvcache.DecodeState, kv, b, slot, token, pos):
                    single = kvcache.slice_request(kv, b)
                    caches = kvcache.insert_request(state.caches, single, slot, cfg)
                    return kvcache.DecodeState(
                        caches=caches,
                        tokens=state.tokens.at[slot].set(token),
                        positions=state.positions.at[slot].set(pos),
                        active=state.active.at[slot].set(True),
                        key=state.key,
                    )

            self._admit_fns[key] = self._jit(adm)
        return self._admit_fns[key]

    # -- admission capacity (KV-capacity-aware for the paged cache) ---------

    def _pages_needed(self, true_len: int, max_new_tokens: int) -> int:
        """Pages to reserve at admit: the prompt + every decode write the
        request can make, including up to ``decode_block - 1`` overshoot
        steps after it finishes mid-block, capped at ``max_len``."""
        cap = min(true_len + max_new_tokens + self.decode_block - 2, self.max_len)
        cap = max(cap, true_len)
        return -(-cap // self.page_size)

    @property
    def _reserved(self) -> List[int]:
        """Per-slot pages reserved beyond any shared prefix (derived, not
        stored: non-shared admit pages + outstanding growth — a single
        source of truth with the ``free_pages`` accounting)."""
        if not self.paged:
            return []
        return [n + g for n, g in zip(self._slot_new, self._growth, strict=False)]

    @property
    def free_pages(self) -> int:
        """Pages free for a NEW reservation (host mirror; paged only):
        pool minus host-known held pages (live slot mappings + prefix-cache
        holds) minus every slot's outstanding decode-growth allowance."""
        if not self.paged:
            return 0
        held = int((self._href > 0).sum())
        # pending admits hold freshly allocated pages whose ids the host has
        # not read back yet — they are disjoint from every _href-held page
        # (the allocator only hands out refs==0 pages), so the count adds
        # exactly
        return self.n_pages - held - sum(self._growth) - self._pending_fresh

    def _resolve_pending(self) -> None:
        """Apply deferred admit-time page-id readbacks (one sync for all).

        ``admit`` defers learning WHICH physical pages the device mapped (the
        ids are only needed by release/fork/swap/audit, all of which call
        here first); the page COUNT was accounted synchronously via
        ``_pending_fresh``, which this zeroes as ids move into ``_href``."""
        if not self._pending_admits:
            return
        # fastpath: allow[FP001] batched resolution of deferred admit readbacks
        tables = np.asarray(self.state.block_tables)
        for slot, n_need in self._pending_admits:
            row = [int(p) for p in tables[slot, :n_need]]
            self._slot_pages[slot] = row
            for p in row:
                self._href[p] += 1
        self._pending_admits.clear()
        self._pending_fresh = 0

    def _evictable_pages(self) -> int:
        """Prefix-cache pages that could be reclaimed right now: unpinned and
        held ONLY by the cache (evicting a page still mapped by live slots
        frees no capacity)."""
        if self.prefix is None:
            return 0
        return self.prefix.evictable(lambda p: self._href[p] == 1)

    def _evict_for(self, need: int) -> bool:
        """LRU-evict cache-only prefix pages until ``need`` pages are free."""
        while self.free_pages < need:
            if self.prefix is None:
                return False
            page = self.prefix.evict_one(lambda p: self._href[p] == 1)
            if page is None:
                return False
            # drop the device-side cache hold; refs hit 0 -> reclaimable
            self.state = self.state._replace(
                page_refs=self.state.page_refs.at[page].add(-1)
            )
            self._href[page] -= 1
        return True

    def can_ever_admit(self, true_len: int, max_new_tokens: int) -> bool:
        """Whether this request could be admitted to an EMPTY engine."""
        if true_len + max_new_tokens > self.max_len:
            return False
        if self.paged and self._pages_needed(true_len, max_new_tokens) > self.n_pages:
            return False
        return True

    def can_admit(self, true_len: int, max_new_tokens: int, n_shared: int = 0) -> bool:
        """Whether admission would succeed right now: a free slot AND (paged)
        enough unreserved pages — counting only pages NOT covered by a shared
        prefix, and counting LRU-evictable cache-only pages as free."""
        if not self.can_ever_admit(true_len, max_new_tokens):
            return False
        if self.slots.n_active >= self.max_slots:
            return False
        if self.paged:
            need = self._pages_needed(true_len, max_new_tokens) - n_shared
            if need > self.free_pages + self._evictable_pages():
                return False
        return True

    # -- public API ---------------------------------------------------------

    def match_prefix(self, prompt, rid: Optional[int] = None, *,
                     hashes: Optional[List[bytes]] = None,
                     touch: bool = True) -> Optional[PrefixMatch]:
        """Look up the prompt's page-aligned prefix in the prefix index.

        Returns a ``PrefixMatch`` (n_shared may be 0 — it still carries the
        chunk hashes for post-admit registration) or None when the engine has
        no prefix cache.  With ``rid`` set, the matched pages are pinned until
        ``admit``/``release_prefix_pin`` so LRU eviction cannot take them.
        ``hashes`` skips recomputing the chunk hashes (they are a pure
        function of the immutable prompt — the scheduler memoizes them);
        ``touch=False`` marks a scheduler scan that must not refresh LRU
        recency (the touch happens at ``pin_prefix`` when a match is taken).
        """
        if not self.prefix_cache:
            return None
        ps = self.page_size
        n = len(prompt)
        if hashes is None:
            hashes = chunk_hashes(prompt, ps, self.pages_per_slot)
        # cap: at least one prompt token is always recomputed (logits need
        # the last position's hidden state)
        cap = min((n - 1) // ps, self.pages_per_slot)
        pages = self.prefix.match(hashes[:cap], touch=touch)
        # tail=False: safe for any pack.  The scheduler sets tail=True only
        # after it actually prefilled just the uncached tail (see PrefixMatch).
        m = PrefixMatch(pages=pages, n_shared=len(pages), hashes=hashes)
        if rid is not None and pages:
            self.pin_prefix(rid, m)
        return m

    def pin_prefix(self, rid: int, match: PrefixMatch) -> None:
        if self.prefix is not None and match.pages and rid not in self._pins:
            self.prefix.pin(match.pages)
            self.prefix.touch(match.hashes[: match.n_shared])
            self._pins[rid] = list(match.pages)

    def release_prefix_pin(self, rid: int) -> None:
        pages = self._pins.pop(rid, None)
        if pages and self.prefix is not None:
            self.prefix.unpin(pages)

    def gather_prefix(self, tables) -> Any:
        """Gather cached prefix pages into a contiguous [R, B, Lp, ...] pack
        for tail-only prefill.  ``tables`` [B, n_pg] int32 physical pages,
        trash-padded; read-only on the pool (no donation)."""
        tables = np.asarray(tables, np.int32)  # fastpath: allow[FP001] host page-table coercion, admit cadence
        key = tables.shape
        if key not in self._gather_fns:
            cfg = self.cfg
            if self.kv_dtype == "int8":
                self._gather_fns[key] = jax.jit(
                    lambda caches, sc, t: kvcache.gather_prefix_pack(
                        caches, t, cfg, scales=sc
                    )
                )
            else:
                self._gather_fns[key] = jax.jit(
                    lambda caches, t: kvcache.gather_prefix_pack(caches, t, cfg)
                )
        if self.kv_dtype == "int8":
            return self._gather_fns[key](
                self.state.caches, self.state.scales, jnp.asarray(tables)
            )
        return self._gather_fns[key](self.state.caches, jnp.asarray(tables))

    def append_chunk(
        self, kv_pack, n_tokens: int, *, batch_index: int = 0,
        rid: Optional[int] = None,
    ) -> Optional[List[int]]:
        """Stream one prefill chunk's K/V into the page pool (chunked prefill).

        Allocates exactly ``n_tokens // page_size`` pages (chunk boundaries
        are page-aligned) at refcount 1 — the "chunk hold", mirrored in
        ``_href`` so the pages count against ``free_pages`` like any other
        reservation — and scatters the pack's pages into them inside a
        donated jitted transition (``kvcache.paged_append_chunk``).  No slot
        is involved: the final chunk's ``admit`` later maps these pages into
        a block table as shared pages (+1 ref each) and the server drops the
        chunk holds (``release_chunk_holds``).

        Returns the physical page ids (one small host sync per chunk — the
        same lifecycle cadence as the admit-time bookkeeping readback), or
        None when the pool cannot cover the chunk right now (the caller
        leaves the request queued and retries after decode frees pages).
        ``rid`` only keys fault injection (the None-return contract doubles
        as the injected-failure path — a faulted append is indistinguishable
        from a capacity race the caller must survive anyway)."""
        if not self.paged:
            raise ValueError("append_chunk requires the paged KV cache")
        if self.faults is not None and self.faults.should_fail("chunk_append", rid):
            return None
        ps = self.page_size
        if n_tokens % ps:
            raise ValueError(f"chunk of {n_tokens} tokens is not page-aligned (ps={ps})")
        n_alloc = n_tokens // ps
        if n_alloc > self.free_pages and not self._evict_for(n_alloc):
            return None
        B = jax.tree.leaves(kv_pack)[0].shape[1]
        L1 = max(
            (a.shape[2] for i, (m, _) in enumerate(self.cfg.block_pattern)
             if m == "attn" for a in jax.tree.leaves(kv_pack[i])),
            default=0,
        )
        key = (L1, B, n_alloc)
        if key not in self._append_fns:
            cfg, psz = self.cfg, ps

            def app(state, kv, b):
                single = kvcache.slice_request(kv, b)
                return kvcache.paged_append_chunk(
                    state, single, cfg, page_size=psz, n_alloc=n_alloc
                )

            self._append_fns[key] = self._jit(app)
        self.state, pages = self._append_fns[key](
            self.state, kv_pack, jnp.int32(batch_index)
        )
        # fastpath: allow[FP001] chunk-cadence page readback for the host hold mirror
        page_list = [int(p) for p in np.asarray(pages)]
        for p in page_list:
            self._href[p] += 1
            self._chunk_holds[p] = self._chunk_holds.get(p, 0) + 1
        self.stats["chunk_pages"] = self.stats.get("chunk_pages", 0) + n_alloc
        return page_list

    def release_chunk_holds(self, pages: List[int]) -> None:
        """Drop the in-flight chunk holds on ``pages`` (decrement-only, one
        tiny dispatch — a per-chunked-request lifecycle event).  Called after
        the final admit mapped the pages into a block table (their bytes
        survive under the slot ref) or when a prefill-only chunked request
        finishes without a slot (refs hit 0 and the pages recycle)."""
        if not pages:
            return
        self.state = self.state._replace(
            page_refs=self.state.page_refs.at[jnp.asarray(pages, jnp.int32)].add(-1)
        )
        for p in pages:
            self._href[p] -= 1
            n = self._chunk_holds.get(p, 0) - 1
            if n <= 0:
                self._chunk_holds.pop(p, None)
            else:
                self._chunk_holds[p] = n

    def register_chunk_pages(
        self, hashes: List[bytes], pages: List[int], start: int
    ) -> None:
        """Register a chunked prompt's streamed pages in the prefix index
        (pages [start, len(pages)) hold full prompt chunks ``hashes[j]``).
        Each new registration takes the usual +1 device cache hold; hashes
        already present (registered by a concurrent request, possibly on a
        different page) are left alone — duplicate content is never
        re-registered."""
        if self.prefix is None:
            return
        add = [
            p for j, p in enumerate(pages)
            if j >= start and j < len(hashes) and self.prefix.insert(hashes[j], p)
        ]
        if add:
            self.state = self.state._replace(
                page_refs=self.state.page_refs.at[jnp.asarray(add, jnp.int32)].add(1)
            )
            for p in add:
                self._href[p] += 1

    def admit(
        self,
        req: GenRequest,
        kv_pack,
        first_token: int,
        true_len: int,
        *,
        batch_index: int = 0,
        prefix: Optional[PrefixMatch] = None,
        resume: bool = False,
    ) -> Optional[int]:
        """Insert a prefilled request into a free slot (the KV handoff).

        ``kv_pack`` may be a batched prefill pack; ``batch_index`` selects
        the row, sliced out on device inside the jitted admit.  Returns None
        when the engine is momentarily full (no slot, or — paged — not enough
        unreserved pages); raises when the request can never fit.

        ``prefix``: a ``match_prefix`` hit — the matched physical pages are
        mapped into the slot's block table (each +1 ref) instead of being
        recomputed, and the reservation counts only NEW pages.  ``kv_pack``
        is a full-prompt pack unless ``prefix.tail`` says the scheduler
        prefilled only the uncached tail; full-pack prefix writes are steered
        to the trash page.  After the admit the host registers the request's
        not-yet-cached full prompt chunks in the prefix index (+1 cache hold
        each, applied inside the jitted admit via ``reg_mask``).

        ``resume=True`` marks a swap-in re-admission: ``true_len`` already
        includes the decoded tokens (so capacity math uses the REMAINING
        budget, not the full ``max_new_tokens``), ``first_token`` is the last
        emitted token (re-consumed by the next decode step, never re-appended
        to the output), and ``prefix`` is the swap stash's kept-page match —
        the admit-time re-match is skipped because the pack only scatters
        logical pages from ``n_shared`` on.

        The resume budget is ``resume_budget(req)`` (remaining + the
        re-consumed last token, whose KV is not in the cache yet — exactly
        like ``first_token`` at a fresh admit), so the resumed reservation
        lands on the SAME total as the uninterrupted run —
        ``_pages_needed(orig_len, max_new)`` — keeping the allocator's
        pool-exhaustion-unreachable invariant intact through the overshoot
        margin."""
        if (not resume and self.faults is not None
                and self.faults.should_fail("admit", req.rid)):
            return None  # injected KV-handoff failure: same contract as full
        max_new_eff = self.resume_budget(req) if resume else req.max_new_tokens
        if true_len + max_new_eff > self.max_len:
            raise ValueError(f"request {req.rid} needs {true_len + max_new_eff} > max_len")
        if self.paged:
            ps = self.page_size
            pps = self.pages_per_slot
            if self.prefix is not None and prefix is None:
                # admit-time re-match: the pack covers the full prompt (the
                # prefill ran before this prompt's chunks were registered —
                # e.g. same-batch duplicates), but already-cached pages can
                # still be MAPPED instead of re-written (the prefix writes
                # steer to the trash page): the capacity win without the
                # compute win.  rid pins the matched pages so the eviction
                # below can never free a page this very admit is mapping.
                prefix = self.match_prefix(req.prompt, rid=req.rid)
            n_shared = prefix.n_shared if prefix is not None else 0
            need_total = self._pages_needed(true_len, max_new_eff)
            need = need_total - n_shared
            if need > self.n_pages:
                self.release_prefix_pin(req.rid)  # caller drops the request
                raise ValueError(
                    f"request {req.rid} needs {need} pages > pool of {self.n_pages}"
                )
            # matched pages are pinned (by the scheduler or the re-match
            # above), so eviction can only take pages this admit does NOT map
            if need > self.free_pages and not self._evict_for(need):
                return None  # pin survives: the caller retries this admit
        slot = self.slots.alloc(req.rid)
        if slot is None:
            return None
        if self.paged:
            n_need = -(-true_len // ps)
            reg_mask = np.zeros((pps,), bool)
            hashes: List[bytes] = []
            if self.prefix is None and n_shared == 0:
                # plain admit: the shared-page plumbing is all constants —
                # reuse the cached device arrays instead of re-uploading
                shared_dev = self._plain_shared
                reg_dev = self._plain_regmask
                n_shared_dev = pack0_dev = self._zero_i32
            else:
                shared_arr = np.full((pps,), self.n_pages, np.int32)
                if n_shared:
                    shared_arr[:n_shared] = prefix.pages
                # which fresh pages the host will register (full prompt
                # chunks whose chain hash is not yet in the index) — they
                # start at refs == 2 (slot hold + cache hold) inside the
                # jitted admit
                if self.prefix is not None:
                    hashes = prefix.hashes  # re-match above guarantees a match
                    for j in range(n_shared, min(true_len // ps, pps, len(hashes))):
                        if hashes[j] not in self.prefix:
                            reg_mask[j] = True
                pack_page0 = n_shared if (prefix is not None and prefix.tail) else 0
                shared_dev = jnp.asarray(shared_arr)
                reg_dev = jnp.asarray(reg_mask)
                n_shared_dev = jnp.int32(n_shared)
                pack0_dev = jnp.int32(pack_page0)
            self.state = self._admit_fn(kv_pack)(
                self.state,
                kv_pack,
                jnp.int32(batch_index),
                jnp.int32(slot),
                jnp.int32(first_token),
                jnp.int32(true_len),
                shared_dev,
                n_shared_dev,
                reg_dev,
                pack0_dev,
            )
            # admit-time host bookkeeping: the host must learn the physical
            # pages to mirror holds, register chunks, and route future prefix
            # matches.  Reading them back HERE would serialize every admit
            # against the whole device queue (admit -> sync -> admit -> ...),
            # so the plain case — no prefix index, no shared pages — defers
            # the id readback to the next natural host sync (``step_block``'s
            # token readback / fork / swap / audit), tracking the page COUNT
            # synchronously so ``free_pages`` stays exact.  Prefix-cache and
            # shared-page admits keep the synchronous readback: registration
            # must land in the index before the next request is matched.
            if self.prefix is None and n_shared == 0:
                self._pending_admits.append((slot, n_need))
                self._pending_fresh += n_need
            else:
                # fastpath: allow[FP001] admit-cadence readback of the slot's physical pages
                row = [int(p) for p in np.asarray(self.state.block_tables[slot])[:n_need]]
                self._slot_pages[slot] = row
                for p in row:
                    self._href[p] += 1
                if self.prefix is not None:
                    for j in range(pps):
                        if reg_mask[j]:
                            self.prefix.insert(hashes[j], row[j])
                            self._href[row[j]] += 1
            self._growth[slot] = need_total - n_need
            self._slot_new[slot] = n_need - n_shared
            self.admit_new_pages[req.rid] = need
            self.admit_shared_pages[req.rid] = n_shared
            self.stats["admits"] += 1
            self.stats["new_pages"] += need
            self.stats["shared_pages"] += n_shared
            self.release_prefix_pin(req.rid)
        else:
            self.state = self._admit_fn(kv_pack)(
                self.state,
                kv_pack,
                jnp.int32(batch_index),
                jnp.int32(slot),
                jnp.int32(first_token),
                jnp.int32(true_len),
            )
        self.slots.lengths[slot] = true_len
        self.requests[req.rid] = req
        if not resume:
            req.tokens.append(first_token)
        return slot

    def fork(
        self, new_req: GenRequest, src_rid: int, token: Optional[int] = None
    ) -> Optional[int]:
        """Clone a live request's decode state into a free slot at zero KV
        cost (best-of-n / beam branch): the block-table row is copied with a
        +1 refcount per mapped page; no cache bytes move.  ``token`` replaces
        the branch's last emitted token so the streams diverge — the first
        write either branch makes into the shared tail page is redirected to
        a private copy by the fused block's copy-on-write.

        The fork reserves its remaining growth pages plus 2 COW-copy pages
        (both branches may copy the shared tail page within one block).
        Returns the new slot or None when slots/pages are exhausted."""
        if not self.paged:
            raise ValueError("fork() requires the paged KV cache")
        if src_rid not in self.requests:
            raise KeyError(f"request {src_rid} is not decoding here")
        self._resolve_pending()
        self._fork_used = True  # decode blocks need COW from here on
        src_slot = self.slots.request_ids.index(src_rid)
        src_req = self.requests[src_rid]
        ps = self.page_size
        cur_len = min(self.slots.lengths[src_slot], self.max_len)
        remaining = new_req.max_new_tokens - len(src_req.tokens)
        if remaining <= 0:
            raise ValueError(
                f"fork of {src_rid}: max_new_tokens {new_req.max_new_tokens} "
                f"already exhausted by the {len(src_req.tokens)} cloned tokens"
            )
        n_mapped = -(-cur_len // ps)
        need_total = self._pages_needed(cur_len, remaining)
        growth = max(need_total - n_mapped, 0) + 2
        if growth > self.free_pages and not self._evict_for(growth):
            return None
        slot = self.slots.alloc(new_req.rid)
        if slot is None:
            return None
        new_req.tokens = list(src_req.tokens)
        tok = int(token) if token is not None else new_req.tokens[-1]
        new_req.tokens[-1] = tok
        if self._fork_fn is None:
            cfg = self.cfg
            self._fork_fn = self._jit(
                lambda st, s, d, t: kvcache.paged_fork(st, s, d, t, cfg)
            )
        self.state = self._fork_fn(
            self.state, jnp.int32(src_slot), jnp.int32(slot), jnp.int32(tok)
        )
        row = [int(p) for p in np.asarray(self.state.block_tables[slot])[:n_mapped]]
        self._slot_pages[slot] = row
        for p in row:
            self._href[p] += 1
        self._growth[slot] = growth
        self._slot_new[slot] = 0  # every mapped page is shared with the source
        self.slots.lengths[slot] = cur_len
        self.requests[new_req.rid] = new_req
        self.admit_new_pages[new_req.rid] = growth
        self.admit_shared_pages[new_req.rid] = n_mapped
        self.stats["admits"] += 1
        self.stats["new_pages"] += growth
        self.stats["shared_pages"] += n_mapped
        return slot

    def swap_out(self, rid: int) -> SwappedRequest:
        """Preempt a live request: page-level swap of its KV to host.

        The PRIVATE pages — the uncached prompt tail plus everything decode
        wrote — are gathered into a host pack (``kvcache.paged_swap_out``,
        one sync, a rare lifecycle event).  Prefix-shared pages (registered
        in the prefix index, so ``refs > 1``) are NOT copied: this slot's
        mapping ref is dropped by the decrement-only release and the bytes
        stay in the pool, kept alive by the index cache hold and a swap pin
        that bridges the gap until ``swap_in`` remaps them.  The slot, its
        page reservation, and its growth allowance are all freed.

        ``swap_in`` resumes the stream bit-identically under greedy
        sampling; sampled streams additionally require the engine-global
        per-step PRNG schedule to be unchanged (the key splits once per
        decode step regardless of slot occupancy)."""
        if not self.paged:
            raise ValueError("swap_out requires the paged KV cache")
        if rid not in self.requests:
            raise KeyError(f"request {rid} is not decoding here")
        self._resolve_pending()
        if self.faults is not None and self.faults.should_fail("swap_out", rid):
            raise TransientFault(
                f"injected swap_out failure for request {rid} (nothing mutated)"
            )
        slot = self.slots.request_ids.index(rid)
        req = self.requests[rid]
        length = self.slots.lengths[slot]
        n_keep, kept, hashes = 0, [], []
        if self.prefix is not None:
            m = self.match_prefix(req.prompt)  # same cap/hash rules as admit
            hashes = m.hashes
            # keep exactly the leading run where the index maps OUR physical
            # pages (it always does for chunks this admit registered or
            # mapped, but a prefix evicted and re-registered from another
            # request's pages must fall back to a byte copy, not aliasing)
            for a, b in zip(m.pages, self._slot_pages[slot], strict=False):
                if a != b:
                    break
                n_keep += 1
            kept = self._slot_pages[slot][:n_keep]
            if kept:
                self.prefix.swap_pin(rid, kept)
        pack = kvcache.paged_swap_out(
            self.state, slot, length, self.cfg, page_size=self.page_size,
            start_page=n_keep,
        )
        # release the slot: decrement-only on device (shared pages keep their
        # other holders' refs and bytes), mirrored on host
        keep = np.ones((self.max_slots,), bool)
        keep[slot] = False
        self._growth[slot] = 0
        self._slot_new[slot] = 0
        for p in self._slot_pages[slot]:
            self._href[p] -= 1
        self._slot_pages[slot] = []
        self.slots.free(slot)
        del self.requests[rid]
        self.admit_new_pages.pop(rid, None)
        self.admit_shared_pages.pop(rid, None)
        self.state = self._release(self.state, jnp.asarray(keep))
        self.stats["swap_outs"] = self.stats.get("swap_outs", 0) + 1
        return SwappedRequest(
            req=req, engine=self, pack=pack, length=length,
            last_token=req.tokens[-1], n_keep=n_keep, kept_pages=kept,
            hashes=hashes,
        )

    @staticmethod
    def resume_budget(req: GenRequest) -> int:
        """Decode budget of a swapped-out request: the remaining new tokens
        PLUS the re-consumed last token, whose KV is still unwritten — the
        exact mirror of ``first_token`` being counted inside a fresh admit's
        ``max_new_tokens``.  The single source of truth for swap-in capacity
        checks and the resumed reservation (admit with ``resume=True``), so
        the two can never disagree."""
        return req.max_new_tokens - len(req.tokens) + 1

    def swap_gain(self, rid: int) -> int:
        """Pages that would become ALLOCATABLE if ``rid`` were swapped out
        right now: its growth allowance plus every mapped page it holds
        alone.  Pages with other holders — the prefix index's cache hold or
        sharing slots — stay resident (and a swap PINS the index-matched
        ones, so unlike a natural release they cannot even be evicted).  The
        preemption policy uses this to skip preemptions that can never free
        enough capacity — swapping a victim whose pages mostly survive would
        deadlock the blocked request against its own victims' pins."""
        if not self.paged or rid not in self.requests:
            return 0
        self._resolve_pending()
        slot = self.slots.request_ids.index(rid)
        return self._growth[slot] + sum(
            1 for p in self._slot_pages[slot] if self._href[p] == 1
        )

    def swap_in(self, sw: SwappedRequest) -> Optional[int]:
        """Re-admit a swapped-out request bit-identically: remap the kept
        prefix pages (+1 ref each), scatter the host pack into fresh pages,
        restore the resume token/position, and release the swap pins.
        Returns the new slot, or None while capacity is still short (the
        stash and its pins survive for a later retry)."""
        if sw.engine is not self:
            raise ValueError(
                f"request {sw.req.rid} was swapped out of a different engine "
                f"(its kept pages are physical ids in that engine's pool)"
            )
        if self.faults is not None and self.faults.should_fail("swap_in", sw.req.rid):
            return None  # injected scatter failure: stash + pins survive
        req = sw.req
        if not self.can_admit(sw.length, self.resume_budget(req),
                              n_shared=sw.n_keep):
            return None
        m = PrefixMatch(
            pages=list(sw.kept_pages), n_shared=sw.n_keep,
            hashes=list(sw.hashes), tail=True,
        )
        slot = self.admit(
            req, sw.pack, sw.last_token, sw.length, prefix=m, resume=True
        )
        if slot is not None:
            self.stats["swap_ins"] = self.stats.get("swap_ins", 0) + 1
            if self.prefix is not None:
                self.prefix.swap_unpin(req.rid)
        return slot

    def _auto_block(self) -> int:
        """Fused steps for the next block: enough to cover the largest
        remaining budget, QUANTIZED up to a power of two (capped at
        ``decode_block``).  The quantization keeps the jit-key set at
        log2(decode_block) values instead of one per exact remaining count —
        a drain tail would otherwise mint fresh whole-model compiles right
        where benchmarks measure.  Running a few extra steps past the
        largest remainder is free of observable effect: the stream is
        invariant to block partitioning (the PRNG chain advances per
        accepted token) and the host loop discards overshoot tokens."""
        rem = [
            req.max_new_tokens - len(req.tokens)
            for req in self.requests.values()
        ]
        k = max(1, min(self.decode_block, max(rem, default=1)))
        return min(self.decode_block, 1 << (k - 1).bit_length())

    def _n_pg_eff(self, k: int) -> Optional[int]:
        """Effective block-table width for a k-step block: the power-of-two
        page count covering the longest ACTIVE sequence after k more writes.

        The host slot lengths mirror the device write positions at block
        start (admit sets both to true_len; each accepted token advances
        both), so ``max(lengths) + k`` bounds every position the block can
        write or attend.  Rounding up to a power of two keeps the jit-cache
        key set logarithmic in ``pages_per_slot`` — never a per-exact-length
        key.  Inactive slots may hold longer (released) tables, but their
        writes are trash-steered and their outputs host-masked."""
        if not self.paged:
            return None
        lens = [
            self.slots.lengths[s]
            for s, rid in enumerate(self.slots.request_ids)
            if rid is not None
        ]
        n_eff = max(1, -(-(max(lens, default=0) + k) // self.page_size))
        if n_eff < self.pages_per_slot:
            n_eff = 1 << (n_eff - 1).bit_length()
        # floor of 4 pages: a narrower window saves nothing measurable, and
        # the floor halves the (k, n_eff) jit-key product for short traffic
        n_eff = max(n_eff, 4)
        return min(n_eff, self.pages_per_slot)

    def step_block(self, k: Optional[int] = None) -> List[Tuple[int, int]]:
        """Run ``k`` fused decode steps (default: auto-sized <= decode_block).

        One jitted dispatch, one host sync.  Returns the accepted
        (rid, token) pairs; EOS / max-token bookkeeping happens here on the
        host against the returned block, and finished slots are released on
        device afterwards."""
        if self.slots.n_active == 0:
            return []
        k = k if k is not None else self._auto_block()
        if self.paged and k > self.decode_block:
            # the page reservation only covers decode_block-1 overshoot steps
            raise ValueError(f"paged step_block k={k} > decode_block={self.decode_block}")
        self.state, toks = self._block_fn(k, self._n_pg_eff(k))(self.params, self.state)
        block = np.asarray(toks)  # fastpath: allow[FP001] the one sanctioned host sync per k-step block
        if self.paged:
            # the device just synced on the token block, so resolving the
            # admit-time page-id readbacks deferred by ``admit`` is ~free
            # here — and it must happen before the release loop below reads
            # ``_slot_pages`` for finished slots
            self._resolve_pending()
        out: List[Tuple[int, int]] = []
        freed: List[int] = []
        for slot, rid in enumerate(self.slots.request_ids):
            if rid is None:
                continue
            req = self.requests[rid]
            for j in range(k):
                tok = int(block[j, slot])
                req.tokens.append(tok)
                self.slots.lengths[slot] += 1
                out.append((rid, tok))
                if len(req.tokens) >= req.max_new_tokens or (
                    req.eos_id is not None and tok == req.eos_id
                ):
                    req.done = True
                    req.status = STATUS_FINISHED
                    self.slots.free(slot)
                    freed.append(slot)
                    del self.requests[rid]
                    if self.paged:
                        # per-request stat entries live only as long as the
                        # request; cumulative totals stay in self.stats
                        self.admit_new_pages.pop(rid, None)
                        self.admit_shared_pages.pop(rid, None)
                    break
        if freed:
            keep = np.ones((self.max_slots,), bool)
            keep[freed] = False
            if self.paged:
                for s in freed:
                    self._growth[s] = 0
                    self._slot_new[s] = 0
                    for p in self._slot_pages[s]:
                        self._href[p] -= 1
                    self._slot_pages[s] = []
            # device release is decrement-only: pages shared with other slots
            # or held by the prefix cache keep refs > 0 and their bytes
            self.state = self._release(self.state, jnp.asarray(keep))
        return out

    def step(self) -> List[Tuple[int, int]]:
        """One decode iteration (seed-compatible granularity)."""
        return self.step_block(1)

    # -- robustness: abort / crash / invariant audit ------------------------

    def abort(self, rid: int) -> bool:
        """Release a DECODING request's slot mid-stream (cancellation).

        Exactly the engine half of the normal finish path in ``step_block``
        minus the decode block: growth allowance zeroed, the slot's page
        mappings dropped (decrement-only device release — pages shared with
        other slots or the prefix index keep their bytes), per-request stats
        pruned.  Returns False when ``rid`` is not decoding here (the caller
        tries every engine).  Does NOT touch ``req.done``/``status`` — the
        server owns request state; this is pure engine mechanism."""
        if rid not in self.requests:
            return False
        slot = self.slots.request_ids.index(rid)
        if self.paged:
            self._resolve_pending()
            self._growth[slot] = 0
            self._slot_new[slot] = 0
            for p in self._slot_pages[slot]:
                self._href[p] -= 1
            self._slot_pages[slot] = []
            self.admit_new_pages.pop(rid, None)
            self.admit_shared_pages.pop(rid, None)
        self.slots.free(slot)
        del self.requests[rid]
        keep = np.ones((self.max_slots,), bool)
        keep[slot] = False
        self.state = self._release(self.state, jnp.asarray(keep))
        return True

    def crash(
        self, *, preserve_kv: bool = False
    ) -> Tuple[List[SwappedRequest], List[GenRequest]]:
        """Simulate this engine dying: reinitialise ALL device state and
        host mirrors, returning what can be recovered.

        ``preserve_kv=True`` models "the engine wedged but its HBM is still
        readable": every in-flight request's FULL KV is extracted to a
        host-side stash (``kvcache.paged_swap_out`` from logical page 0 — a
        ``SwappedRequest`` with ``n_keep == 0``, entirely host-resident) for
        ordinary swap-in resubmission on the reinitialised engine, streams
        bit-identical.  ``preserve_kv=False`` is the hard crash: the KV is
        gone; in-flight requests are returned for replay from their prompts
        (greedy streams re-derive identically).

        The sampling PRNG key survives the reset (it is decode-global state,
        not per-request — preserving it keeps the engine's step schedule,
        and greedy streams never consult it anyway).  The prefix index is
        rebuilt empty: its pages died with the pool, and losing the index
        costs recompute, never correctness."""
        stashes: List[SwappedRequest] = []
        lost: List[GenRequest] = []
        if preserve_kv and self.paged:
            for slot, rid in enumerate(self.slots.request_ids):
                if rid is None:
                    continue
                req = self.requests[rid]
                length = self.slots.lengths[slot]
                pack = kvcache.paged_swap_out(
                    self.state, slot, length, self.cfg,
                    page_size=self.page_size, start_page=0,
                )
                stashes.append(SwappedRequest(
                    req=req, engine=self, pack=pack, length=length,
                    last_token=req.tokens[-1], n_keep=0, kept_pages=[],
                    hashes=[],
                ))
        else:
            lost.extend(self.requests.values())
        key = self.state.key
        if self.paged:
            self.state = kvcache.init_paged_decode_state(
                self.cfg, self.max_slots, self.max_len, self.page_size,
                self.n_pages, key, kv_dtype=self.kv_dtype,
            )
            self._href = np.zeros(self.n_pages, np.int64)
            self._growth = [0] * self.max_slots
            self._slot_new = [0] * self.max_slots
            self._slot_pages = [[] for _ in range(self.max_slots)]
            self._pending_admits = []
            self._pending_fresh = 0
            self._fork_used = False  # clones died with the pool
            self._chunk_holds = {}
            self._pins = {}
            if self.prefix is not None:
                self.prefix = PrefixIndex(self.page_size)
            self.admit_new_pages = {}
            self.admit_shared_pages = {}
            self.stats["crashes"] = self.stats.get("crashes", 0) + 1
        else:
            self.state = kvcache.init_decode_state(
                self.cfg, self.max_slots, self.max_len, key
            )
        self.slots = kvcache.SlotState(self.max_slots, self.max_len)
        self.requests = {}
        return stashes, lost

    def audit(self) -> kvcache.AuditReport:
        """Run the on-device KV invariant auditor against this engine's
        state + host mirrors (``kvcache.audit``): refcount conservation,
        block-table validity, trash-page isolation.  Slab engines have no
        refcounted allocator to audit and report trivially clean."""
        if not self.paged:
            return kvcache.AuditReport(ok=True, n_pages=0, discrepancies=[])
        self._resolve_pending()
        index_pages = self.prefix.pages() if self.prefix is not None else ()
        chunk_holds = [
            p for p, n in self._chunk_holds.items() for _ in range(n)
        ]
        return kvcache.audit(
            self.state, page_size=self.page_size, index_pages=index_pages,
            chunk_holds=chunk_holds, href=self._href,
        )


# ---------------------------------------------------------------------------
# Disaggregated server (the paper's architecture)
# ---------------------------------------------------------------------------


class DisaggregatedServer:
    """Prefill pool -> KV handoff -> decode pool, continuous batching.

    Scheduling POLICY is pluggable (``serving.scheduler``): the server owns
    only mechanism — bucketed batched prefill, the KV handoff, admission
    plumbing, fused decode blocks — and defers ordering decisions to its
    ``Scheduler``.  Each round it prefills one BATCH of queued prompts (the
    policy-ordered queue head picks the bucket, then every queued request
    with a compatible group key — same tail bucket, same prefix capacity,
    same routed decode engine — joins up to ``max_prefill_batch``), re-admits
    swapped-out requests, admits waiting requests in policy order (invoking
    the policy's preemption hook when one is blocked), and runs one fused
    decode block per decode engine.  The default ``FCFSScheduler`` is
    bit-identical to the old hardcoded oldest-first behaviour; see
    ``KVAwareScheduler`` (page-footprint ordering + aging) and
    ``PriorityScheduler`` (priorities + page-level preemption/swap).

    With prefix-caching decode engines, scheduling is KV-cache aware
    (production-stack-style routing): each queued prompt is matched against
    every engine's prefix index, the longest hit pins its pages and routes
    the request to that engine, prefill runs only on the uncached tail
    (attention-only models), and admit maps the cached pages instead of
    rewriting them.

    With a chunk-enabled prefill engine (``PrefillEngine(chunk_tokens=...)``)
    and a paged decode pool, prompts longer than the threshold prefill in
    page-aligned chunks (``ChunkPrefillState``): each round the queue head's
    NEXT chunk runs — attending everything already streamed at absolute
    positions — and its K/V pages land in the decode pool immediately
    (``DecodeEngine.append_chunk``), so the KV handoff is a stream of pages
    rather than one admit-time slab, pages are reserved chunk by chunk, and
    the request goes back in the queue between chunks where the policy can
    interleave shorter work.  The final chunk emits the first token and
    admits through the ordinary tail-pack path (its streamed pages mapped
    like a prefix match), which keeps chunked streams bit-identical to
    monolithic prefill.

    ``transfer`` is the KV handoff hook: identity on single host; on a real
    cluster it is the pod-to-pod device transfer (see launch/serve.py).
    In the chunked path it runs per chunk — the incremental
    prefill-chip -> decode-chip page stream the paper's disaggregation
    needs at pod scale.
    """

    def __init__(
        self,
        prefill_engines: List[PrefillEngine],
        decode_engines: List[DecodeEngine],
        *,
        config: Optional[EngineConfig] = None,
        transfer=lambda kv: kv,
        seed: int = 0,
        max_prefill_batch: int = 8,
        scheduler: Optional[Scheduler] = None,
        faults: Optional[object] = None,
        audit_every: Optional[int] = None,
    ):
        # ``config`` is the canonical path for the server-level knobs; the
        # loose kwargs remain as a compatibility shim (deprecated — new call
        # sites should pass an EngineConfig, or use ``from_config`` to build
        # the engines too)
        if config is not None:
            seed = config.seed
            max_prefill_batch = config.max_prefill_batch
            scheduler = config.build_scheduler() if scheduler is None else scheduler
            faults = config.faults if faults is None else faults
            audit_every = config.audit_every if audit_every is None else audit_every
        self.config = config
        self.prefills = prefill_engines
        self.decodes = decode_engines
        self.transfer = transfer
        self.key = jax.random.PRNGKey(seed)
        self.max_prefill_batch = max(1, max_prefill_batch)
        self.scheduler = scheduler if scheduler is not None else FCFSScheduler()
        # fault injection (serving.faults): the server owns ONE injector and
        # shares it with every decode engine so the whole fault schedule is
        # drawn from a single seeded stream in scheduler order
        if faults is not None and isinstance(faults, FaultPlan):
            faults = FaultInjector(faults)
        self.faults: Optional[FaultInjector] = faults
        if self.faults is not None:
            for d in self.decodes:
                d.faults = self.faults
        # run the KV invariant auditor (strict) every N scheduling rounds
        self.audit_every = audit_every
        # one dict per simulated engine crash: round, replayed/stashed rids
        self.crash_events: List[dict] = []
        self._has_deadlines = False  # skip the deadline sweep until one exists
        self.all_requests: Dict[int, GenRequest] = {}
        self.peak_active = 0  # max concurrent decode requests seen (for benchmarks)
        self._rr = 0
        # unified batching (opt-in): batch chunk work of DIFFERENT requests
        # into one prefill dispatch and coalesce it with the decode step
        # under the round's token budget; off = the serial one-chunk-per-
        # round schedule every committed baseline was recorded against
        self.unified_batching = bool(config.unified_batching) if config else False
        self._token_budget: Optional[int] = config.token_budget if config else None
        # rounds a deferred chunk head has waited (aging bound: a tight
        # budget may starve chunk work while decode stays saturated)
        self._defer_rounds = 0
        self.unified_stats = {
            "rounds": 0, "chunk_rows": 0, "deferred_rounds": 0,
            "budget_tokens": 0, "used_tokens": 0,
            # prefill accounting (batch dedup observability): tokens actually
            # DISPATCHED through monolithic prefill groups, and tokens a
            # same-batch shared-prefix dedup kept out of those dispatches
            "prefill_tokens": 0, "dedup_groups": 0, "dedup_saved_tokens": 0,
        }
        # batch-level prefix dedup (opt-in, requires prefix_cache): requests
        # in the SAME bucketed prefill dispatch that share a page-aligned
        # prefix with each other run that prefix once (see _dedup_group)
        self.batch_dedup = bool(config.batch_dedup) if config else False
        # in-progress chunked prefills (rid -> cursor); the requests
        # themselves stay in the scheduler queue between chunks
        self.chunks: Dict[int, ChunkPrefillState] = {}
        # intermediate chunks discard their sampled token, so they burn a
        # fixed dummy key instead of advancing the server's PRNG chain —
        # the final chunk's first-token sample then consumes the SAME split
        # a monolithic prefill of that prompt would have consumed
        self._chunk_key = jax.random.PRNGKey(0)
        # (rid, page_size) -> chunk hashes: prompts are immutable, so the
        # per-round routing scans never re-hash a queued prompt; entries are
        # dropped when the request leaves the queue or finishes (_forget)
        self._hash_memo: Dict[Tuple[int, int], List[bytes]] = {}

    @classmethod
    def from_config(
        cls,
        params,
        cfg: ModelConfig,
        config: EngineConfig,
        *,
        transfer=lambda kv: kv,
        n_prefills: int = 1,
        n_decodes: int = 1,
        replica: int = 0,
    ) -> "DisaggregatedServer":
        """Build the whole single-replica stack — prefill pool -> KV handoff
        -> decode pool — from one ``EngineConfig``.

        ``replica`` offsets the PRNG seeds (server chain and decode stream)
        by a fixed amount so N replicas built from ONE config draw distinct
        sampling streams; decode engine ``i`` additionally offsets by ``i``
        (matching the launcher's long-standing ``seed + i`` convention).
        Greedy sampling — every committed baseline — is seed-independent, so
        the offsets never break bit-identity gates."""
        if not isinstance(config, EngineConfig):
            raise TypeError(
                f"from_config takes an EngineConfig, got {type(config).__name__}"
            )
        rc = config.replace(seed=config.seed + replica) if replica else config
        if rc.chunk_tokens == "auto":
            # resolve the measured-TBT chunk quantum ONCE, before any engine
            # is built — every replica's engines then share the concrete
            # config (the tuner itself builds throwaway probe engines)
            from .autotune import tune_chunk_tokens

            rc = rc.replace(chunk_tokens=tune_chunk_tokens(params, cfg, rc))
        prefills = [
            PrefillEngine(params, cfg, config=rc) for _ in range(n_prefills)
        ]
        decodes = [
            DecodeEngine(params, cfg, config=rc.replace(seed=rc.seed + i) if i else rc)
            for i in range(n_decodes)
        ]
        return cls(prefills, decodes, config=rc, transfer=transfer)

    # the queue / waiting containers live on the scheduler (policy state);
    # these aliases keep the long-standing introspection surface working
    @property
    def queue(self) -> List[GenRequest]:
        return self.scheduler.queue

    @queue.setter
    def queue(self, v) -> None:
        self.scheduler.queue = v

    @property
    def waiting(self) -> List[WaitingEntry]:
        return self.scheduler.waiting

    @waiting.setter
    def waiting(self, v) -> None:
        self.scheduler.waiting = v

    def submit(self, req: GenRequest) -> RequestHandle:
        """Validate and queue a request, rejecting up front what the cluster
        can never serve: prompts past the largest prefill bucket (the old path
        minted an unbounded jit key per oversized length) and prompt+max_new
        combinations no decode engine has capacity for (the old path blew up
        only at admit).  Queue ORDER is the scheduler's business.

        Returns a ``RequestHandle`` (status/result/cancel/stream for THIS
        request); the rid-based surface (``cancel(rid)``, ``outcomes()``)
        keeps working unchanged — the handle delegates to it."""
        n = len(req.prompt)
        limits = [e.buckets[-1] for e in self.prefills if e.bucketed]
        if limits and n > min(limits):
            raise ValueError(
                f"request {req.rid}: prompt length {n} exceeds the largest "
                f"prefill bucket {min(limits)}"
            )
        if req.max_new_tokens > 1 and not any(
            d.can_ever_admit(n, req.max_new_tokens) for d in self.decodes
        ):
            cap = max(d.max_len for d in self.decodes)
            raise ValueError(
                f"request {req.rid}: prompt {n} + max_new_tokens "
                f"{req.max_new_tokens} exceeds every decode engine's capacity "
                f"(max_len {cap})"
            )
        if req.deadline_rounds is not None or req.ttft_deadline is not None:
            self._has_deadlines = True
        self.scheduler.add(req)
        self.all_requests[req.rid] = req
        return RequestHandle(req.rid, self)

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def rounds_since_submit(self, rid: int) -> int:
        """Scheduling rounds run since ``rid`` was submitted (the round-clock
        the API surface reports TTFT against)."""
        s = self.scheduler
        return s.round - s.submit_round.get(rid, s.round)

    def pending(self) -> bool:
        """Whether any request is still in flight anywhere: queued, waiting
        for a slot, swapped out to host, or decoding."""
        s = self.scheduler
        return bool(
            s.queue or s.waiting or s.swapped
            or any(d.requests for d in self.decodes)
        )


    def _forget(self, rid: int) -> None:
        """Drop every piece of host bookkeeping for a request that exited —
        finished, prefill-only, or abandoned — so long-running servers cannot
        leak hash memos, prefix pins, or chunk holds (the churn-loop
        regression)."""
        self._finish_chunked(rid, admitted=False)
        self.scheduler.forget(rid)
        if self.faults is not None:
            self.faults.forget(rid)
        for d in self.decodes:
            self._hash_memo.pop((rid, getattr(d, "page_size", 0)), None)
            if getattr(d, "prefix", None) is not None:
                d.release_prefix_pin(rid)
                d.prefix.swap_unpin(rid)

    # -- robustness: cancellation, deadlines, crash recovery, auditing ------

    def _stage_of(self, rid: int) -> str:
        """Which lifecycle stage a request currently occupies (see
        docs/serving.md state diagram): queued -> chunking -> waiting ->
        decoding -> swapped -> done."""
        req = self.all_requests.get(rid)
        if req is not None and req.done:
            return "done"
        if rid in self.chunks:
            return "chunking"
        s = self.scheduler
        if any(r.rid == rid for r in s.queue):
            return "queued"
        if any(e.req.rid == rid for e in s.waiting):
            return "waiting"
        if any(sw.req.rid == rid for sw in s.swapped):
            return "swapped"
        if any(rid in d.requests for d in self.decodes):
            return "decoding"
        return "unknown"

    def cancel(self, rid: int, *, status: str = STATUS_CANCELLED) -> bool:
        """Cleanly abort a request at WHATEVER lifecycle stage it occupies —
        queued, mid-chunk-prefill, prefilled-waiting, decoding, or
        swapped-out — returning every resource it holds (chunk holds, prefix
        pins, swap pins, device page refs) to zero.  Returns False when the
        request is unknown or already terminal (cancellation raced a
        finish: the finish wins and keeps its tokens).

        ``status`` is recorded on the request (``CANCELLED`` by default;
        the deadline sweep passes ``DEADLINE``, load shedding ``SHED``, and
        fault exhaustion ``FAILED``).  Tokens already streamed stay on
        ``req.tokens`` — a cancelled stream is truncated, not erased."""
        req = self.all_requests.get(rid)
        if req is None or req.done:
            return False
        s = self.scheduler
        # queued (incl. mid-chunk: the request sits at the queue head between
        # chunks; _forget below tears down the chunk cursor and its holds)
        s.queue = [r for r in s.queue if r.rid != rid]
        # prefilled-waiting: drop the entry; _forget releases the match pin
        s.waiting = [e for e in s.waiting if e.req.rid != rid]
        # swapped-out: drop the host stash; _forget releases the swap pins
        s.swapped = [sw for sw in s.swapped if sw.req.rid != rid]
        # decoding: free the device slot on whichever engine holds it
        for d in self.decodes:
            if d.abort(rid):
                break
        req.done = True
        req.status = status
        self._forget(rid)
        return True

    def _enforce_deadlines(self) -> None:
        """Cancel (status DEADLINE) every live request past its deadline:
        ``deadline_rounds`` bounds total scheduling rounds since submit,
        ``ttft_deadline`` bounds rounds to the FIRST token.  Runs at the top
        of each round, before any work is spent on expired requests."""
        s = self.scheduler
        for rid, req in list(self.all_requests.items()):
            if req.done:
                continue
            waited = s.round - s.submit_round.get(rid, s.round)
            if req.deadline_rounds is not None and waited >= req.deadline_rounds:
                self.cancel(rid, status=STATUS_DEADLINE)
            elif (
                req.ttft_deadline is not None
                and not req.tokens
                and waited >= req.ttft_deadline
            ):
                self.cancel(rid, status=STATUS_DEADLINE)

    def crash_engine(self, engine: DecodeEngine, *, preserve_kv: bool = False):
        """Simulate ``engine`` dying mid-trace and recover every request that
        touched it (the fault plan's ``crash_round`` routes here).

        Requests merely ROUTED to the dead engine (prefilled-waiting with a
        prefix match there, mid-chunk streams, swap stashes keeping device
        pages there) lose KV that lived in its pool and REPLAY: tokens are
        reset and the bare request requeues, rerouted from scratch —
        prefix-cache hits on surviving engines make the replay cheap, and
        greedy streams re-derive bit-identically.  In-flight DECODING
        requests either replay too (hard crash) or — ``preserve_kv`` — are
        extracted to host stashes and resubmitted through the ordinary
        swap-in path on the reinitialised engine (see ``DecodeEngine.crash``).
        Returns the set of affected rids; details land on
        ``self.crash_events``."""
        s = self.scheduler
        replay: List[GenRequest] = []
        # waiting entries whose prefix match pinned pages on the dead engine
        # (their uncached-tail KV pack references those pages at admit time);
        # matchless entries admit self-contained packs and survive anywhere
        kept_waiting = []
        for e in s.waiting:
            if e.engine is engine and e.match is not None and e.match.n_shared > 0:
                replay.append(e.req)
            else:
                kept_waiting.append(e)
        s.waiting = kept_waiting
        # mid-chunk streams: their pages died with the pool.  Pop the cursor
        # WITHOUT _finish_chunked — releasing holds against the about-to-be
        # reinitialised state would corrupt the fresh refcounts.  The request
        # itself is still in the queue; reset it to restart chunking.
        for rid, st in list(self.chunks.items()):
            if st.engine is engine:
                del self.chunks[rid]
                replay.append(st.req)
        # swap stashes keeping device pages on the dead engine (n_keep > 0);
        # fully host-side packs (n_keep == 0) survive a dead pool untouched
        kept_swapped = []
        for sw in s.swapped:
            if sw.engine is engine and sw.n_keep > 0:
                replay.append(sw.req)
            else:
                kept_swapped.append(sw)
        s.swapped = kept_swapped
        stashes, lost = engine.crash(preserve_kv=preserve_kv)
        s.swapped.extend(stashes)
        replay.extend(lost)
        affected = {r.rid for r in replay} | {sw.req.rid for sw in stashes}
        seen = set()
        for req in replay:
            if req.rid in seen:
                continue
            seen.add(req.rid)
            req.tokens = []
            req.done = False
            req.status = STATUS_PENDING
            s.queue = [r for r in s.queue if r.rid != req.rid]
            s.forget(req.rid)
            for d in self.decodes:
                self._hash_memo.pop((req.rid, getattr(d, "page_size", 0)), None)
                if d is not engine and getattr(d, "prefix", None) is not None:
                    d.release_prefix_pin(req.rid)
                    d.prefix.swap_unpin(req.rid)
            s.add(req)  # fresh submit bookkeeping; rerouted from scratch
        self.crash_events.append({
            "round": s.round,
            "replayed": sorted(seen),
            "stashed": sorted(sw.req.rid for sw in stashes),
        })
        return affected

    def outcomes(self) -> Dict[int, "RequestOutcome"]:
        """Structured per-request status snapshot: terminal status (or
        PENDING), current lifecycle stage, and the tokens streamed so far.
        This is what ``SchedulerExhausted.statuses`` carries."""
        out: Dict[int, RequestOutcome] = {}
        for rid, req in self.all_requests.items():
            status = req.status
            if req.done and status == STATUS_PENDING:
                status = STATUS_FINISHED  # finished through a direct-engine path
            out[rid] = RequestOutcome(
                rid=rid, status=status, stage=self._stage_of(rid),
                tokens=list(req.tokens),
            )
        return out

    def audit(self, strict: bool = False) -> List[kvcache.AuditReport]:
        """Run the KV invariant auditor on every decode engine.  With
        ``strict`` raise AssertionError on any discrepancy (how
        ``audit_every`` and the chaos tests consume it)."""
        reports = [d.audit() for d in self.decodes]
        if strict:
            bad = [
                f"engine {i}: {line}"
                for i, rep in enumerate(reports) if not rep.ok
                for line in rep.discrepancies
            ]
            if bad:
                raise AssertionError(
                    "KV invariant audit failed:\n  " + "\n  ".join(bad)
                )
        return reports

    # -- chunked prefill (the streaming page-level KV handoff) --------------

    def chunk_pending(self, req: GenRequest) -> bool:
        """Whether this request prefills through the chunked path: already in
        progress, or long enough to start chunking once it reaches the queue
        head (some prefill engine has ``chunk_tokens`` set and a paged decode
        engine can eventually host the whole request).  Used by the policies
        to keep such requests out of monolithic prefill groups and to rank
        them by their next-chunk page quantum."""
        if req.rid in self.chunks:
            return True
        ce = next((e for e in self.prefills if e.chunk_tokens), None)
        return (
            ce is not None
            and len(req.prompt) > ce.chunk_tokens
            and any(
                d.paged and d.can_ever_admit(len(req.prompt), req.max_new_tokens)
                for d in self.decodes
            )
        )

    def next_chunk_pages(self, req: GenRequest) -> Optional[int]:
        """Pages the request's NEXT chunked-prefill step will take from the
        pool, or None for requests on the monolithic path.  This is the
        reservation quantum chunk-granular scheduling works in: a 32k prompt
        mid-stream competes for ``chunk_tokens / page_size`` pages per round,
        not its whole footprint (``KVAwareScheduler`` ranks by it)."""
        st = self.chunks.get(req.rid)
        if st is not None:
            d = st.engine
            remaining = len(st.req.prompt) - st.pos
            if remaining > st.chunk_tokens:
                return st.chunk_tokens // d.page_size
            # final chunk: what admission must still reserve beyond the
            # already-streamed pages (tail + growth)
            return max(
                d._pages_needed(len(st.req.prompt), req.max_new_tokens)
                - len(st.all_pages),
                0,
            )
        if not self.chunk_pending(req):
            return None
        # not started yet: estimate against the engine _start_chunk's
        # fallback would route to (most free pages among those that can
        # ever host the request) — prefix-match routing may still pick a
        # different pool, but the filter matches the start path's
        ce = next(e for e in self.prefills if e.chunk_tokens)
        d = max(
            (dd for dd in self.decodes
             if dd.paged and dd.can_ever_admit(len(req.prompt), req.max_new_tokens)),
            key=lambda dd: dd.free_pages,
        )
        return -(-ce.chunk_tokens // d.page_size)

    def _chunk_engine(self, eng: PrefillEngine, req: GenRequest) -> Optional[PrefillEngine]:
        """The prefill engine to run this round's chunk on (the round's own
        engine when chunk-enabled, else any chunk-enabled one), or None when
        the head takes the monolithic path."""
        if not self.chunk_pending(req):
            return None
        if eng.chunk_tokens:
            return eng
        return next((e for e in self.prefills if e.chunk_tokens), None)

    def _start_chunk(self, eng: PrefillEngine, req: GenRequest) -> ChunkPrefillState:
        """Route a fresh chunked prefill: prefer the prefix-cache engine
        already holding the longest prefix of this prompt (its cached chunks
        are skipped outright — the cursor starts past them), else the paged
        engine with the most free pages.  The routing is fixed for the whole
        chunked prefill: streamed pages are physical ids in that pool."""
        m, d = self.scheduler.match_for(self, req)
        if not (m is not None and d is not None and d._tail_ok and m.n_shared > 0):
            m = None
            cands = [
                dd for dd in self.decodes
                if dd.paged and dd.can_ever_admit(len(req.prompt), req.max_new_tokens)
            ]
            d = max(cands, key=lambda dd: dd.free_pages)
        if eng.chunk_tokens % d.page_size:
            raise ValueError(
                f"chunk_tokens {eng.chunk_tokens} must be a multiple of the "
                f"decode engine's page_size {d.page_size} (chunk boundaries "
                f"are page-aligned)"
            )
        hashes: List[bytes] = []
        if d.prefix is not None:
            hk = (req.rid, d.page_size)
            hashes = self._hash_memo.get(hk) or chunk_hashes(
                req.prompt, d.page_size, d.pages_per_slot
            )
        st = ChunkPrefillState(
            req=req, engine=d, chunk_tokens=eng.chunk_tokens, hashes=hashes
        )
        if m is not None:
            d.pin_prefix(req.rid, m)
            st.matched = list(m.pages)
            st.pos = m.n_shared * d.page_size
        self.chunks[req.rid] = st
        return st

    def _chunk_prefix_arg(self, st: ChunkPrefillState, B: int):
        """The prefix pack for the next chunk: every already-computed page,
        gathered from the routed pool into a pow2-page-bucketed pack (so
        prefix-length jit keys stay log-bounded), plus — hybrid models — the
        carried conv/SSD state per mamba pattern position.  ``B`` right-pads
        the batch axis (trash-mapped table rows / zero carry rows) to match a
        padded final-chunk call; the padding rows are dummy by contract."""
        d = st.engine
        if st.pos == 0:
            return None
        n_pg = st.pos // d.page_size
        n_pg_b = 1 << max(n_pg - 1, 0).bit_length()  # pow2 >= n_pg
        n_pg_b = min(max(n_pg_b, 1), d.pages_per_slot)
        tables = np.full((B, n_pg_b), d.n_pages, np.int32)
        tables[0, :n_pg] = st.all_pages
        pack = d.gather_prefix(tables)
        if st.carry is not None:
            def pad_b(a):
                if a.shape[1] == B:
                    return a
                return jnp.pad(
                    a, [(0, 0), (0, B - a.shape[1])] + [(0, 0)] * (a.ndim - 2)
                )
            pack = [
                jax.tree.map(pad_b, st.carry[i]) if st.carry[i] is not None
                else pack[i]
                for i in range(len(pack))
            ]
        return pack

    def _prefill_chunk_round(self, eng: PrefillEngine, head: GenRequest) -> None:
        """Run ONE chunk of the queue head's chunked prefill: gather the
        streamed prefix, prefill [pos, pos + chunk) on the prefill engine,
        and either append the chunk's pages to the routed decode pool
        (non-final; the request requeues so other work interleaves) or
        finish — the final chunk's logits yield the first token and the
        request joins the waiting list as an ordinary tail-pack admission."""
        sched = self.scheduler
        st = self.chunks.get(head.rid) or self._start_chunk(eng, head)
        d = st.engine
        remaining = len(head.prompt) - st.pos
        final = remaining <= st.chunk_tokens
        sched.queue.pop(0)
        if not final and (
            st.chunk_tokens // d.page_size > d.free_pages + d._evictable_pages()
        ):
            # the pool cannot take this chunk yet; hold the head position and
            # let decode drain pages into it (no prefill happens this round)
            sched.queue.insert(0, head)
            return
        n = remaining if final else st.chunk_tokens
        key = self._next_key() if final else self._chunk_key
        # the final chunk pads its batch like any prefill group so the
        # sampled first token is bit-identical to the monolithic path
        pad = (self.max_prefill_batch if eng.bucketed else None) if final else None
        tok, kvb = eng.prefill_chunk(
            head, key, pos=st.pos, n_tokens=n,
            prefix=self._chunk_prefix_arg(st, pad or 1), pad_to=pad,
        )
        kvb = self.transfer(kvb)  # per-chunk KV handoff (page stream)
        if final:
            m = PrefixMatch(
                pages=st.all_pages, n_shared=len(st.all_pages),
                hashes=list(st.hashes), tail=True,
            )
            if head.max_new_tokens <= 1:
                head.tokens.append(tok)
                head.done = True
                head.status = STATUS_FINISHED
                sched.note_admitted(head.rid)
                self._forget(head.rid)  # releases the chunk holds and pins
            else:
                sched.waiting.append(
                    WaitingEntry(head, kvb, 0, tok, len(head.prompt), m, d)
                )
        else:
            pages = d.append_chunk(kvb, n, rid=head.rid)
            if pages is None:  # capacity raced away (or an injected page-
                # stream fault); recompute next round — unless the fault plan
                # says this request's stream is permanently broken
                if self.faults is not None and self.faults.exhausted(
                    "chunk_append", head.rid
                ):
                    self.cancel(head.rid, status=STATUS_FAILED)
                    return
                sched.queue.insert(0, head)
                return
            st.pages.extend(pages)
            st.pos += n
            if d._is_hybrid:
                st.carry = [
                    kvb[i] if mixer == "mamba" else None
                    for i, (mixer, _) in enumerate(d.cfg.block_pattern)
                ]
            sched.requeue_partial(head)

    # -- unified batching (decode-maximal rounds) ---------------------------

    #: rounds a deferred chunk head may wait before it runs regardless of
    #: the budget (starvation bound for tight budgets under saturated decode)
    UNIFIED_DEFER_LIMIT = 4

    def round_token_budget(self, quantum: int) -> int:
        """This round's token budget: decode tokens + rider chunk tokens
        must fit under it.  The configured ``token_budget`` if set; the
        default — full decode pools plus a full prefill batch of chunks —
        always fits the head's chunk (never defers) AND leaves rider
        headroom, so idle decode capacity converts into chunk progress
        (pure throughput mode).  A TIGHTER budget is the TBT lever:
        saturated-decode rounds shed riders, then become decode-only, and
        chunk work waits for drained slots."""
        if self._token_budget is not None:
            return self._token_budget
        return (sum(d.max_slots * d.decode_block for d in self.decodes)
                + self.max_prefill_batch * quantum)

    def chunk_rider_ok(self, head: GenRequest, r: GenRequest) -> bool:
        """Mechanism filter for unified-round riders: ``r`` may share the
        head's batched chunk dispatch iff its chunked prefill is already
        ROUTED (its first chunk ran as a head round — routing is fixed at
        start, so only started requests are known to live on the head's
        pool), on the same engine at the same quantum, and its next chunk is
        non-final.  The scheduler's ``pick_riders`` ranks among these."""
        if r.rid == head.rid:
            return False
        hst = self.chunks.get(head.rid)
        st = self.chunks.get(r.rid)
        if hst is None or st is None:
            return False
        if st.engine is not hst.engine or st.chunk_tokens != hst.chunk_tokens:
            return False
        return len(r.prompt) - st.pos > st.chunk_tokens

    def _group_chunk_prefix_arg(self, sts: List[ChunkPrefillState], B: int):
        """Per-row prefix pack for a batched chunk round: row i gets its own
        streamed pages (one pow2-bucketed gather over the shared pool) and —
        hybrid models — its own carried conv/SSD state, zero for rows still
        at position 0 (a fresh mamba scan starts from the zero state, so
        zero-carry IS the pos-0 semantics)."""
        d = sts[0].engine
        if all(st.pos == 0 for st in sts):
            return None
        n_pg = [st.pos // d.page_size for st in sts]
        n_pg_b = 1 << max(max(n_pg) - 1, 0).bit_length()  # pow2 >= max rows
        n_pg_b = min(max(n_pg_b, 1), d.pages_per_slot)
        tables = np.full((B, n_pg_b), d.n_pages, np.int32)
        for i, st in enumerate(sts):
            if n_pg[i]:
                tables[i, : n_pg[i]] = st.all_pages
        pack = d.gather_prefix(tables)
        if d._is_hybrid:
            pack = list(pack)
            for li, (mixer, _) in enumerate(d.cfg.block_pattern):
                if mixer != "mamba":
                    continue
                rows = [
                    st.carry[li] if st.carry is not None else None for st in sts
                ]
                ref = next((c for c in rows if c is not None), None)
                if ref is None:
                    continue  # every row at pos 0: the gathered pack row is unused
                rows = [
                    c if c is not None else jax.tree.map(jnp.zeros_like, ref)
                    for c in rows
                ]

                def cat(*ls):  # leaves [?, 1, ...] -> [?, B, ...] (axis 1 = batch)
                    out = jnp.concatenate(ls, axis=1)
                    if out.shape[1] < B:
                        out = jnp.pad(
                            out,
                            [(0, 0), (0, B - out.shape[1])]
                            + [(0, 0)] * (out.ndim - 2),
                        )
                    return out

                pack[li] = jax.tree.map(cat, *rows)
        return pack

    def _unified_chunk_round(self, eng: PrefillEngine, head: GenRequest) -> None:
        """One DECODE-MAXIMAL chunk round: batch page-aligned chunks of
        several chunked requests into one prefill dispatch, sized so the
        round's chunk work plus the decode pools' planned tokens fit the
        token budget.  Three outcomes:

        * the budget's chunk allowance covers >= 1 chunk: the head plus up
          to ``allowance // quantum - 1`` riders (scheduler-ranked, capped
          by the pool's free pages) run as ONE batched prefill, every row
          appended to the shared pool and requeued;
        * the allowance is short (decode pools saturated under a tight
          budget): the round is DECODE-ONLY — chunk work defers, decoding
          requests keep their TBT — bounded by ``UNIFIED_DEFER_LIMIT``
          rounds before the head runs anyway (starvation bound);
        * the head's next chunk is FINAL: delegate to the serial round —
          the first-token sample must replay the serial pad/key schedule
          bit for bit, so finals never batch with riders.
        """
        sched = self.scheduler
        st = self.chunks.get(head.rid) or self._start_chunk(eng, head)
        d = st.engine
        if len(head.prompt) - st.pos <= st.chunk_tokens:
            self._prefill_chunk_round(eng, head)
            return
        q = st.chunk_tokens
        budget = self.round_token_budget(q)
        decode_tokens = sum(
            dd.slots.n_active * dd._auto_block()
            for dd in self.decodes if dd.slots.n_active
        )
        allowance = budget - decode_tokens
        self.unified_stats["rounds"] += 1
        self.unified_stats["budget_tokens"] += budget
        self.unified_stats["used_tokens"] += decode_tokens
        if allowance < q and self._defer_rounds < self.UNIFIED_DEFER_LIMIT:
            self._defer_rounds += 1
            self.unified_stats["deferred_rounds"] += 1
            return  # decode-only round; the head keeps its queue position
        self._defer_rounds = 0
        pg_per_row = q // d.page_size
        cap_rows = (d.free_pages + d._evictable_pages()) // max(pg_per_row, 1)
        if cap_rows < 1:
            # the pool cannot take even the head's chunk; hold the head and
            # let decode drain pages into it (the serial path's contract)
            return
        max_rows = min(
            max(allowance // q, 1),  # aging override still runs the head
            cap_rows,
            self.max_prefill_batch,
        )
        riders = (
            sched.pick_riders(self, head, max_rows - 1) if max_rows > 1 else []
        )
        rows = [head] + riders
        taken = {r.rid for r in rows}
        sched.queue = [r for r in sched.queue if r.rid not in taken]
        sts = [self.chunks[r.rid] for r in rows]
        B = len(rows)
        B_pad = 1 << max(B - 1, 0).bit_length()  # pow2 rows: bounded jit keys
        kvb = eng.prefill_chunk_group(
            [(r, self.chunks[r.rid].pos) for r in rows], q, self._chunk_key,
            prefix=self._group_chunk_prefix_arg(sts, B_pad), pad_to=B_pad,
        )
        kvb = self.transfer(kvb)  # per-round KV handoff (page stream)
        self.unified_stats["chunk_rows"] += B
        self.unified_stats["used_tokens"] += B * q
        for i, r in enumerate(rows):
            rst = self.chunks[r.rid]
            pages = d.append_chunk(kvb, q, batch_index=i, rid=r.rid)
            if pages is None:  # capacity raced away or an injected fault
                if self.faults is not None and self.faults.exhausted(
                    "chunk_append", r.rid
                ):
                    self.cancel(r.rid, status=STATUS_FAILED)
                    continue
                sched.queue.insert(0, r)  # retry next round, head position
                continue
            rst.pages.extend(pages)
            rst.pos += q
            if d._is_hybrid:
                rst.carry = [
                    jax.tree.map(lambda a: a[:, i : i + 1], kvb[li])
                    if mixer == "mamba" else None
                    for li, (mixer, _) in enumerate(d.cfg.block_pattern)
                ]
            sched.requeue_partial(r)

    def _finish_chunked(self, rid: int, *, admitted: bool) -> None:
        """Retire a chunked prefill's host state.  ``admitted=True`` (the
        final admit mapped the streamed pages into a block table): register
        the full-chunk pages in the prefix index, then drop the chunk holds —
        the slot (and any cache holds) keep the pages alive.
        ``admitted=False`` (prefill-only finish / abandon): just drop the
        holds and pins; unregistered pages recycle at refcount 0."""
        st = self.chunks.pop(rid, None)
        if st is None:
            return
        d = st.engine
        if admitted:
            d.register_chunk_pages(st.hashes, st.all_pages, start=len(st.matched))
        d.release_chunk_holds(st.pages)
        if not admitted:
            d.release_prefix_pin(rid)

    def _dedup_group(self, eng: PrefillEngine, group, matches):
        """Batch-level prefix dedup (``EngineConfig.batch_dedup``).

        Requests landing in the SAME bucketed prefill dispatch that share a
        page-aligned token prefix with EACH OTHER — but match nothing already
        cached — would each prefill that prefix redundantly: the admit-time
        re-match only shares the PAGES, after the compute is already spent.
        This pre-pass clusters group members by chained chunk hash, streams
        each cluster's common prefix through the chunked-prefill machinery
        ONCE (B=1, the fixed dummy chunk key — the server PRNG chain is
        untouched, so every later draw replays the non-dedup schedule bit for
        bit), registers the pages in the routed engine's prefix index, and
        synthesizes a ``PrefixMatch`` per member; the group then takes the
        ordinary tail-only prefill path.  Returns the updated ``matches``;
        any capacity shortfall leaves the affected cluster unmatched — dedup
        is an optimization, never an admission requirement."""
        cands = [d for d in self.decodes if d.prefix is not None and d._tail_ok]
        if not cands:
            return matches
        d = max(cands, key=lambda dd: dd.max_slots - dd.slots.n_active)
        ps = d.page_size
        hs = [chunk_hashes(r.prompt, ps, d.pages_per_slot) for r in group]
        # same cap rule as match_prefix: >= 1 tail token is always recomputed
        caps = [
            min((len(r.prompt) - 1) // ps, d.pages_per_slot) for r in group
        ]
        by_head: Dict[bytes, List[int]] = {}
        for i, h in enumerate(hs):
            if caps[i] >= 1 and h:
                by_head.setdefault(h[0], []).append(i)
        out = list(matches)
        for members in by_head.values():
            if len(members) < 2:
                continue
            lead = members[0]
            # chained hashes are prefix-complete: equality at chunk j means
            # the whole j-page prefix matches across the cluster
            n_shared = min(caps[i] for i in members)
            for j in range(n_shared):
                if any(hs[i][j] != hs[lead][j] for i in members[1:]):
                    n_shared = j
                    break
            if n_shared < 1:
                continue
            _, kvb = eng.prefill_chunk(
                group[lead], self._chunk_key, pos=0, n_tokens=n_shared * ps
            )
            kvb = self.transfer(kvb)  # KV handoff, same as any prefill
            pages = d.append_chunk(
                kvb, n_shared * ps, rid=group[lead].rid
            )
            if pages is None:  # pool can't take the prefix right now
                continue
            d.register_chunk_pages(hs[lead][:n_shared], pages, start=0)
            for i in members:
                m = d.match_prefix(group[i].prompt, hashes=hs[i])
                if m is not None and m.n_shared:
                    d.pin_prefix(group[i].rid, m)
                    out[i] = (m, d)
            d.release_chunk_holds(pages)
            # the shared chunk is a real prefill dispatch: count it, so
            # prefill_tokens + dedup_saved_tokens always equals the tokens a
            # dedup-free schedule would have dispatched
            self.unified_stats["prefill_tokens"] += n_shared * ps
            self.unified_stats["dedup_groups"] += 1
            self.unified_stats["dedup_saved_tokens"] += (
                (len(members) - 1) * n_shared * ps
            )
        return out

    def _prefill_group(self, eng: PrefillEngine, group, matches) -> None:
        """Prefill one compatible group and hand the KV off: prefix-matched
        requests prefill only their uncached tails (attention-only engines),
        finished prefill-only requests complete here, the rest join the
        scheduler's waiting list."""
        sched = self.scheduler
        pad_to = self.max_prefill_batch if eng.bucketed else None
        # batch-level prefix dedup: members of THIS dispatch sharing a
        # page-aligned prefix with each other (but matching nothing cached)
        # get synthesized PrefixMatches so the shared prefix runs once
        if self.batch_dedup and len(group) > 1 and all(
            m is None for m, _ in matches
        ):
            matches = self._dedup_group(eng, group, matches)
        # prefix sharing: gather the matched pages from the routed decode
        # engine's pool and prefill only the uncached tails (attention-
        # only engines; hybrids recompute in full but still map the
        # shared pages at admit)
        prefix_arg = None
        routed = next((d for (m, d) in matches if m is not None), None)
        if routed is not None and routed._tail_ok:
            n_pg_b = max(
                sched.group_key(r, m, d, eng.buckets)[1] or 1
                for r, (m, d) in zip(group, matches, strict=False)
            )
            B_pad = max(pad_to or len(group), len(group))
            tables = np.full((B_pad, n_pg_b), routed.n_pages, np.int32)
            shared_lens = []
            for i, (m, _) in enumerate(matches):
                ns = 0 if m is None else m.n_shared
                if ns:
                    tables[i, :ns] = m.pages
                shared_lens.append(ns * routed.page_size)
            prefix_arg = (routed.gather_prefix(tables), shared_lens)
            for m, _ in matches:
                if m is not None:
                    m.tail = True  # the pack below holds only the tails
        self.unified_stats["prefill_tokens"] += (
            sum(len(r.prompt) for r in group) if prefix_arg is None
            else sum(
                len(r.prompt) - s
                for r, s in zip(group, prefix_arg[1], strict=False)
            )
        )
        toks, kvb, tls = eng.prefill_batch(
            group, self._next_key(), pad_to=pad_to, prefix=prefix_arg
        )
        kvb = self.transfer(kvb)  # KV handoff (pod-to-pod in production)
        for i, req in enumerate(group):
            m, d = matches[i]
            if req.max_new_tokens <= 1:
                req.tokens.append(toks[i])
                req.done = True
                req.status = STATUS_FINISHED
                if m is not None:
                    d.release_prefix_pin(req.rid)
                sched.note_admitted(req.rid)
                self._forget(req.rid)
            else:
                sched.waiting.append(
                    WaitingEntry(req, kvb, i, toks[i], tls[i], m, d)
                )

    def _try_admit(self, e: WaitingEntry) -> bool:
        """Admit one waiting entry into a decode engine with capacity (a free
        slot and, for paged engines, enough unreserved KV pages) — most spare
        capacity first.  Prefix-matched requests are ROUTED: their shared
        pages (and, for tail-only packs, the only pool that can complete
        them) live in the matching engine."""
        req, m, d = e.req, e.match, e.engine
        admitted = False
        if m is not None and m.n_shared > 0:
            if d.can_admit(e.true_len, req.max_new_tokens, n_shared=m.n_shared):
                admitted = (
                    d.admit(req, e.kv, e.first_token, e.true_len,
                            batch_index=e.batch_index, prefix=m)
                    is not None
                )
        else:
            cands = [
                dd for dd in self.decodes
                if dd.can_admit(e.true_len, req.max_new_tokens)
            ]
            if cands:
                dec = max(cands, key=lambda dd: dd.max_slots - dd.slots.n_active)
                admitted = (
                    dec.admit(req, e.kv, e.first_token, e.true_len,
                              batch_index=e.batch_index)
                    is not None
                )
        if admitted:
            self.scheduler.note_admitted(req.rid)
            if req.rid in self.chunks:
                self._finish_chunked(req.rid, admitted=True)
        return admitted

    def run_round(self):
        """One scheduling round: batched prefill (or one CHUNK of a long
        prompt's streaming prefill), swap-ins, policy-ordered admission
        (with the preemption hook), fused decode blocks."""
        sched = self.scheduler
        sched.begin_round(self)
        # 0) failure machinery first: the fault clock ticks (in lockstep with
        # the scheduler round), a planned engine crash fires, expired
        # deadlines cancel, and the shedding policy drops hopeless queue
        # entries — all BEFORE any work is spent on them
        if self.faults is not None:
            self.faults.begin_round()
            if self.faults.crash_due():
                victim = self.decodes[
                    self.faults.plan.crash_engine % len(self.decodes)
                ]
                self.crash_engine(
                    victim, preserve_kv=self.faults.plan.preserve_kv
                )
        if self._has_deadlines:
            self._enforce_deadlines()
        if sched.shed_after_rounds is not None:
            for r in sched.shed(self):
                if self.cancel(r.rid, status=STATUS_SHED):
                    sched.stats["shed"] += 1
        # 1) one same-bucket prefill batch per round (round-robin engines).
        # Gate on free decode capacity: each waiting entry pins its whole
        # padded batch pack on device, so prefilling ahead of slots the
        # decode pool can't absorb only accumulates dead KV buffers.
        free_slots = sum(d.max_slots - d.slots.n_active for d in self.decodes)
        if sched.queue and len(sched.waiting) < max(free_slots, 1):
            eng = self.prefills[self._rr % len(self.prefills)]
            self._rr += 1
            ceng = self._chunk_engine(eng, sched.queue[0])
            if ceng is not None:
                if self.unified_batching:
                    self._unified_chunk_round(ceng, sched.queue[0])
                else:
                    self._prefill_chunk_round(ceng, sched.queue[0])
            else:
                if eng.bucketed:
                    group, matches = sched.take_group(self, eng.buckets)
                else:
                    group, matches = [sched.queue.pop(0)], [(None, None)]
                self._prefill_group(eng, group, matches)
        # 2) swapped-out requests first (they already earned their slot once),
        # then waiting entries in policy order; a blocked entry gives the
        # policy one preemption attempt before it stays waiting
        sched.try_swap_in(self)
        if self.faults is not None and self.faults.plan.give_up:
            # a give_up plan turns exhausted retry budgets into terminal
            # FAILED statuses instead of retrying forever
            for sw in list(sched.swapped):
                if self.faults.exhausted("swap_in", sw.req.rid):
                    self.cancel(sw.req.rid, status=STATUS_FAILED)
        admitted = set()
        for e in sched.admit_order(self):
            if self.faults is not None and self.faults.exhausted(
                "admit", e.req.rid
            ):
                self.cancel(e.req.rid, status=STATUS_FAILED)
                continue  # cancel already removed it from waiting
            ok = self._try_admit(e)
            if not ok and sched.on_blocked(self, e):
                ok = self._try_admit(e)
            if ok:
                admitted.add(id(e))
            elif sched.barrier(self, e):
                break  # capacity drains to this aged entry; no backfilling
        if admitted:
            sched.waiting = [e for e in sched.waiting if id(e) not in admitted]
        self.peak_active = max(
            self.peak_active, sum(d.slots.n_active for d in self.decodes)
        )
        # 3) one fused decode block everywhere; finished requests drop their
        # host bookkeeping on the way out (every exit path funnels here).
        # .get(): an engine may carry requests the server never saw (fork()
        # best-of-n branches admitted directly on the engine)
        for dec in self.decodes:
            for rid in {r for r, _ in dec.step_block()}:
                req = self.all_requests.get(rid)
                if req is not None and req.done:
                    self._forget(rid)
        # 4) periodic KV invariant audit (strict: any refcount / block-table
        # discrepancy is a bug worth dying loudly for, even in production)
        if self.audit_every and sched.round % self.audit_every == 0:
            self.audit(strict=True)

    def drain(self, max_rounds: Optional[int] = None) -> Dict[int, RequestOutcome]:
        """THE drain contract (documented once, here — ``run()`` and
        ``run_round()`` are views over it):

        Runs scheduling rounds until nothing is pending (no request queued,
        waiting, swapped, or decoding) or ``max_rounds`` rounds have run
        (``None`` = unbounded), then returns ``outcomes()`` — a structured
        rid -> ``RequestOutcome`` snapshot of EVERY submitted request,
        terminal or not.  ``drain`` never raises on leftover work: check
        ``pending()`` or the returned stages to see whether it finished.

        RESUME: the server is always left fully intact — queued / waiting /
        swapped / decoding state, device pages, pins, and holds all live —
        so calling ``drain()`` (or ``run()``, or ``run_round()``) again
        continues exactly where it stopped; nothing is dropped.  The three
        entry points differ only in step count and error signalling:

        * ``run_round()`` — exactly one round, no completion check;
        * ``drain(max_rounds)`` — up to ``max_rounds`` rounds, returns
          outcomes, never raises;
        * ``run(max_steps)`` — ``drain(max_steps)`` + raises
          ``SchedulerExhausted`` (carrying the same outcomes snapshot as
          ``statuses``) if work remains, else returns the legacy
          ``{rid: tokens}`` view.  Kept as the anchor-compatible alias every
          existing trace and test drives."""
        rounds = 0
        while self.pending() and (max_rounds is None or rounds < max_rounds):
            rounds += 1
            self.run_round()
        return self.outcomes()

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive to completion; returns ``{rid: tokens}`` for every request
        that reached a terminal status (including cancelled/expired ones —
        check ``req.status`` or ``self.outcomes()`` to tell them apart).
        Anchor-compatible alias of ``drain(max_steps)`` — see ``drain`` for
        the unified contract — that raises ``SchedulerExhausted`` (resumable:
        triage, then call ``run()`` again) if rounds run out first."""
        self.drain(max_steps)
        if self.pending():
            done = {rid: r.tokens for rid, r in self.all_requests.items() if r.done}
            unfinished = sorted(
                rid for rid, r in self.all_requests.items() if not r.done
            )
            raise SchedulerExhausted(
                f"hit max_steps={max_steps} with {len(unfinished)} request(s) "
                f"unfinished: {unfinished[:8]}{'...' if len(unfinished) > 8 else ''}",
                done=done,
                unfinished=unfinished,
                statuses=self.outcomes(),
            )
        return {rid: r.tokens for rid, r in self.all_requests.items() if r.done}


class MonolithicEngine:
    """Co-located baseline: one engine interleaves prefill and decode."""

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int = 8, max_len: int = 512,
                 sampling: Optional[SamplingParams] = None, seed: int = 0,
                 decode_block: int = 8, paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None):
        self.prefill = PrefillEngine(params, cfg, sampling)
        self.decode = DecodeEngine(params, cfg, max_slots=max_slots, max_len=max_len,
                                   sampling=sampling, seed=seed, decode_block=decode_block,
                                   paged=paged, page_size=page_size, n_pages=n_pages)
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[GenRequest] = []
        self.all_requests: Dict[int, GenRequest] = {}

    def submit(self, req: GenRequest):
        n = len(req.prompt)
        if self.prefill.bucketed and n > self.prefill.buckets[-1]:
            raise ValueError(
                f"request {req.rid}: prompt length {n} exceeds the largest "
                f"prefill bucket {self.prefill.buckets[-1]}"
            )
        if req.max_new_tokens > 1 and not self.decode.can_ever_admit(
            n, req.max_new_tokens
        ):
            raise ValueError(
                f"request {req.rid}: prompt {n} + max_new_tokens "
                f"{req.max_new_tokens} exceeds decode capacity (max_len "
                f"{self.decode.max_len})"
            )
        self.queue.append(req)
        self.all_requests[req.rid] = req

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or self.decode.requests) and steps < max_steps:
            steps += 1
            if self.queue:
                req = self.queue[0]
                if self.decode.can_admit(len(req.prompt), req.max_new_tokens) or (
                    req.max_new_tokens <= 1
                ):
                    self.queue.pop(0)
                    tok, kv, true_len = self.prefill.prefill(req, self._next_key())
                    if req.max_new_tokens <= 1:
                        req.tokens.append(tok)
                        req.done = True
                        req.status = STATUS_FINISHED
                    else:
                        self.decode.admit(req, kv, tok, true_len)
            self.decode.step_block()
        if self.queue or self.decode.requests:
            done = {rid: r.tokens for rid, r in self.all_requests.items() if r.done}
            unfinished = sorted(
                rid for rid, r in self.all_requests.items() if not r.done
            )
            statuses = {
                rid: RequestOutcome(
                    rid=rid,
                    status=r.status if r.status != STATUS_PENDING or not r.done
                    else STATUS_FINISHED,
                    stage="done" if r.done
                    else "decoding" if rid in self.decode.requests
                    else "queued",
                    tokens=list(r.tokens),
                )
                for rid, r in self.all_requests.items()
            }
            raise SchedulerExhausted(
                f"hit max_steps={max_steps} with {len(unfinished)} request(s) "
                f"unfinished: {unfinished[:8]}{'...' if len(unfinished) > 8 else ''}",
                done=done,
                unfinished=unfinished,
                statuses=statuses,
            )
        return {rid: r.tokens for rid, r in self.all_requests.items() if r.done}
