"""Serving engines.

``PrefillEngine`` / ``DecodeEngine`` / ``DisaggregatedServer`` implement the
paper's serving architecture in JAX: prefill runs on one engine (in
production: a Prefill-Chip pod / mesh), the KV cache is handed off, and
decode proceeds with continuous batching on another engine (Decode-Chip
pod).  ``MonolithicEngine`` is the co-located baseline (same machine runs
both phases) used by tests and the quickstart example.

Engines are deliberately synchronous and single-host here (the distributed
versions are built in ``repro/launch`` via jit+shardings over the production
mesh); the scheduling logic — slots, admission, continuous batching,
bucketed prefill — is the real thing.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M
from . import kvcache
from .sampling import SamplingParams, sample


@dataclass
class GenRequest:
    rid: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    eos_id: Optional[int] = None
    # outputs
    tokens: List[int] = field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets=(64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return int(2 ** math.ceil(math.log2(n)))


# ---------------------------------------------------------------------------
# Prefill engine
# ---------------------------------------------------------------------------


class PrefillEngine:
    """Runs prompt prefill (bucketed lengths, jit-cached per bucket)."""

    def __init__(self, params, cfg: ModelConfig, sampling: SamplingParams = SamplingParams()):
        self.params = params
        self.cfg = cfg
        self.sampling = sampling
        self._fns: Dict[int, Any] = {}  # jit cache keyed by prompt length

    def _fn(self, S: int):
        if S not in self._fns:
            cfg = self.cfg
            self._fns[S] = jax.jit(lambda p, t: M.prefill(p, t, cfg))
        return self._fns[S]

    def prefill(self, req: GenRequest, key) -> Tuple[int, Any, int]:
        """Returns (first_token, kv_pack, true_len).

        Prompt lengths are padded up to power-of-two-ish buckets so the jit
        cache stays small; padding tokens are masked by running only the true
        prefix (CPU path) — the TPU path would mask inside the kernel.
        """
        S = len(req.prompt)
        toks = np.asarray(req.prompt, np.int32)[None, :]
        logits, caches, _ = self._fn(S)(self.params, jnp.asarray(toks))
        tok = int(sample(logits, key, self.sampling)[0])
        return tok, caches, S


# ---------------------------------------------------------------------------
# Decode engine (continuous batching over slots)
# ---------------------------------------------------------------------------


class DecodeEngine:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        *,
        max_slots: int = 8,
        max_len: int = 512,
        sampling: SamplingParams = SamplingParams(),
    ):
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.sampling = sampling
        self.slots = kvcache.SlotState(max_slots, max_len)
        self.caches = kvcache.batch_cache(cfg, max_slots, max_len)
        self.tokens = np.zeros((max_slots,), np.int32)  # last emitted token
        self.positions = np.zeros((max_slots,), np.int32)  # next write position
        self.requests: Dict[int, GenRequest] = {}
        self._step = self._build_step()

    def _build_step(self):
        cfg = self.cfg

        def step(params, caches, tokens, positions, active, key):
            logits, new_caches = M.decode_step(params, tokens, caches, positions, cfg)
            nxt = sample(logits, key, self.sampling)
            # inactive slots keep emitting their old token (masked on host)
            nxt = jnp.where(active, nxt, tokens)
            return nxt, new_caches

        return jax.jit(step)

    def admit(self, req: GenRequest, kv_pack, first_token: int, true_len: int) -> Optional[int]:
        if true_len + req.max_new_tokens > self.max_len:
            raise ValueError(f"request {req.rid} needs {true_len + req.max_new_tokens} > max_len")
        slot = self.slots.alloc(req.rid)
        if slot is None:
            return None
        self.caches = kvcache.insert_request(self.caches, kv_pack, slot, self.cfg)
        self.slots.lengths[slot] = true_len
        self.tokens[slot] = first_token
        self.positions[slot] = true_len
        self.requests[req.rid] = req
        req.tokens.append(first_token)
        return slot

    def step(self, key) -> List[Tuple[int, int]]:
        """One decode iteration over all active slots.  Returns (rid, token)."""
        active_np = np.array([r is not None for r in self.slots.request_ids])
        if not active_np.any():
            return []
        nxt, self.caches = self._step(
            self.params,
            self.caches,
            jnp.asarray(self.tokens),
            jnp.asarray(self.positions),
            jnp.asarray(active_np),
            key,
        )
        nxt = np.asarray(nxt)
        out = []
        for slot, rid in enumerate(self.slots.request_ids):
            if rid is None:
                continue
            tok = int(nxt[slot])
            req = self.requests[rid]
            req.tokens.append(tok)
            self.positions[slot] += 1
            self.slots.lengths[slot] += 1
            self.tokens[slot] = tok
            out.append((rid, tok))
            n_new = len(req.tokens)
            if n_new >= req.max_new_tokens or (req.eos_id is not None and tok == req.eos_id):
                req.done = True
                self.slots.free(slot)
                del self.requests[rid]
        return out


# ---------------------------------------------------------------------------
# Disaggregated server (the paper's architecture)
# ---------------------------------------------------------------------------


class DisaggregatedServer:
    """Prefill pool -> KV handoff -> decode pool, continuous batching.

    ``transfer`` is the KV handoff hook: identity on single host; on a real
    cluster it is the pod-to-pod device transfer (see launch/serve.py).
    """

    def __init__(
        self,
        prefill_engines: List[PrefillEngine],
        decode_engines: List[DecodeEngine],
        *,
        transfer=lambda kv: kv,
        seed: int = 0,
    ):
        self.prefills = prefill_engines
        self.decodes = decode_engines
        self.transfer = transfer
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[GenRequest] = []
        self.waiting: List[Tuple[GenRequest, Any, int, int]] = []  # (req, kv, tok, len)
        self.all_requests: Dict[int, GenRequest] = {}
        self._rr = 0

    def submit(self, req: GenRequest):
        self.queue.append(req)
        self.all_requests[req.rid] = req

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Drive to completion: prefill queue, admit, decode until done."""
        steps = 0
        while (
            self.queue
            or self.waiting
            or any(d.requests for d in self.decodes)
        ) and steps < max_steps:
            steps += 1
            # 1) prefill one queued request per engine (round-robin)
            if self.queue:
                eng = self.prefills[self._rr % len(self.prefills)]
                self._rr += 1
                req = self.queue.pop(0)
                tok, kv, true_len = eng.prefill(req, self._next_key())
                kv = self.transfer(kv)  # KV handoff (pod-to-pod in production)
                if req.max_new_tokens <= 1:
                    req.tokens.append(tok)
                    req.done = True
                else:
                    self.waiting.append((req, kv, tok, true_len))
            # 2) admit waiting requests into free decode slots (most-free first)
            still = []
            for req, kv, tok, true_len in self.waiting:
                dec = max(self.decodes, key=lambda d: d.max_slots - d.slots.n_active)
                if dec.slots.n_active < dec.max_slots:
                    dec.admit(req, kv, tok, true_len)
                else:
                    still.append((req, kv, tok, true_len))
            self.waiting = still
            # 3) one decode iteration everywhere
            for dec in self.decodes:
                dec.step(self._next_key())
        return {rid: r.tokens for rid, r in self.all_requests.items() if r.done}


class MonolithicEngine:
    """Co-located baseline: one engine interleaves prefill and decode."""

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int = 8, max_len: int = 512,
                 sampling: SamplingParams = SamplingParams(), seed: int = 0):
        self.prefill = PrefillEngine(params, cfg, sampling)
        self.decode = DecodeEngine(params, cfg, max_slots=max_slots, max_len=max_len,
                                   sampling=sampling)
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[GenRequest] = []
        self.all_requests: Dict[int, GenRequest] = {}

    def submit(self, req: GenRequest):
        self.queue.append(req)
        self.all_requests[req.rid] = req

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        steps = 0
        while (self.queue or self.decode.requests) and steps < max_steps:
            steps += 1
            if self.queue and self.decode.slots.n_active < self.decode.max_slots:
                req = self.queue.pop(0)
                tok, kv, true_len = self.prefill.prefill(req, self._next_key())
                if req.max_new_tokens <= 1:
                    req.tokens.append(tok)
                    req.done = True
                else:
                    self.decode.admit(req, kv, tok, true_len)
            self.decode.step(self._next_key())
        return {rid: r.tokens for rid, r in self.all_requests.items() if r.done}
