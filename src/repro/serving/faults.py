"""Deterministic fault injection for the serving stack's lifecycle seams.

Production disaggregated serving treats failure handling as a first-class
subsystem: KV transfers time out, engines wedge mid-decode, admission races
lose.  This module supplies the test/bench half of that story — a seeded
``FaultPlan`` whose injector makes the *existing* lifecycle seams fail on
purpose, deterministically, so the recovery paths (retry, requeue, crash
resubmission, replay) are exercised and gated in CI rather than discovered
in production.

Injection sites (chosen because each already has a caller-visible "try
again later" contract, so a fault is indistinguishable from a capacity
race the code must survive anyway):

``chunk_append``   ``DecodeEngine.append_chunk`` returns None — the chunk's
                   page stream "failed"; the server leaves the request at
                   the queue head and recomputes the chunk next round.
``admit``          ``DecodeEngine.admit`` returns None — the KV handoff
                   "failed"; the entry stays waiting and retries.
``swap_in``        ``DecodeEngine.swap_in`` returns None — the host->device
                   scatter "failed"; the stash (and its pins) survive.
``swap_out``       ``DecodeEngine.swap_out`` raises ``TransientFault`` —
                   the device->host pack "failed"; the preemption policy
                   skips the victim this round (nothing was mutated).

Plus one whole-engine failure: ``crash_round`` simulates a ``DecodeEngine``
dying mid-trace (``DisaggregatedServer.crash_engine``): its device state is
reinitialised and every in-flight request is either resubmitted from a
host-side stash (``preserve_kv=True`` — the "engine wedged but HBM is
readable" case, recovered via ``kvcache.paged_extract_request``) or
replayed from the prompt (``preserve_kv=False`` — the hard crash; greedy
streams re-derive bit-identically).

Determinism contract: one ``numpy`` Generator seeded from the plan, drawn
once per (site, request) attempt in scheduler order.  Under deterministic
scheduling the whole fault schedule is a pure function of
``(plan.seed, workload)`` — any chaos-test failure replays with one
command.  Retries are bounded: after ``max_retries`` failed attempts a
site either clears (the fault "heals", default) or — ``give_up=True`` —
reports the request as permanently failed (``exhausted()`` turns True and
the server cancels it with status ``FAILED``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

#: the injectable lifecycle seams (see module docstring)
FAULT_SITES = ("chunk_append", "admit", "swap_in", "swap_out")


class TransientFault(RuntimeError):
    """A retryable injected failure at a lifecycle seam whose contract is an
    exception rather than a None return (currently only ``swap_out``).  The
    operation did NOT happen; no state was mutated; the caller may retry."""


@dataclass
class FaultPlan:
    """Seeded, declarative description of what should fail and when.

    seed            RNG seed — the whole fault schedule is a pure function
                    of it (print it; replay with it)
    rates           per-site failure probability in [0, 1] (sites absent or
                    at 0.0 never fail); see ``FAULT_SITES``
    max_retries     failed attempts per (site, request) before the fault
                    either clears or (``give_up``) turns permanent
    backoff_rounds  extra rounds a faulted (site, request) keeps failing
                    without a new draw, scaled by the attempt count
                    (0 = retry immediately next round)
    give_up         after ``max_retries``: True -> the request is
                    permanently failed (server cancels it with status
                    ``FAILED``); False (default) -> the fault heals and the
                    next attempt draws normally
    crash_round     simulate a whole-DecodeEngine crash at this scheduling
                    round (None = never)
    crash_engine    index (mod the server's decode list) of the engine to
                    crash
    preserve_kv     crash recovery mode: True = the engine's HBM is still
                    readable, in-flight requests are extracted to host
                    stashes and resubmitted; False = hard crash, in-flight
                    requests replay from their prompts
    """

    seed: int = 0
    rates: Dict[str, float] = field(default_factory=dict)
    max_retries: int = 8
    backoff_rounds: int = 0
    give_up: bool = False
    crash_round: Optional[int] = None
    crash_engine: int = 0
    preserve_kv: bool = False

    def __post_init__(self):
        unknown = set(self.rates) - set(FAULT_SITES)
        if unknown:
            raise ValueError(
                f"unknown fault site(s) {sorted(unknown)}; pick from {FAULT_SITES}"
            )


class FaultInjector:
    """Executes a ``FaultPlan``: one seeded Generator, per-(site, request)
    attempt counters, round-scaled backoff, and the crash trigger.

    The server owns exactly one injector and shares it with its decode
    engines; every ``should_fail`` call draws (or consults backoff) in
    deterministic scheduling order, so two runs with the same plan and
    workload inject byte-identical fault schedules.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = np.random.default_rng(plan.seed)
        self.round = 0
        # (site, rid) -> consecutive failed attempts / earliest retry round
        self.attempts: Dict[Tuple[str, Optional[int]], int] = {}
        self.backoff_until: Dict[Tuple[str, Optional[int]], int] = {}
        self._crashed = False
        self.stats = {"injected": {s: 0 for s in FAULT_SITES}, "crashes": 0}

    def begin_round(self) -> None:
        """Advance the injector's round clock (drives backoff + the crash)."""
        self.round += 1

    def should_fail(self, site: str, rid: Optional[int] = None) -> bool:
        """Whether this attempt at ``site`` for request ``rid`` fails.

        Draws at most once; a (site, request) under backoff keeps failing
        without a draw so the retry cadence — not the retry count — is what
        backoff stretches.  After ``max_retries`` failures the fault either
        clears (default: this attempt succeeds and the counters reset) or,
        with ``give_up``, keeps failing forever — the caller is expected to
        notice ``exhausted()`` and fail the request out structurally."""
        rate = self.plan.rates.get(site, 0.0)
        if rate <= 0.0:
            return False
        key = (site, rid)
        n = self.attempts.get(key, 0)
        if n >= self.plan.max_retries:
            if self.plan.give_up:
                return True  # permanent: exhausted() tells the caller why
            self.attempts.pop(key, None)  # bounded retry: the fault heals
            self.backoff_until.pop(key, None)
            return False
        if self.round < self.backoff_until.get(key, 0):
            return True  # still backing off; no draw, no new attempt
        if float(self.rng.random()) < rate:
            self.attempts[key] = n + 1
            self.backoff_until[key] = (
                self.round + self.plan.backoff_rounds * (n + 1)
            )
            self.stats["injected"][site] += 1
            return True
        self.attempts.pop(key, None)
        self.backoff_until.pop(key, None)
        return False

    def exhausted(self, site: str, rid: Optional[int] = None) -> bool:
        """True when (site, rid) burned its whole retry budget under a
        ``give_up`` plan — the caller should fail the request structurally
        (terminal status ``FAILED``) instead of retrying forever."""
        return (
            self.plan.give_up
            and self.attempts.get((site, rid), 0) >= self.plan.max_retries
        )

    def crash_due(self) -> bool:
        """Whether the planned engine crash fires THIS round (consumed: the
        plan crashes at most once)."""
        if (
            self.plan.crash_round is not None
            and not self._crashed
            and self.round >= self.plan.crash_round
        ):
            self._crashed = True
            self.stats["crashes"] += 1
            return True
        return False

    def forget(self, rid: int) -> None:
        """Drop per-request attempt state (a request that exited the system
        must not leak injector bookkeeping)."""
        for key in [k for k in self.attempts if k[1] == rid]:
            del self.attempts[key]
        for key in [k for k in self.backoff_until if k[1] == rid]:
            del self.backoff_until[key]
