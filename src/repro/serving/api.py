"""Async streaming front door: per-token generators over per-round blocks.

The engines below this layer are synchronous and block-oriented: one
scheduling round runs one fused decode block per engine and hands the host a
``[decode_block, max_slots]`` token block (ONE sanctioned device sync per
block — see ROADMAP "serving fast path").  Callers, though, want the
production-shaped surface::

    client = Client.from_config(params, cfg, config, replicas=2)
    async for tok in client.generate(prompt, max_new_tokens=32):
        ...

``Client`` adapts one into the other.  Each ``generate()`` call submits a
request (through the router's KV-aware ``submit`` or a single server's) and
returns an async generator that yields tokens one by one as rounds land
them.  Concurrent generators COOPERATE on driving: whichever stream runs dry
takes the round lock and advances the backend by exactly one round, then
yields the event loop so sibling streams drain what the round produced.  The
round sequence is the same global, deterministic sequence a synchronous
``drain()`` would run — the event loop only changes who happens to call it,
never what it computes — so routed async streams stay bit-identical to the
synchronous path.

Tokens are read from the host-side request records (``req.tokens``), which
the per-block readback already populated: the async layer introduces NO
extra device syncs (``tools/fastpath_lint.py`` checks this file like any
other serving module).

TTFT / TBT are measured HERE, at the API surface, where a user would see
them: ``StreamMetrics.ttft_s`` is wall-clock submit -> first yielded token,
``tbt_s`` the wall-clock gaps between yielded tokens, and ``ttft_rounds``
the deterministic round-clock equivalent (owning replica rounds before the
first token).

Terminal statuses and cancellation surface through the SAME handle: the
generator simply stops yielding when the request reaches any terminal
status (``StreamMetrics.status`` records which), and closing the generator
early (``break`` / ``aclose()``) cancels the in-flight request via
``handle.cancel()``.
"""
from __future__ import annotations

import asyncio
import itertools
import time
from dataclasses import dataclass, field
from typing import AsyncIterator, Dict, List, Optional

from ..configs.base import ModelConfig
from .config import EngineConfig
from .engine import (
    DisaggregatedServer,
    GenRequest,
    RequestHandle,
    SchedulerExhausted,
)
from .router import Router


@dataclass
class StreamMetrics:
    """Per-request latency record, measured at the API surface.

    ttft_s / ttft_rounds  submit -> FIRST yielded token (wall clock / owning
                          replica's deterministic round clock)
    tbt_s                 wall-clock gaps between consecutively yielded
                          tokens (len == n_tokens - 1 for a finished stream)
    status                terminal STATUS_* once the stream ended (None while
                          live); cancelled/expired streams are truncated,
                          not erased
    """

    rid: int
    submit_s: float
    ttft_s: Optional[float] = None
    ttft_rounds: Optional[int] = None
    tbt_s: List[float] = field(default_factory=list)
    n_tokens: int = 0
    finish_s: Optional[float] = None
    status: Optional[str] = None


class Client:
    """Asyncio streaming client over a ``Router`` or ``DisaggregatedServer``.

    Accepts only a ready backend (or an ``EngineConfig`` via
    ``from_config``) — never loose engine kwargs.
    """

    def __init__(self, backend, *, max_rounds: int = 10_000):
        self.backend = backend
        self.max_rounds = max_rounds
        self.metrics: Dict[int, StreamMetrics] = {}
        # one backend round at a time: the lock serializes round-driving
        # across concurrent streams (the rounds themselves stay the global
        # deterministic sequence regardless of which stream drives)
        self._round_lock = asyncio.Lock()
        self._rids = itertools.count()

    @classmethod
    def from_config(
        cls,
        params,
        cfg: ModelConfig,
        config: EngineConfig,
        *,
        replicas: int = 1,
        max_rounds: int = 10_000,
    ) -> "Client":
        """Build the whole stack from one ``EngineConfig``: a KV-aware
        ``Router`` over ``replicas`` server replicas (or a bare single
        server for ``replicas=1``)."""
        if replicas == 1:
            backend = DisaggregatedServer.from_config(params, cfg, config)
        else:
            backend = Router(params, cfg, config, replicas=replicas)
        return cls(backend, max_rounds=max_rounds)

    def _fresh_rid(self) -> int:
        rid = next(self._rids)
        while rid in self.backend.all_requests:
            rid = next(self._rids)
        return rid

    def submit(
        self,
        prompt,
        *,
        max_new_tokens: int = 16,
        rid: Optional[int] = None,
        eos_id: Optional[int] = None,
        priority: int = 0,
        deadline_rounds: Optional[int] = None,
        ttft_deadline: Optional[int] = None,
    ) -> RequestHandle:
        """Submit one request (KV-aware routed when the backend is a
        ``Router``); returns its ``RequestHandle``.  The handle's sync
        surface (``status()``/``result()``/``cancel()``) and the async
        ``stream(handle)`` both work on it."""
        if rid is None:
            rid = self._fresh_rid()
        req = GenRequest(
            rid, prompt, max_new_tokens=max_new_tokens, eos_id=eos_id,
            priority=priority, deadline_rounds=deadline_rounds,
            ttft_deadline=ttft_deadline,
        )
        handle = self.backend.submit(req)
        self.metrics[rid] = StreamMetrics(rid=rid, submit_s=time.perf_counter())
        return handle

    async def generate(self, prompt, **submit_kwargs) -> AsyncIterator[int]:
        """``async for token in client.generate(prompt, max_new_tokens=...)``.

        Submit + stream in one call; kwargs are ``submit()``'s.  Breaking out
        of the loop cancels the in-flight request (see ``stream``)."""
        handle = self.submit(prompt, **submit_kwargs)
        async for tok in self.stream(handle):
            yield tok

    async def stream(self, handle: RequestHandle) -> AsyncIterator[int]:
        """Per-token async generator for one submitted request.

        Yields each new token as scheduling rounds produce them; returns when
        the request reaches ANY terminal status (check
        ``client.metrics[rid].status`` — a cancelled or expired stream is
        truncated, not an exception).  Closing the generator before the
        request finished cancels it through the same handle."""
        rid = handle.rid
        m = self.metrics.setdefault(
            rid, StreamMetrics(rid=rid, submit_s=time.perf_counter())
        )
        req = handle.request
        emitted, rounds, last_s = 0, 0, None
        try:
            while True:
                while emitted < len(req.tokens):
                    tok = req.tokens[emitted]
                    emitted += 1
                    now = time.perf_counter()
                    if last_s is None:
                        m.ttft_s = now - m.submit_s
                        m.ttft_rounds = self.backend.rounds_since_submit(rid)
                    else:
                        m.tbt_s.append(now - last_s)
                    last_s = now
                    m.n_tokens = emitted
                    yield tok
                if req.done:
                    return
                if rounds >= self.max_rounds:
                    raise SchedulerExhausted(
                        f"request {rid} stream stalled after "
                        f"{self.max_rounds} rounds",
                        done={r: q.tokens
                              for r, q in self.backend.all_requests.items()
                              if q.done},
                        unfinished=sorted(
                            r for r, q in self.backend.all_requests.items()
                            if not q.done
                        ),
                        statuses=self.backend.outcomes(),
                    )
                async with self._round_lock:
                    # re-check under the lock: a sibling stream may have
                    # driven the round that produced our next token while we
                    # were waiting for it
                    if not req.done and emitted >= len(req.tokens):
                        self.backend.run_round()
                        rounds += 1
                # let sibling streams drain what this round produced before
                # anyone drives the next one
                await asyncio.sleep(0)
        finally:
            if not req.done:
                handle.cancel()
            m.status = handle.status()
            m.finish_s = time.perf_counter()
