"""Host-side prefix index for the paged KV cache (refcounted prefix sharing).

The paged pools and block tables already let two slots map the same physical
page; this module supplies the HOST half of prefix sharing: a map from
*chained* hashes of page-sized token chunks to the physical page that holds
that chunk's K/V, with LRU ordering and pin counts.  The DEVICE half is the
refcounted allocator in ``kvcache`` (``page_refs``): every index entry holds a
+1 "cache hold" on its page, so the device-resident allocator (which only
hands out pages with ``refs == 0``) can never recycle a cached page while the
host still maps it.  Division of truth:

* **on device** (inside the donated state): ``page_refs`` — the only thing
  allocation/release/COW consult; it is authoritative for "is this page live".
* **on host** (here): *which prompt prefix* a page holds — pure metadata.
  Losing it (eviction) costs recompute, never correctness.

Hashes are chained — ``h_j = H(h_{j-1} || tokens[j*ps:(j+1)*ps])`` — so a
chunk's identity includes its whole prefix: the same 16 tokens after two
different prefixes are two different cache entries (their K/V differ through
attention).  A request's shareable prefix is the longest leading run of its
chunk hashes present in the index, additionally capped at
``(true_len - 1) // page_size`` chunks so at least one prompt token is always
left for prefill to recompute (logits need the last position's hidden state).

This mirrors vLLM's hash-block prefix caching and the KV-cache-aware routing
of production-stack/Nexus: requests are routed to (admitted into) the engine
that already holds their prefix pages.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Callable, Dict, List, Optional

import numpy as np


def chunk_hashes(tokens, page_size: int, max_chunks: Optional[int] = None) -> List[bytes]:
    """Chained hashes of the full ``page_size``-token chunks of ``tokens``.

    ``h_j`` covers tokens ``[0, (j+1) * page_size)`` — prefix-complete, so a
    hash hit implies the whole prefix matches, not just the chunk body.
    """
    arr = np.asarray(tokens, np.int32)  # fastpath: allow[FP001] hashes the host token list (numpy in)
    n = len(arr) // page_size
    if max_chunks is not None:
        n = min(n, max_chunks)
    out: List[bytes] = []
    prev = b""
    for j in range(n):
        m = hashlib.blake2b(digest_size=16)
        m.update(prev)
        m.update(arr[j * page_size : (j + 1) * page_size].tobytes())
        prev = m.digest()
        out.append(prev)
    return out


class PrefixIndex:
    """hash -> physical page, LRU-ordered, with per-page pin counts.

    Pins bridge the match -> admit gap: a matched prefix is pinned until the
    request is admitted (or abandoned) so LRU eviction cannot free pages a
    scheduled prefill is about to attend through.
    """

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._entries: "OrderedDict[bytes, int]" = OrderedDict()  # hash -> page
        self._pins: Dict[int, int] = {}  # page -> pin count
        self._swap_pins: Dict[int, List[int]] = {}  # rid -> pages pinned across a swap gap

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, h: bytes) -> bool:
        return h in self._entries

    def pages(self) -> List[int]:
        return list(self._entries.values())

    def match(self, hashes: List[bytes], touch: bool = True) -> List[int]:
        """Physical pages of the longest leading run of ``hashes`` present.

        ``touch`` moves every hit to the LRU tail so hot prefixes survive.
        Scheduler *scans* (requests merely considered, not selected) pass
        ``touch=False`` so cold queued prompts cannot refresh recency round
        after round; the touch happens when the match is actually taken
        (``touch()``, called from the engine's pin)."""
        pages: List[int] = []
        for h in hashes:
            page = self._entries.get(h)
            if page is None:
                break
            if touch:
                self._entries.move_to_end(h)
            pages.append(page)
        return pages

    def touch(self, hashes: List[bytes]) -> None:
        """LRU-refresh the entries for ``hashes`` (a selected match)."""
        for h in hashes:
            if h in self._entries:
                self._entries.move_to_end(h)

    def insert(self, h: bytes, page: int) -> bool:
        """Register ``page`` under ``h``; False if the hash already exists
        (the existing mapping is kept and touched — duplicate K/V content on
        another page is possible but never re-registered)."""
        if h in self._entries:
            self._entries.move_to_end(h)
            return False
        self._entries[h] = page
        return True

    def pin(self, pages: List[int]) -> None:
        for p in pages:
            self._pins[p] = self._pins.get(p, 0) + 1

    def unpin(self, pages: List[int]) -> None:
        for p in pages:
            n = self._pins.get(p, 0) - 1
            if n <= 0:
                self._pins.pop(p, None)
            else:
                self._pins[p] = n

    def pinned(self, page: int) -> bool:
        return self._pins.get(page, 0) > 0

    def swap_pin(self, rid: int, pages: List[int]) -> None:
        """Pin ``pages`` for the whole swap-out -> swap-in gap of request
        ``rid`` (idempotent per rid).  A preempted request's prefix-shared
        pages are held only by the index while it sits on host — the swap
        dropped its mapping ref instead of copying the bytes — so LRU
        eviction must not reclaim them before ``swap_in`` remaps them."""
        if rid in self._swap_pins:
            return
        self._swap_pins[rid] = list(pages)
        self.pin(pages)

    def swap_unpin(self, rid: int) -> None:
        """Release request ``rid``'s swap-gap pin (no-op when it holds none):
        called on swap-in and on every abandon/cleanup path so a preempted
        request can never leak pins."""
        pages = self._swap_pins.pop(rid, None)
        if pages:
            self.unpin(pages)

    def evictable(self, cache_only: Callable[[int], bool]) -> int:
        """How many entries could be evicted right now (unpinned and, per the
        caller's predicate, held only by the cache — evicting a page still
        mapped by live slots frees no capacity)."""
        return sum(
            1
            for p in self._entries.values()
            if not self.pinned(p) and cache_only(p)
        )

    def evict_one(self, cache_only: Callable[[int], bool]) -> Optional[int]:
        """Drop the LRU-oldest evictable entry; returns its page (the caller
        must release the device-side cache hold) or None."""
        for h, p in self._entries.items():  # OrderedDict iterates LRU-first
            if not self.pinned(p) and cache_only(p):
                del self._entries[h]
                return p
        return None
