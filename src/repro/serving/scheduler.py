"""Pluggable scheduling policies for the ``DisaggregatedServer``.

The paper's Decode Chips win by keeping memory-bound decode hardware
saturated at a lower TDP — which makes the *scheduler* (what gets admitted
when, and what gets evicted under KV pressure) the lever that decides whether
a smaller decode pool can absorb bursty traffic.  This module extracts all
scheduling POLICY out of the server into a ``Scheduler`` interface; the
server keeps only mechanism (prefill batching, the KV handoff, decode
blocks) and asks the policy three questions per round:

* in what order should the queue be prefilled (``order`` — the head of the
  queue seeds the next same-bucket prefill batch),
* in what order should prefilled requests be admitted into decode slots
  (``admit_order``), and
* what to do when a request cannot be admitted anywhere (``on_blocked`` —
  the preemption hook).

Three policies ship:

``FCFSScheduler``
    Oldest-first, exactly the pre-refactor hardcoded behaviour — the
    regression anchor.  Token streams (greedy AND sampled) are bit-identical
    to the old ``DisaggregatedServer``.

``KVAwareScheduler``
    Orders the queue and the waiting list by reserved-page footprint
    (cf. Nexus's proactive scheduling): small requests stop head-of-line
    blocking behind page-hungry ones, cutting queue-wait p50/p99 while
    total throughput stays put (the same work is done, in a better order).
    An aging bound (``age_rounds``) promotes any request that has waited too
    long to strict FIFO, so page-hungry requests cannot starve.

``PriorityScheduler``
    Per-request ``GenRequest.priority`` (higher = more important; FIFO
    within a class).  Under admission pressure it preempts the
    lowest-priority running request via page-level swap
    (``DecodeEngine.swap_out`` / ``swap_in`` on top of
    ``kvcache.paged_swap_out`` / ``paged_swap_in``): the victim's private KV
    pages + resume state are stashed on host, its prefix-shared pages stay
    in the pool (mapping ref dropped, swap pin held), and it is re-admitted
    later — bit-identically under greedy sampling — when capacity returns.

Policy state lives entirely on host: the queue, the waiting list, the
swapped stash, and the wait metrics.  Nothing here touches device state
except through the engines' donated transitions.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Tuple

from .faults import TransientFault
from .prefix_cache import chunk_hashes

if TYPE_CHECKING:  # engine.py imports this module; keep the cycle type-only
    from .engine import DecodeEngine, DisaggregatedServer, GenRequest, PrefixMatch


@dataclass(eq=False)
class WaitingEntry:
    """A prefilled request waiting for a decode slot.

    ``kv`` is the (possibly batched) prefill pack pinned on device until the
    admit slices row ``batch_index`` out; ``match``/``engine`` carry the
    prefix-routing decision (a matched request can only be completed by the
    engine holding its shared pages when the pack is tail-only)."""

    req: "GenRequest"
    kv: Any
    batch_index: int
    first_token: int
    true_len: int
    match: Optional["PrefixMatch"]
    engine: Optional["DecodeEngine"]


@dataclass(eq=False)
class SwappedRequest:
    """A preempted request's host-side stash (see ``DecodeEngine.swap_out``).

    pack        host (numpy) KV pack of the PRIVATE pages — logical pages
                [n_keep, ceil(length / page_size)), page-padded
    length      KV positions written before the swap (prompt + decoded)
    last_token  resume token: the next decode step consumes it at ``length``
    n_keep      leading prefix pages left in the pool (mapping ref dropped,
                bytes kept alive by the index cache hold + a swap pin)
    kept_pages  their physical page ids (remapped verbatim at swap-in)
    hashes      the prompt's chunk hashes (re-registration at swap-in)
    """

    req: "GenRequest"
    engine: "DecodeEngine"
    pack: Any
    length: int
    last_token: int
    n_keep: int
    kept_pages: List[int]
    hashes: List[bytes]


class Scheduler:
    """Base policy: FCFS semantics, no preemption.

    Subclasses override ``order`` / ``admit_order`` / ``on_blocked`` /
    ``_may_resume``; the queue/waiting/swapped containers, wait metrics, and
    the prefill-group mechanics live here so every policy shares them.
    """

    name = "fcfs"

    def __init__(self, shed_after_rounds: Optional[int] = None):
        # load-shedding policy knob: a QUEUED request that has waited this
        # many scheduling rounds is shed (terminal status SHED) instead of
        # waiting forever under overload; None (default) never sheds, which
        # keeps the FCFS regression anchor untouched
        self.shed_after_rounds = shed_after_rounds
        self.queue: List["GenRequest"] = []
        self.waiting: List[WaitingEntry] = []
        self.swapped: List[SwappedRequest] = []
        self.round = 0
        # submit bookkeeping (dropped per request by ``forget``); the wait
        # metrics below persist for benchmarks, bounded by requests served —
        # the same lifetime as the server's ``all_requests``
        self.submit_round: Dict[int, int] = {}
        self._submit_seq: Dict[int, int] = {}
        self._submit_s: Dict[int, float] = {}
        self._seq = 0
        self.queue_wait_rounds: Dict[int, int] = {}
        self.queue_wait_s: Dict[int, float] = {}
        self.stats = {"preemptions": 0, "swap_ins": 0, "shed": 0}

    # -- lifecycle ----------------------------------------------------------

    def add(self, req: "GenRequest") -> None:
        """Queue a validated request (called by ``server.submit``)."""
        self.queue.append(req)
        self.submit_round[req.rid] = self.round
        self._submit_seq[req.rid] = self._seq
        self._submit_s[req.rid] = time.perf_counter()
        self._seq += 1

    def note_admitted(self, rid: int) -> None:
        """Record queue-wait at the FIRST admission (swap re-admits keep the
        original wait — the request already left the queue once)."""
        if rid in self.queue_wait_rounds or rid not in self.submit_round:
            return
        self.queue_wait_rounds[rid] = self.round - self.submit_round[rid]
        self.queue_wait_s[rid] = time.perf_counter() - self._submit_s[rid]

    def forget(self, rid: int) -> None:
        """Drop per-request submit bookkeeping (every exit path funnels into
        ``server._forget`` which calls this)."""
        self.submit_round.pop(rid, None)
        self._submit_seq.pop(rid, None)
        self._submit_s.pop(rid, None)

    def begin_round(self, server: "DisaggregatedServer") -> None:
        self.round += 1
        self.order(server)

    # -- policy hooks -------------------------------------------------------

    def order(self, server: "DisaggregatedServer") -> None:
        """Reorder ``self.queue`` in place; the head seeds the next prefill
        group.  FCFS: keep submission order."""

    def admit_order(self, server: "DisaggregatedServer") -> List[WaitingEntry]:
        """The order in which waiting entries should try admission.  FCFS:
        prefill-completion (== submission) order."""
        return list(self.waiting)

    def on_blocked(self, server: "DisaggregatedServer", entry: WaitingEntry) -> bool:
        """Called when ``entry`` could not be admitted anywhere this round.
        Return True iff capacity may have been freed (the server retries the
        admit immediately).  FCFS: never preempts."""
        return False

    def barrier(self, server: "DisaggregatedServer", entry: WaitingEntry) -> bool:
        """Whether a still-blocked ``entry`` bars every admission ranked
        after it this round (capacity drains to it instead of backfilling).
        FCFS: never — the pre-refactor loop admits anything that fits behind
        a blocked head, and that behaviour is the regression anchor."""
        return False

    def requeue_partial(self, req: "GenRequest") -> None:
        """Where a partially-prefilled (chunked) request goes after each
        non-final chunk: the queue TAIL, so every other queued request gets a
        prefill turn between one long prompt's chunks (round-robin
        interleaving — the Sarathi-style fairness chunking exists for).
        Policies that re-sort the queue every round (KV-aware, priority) see
        the request again in ``order`` regardless of where it lands here.
        With chunking disabled this hook never runs, so FCFS stays
        bit-identical to the pre-refactor anchor."""
        self.queue.append(req)

    def pick_riders(
        self, server: "DisaggregatedServer", head: "GenRequest",
        max_riders: int,
    ) -> List["GenRequest"]:
        """Unified batching: which OTHER queued chunked requests ride the
        head's chunk round (one batched prefill dispatch).  Queue order —
        which the policy already owns via ``order`` — so the KV-aware
        policy's footprint ranking carries over to rider choice for free.
        ``server.chunk_rider_ok`` enforces mechanism (same routed pool, same
        quantum, non-final); this hook only ranks.  Never called with
        ``unified_batching`` off."""
        out: List["GenRequest"] = []
        for r in self.queue[1:]:
            if len(out) >= max_riders:
                break
            if server.chunk_rider_ok(head, r):
                out.append(r)
        return out

    def _may_resume(self, server: "DisaggregatedServer", sw: SwappedRequest) -> bool:
        """Policy veto for re-admitting a swapped request this round."""
        return True

    def shed(self, server: "DisaggregatedServer") -> List["GenRequest"]:
        """Load-shedding hook: which QUEUED requests to fail out (terminal
        status SHED) this round instead of serving.  Default policy: any
        request still queued after ``shed_after_rounds`` rounds — the system
        is overloaded past its deadline horizon and keeping the request
        only delays everyone behind it.  Mid-chunk requests are exempt:
        their streamed pages are sunk cost about to pay off.  Policies can
        override for smarter shedding (e.g. lowest priority first)."""
        if self.shed_after_rounds is None:
            return []
        out = []
        for r in self.queue:
            if r.rid in server.chunks:
                continue
            waited = self.round - self.submit_round.get(r.rid, self.round)
            if waited >= self.shed_after_rounds:
                out.append(r)
        return out

    def try_swap_in(self, server: "DisaggregatedServer") -> None:
        """Re-admit swapped-out requests (oldest first) when their engine has
        capacity again; runs before fresh admissions each round."""
        if not self.swapped:
            return
        still = []
        for sw in self.swapped:
            if self._may_resume(server, sw) and sw.engine.swap_in(sw) is not None:
                self.stats["swap_ins"] += 1
            else:
                still.append(sw)
        self.swapped = still

    # -- prefill-group mechanics (policy-independent; the policy only picks
    # -- the queue ORDER, the group is always the head's bucket-mates) ------

    def match_for(self, server: "DisaggregatedServer", req: "GenRequest"):
        """KV-cache-aware routing: the decode engine already holding the
        longest prefix of this prompt (cf. production-stack's router).

        A scan, not a take: chunk hashes are memoized per (request, page
        size) — prompts are immutable — and index recency is NOT refreshed
        (``touch=False``); the selected match touches at pin time."""
        best, best_eng = None, None
        for d in server.decodes:
            if not getattr(d, "prefix_cache", False):
                continue
            if not d.can_ever_admit(len(req.prompt), req.max_new_tokens):
                continue
            hk = (req.rid, d.page_size)
            if hk not in server._hash_memo:
                server._hash_memo[hk] = chunk_hashes(
                    req.prompt, d.page_size, d.pages_per_slot
                )
            m = d.match_prefix(req.prompt, hashes=server._hash_memo[hk], touch=False)
            if m and m.n_shared > 0 and (best is None or m.n_shared > best.n_shared):
                best, best_eng = m, d
        return best, best_eng

    def group_key(self, req: "GenRequest", match, eng_d, buckets) -> Tuple:
        """Prefill-batch compatibility key: same tail bucket, same prefix
        capacity bucket, same routed decode engine."""
        from .engine import _bucket  # runtime import: engine imports us first

        if match is None:
            return (_bucket(len(req.prompt), buckets), None, None)
        tail = len(req.prompt) - match.n_shared * eng_d.page_size
        n_pg_b = 1 << max(match.n_shared - 1, 0).bit_length()  # pow2 >= n_shared
        n_pg_b = min(max(n_pg_b, 1), eng_d.pages_per_slot)
        return (_bucket(tail, buckets), n_pg_b, id(eng_d))

    def take_group(self, server: "DisaggregatedServer", buckets):
        """Pop the queue head's group-mates under prefix-aware keys and pin
        the selected matches until admit.  Returns (group, matches) with
        matches[i] = (PrefixMatch | None, routed DecodeEngine | None)."""
        head = self.queue[0]
        m0, d0 = self.match_for(server, head)
        want = self.group_key(head, m0, d0, buckets)
        group, matches, rest = [head], [(m0, d0)], []
        for r in self.queue[1:]:
            # chunked-path requests never join a monolithic group: their
            # prefill is the per-round chunk state machine (engine.py)
            if len(group) < server.max_prefill_batch and not server.chunk_pending(r):
                m, d = self.match_for(server, r)
                if self.group_key(r, m, d, buckets) == want:
                    group.append(r)
                    matches.append((m, d))
                    continue
            rest.append(r)
        self.queue = rest
        for r, (m, d) in zip(group, matches, strict=False):
            if m is not None:
                d.pin_prefix(r.rid, m)
            # the request leaves the queue: its memoized hashes ride on in
            # the PrefixMatch (admit registration), the memo entry can go
            for d2 in server.decodes:
                server._hash_memo.pop((r.rid, getattr(d2, "page_size", 0)), None)
        return group, matches


class FCFSScheduler(Scheduler):
    """Oldest-first admission — the pre-refactor behaviour, bit for bit."""

    name = "fcfs"


class KVAwareScheduler(Scheduler):
    """Smallest-reserved-page-footprint first, with an aging bound.

    The footprint is exactly what paged admission will reserve
    (``DecodeEngine._pages_needed`` minus any shared-prefix pages), so the
    order matches real KV pressure, not prompt length.  Any request that has
    waited ``age_rounds`` scheduling rounds is promoted to strict FIFO ahead
    of every un-aged one — the starvation bound for page-hungry requests.
    """

    name = "kv-aware"

    def __init__(self, age_rounds: int = 32, **kw):
        super().__init__(**kw)
        self.age_rounds = age_rounds

    def footprint(self, server: "DisaggregatedServer", req: "GenRequest") -> int:
        """Pages a paged decode engine would reserve for this request (falls
        back to prompt + max_new positions when no engine is paged).

        Chunked-prefill requests are ranked by what their NEXT step actually
        takes from the pool — one chunk's pages mid-stream, the tail + growth
        reservation at the final admit — not their whole-prompt footprint:
        chunking turns a 32k prompt into a sequence of small reservations,
        and the ordering should see exactly that."""
        cp = server.next_chunk_pages(req)
        if cp is not None:
            return cp
        d = next((d for d in server.decodes if d.paged), None)
        if d is None:
            return len(req.prompt) + req.max_new_tokens
        return d._pages_needed(len(req.prompt), req.max_new_tokens)

    def _key(self, server, req: "GenRequest", shared: int = 0):
        waited = self.round - self.submit_round.get(req.rid, self.round)
        seq = self._submit_seq.get(req.rid, 0)
        if waited >= self.age_rounds:
            return (0, seq, 0)  # aged: strict FIFO, ahead of everything
        return (1, self.footprint(server, req) - shared, seq)

    def order(self, server):
        self.queue.sort(key=lambda r: self._key(server, r))

    def admit_order(self, server):
        # chunked entries pass shared=0: their footprint() already nets out
        # the streamed pages (subtracting the tail match again would double-
        # count every page the chunk stream put in the pool)
        return sorted(
            self.waiting,
            key=lambda e: self._key(
                server, e.req,
                0 if e.req.rid in server.chunks
                else (e.match.n_shared if e.match is not None else 0),
            ),
        )

    def barrier(self, server, entry: WaitingEntry) -> bool:
        """The starvation bound's second half: once a request has AGED, it
        not only ranks first — while it stays blocked, nothing ranked after
        it may backfill the capacity it is waiting to accumulate.  Without
        this, a page-hungry request under a continuous stream of small ones
        would be first in line forever and admitted never."""
        waited = self.round - self.submit_round.get(entry.req.rid, self.round)
        return waited >= self.age_rounds


class PriorityScheduler(Scheduler):
    """Strict priorities (``GenRequest.priority``, higher first; FIFO within
    a class) with optional page-level preemption.

    ``swap=True``: when a waiting request cannot be admitted anywhere, the
    lowest-priority STRICTLY-lower running request on a candidate engine is
    swapped out (``DecodeEngine.swap_out`` — private pages to host, shared
    pages stay pooled under a swap pin) until the blocked request fits.
    Swapped requests are re-admitted bit-identically (greedy) once capacity
    returns and no higher-priority work is pending.  ``max_preemptions_per_
    round`` bounds swap thrash; ties among victims break latest-submitted
    first (least sunk work lost, vLLM-style).  ``age_rounds`` bounds
    starvation the same way KV-aware's bound does: a request blocked that
    long bars lower-ranked backfilling until the capacity it is waiting on
    drains to it.
    """

    name = "priority"

    def __init__(self, swap: bool = True, max_preemptions_per_round: int = 2,
                 age_rounds: int = 32, **kw):
        super().__init__(**kw)
        self.swap = swap
        self.max_preemptions_per_round = max_preemptions_per_round
        self.age_rounds = age_rounds
        self._budget = max_preemptions_per_round

    def begin_round(self, server):
        self._budget = self.max_preemptions_per_round
        super().begin_round(server)

    def order(self, server):
        self.queue.sort(key=lambda r: -r.priority)  # stable: FIFO per class

    def admit_order(self, server):
        return sorted(self.waiting, key=lambda e: -e.req.priority)

    def _may_resume(self, server, sw: SwappedRequest) -> bool:
        # capacity should go to pending higher-priority work first; without
        # this veto a swap-in could be preempted right back out (thrash)
        if any(e.req.priority > sw.req.priority for e in self.waiting):
            return False
        if any(r.priority > sw.req.priority for r in self.queue):
            return False
        return True

    def on_blocked(self, server, entry: WaitingEntry) -> bool:
        if not self.swap or self._budget <= 0:
            return False
        req = entry.req
        m = entry.match
        routed = m is not None and m.n_shared > 0
        if routed:
            cands = [entry.engine]  # a tail pack only completes on its engine
        else:
            cands = [
                d for d in server.decodes
                if d.paged and d.can_ever_admit(entry.true_len, req.max_new_tokens)
            ]
        for d in cands:
            ns = m.n_shared if (routed and d is entry.engine) else 0
            victims = sorted(
                (r for r in d.requests.values() if r.priority < req.priority),
                key=lambda r: (r.priority, -self._submit_seq.get(r.rid, r.rid)),
            )
            if not victims:
                continue
            # feasibility precheck, capped at this round's remaining budget:
            # preempt ONLY if the victims we are still allowed to evict can
            # actually produce enough pages.  A victim's prefix-shared pages
            # survive the swap under an unevictable swap pin, so a partial
            # or infeasible preemption would strand swapped victims and
            # deadlock the blocked request against their pins (the victims,
            # left running, instead finish and free everything naturally).
            need = d._pages_needed(entry.true_len, req.max_new_tokens) - ns
            potential = (d.free_pages + d._evictable_pages()
                         + sum(d.swap_gain(r.rid)
                               for r in victims[: self._budget]))
            if potential < need:
                continue
            freed = False
            while (
                victims
                and self._budget > 0
                and not d.can_admit(entry.true_len, req.max_new_tokens, n_shared=ns)
            ):
                victim = victims.pop(0)
                try:
                    self.swapped.append(d.swap_out(victim.rid))
                except TransientFault:
                    # injected swap failure: nothing was mutated — the
                    # victim keeps running, the budget is uncharged, and
                    # the blocked entry retries next round
                    continue
                self.stats["preemptions"] += 1
                self._budget -= 1
                freed = True
            if freed and d.can_admit(entry.true_len, req.max_new_tokens, n_shared=ns):
                return True
        return False

    def barrier(self, server, entry: WaitingEntry) -> bool:
        """Starvation bound (same shape as KV-aware's): a request blocked
        for ``age_rounds`` — e.g. one whose preemption is infeasible and must
        wait for a natural drain — stops lower-ranked entries from
        backfilling the capacity it is waiting to accumulate."""
        waited = self.round - self.submit_round.get(entry.req.rid, self.round)
        return waited >= self.age_rounds


SCHEDULERS = {
    "fcfs": FCFSScheduler,
    "kv-aware": KVAwareScheduler,
    "priority": PriorityScheduler,
}


def make_scheduler(name: str, **kwargs) -> Scheduler:
    """Build a scheduler by CLI name (``--scheduler {fcfs,kv-aware,priority}``).

    kwargs are forwarded to the policy constructor; ``swap`` is accepted for
    every policy but only meaningful for ``priority`` (others ignore it)."""
    if name not in SCHEDULERS:
        raise ValueError(f"unknown scheduler {name!r}; pick from {sorted(SCHEDULERS)}")
    cls = SCHEDULERS[name]
    if cls is not PriorityScheduler:
        kwargs.pop("swap", None)
    return cls(**kwargs)
