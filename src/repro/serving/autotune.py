"""Measured-TBT chunk-quantum tuner (``chunk_tokens="auto"``).

Chunked prefill bounds time-between-tokens (TBT) for decoding requests by
capping how much prompt work a round may interleave with the decode step.
The right cap is hardware- and model-dependent: the SPAD observation is
that prefill arithmetic intensity saturates far earlier on decode-class
hardware, so a fixed quantum tuned on one chip is wrong on another.

The tuner measures, on the REAL jitted paths the server will run:

* ``t_block`` — one fused decode block over a full batch (``max_slots``
  rows, ``decode_block`` steps): the floor every round pays.
* ``t_chunk(q)`` — one bucketed prefill call of ``q`` tokens, for
  page-aligned power-of-two candidates ``q = page_size * 2**i``.

and picks the LARGEST quantum whose round still meets the SLO::

    t_chunk(q) + t_block <= tbt_target_ms

A larger quantum finishes long prompts in fewer rounds (better TTFT); the
SLO bounds what that may cost concurrent decodes (worst-case TBT for a
decoding request is one chunk plus one block).  When even the smallest
candidate misses the target the tuner falls back to one page —
chunked-prefill granularity cannot go below the page grid.

Timing uses medians of a handful of repeats after a compile warmup; the
engines built here are throwaways (the server builds its own afterwards),
so the only lasting cost is startup wall-clock, and the jit cache makes
the server's first real rounds cheaper, not slower.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["tune_chunk_tokens", "chunk_candidates"]

_REPEATS = 5  # timed repeats per measurement (median taken)


def chunk_candidates(page_size: int, max_len: int, buckets) -> List[int]:
    """Page-aligned power-of-two quanta to try: ``page_size * 2**i`` while
    a chunk still fits under both the KV capacity and the bucket ladder."""
    cap = min(max_len, max(buckets)) if buckets else max_len
    out: List[int] = []
    q = page_size
    while q <= cap:
        out.append(q)
        q *= 2
    return out


def _median_time(fn, *, repeats: int = _REPEATS) -> float:
    """Median wall-clock of ``fn()`` over ``repeats`` runs, after one
    warmup call that eats the compile."""
    fn()  # warmup / compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def tune_chunk_tokens(
    params,
    cfg,
    config,
    *,
    report: Optional[Dict] = None,
) -> int:
    """Resolve ``chunk_tokens="auto"`` to a concrete page-aligned quantum.

    ``config`` is the ``EngineConfig`` being resolved (its ``tbt_target_ms``
    is the SLO; validated non-None at construction).  Pass ``report={}`` to
    receive the raw measurements (candidate -> seconds, plus ``t_block``).
    """
    from .engine import DecodeEngine, GenRequest, PrefillEngine

    if config.tbt_target_ms is None:
        raise ValueError("tune_chunk_tokens requires config.tbt_target_ms")
    target_s = config.tbt_target_ms / 1e3

    # throwaway engines on the REAL jitted paths (plain greedy config: the
    # tuner measures compute, not sampling / prefix bookkeeping)
    probe = config.replace(
        chunk_tokens=None, unified_batching=False, token_budget=None,
        prefix_cache=False, faults=None, audit_every=None,
    )
    pre = PrefillEngine(params, cfg, **probe.prefill_args())
    dec = DecodeEngine(params, cfg, **probe.decode_args())

    # fill every decode slot so t_block is the saturated-batch cost
    key = jax.random.PRNGKey(probe.seed)
    for i in range(probe.max_slots):
        prompt = [(7 * i + j) % cfg.vocab_size for j in range(probe.page_size)]
        req = GenRequest(
            rid=i, prompt=prompt,
            max_new_tokens=probe.max_len - probe.page_size,
        )
        key, sub = jax.random.split(key)
        toks, kv, lens = pre.prefill_batch([req], sub)
        dec.admit(req, kv, toks[0], lens[0])

    def block():
        out = dec.step_block(dec.decode_block)
        # step_block syncs on the token readback; nothing more to block on
        assert out

    t_block = _median_time(block)

    t_chunk: Dict[int, float] = {}
    for q in chunk_candidates(probe.page_size, probe.max_len, probe.buckets):
        prompt = [(3 * q + j) % cfg.vocab_size for j in range(q)]
        req = GenRequest(rid=10_000 + q, prompt=prompt, max_new_tokens=1)

        def chunk(req=req):
            # prefill_batch syncs on its own first-token readback, so the
            # call returning bounds the dispatch
            pre.prefill_batch([req], jax.random.PRNGKey(0))

        t_chunk[q] = _median_time(chunk)

    fits = [q for q, t in t_chunk.items() if t + t_block <= target_s]
    chosen = max(fits) if fits else probe.page_size
    if report is not None:
        report["t_block_s"] = t_block
        report["t_chunk_s"] = dict(t_chunk)
        report["tbt_target_ms"] = config.tbt_target_ms
        report["chosen"] = chosen
    return chosen
