"""Serving runtime: engines, KV-cache slots, sampling, disaggregation,
pluggable schedulers."""
from .engine import (  # noqa: F401
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    MonolithicEngine,
    PrefillEngine,
    PrefixMatch,
    SchedulerExhausted,
)
from .prefix_cache import PrefixIndex, chunk_hashes  # noqa: F401
from .sampling import SamplingParams, sample  # noqa: F401
from .scheduler import (  # noqa: F401
    FCFSScheduler,
    KVAwareScheduler,
    PriorityScheduler,
    Scheduler,
    SwappedRequest,
    WaitingEntry,
    make_scheduler,
)
