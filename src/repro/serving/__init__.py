"""Serving runtime: engines, KV-cache slots, sampling, disaggregation."""
from .engine import (  # noqa: F401
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    MonolithicEngine,
    PrefillEngine,
    SchedulerExhausted,
)
from .sampling import SamplingParams, sample  # noqa: F401
