"""Serving runtime: the executable half of the paper's disaggregated design.

Public surface (the names re-exported here are the supported API; the
normative behavioural contracts live in ROADMAP.md and are enforced by the
tier-1 tests — docs/serving.md is the narrative guide):

* ``PrefillEngine`` — bucketed, batched prompt prefill; with
  ``chunk_tokens`` set, long prompts prefill in page-aligned chunks whose
  K/V streams into a paged decode pool between other requests' turns.
* ``DecodeEngine`` — continuous-batching decode over device-resident state
  (donated jitted transitions, fused ``decode_block``-step scans, at most
  one host sync per block).  ``paged=True`` adds the refcounted page-pool
  KV cache; ``prefix_cache=True`` adds prefix sharing + copy-on-write;
  ``fork``/``swap_out``/``swap_in`` are the best-of-n and preemption
  entry points.
* ``DisaggregatedServer`` — prefill pool -> KV handoff -> decode pool; owns
  mechanism only, defers ordering to its ``Scheduler``.
* ``MonolithicEngine`` — the co-located baseline.
* ``GenRequest`` / ``SamplingParams`` / ``sample`` — request and sampling
  primitives.
* ``Scheduler`` and its policies (``FCFSScheduler`` — the bit-exact
  regression anchor, ``KVAwareScheduler``, ``PriorityScheduler``,
  ``make_scheduler``), plus the queue entry types ``WaitingEntry`` /
  ``SwappedRequest``.
* ``PrefixIndex`` / ``chunk_hashes`` — the host half of prefix sharing
  (chained page-chunk hashes -> physical pages; holds a +1 device refcount
  per cached page).
* ``PrefixMatch`` / ``ChunkPrefillState`` — introspection types for routed
  prefix hits and in-progress chunked prefills.
* ``SchedulerExhausted`` — raised by ``run(max_steps=...)`` with the work
  left intact (resumable) and a structured per-request status snapshot
  (``statuses``: rid -> ``RequestOutcome``), never silently dropping
  requests.
* ``EngineConfig`` — the one frozen, validated bag of engine/server knobs
  (``serving.config``); engines take it via ``config=``, the server via
  ``DisaggregatedServer.from_config``, and the front-door layers accept
  ONLY it.  The loose constructor kwargs remain as a deprecated shim.
* ``Router`` / ``RouteDecision`` — the multi-replica KV-aware front door
  (``serving.router``): N server replicas, each submit routed on prefix-
  cache locality (chained chunk hashes vs every replica's ``PrefixIndex``),
  free pages, then queue depth, with deterministic tie-breaking.
* ``Client`` / ``StreamMetrics`` — the asyncio streaming API
  (``serving.api``): ``async for token in client.generate(...)`` adapts the
  per-round token blocks into per-token generators; TTFT/TBT measured at
  the API surface.
* ``RequestHandle`` — returned by ``submit()`` (server and router):
  ``status()`` / ``result()`` / ``cancel()`` / ``stream()`` for one request
  without juggling rids against ``outcomes()``; delegates to the rid-based
  surface, which keeps working.
* ``server.drain(max_rounds=...)`` — THE unified drain contract
  (``run()`` / ``run_round()`` are its anchor-compatible views; see the
  ``drain`` docstring).
* Request-lifecycle robustness: terminal statuses (``STATUS_FINISHED`` /
  ``STATUS_CANCELLED`` / ``STATUS_DEADLINE`` / ``STATUS_FAILED`` /
  ``STATUS_SHED``, collected in ``TERMINAL_STATUSES``) recorded on every
  request; ``server.cancel`` aborts cleanly at any lifecycle stage;
  ``GenRequest.deadline_rounds`` / ``ttft_deadline`` expire requests;
  ``FaultPlan`` / ``FaultInjector`` (``serving.faults``) inject seeded,
  deterministic failures at the lifecycle seams (``TransientFault`` is the
  swap-out flavour); ``server.audit`` / ``DecodeEngine.audit`` run the KV
  invariant auditor; ``server.crash_engine`` recovers a dead engine's
  in-flight work.  See docs/serving.md §7.
"""
from .api import Client, StreamMetrics  # noqa: F401
from .config import EngineConfig  # noqa: F401
from .engine import (  # noqa: F401
    STATUS_CANCELLED,
    STATUS_DEADLINE,
    STATUS_FAILED,
    STATUS_FINISHED,
    STATUS_PENDING,
    STATUS_SHED,
    TERMINAL_STATUSES,
    ChunkPrefillState,
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    MonolithicEngine,
    PrefillEngine,
    PrefixMatch,
    RequestHandle,
    RequestOutcome,
    SchedulerExhausted,
)
from .faults import FAULT_SITES, FaultInjector, FaultPlan, TransientFault  # noqa: F401
from .router import RouteDecision, Router  # noqa: F401
from .prefix_cache import PrefixIndex, chunk_hashes  # noqa: F401
from .sampling import SamplingParams, sample  # noqa: F401
from .scheduler import (  # noqa: F401
    FCFSScheduler,
    KVAwareScheduler,
    PriorityScheduler,
    Scheduler,
    SwappedRequest,
    WaitingEntry,
    make_scheduler,
)
