"""Serving runtime: engines, KV-cache slots, sampling, disaggregation."""
from .engine import (  # noqa: F401
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    MonolithicEngine,
    PrefillEngine,
    PrefixMatch,
    SchedulerExhausted,
)
from .prefix_cache import PrefixIndex, chunk_hashes  # noqa: F401
from .sampling import SamplingParams, sample  # noqa: F401
