"""Token sampling: greedy / temperature / top-k / top-p, batched + jittable.

Determinism contract (what the bit-identical stream tests lean on): greedy
sampling (``temperature == 0``) is a pure argmax — key- and batch-shape-
independent.  Stochastic sampling draws ONE categorical over the whole
``[B, V]`` batch per call, so a row's token depends on (key, its row index,
B): two schedules produce identical sampled streams only when each request
sees the same keys at the same row of the same-shaped batch.  The engines
arrange exactly that where bit-identity is promised — decode splits the
engine key once per step regardless of slot occupancy, and chunked prefill
pads its final chunk to the monolithic batch shape.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    """Per-engine sampling configuration (frozen: safe as a jit closure).

    temperature  0 selects greedy argmax (the default; every committed
                 bench baseline is greedy); > 0 scales logits before the
                 categorical draw
    top_k        keep only the k highest logits (0 disables)
    top_p        nucleus: keep the smallest logit set with cumulative
                 probability >= top_p (1 disables)
    """

    temperature: float = 0.0  # 0 => greedy
    top_k: int = 0  # 0 => disabled
    top_p: float = 1.0  # 1 => disabled


def sample(logits, key, params: SamplingParams):
    """logits [B, V] -> tokens [B] int32 (see the module contract above)."""
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / params.temperature
    if params.top_k > 0:
        kth = jnp.sort(lf, axis=-1)[:, -params.top_k][:, None]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    if params.top_p < 1.0:
        sorted_lf = jnp.sort(lf, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_lf, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.sum(cum < params.top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_lf, cutoff_idx[:, None], axis=-1)
        lf = jnp.where(lf < cutoff, -jnp.inf, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
