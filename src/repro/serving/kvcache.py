"""Slot-based KV-cache management for continuous batching.

TPU-style serving wants static shapes: the decode engine owns a cache of
``max_slots`` rows x ``max_len`` positions per attention layer (JetStream-
style), plus per-slot lengths and active flags.  Prefill produces a
single-request cache which is *inserted* into a free slot — that insert is
the software form of the paper's prefill->decode KV handoff.

All cache trees follow the model layout: a list (one entry per pattern
position) of dicts of stacked [n_repeats, B, ...] arrays.

Device-resident invariants (the serving fast path)
--------------------------------------------------
``DecodeState`` bundles EVERYTHING the decode loop touches per step — the
slot caches, last-emitted tokens, write positions, active mask, and the
sampling PRNG key — into one pytree that lives on device across steps.  The
engine jits its step/admit/release transitions with ``donate_argnums`` on
the state, so XLA updates the KV cache in place instead of re-materializing
``max_slots * max_len`` KV bytes per token.  The host only syncs on the
emitted token block (once per ``decode_block`` tokens), never on the state.

Bucketed-prefill contract: a slot row inserted from a right-padded prefill
may contain garbage K/V at positions [true_len, bucket).  That is safe by
construction: decode starts writing at position true_len and the attention
mask only ever reads positions < pos, so every padded position is
overwritten before it is first attended.

Paged KV cache (the vLLM/SGLang layout, TPU-shaped)
---------------------------------------------------
The slab cache pins ``max_len`` positions per slot no matter how short the
request — exactly the HBM waste the paper's memory-bound Decode Chip cannot
afford.  ``PagedDecodeState`` replaces the per-slot attention slabs with:

* **page pools**: every attention cache leaf becomes
  ``[R, n_pages + 1, page_size, ...]`` — a pool of fixed-size pages shared by
  all slots.  Page index ``n_pages`` (the last one) is the *trash page*: all
  masked/out-of-range writes are steered there instead of being predicated,
  so every cache write lowers to one unconditional scatter/DUS.
* **block tables**: ``[max_slots, max_len // page_size]`` int32 mapping each
  slot's logical page j to a physical pool page; unmapped entries hold the
  trash index, so gathers through a partial table read (masked) trash.
* **a device-resident refcounted allocator**: ``page_refs`` ``[n_pages]``
  int32 (0 = free, else the number of holders: slots mapping the page plus
  one "cache hold" if the host prefix index maps it).  Allocation = rank the
  first ``refs == 0`` pages with a sized ``jnp.nonzero``; release =
  decrement-only (one scatter-add over the freed slots' table entries — a
  page is reclaimed exactly when its count reaches 0, never zeroed while
  another holder remains).  Both run inside the donated jitted transitions —
  the free list never syncs to host.

Refcounts are what make **prefix sharing** safe: two slots whose prompts
share a page-aligned prefix map the *same* physical pages (each holding a
ref), and the host-side prefix index (``prefix_cache.PrefixIndex``) keeps a
+1 cache hold on registered prompt pages so they survive their original
request.  Decode writes gain **copy-on-write** (``cow_redirect``): before the
fused block writes into a page with ``refs > 1``, the writer is redirected to
a fresh page, the shared page's BYTES are copied onto it (one page-granular
gather + scatter per block boundary), and the shared page's count is
decremented — the view-free block then reads the copy straight off the pools
through the new tables.  All of it runs inside the donated jitted block: no
per-token host syncs.  Engines that can prove no page is ever shared (no
prefix index, no forks) compile the block WITHOUT the COW machinery — an
in-place tail write is exactly what an unshared page wants.

View-free decode (the only decode path)
---------------------------------------
The fused decode block never materializes a slab-layout view of the pools:
attention reads K/V per step through the block tables — the Pallas kernel
(``kernels/decode_attention.py``) streams pages via scalar-prefetched
tables on TPU, and the XLA fallback gathers rows per step on other
backends — and each step's fresh K/V is scattered to its page directly.
``paged_gather_view`` / ``paged_writeback`` (the retired gather-view
carry) are kept only as the bit-identity reference the view-free tests
compare against.

Refcounts also make **page-level preemption/swap** safe
(``paged_swap_out`` / ``paged_swap_in``, built on the tested
``paged_extract_request`` round trip): a preempted request's private pages
are gathered to host once (a rare lifecycle sync, never per-step), its
prefix-shared pages stay in the pool — the slot's mapping ref is dropped
instead of copying the bytes, with the prefix index's cache hold and a swap
pin bridging the gap — and re-admission goes through the ordinary donated
``paged_admit`` with a tail pack, so the resumed stream is bit-identical to
an uninterrupted run.

Mamba/conv state is fixed-size per request and stays per-slot
(``[R, max_slots, ...]``); only attention leaves page (and only attention
prefixes are shareable — SSM state is a function of the whole prompt).

Quantized KV pages (``kv_dtype="int8"``)
----------------------------------------
With ``kv_dtype="int8"`` every attention pool leaf stores int8 payloads and
gains a parallel per-page fp32 scale array ``[R, n_pages + 1]`` (symmetric
absmax: ``scale = absmax / 127``), kept as the ``scales`` leaf of the SAME
donated state pytree — so quant state is allocated, released, COW-redirected,
swapped and audited by the refcounted page machinery with no extra
bookkeeping (the trash page has a trash scale that is written freely and
never read).  Quantization happens only at page-granular writeback — admit
packs, chunk appends, and the fused block's whole-page read-modify-write —
and every writeback requantizes the page with a FRESH absmax, so error never
compounds across decode blocks.  Dequantization lives in the gather paths
(``models.attention.gather_pages_dequant``, ``gather_prefix_pack``,
``paged_extract_request``) and the int8 Pallas kernel variant; Mamba/conv
state stays fp32 per-slot.  ``kv_dtype="fp32"`` keeps ``scales=None`` and is
bit-identical to the pre-quant engine everywhere (the negative control).

The bucketed-prefill garbage contract carries over per page: admit copies
whole prompt pages (including bucket garbage in the last partial page), and
decode overwrites position ``pos`` before any step attends it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M

Cache = Any

# attention page-pool storage dtypes (EngineConfig.kv_dtype).  The quant
# helpers live next to the attention gather paths (models/attention.py) so
# the model layer can requantize at writeback without importing serving code;
# they are re-exported here because the page-pool quant CONTRACT (absmax
# symmetric, scale = absmax/127, error <= scale/2) is part of this module's
# refcounted-page design.
KV_DTYPES = ("fp32", "int8")
from ..models.attention import dequantize_pages, quantize_pages  # noqa: E402


@dataclass
class SlotState:
    """Host-side slot bookkeeping (device arrays live in ``DecodeState``)."""

    max_slots: int
    max_len: int
    lengths: List[int] = field(default_factory=list)  # host mirror
    request_ids: List[Optional[int]] = field(default_factory=list)

    def __post_init__(self):
        self.lengths = [0] * self.max_slots
        self.request_ids = [None] * self.max_slots

    def alloc(self, rid: int) -> Optional[int]:
        for i, r in enumerate(self.request_ids):
            if r is None:
                self.request_ids[i] = rid
                self.lengths[i] = 0
                return i
        return None

    def free(self, slot: int):
        self.request_ids[slot] = None
        self.lengths[slot] = 0

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.request_ids)


class DecodeState(NamedTuple):
    """All decode-loop state, device-resident across steps (one pytree).

    caches     model cache tree, [R, max_slots, max_len, ...] per attn leaf
    tokens     [max_slots] int32   last emitted token per slot
    positions  [max_slots] int32   next cache write position per slot
    active     [max_slots] bool    slot currently owns a live request
    key        PRNG key consumed one split per decode step
    """

    caches: Cache
    tokens: jnp.ndarray
    positions: jnp.ndarray
    active: jnp.ndarray
    key: jnp.ndarray


def init_decode_state(cfg: ModelConfig, max_slots: int, max_len: int, key) -> DecodeState:
    return DecodeState(
        caches=batch_cache(cfg, max_slots, max_len),
        tokens=jnp.zeros((max_slots,), jnp.int32),
        positions=jnp.zeros((max_slots,), jnp.int32),
        active=jnp.zeros((max_slots,), bool),
        key=key,
    )


def batch_cache(cfg: ModelConfig, max_slots: int, max_len: int) -> Cache:
    """Zero-initialized slot cache [R, max_slots, max_len, ...]."""
    return M.zeros_cache(cfg, max_slots, max_len)


def insert_request(batch: Cache, single: Cache, slot, cfg: ModelConfig) -> Cache:
    """Insert a prefilled single-request cache (B=1) into ``slot``.

    Attention caches copy the prefix [L1] into the slot row; mamba caches
    (fixed size) replace the row.  ``slot`` may be a traced int32 — the
    engine jits this with the state donated so admits are in-place instead
    of an un-jitted tree-wide copy.
    """
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        b = batch[i]
        s = single[i]
        if mixer == "attn":
            def ins(dst, src):
                # dst [R, S, L, ...], src [R, 1, L1, ...]
                L1 = min(src.shape[2], dst.shape[2])
                pad = dst.shape[2] - L1
                row = jnp.pad(
                    src[:, 0, :L1], [(0, 0), (0, pad)] + [(0, 0)] * (src.ndim - 3)
                )
                return jax.lax.dynamic_update_index_in_dim(dst, row.astype(dst.dtype), slot, 1)
        else:
            def ins(dst, src):
                return jax.lax.dynamic_update_index_in_dim(dst, src[:, 0].astype(dst.dtype), slot, 1)
        out.append(jax.tree.map(ins, b, s))
    return out


def slice_request(batch: Cache, b) -> Cache:
    """Slice request ``b`` out of a batched prefill pack -> B=1 pack.

    ``b`` may be traced; used inside jitted admits from batched prefill."""
    return jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(a, b, 1, axis=1), batch)


def extract_request(batch: Cache, slot: int, length: int, cfg: ModelConfig) -> Cache:
    """Pull one request's cache back out (decode->prefill reallocation path)."""
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        b = batch[i]
        if mixer == "attn":
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1, :length], b))
        else:
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1], b))
    return out


def kv_cache_bytes(cfg: ModelConfig, max_slots: int, max_len: int) -> int:
    specs = M.init_cache_specs(cfg, max_slots, max_len)
    return sum(
        int(jnp.prod(jnp.array(s.shape))) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(specs)
    )


# ---------------------------------------------------------------------------
# Paged KV cache: pools + block tables + device-resident free-page allocator
# ---------------------------------------------------------------------------


class PagedDecodeState(NamedTuple):
    """Paged decode-loop state, device-resident across steps (one pytree).

    caches        attn leaves [R, n_pages+1, page_size, ...] (last page = trash);
                  mamba leaves [R, max_slots, ...] (fixed-size, per-slot)
    block_tables  [max_slots, max_len // page_size] int32; unmapped = n_pages
    page_refs     [n_pages] int32; 0 = free, else number of holders (slots
                  mapping the page + 1 if the host prefix index holds it)
    tokens        [max_slots] int32   last emitted token per slot
    positions     [max_slots] int32   next cache write position per slot
    active        [max_slots] bool    slot currently owns a live request
    key           PRNG key consumed one split per decode step
    scales        None (fp32 pools), or the per-page quant scales for
                  ``kv_dtype="int8"``: a list per pattern position — attn
                  positions hold a dict mirroring the cache leaf keys with
                  ``[R, n_pages + 1]`` float32 arrays (index n_pages = the
                  trash page's freely-scribbled scale), mamba positions None
    """

    caches: Cache
    block_tables: jnp.ndarray
    page_refs: jnp.ndarray
    tokens: jnp.ndarray
    positions: jnp.ndarray
    active: jnp.ndarray
    key: jnp.ndarray
    scales: Any = None


def init_paged_decode_state(
    cfg: ModelConfig, max_slots: int, max_len: int, page_size: int, n_pages: int, key,
    kv_dtype: str = "fp32",
) -> PagedDecodeState:
    assert max_len % page_size == 0, (max_len, page_size)
    assert kv_dtype in KV_DTYPES, kv_dtype
    pages_per_slot = max_len // page_size
    caches = M.zeros_paged_cache(cfg, max_slots, n_pages + 1, page_size)
    scales = None
    if kv_dtype == "int8":
        R = cfg.n_repeats
        qcaches, scales = [], []
        for i, (mixer, _) in enumerate(cfg.block_pattern):
            if mixer == "attn":
                qcaches.append(
                    jax.tree.map(lambda a: a.astype(jnp.int8), caches[i])
                )
                scales.append(
                    jax.tree.map(
                        lambda a: jnp.zeros((R, n_pages + 1), jnp.float32),
                        caches[i],
                    )
                )
            else:
                qcaches.append(caches[i])
                scales.append(None)
        caches = qcaches
    return PagedDecodeState(
        caches=caches,
        block_tables=jnp.full((max_slots, pages_per_slot), n_pages, jnp.int32),
        page_refs=jnp.zeros((n_pages,), jnp.int32),
        tokens=jnp.zeros((max_slots,), jnp.int32),
        positions=jnp.zeros((max_slots,), jnp.int32),
        active=jnp.zeros((max_slots,), bool),
        key=key,
        scales=scales,
    )


def alloc_decode_pages(page_refs, need):
    """Grab one free (``refs == 0``) page per slot where ``need`` [max_slots]
    bool is set, and set its refcount to 1 (the allocating slot's hold).

    Returns (new_refs, page_ids [max_slots] int32); slots that need nothing
    (or an exhausted pool — unreachable under the engine's reservation-based
    admission) get the trash index ``n_pages``.  Runs inside the fused decode
    scan: pure ranking arithmetic, no host sync.  Pages with any live holder
    — slots or the prefix cache — have ``refs > 0`` and can never be handed
    out here: reclamation happens only at refcount 0.
    """
    n_pages = page_refs.shape[0]
    S = need.shape[0]
    (free_idx,) = jnp.nonzero(page_refs == 0, size=S, fill_value=n_pages)
    rank = jnp.clip(jnp.cumsum(need) - 1, 0, S - 1)
    pages = jnp.where(need, free_idx[rank], n_pages)
    refs = page_refs.at[pages].set(1, mode="drop")
    return refs, pages.astype(jnp.int32)


def cow_redirect(page_refs, block_tables, pos0, will_write, k: int, page_size: int,
                 caches: Optional[Cache] = None, cfg: Optional[ModelConfig] = None,
                 scales=None):
    """Copy-on-write for the fused decode block, applied before the k-step scan.

    Every logical page the block will write — pages overlapping positions
    [pos0, pos0 + k) of a writing slot — whose physical page is shared
    (``refs > 1``) gets a fresh page: the writer's block-table entry is
    redirected and the shared page's refcount is decremented.

    With ``caches``/``cfg`` the shared page's BYTES are copied onto the fresh
    page (one page-granular gather + scatter per boundary, steered to the
    trash page for non-redirected slots) and (refs, tables, caches) is
    returned.  The view-free decode block needs this: it reads K/V straight
    off the pools through the NEW tables, so the copy must already hold the
    shared prefix when the scan starts.  Without ``caches`` only
    (refs, tables) is returned — the legacy gather-view path carries the
    prefix bytes through its whole-page writeback instead.

    With ``scales`` (int8 pools) each redirected page's quant scale is copied
    alongside its bytes — the copy carries bit-identical int8 payloads AND
    scales, so a COW'd shared prefix dequantizes to exactly the original
    values — and (refs, tables, caches, scales) is returned.

    Pure arithmetic inside the donated jitted block — no host syncs; the
    fork-time page reservation guarantees free pages exist for every possible
    redirect.
    """
    n_pages = page_refs.shape[0]
    S, n_pg = block_tables.shape
    rows = jnp.arange(S)
    refs, bt = page_refs, block_tables
    for j in range((k - 1) // page_size + 2):
        lp = pos0 // page_size + j  # [S] logical page
        touched = will_write & (lp * page_size < pos0 + k) & (lp < n_pg)
        lpc = jnp.clip(lp, 0, n_pg - 1)
        phys = bt[rows, lpc]
        physc = jnp.clip(phys, 0, n_pages - 1)
        shared = touched & (phys < n_pages) & (refs[physc] > 1)
        refs, fresh = alloc_decode_pages(refs, shared)
        refs = refs.at[jnp.where(shared, physc, n_pages)].add(-1, mode="drop")
        bt = bt.at[rows, jnp.where(shared, lpc, n_pg)].set(fresh, mode="drop")
        if caches is not None:
            # fresh already carries the trash index for non-redirected slots,
            # so the copy is one unconditional page-granular scatter per leaf
            new_caches = []
            new_scales = [] if scales is not None else None
            for i, (mixer, _) in enumerate(cfg.block_pattern):
                if mixer == "attn":
                    def cp(pool):
                        return pool.at[:, fresh].set(pool[:, physc])
                    new_caches.append(jax.tree.map(cp, caches[i]))
                    if scales is not None:
                        new_scales.append(jax.tree.map(cp, scales[i]))
                else:
                    new_caches.append(caches[i])
                    if scales is not None:
                        new_scales.append(scales[i])
            caches = new_caches
            if scales is not None:
                scales = new_scales
    if caches is not None:
        if scales is not None:
            return refs, bt, caches, scales
        return refs, bt, caches
    return refs, bt


def paged_admit(
    state: PagedDecodeState, single: Cache, slot, token, true_len, cfg: ModelConfig,
    *, page_size: int, shared_pages=None, n_shared=None, reg_mask=None,
    pack_page0=None,
) -> PagedDecodeState:
    """Map ``slot``'s block table — shared prefix pages first, then freshly
    allocated ones — and scatter the prefilled cache pack into the fresh pages
    (the paged KV handoff).

    ``slot``/``token``/``true_len`` may be traced — the engine jits this with
    the state donated.  Prompt pages are written whole; writes for logical
    pages past the allocation land on the trash page (see module docstring).

    Prefix sharing (all optional, defaults reproduce the unshared admit):

    shared_pages  [pages_per_slot] int32 — physical pages of the matched
                  prefix (positions past ``n_shared`` ignored).  Each gains a
                  +1 refcount (this slot's hold); none of them is written.
    n_shared      scalar int32 — number of leading logical pages taken from
                  ``shared_pages``.  Always < ceil(true_len / page_size): the
                  prefill recomputes at least the last prompt token.
    reg_mask      [pages_per_slot] bool — logical pages the host will register
                  in the prefix index right after this admit; those fresh
                  pages start at refs == 2 (slot hold + cache hold).
    pack_page0    scalar int32 — the logical page the pack's first page maps
                  to: ``n_shared`` for a tail-only prefill pack, 0 for a
                  full-prompt pack (hybrid models recompute everything but
                  still map shared pages; their prefix writes are steered to
                  the trash page instead of re-writing shared pages).
    """
    ps = page_size
    pages_per_slot = state.block_tables.shape[1]
    n_pages = state.page_refs.shape[0]
    true_len = jnp.asarray(true_len, jnp.int32)
    n_shared = jnp.asarray(0 if n_shared is None else n_shared, jnp.int32)
    pack_page0 = jnp.asarray(0 if pack_page0 is None else pack_page0, jnp.int32)
    if shared_pages is None:
        shared_pages = jnp.full((pages_per_slot,), n_pages, jnp.int32)
    if reg_mask is None:
        reg_mask = jnp.zeros((pages_per_slot,), bool)
    n_need = (true_len + ps - 1) // ps
    (free_idx,) = jnp.nonzero(state.page_refs == 0, size=pages_per_slot, fill_value=n_pages)
    j = jnp.arange(pages_per_slot)
    fresh_ids = free_idx[jnp.clip(j - n_shared, 0, pages_per_slot - 1)]
    page_ids = jnp.where(
        j < n_shared, shared_pages, jnp.where(j < n_need, fresh_ids, n_pages)
    ).astype(jnp.int32)
    # +1 hold for every mapped page (shared and fresh); +1 cache hold for the
    # fresh pages the host registers.  Out-of-range (trash) indices drop.
    refs = state.page_refs.at[jnp.where(j < n_need, page_ids, n_pages)].add(1, mode="drop")
    reg = jnp.where((j >= n_shared) & (j < n_need) & reg_mask, page_ids, n_pages)
    refs = refs.at[reg].add(1, mode="drop")
    block_tables = state.block_tables.at[slot].set(page_ids)

    def pack_pages(src):
        # src [R, 1, L1, ...] -> (pages [R, n_src, ps, ...], tgt [n_src]).
        # Pack page m holds logical page pack_page0 + m; targets outside
        # [n_shared, n_need) — shared prefix pages and bucket garbage — carry
        # the trash index.
        L1 = src.shape[2]
        n_src = min(-(-L1 // ps), pages_per_slot)
        pad = n_src * ps - L1
        row = src[:, 0]
        if pad > 0:
            row = jnp.pad(row, [(0, 0), (0, pad)] + [(0, 0)] * (row.ndim - 2))
        pages = row[:, : n_src * ps].reshape(
            (row.shape[0], n_src, ps) + row.shape[2:]
        )
        tgt_logical = pack_page0 + jnp.arange(n_src)
        tgt = jnp.where(
            (tgt_logical >= n_shared) & (tgt_logical < n_need),
            page_ids[jnp.clip(tgt_logical, 0, pages_per_slot - 1)],
            n_pages,
        )
        return pages, tgt

    caches = []
    new_scales = None if state.scales is None else []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            if state.scales is None:
                def ins(dst, src):
                    # dst [R, P+1, ps, ...]: ONE scatter of the pack's pages
                    pages, tgt = pack_pages(src)
                    return dst.at[:, tgt].set(pages.astype(dst.dtype))

                caches.append(jax.tree.map(ins, state.caches[i], single[i]))
            else:
                # int8 pools: quantize each pack page (fresh absmax) and
                # scatter payload + scale with the SAME trash-steered targets
                leaf, sc = {}, {}
                for kk in state.caches[i]:
                    pages, tgt = pack_pages(single[i][kk])
                    qv, s = quantize_pages(pages)
                    leaf[kk] = state.caches[i][kk].at[:, tgt].set(qv)
                    sc[kk] = state.scales[i][kk].at[:, tgt].set(s)
                caches.append(leaf)
                new_scales.append(sc)
        else:
            def ins(dst, src):
                return jax.lax.dynamic_update_index_in_dim(dst, src[:, 0].astype(dst.dtype), slot, 1)
            caches.append(jax.tree.map(ins, state.caches[i], single[i]))
            if new_scales is not None:
                new_scales.append(None)

    return PagedDecodeState(
        caches=caches,
        block_tables=block_tables,
        page_refs=refs,
        tokens=state.tokens.at[slot].set(token),
        positions=state.positions.at[slot].set(true_len),
        active=state.active.at[slot].set(True),
        key=state.key,
        scales=new_scales,
    )


def paged_append_chunk(
    state: PagedDecodeState, single: Cache, cfg: ModelConfig, *,
    page_size: int, n_alloc: int,
) -> Tuple[PagedDecodeState, jnp.ndarray]:
    """Stream one prefill chunk's K/V into the page pools (chunked prefill).

    Allocates ``n_alloc`` free pages (refs 0 -> 1: the in-flight "chunk
    hold") and scatters the B=1 chunk pack ``single`` into them, WHOLE pages
    at a time — the same page-granular scatter shape as ``paged_admit``, but
    with NO slot: the pages belong to a prompt that is still prefilling, so
    they live only in the returned page-id list (mirrored by the engine's
    host bookkeeping) until the final chunk's admit maps them into a block
    table as shared pages.  Pack pages past ``n_alloc`` — bucket padding of
    the ragged last pack page — are steered to the trash page, so the
    scatter stays unconditional.

    ``n_alloc`` is static (chunks are fixed-size, page-aligned), so the jit
    key is bounded by the chunk configuration, not the prompt length.
    Returns (new state, page_ids [n_alloc] int32).  Mamba leaves pass
    through untouched: SSM state is carried across chunks by the prefill
    engine (it is a whole-prompt function, not a paged quantity) and lands
    per-slot only at the final admit.
    """
    n_pages = state.page_refs.shape[0]
    (free_idx,) = jnp.nonzero(state.page_refs == 0, size=n_alloc, fill_value=n_pages)
    refs = state.page_refs.at[free_idx].set(1, mode="drop")
    ps = page_size

    def pack_pages(src):
        # src [R, 1, L1, ...]: pack page m maps to free_idx[m] for
        # m < n_alloc, trash beyond (bucket pad)
        L1 = src.shape[2]
        n_src = -(-L1 // ps)
        pad = n_src * ps - L1
        row = src[:, 0]
        if pad > 0:
            row = jnp.pad(row, [(0, 0), (0, pad)] + [(0, 0)] * (row.ndim - 2))
        pages = row.reshape((row.shape[0], n_src, ps) + row.shape[2:])
        m = jnp.arange(n_src)
        tgt = jnp.where(
            m < n_alloc, free_idx[jnp.clip(m, 0, n_alloc - 1)], n_pages
        )
        return pages, tgt

    caches = []
    new_scales = None if state.scales is None else []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            if state.scales is None:
                def ins(dst, src):
                    # dst [R, P+1, ps, ...]: ONE scatter of the chunk's pages
                    pages, tgt = pack_pages(src)
                    return dst.at[:, tgt].set(pages.astype(dst.dtype))

                caches.append(jax.tree.map(ins, state.caches[i], single[i]))
            else:
                leaf, sc = {}, {}
                for kk in state.caches[i]:
                    pages, tgt = pack_pages(single[i][kk])
                    qv, s = quantize_pages(pages)
                    leaf[kk] = state.caches[i][kk].at[:, tgt].set(qv)
                    sc[kk] = state.scales[i][kk].at[:, tgt].set(s)
                caches.append(leaf)
                new_scales.append(sc)
        else:
            caches.append(state.caches[i])
            if new_scales is not None:
                new_scales.append(None)
    if new_scales is not None:
        new_state = state._replace(caches=caches, page_refs=refs, scales=new_scales)
    else:
        new_state = state._replace(caches=caches, page_refs=refs)
    return new_state, free_idx.astype(jnp.int32)


def paged_fork(
    state: PagedDecodeState, src, dst, token, cfg: ModelConfig
) -> PagedDecodeState:
    """Clone slot ``src``'s decode state into free slot ``dst``, sharing every
    mapped page (best-of-n / beam forks): the block-table row is copied, each
    mapped page gains a +1 refcount, and per-slot state (positions, mamba
    leaves) is duplicated.  ``token`` replaces the fork's last emitted token
    so the two branches diverge; the first write either branch makes into the
    shared tail page triggers copy-on-write inside the fused block
    (``cow_redirect``).  All args may be traced; jitted + donated by the
    engine."""
    n_pg = state.block_tables.shape[1]
    row = jax.lax.dynamic_slice_in_dim(state.block_tables, src, 1, axis=0)[0]
    refs = state.page_refs.at[row].add(1, mode="drop")
    bt = jax.lax.dynamic_update_index_in_dim(state.block_tables, row, dst, 0)
    caches = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        c = state.caches[i]
        if mixer == "attn":
            caches.append(c)  # shared via the table row + refcounts
        else:
            def cp(leaf):
                r = jax.lax.dynamic_slice_in_dim(leaf, src, 1, axis=1)
                return jax.lax.dynamic_update_slice_in_dim(leaf, r, dst, axis=1)
            caches.append(jax.tree.map(cp, c))
    pos = state.positions[src]
    return PagedDecodeState(
        caches=caches,
        block_tables=bt,
        page_refs=refs,
        tokens=state.tokens.at[dst].set(token),
        positions=state.positions.at[dst].set(pos),
        active=state.active.at[dst].set(True),
        key=state.key,
        scales=state.scales,  # shared pages share their scales (COW copies both)
    )


def paged_gather_view(caches: Cache, block_tables, cfg: ModelConfig) -> Cache:
    """Materialize the slab-layout view of the paged pools for one decode
    block: attn leaves [R, P+1, ps, ...] -> [R, S, max_len, ...] through the
    block tables; mamba leaves pass through (already per-slot).

    The fused decode block gathers this ONCE, runs its k steps against the
    view (byte-for-byte the slab math -> bit-identical streams), and writes
    the k fresh positions back to the pool with ``paged_writeback`` — so the
    per-step cost matches the slab engine and the gather/scatter amortizes
    over the block.  The view is a transient working buffer inside the jitted
    block (freed between blocks); persistent KV state is only the pool."""
    S = block_tables.shape[0]
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            def g(pool):
                rows = pool[:, block_tables]  # [R, S, n_pg, ps, ...]
                return rows.reshape(
                    (rows.shape[0], S, rows.shape[2] * rows.shape[3]) + rows.shape[4:]
                )
            out.append(jax.tree.map(g, caches[i]))
        else:
            out.append(caches[i])
    return out


def paged_writeback(
    caches: Cache, view: Cache, block_tables, pos0, k: int, cfg: ModelConfig
) -> Cache:
    """Copy the logical pages each slot wrote during the block — positions
    [pos0, pos0 + k) span at most (k-1)//page_size + 2 of them — from the
    view back into the page pools, WHOLE pages at a time (page-granular
    scatters of contiguous rows, not per-position writes).

    Copying a whole touched page is exact: positions before pos0 carry the
    values gathered from the pool at block start, and positions past the
    write head are garbage under the same overwrite-before-attend contract as
    bucketed prefill.  Slots whose pages are out of reach — frozen at
    max_len, released (trash-mapped) — land on the trash page.  Mamba leaves
    take the view's (updated, per-slot) state wholesale."""
    S = pos0.shape[0]
    n_pg = block_tables.shape[1]
    rows_idx = jnp.arange(S)
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            def wb(pool, v):
                ps = pool.shape[2]
                trash = pool.shape[1] - 1
                vp = v.reshape(v.shape[:2] + (n_pg, ps) + v.shape[3:])
                outp = pool
                # one flat-indexed scatter per touched logical page (rank-1
                # page indices with a contiguous page payload lower to block
                # copies; a single combined rank-2-indexed scatter does not)
                for j in range((k - 1) // ps + 2):
                    lp = pos0 // ps + j  # [S] logical page
                    valid = (lp * ps < pos0 + k) & (lp < n_pg)
                    lpc = jnp.clip(lp, 0, n_pg - 1)
                    page = jnp.take_along_axis(
                        vp, lpc.reshape((1, S, 1) + (1,) * (vp.ndim - 3)), axis=2
                    )[:, :, 0]  # [R, S, ps, ...]
                    pg = jnp.where(valid, block_tables[rows_idx, lpc], trash)
                    outp = outp.at[:, pg].set(page.astype(pool.dtype))
                return outp

            out.append(jax.tree.map(wb, caches[i], view[i]))
        else:
            out.append(view[i])
    return out


def paged_release(state: PagedDecodeState, keep) -> PagedDecodeState:
    """Release every slot with keep[slot] == False: decrement the refcount of
    each page its block table maps, reset the row to the trash sentinel, and
    deactivate it — one dispatch.

    Decrement-only by construction: a page shared with other slots (or held
    by the prefix cache) keeps ``refs > 0`` and its bytes; it is reclaimed —
    becomes allocatable — exactly when the last holder lets go (refs == 0).
    No clamping: a double release would drive a count negative, which the
    invariant tests catch, rather than silently freeing a held page."""
    n_pages = state.page_refs.shape[0]
    freed = (~jnp.asarray(keep)) & state.active
    dec = jnp.where(freed[:, None], state.block_tables, n_pages)
    refs = state.page_refs.at[dec.reshape(-1)].add(-1, mode="drop")
    return state._replace(
        page_refs=refs,
        block_tables=jnp.where(
            keep[:, None], state.block_tables, jnp.int32(n_pages)
        ).astype(state.block_tables.dtype),
        active=state.active & keep,
    )


def paged_extract_request(
    state: PagedDecodeState, slot: int, length: int, cfg: ModelConfig, *,
    page_size: int, start_page: int = 0,
) -> Cache:
    """Gather one request's pages back into a contiguous B=1 pack
    (decode->prefill chip-reallocation path).  Host-side, concrete indices.

    ``start_page`` skips the leading logical pages.  That is the shared-page
    fix for the preemption path: a request whose leading pages have other
    holders (``refs > 1`` — prefix-index entries, fork siblings) must not be
    extracted as if it solely owned them; the swap path drops this slot's
    mapping ref (decrement-only release) and leaves the bytes in the pool,
    extracting only the private tail from ``start_page`` on."""
    ps = page_size
    n_pg = -(-length // ps)
    bt = state.block_tables[slot, start_page:n_pg]
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        c = state.caches[i]
        if mixer == "attn":
            sc_i = None if state.scales is None else state.scales[i]

            def ex(pool, sc=None):
                rows = pool[:, bt]  # [R, n_pg - start_page, ps, ...]
                if sc is not None:
                    # int8 pool: the pack is the DEQUANTIZED fp32 values, so
                    # re-admission requantizes bit-exactly (the absmax element
                    # reconstructs to +/-127 * scale -> identical scale+payload)
                    rows = dequantize_pages(rows, sc[:, bt])
                flat = rows.reshape(
                    (rows.shape[0], (n_pg - start_page) * ps) + rows.shape[3:]
                )
                return flat[:, None, : length - start_page * ps]

            if sc_i is None:
                out.append(jax.tree.map(ex, c))
            else:
                out.append({kk: ex(c[kk], sc_i[kk]) for kk in c})
        else:
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1], c))
    return out


def paged_swap_out(
    state: PagedDecodeState, slot: int, length: int, cfg: ModelConfig, *,
    page_size: int, start_page: int = 0,
) -> Cache:
    """Stash one request's PRIVATE pages on host for page-level preemption.

    Built on the ``paged_extract_request`` round trip: gathers logical pages
    ``[start_page, ceil(length / page_size))`` — the caller passes the number
    of leading prefix-index-shared pages as ``start_page`` so shared bytes
    are never copied (their mapping ref is dropped instead; the index cache
    hold + a swap pin keep them resident) — and syncs them to host numpy.

    The pack is page-padded (whole pages, garbage beyond the write head under
    the usual overwrite-before-attend contract), so re-admission jit keys are
    bounded by ``pages_per_slot`` instead of one per exact swap length.  The
    caller releases the slot afterwards (decrement-only, inside the donated
    state); this one host sync is a rare lifecycle event, never per-step."""
    n_pg = -(-length // page_size)
    pack = paged_extract_request(
        state, slot, n_pg * page_size, cfg, page_size=page_size,
        start_page=start_page,
    )
    return jax.device_get(pack)  # fastpath: allow[FP001] swap-out runs at preemption cadence, off the decode path


def paged_swap_in(
    state: PagedDecodeState, pack: Cache, slot, token, length, cfg: ModelConfig,
    *, page_size: int, shared_pages=None, n_shared=None, reg_mask=None,
) -> PagedDecodeState:
    """Device twin of ``paged_swap_out``: remap the kept prefix pages (+1 ref
    each), scatter the host pack into freshly allocated pages starting at
    logical page ``n_shared``, and reactivate the slot at position ``length``
    — exactly ``paged_admit`` with a tail pack (``pack_page0 = n_shared``),
    so a resumed request is bit-identical to one that never left.  The engine
    routes swap-ins through its jitted, donated admit; this wrapper is the
    un-jitted reference transition used by unit tests."""
    pack = jax.tree.map(jnp.asarray, pack)
    return paged_admit(
        state, pack, slot, token, length, cfg, page_size=page_size,
        shared_pages=shared_pages, n_shared=n_shared, reg_mask=reg_mask,
        pack_page0=0 if n_shared is None else n_shared,
    )


def gather_prefix_pack(caches: Cache, tables, cfg: ModelConfig, scales=None) -> Cache:
    """Gather cached prefix pages into a contiguous prefix-KV pack for
    tail-only prefill: attn pool leaves [R, P+1, ps, ...] + ``tables``
    [B, n_pg] int32 -> [R, B, n_pg * ps, ...].

    ``tables`` rows are the matched physical pages, trash-padded past each
    request's shared length (and for unmatched rows); trash content is masked
    to exactly zero probability by the prefix-length mask in the attention
    mixers, so padding never perturbs the tail computation.  Mamba leaves
    yield None — SSM state is a whole-prompt function and is never shared
    (hybrid models take the full-recompute, pages-only sharing path).

    With ``scales`` (int8 pools) the gathered pages are dequantized, so the
    pack feeds the fp32 tail-prefill math unchanged.
    """
    B = tables.shape[0]
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            sc_i = None if scales is None else scales[i]

            def g(pool, sc=None):
                rows = pool[:, tables]  # [R, B, n_pg, ps, ...]
                if sc is not None:
                    rows = dequantize_pages(rows, sc[:, tables])
                return rows.reshape(
                    (rows.shape[0], B, rows.shape[2] * rows.shape[3]) + rows.shape[4:]
                )

            if sc_i is None:
                out.append(jax.tree.map(g, caches[i]))
            else:
                out.append({kk: g(caches[i][kk], sc_i[kk]) for kk in caches[i]})
        else:
            out.append(None)
    return out


class AuditReport(NamedTuple):
    """Result of ``audit()``: ``ok`` iff every invariant held; the
    discrepancy strings name the page/slot and the broken invariant."""

    ok: bool
    n_pages: int
    discrepancies: List[str]


def audit(
    state: PagedDecodeState,
    *,
    page_size: int,
    index_pages=(),
    chunk_holds=None,
    href=None,
) -> AuditReport:
    """On-device KV invariant auditor (host-syncs the small allocator arrays
    — refcounts, block tables, positions — never the pools themselves).

    Invariants checked, in terms of the refcount conservation law the whole
    paged design rests on (``page_refs[p]`` == number of live holders):

    1. **block-table validity** — every entry is a real page id or the trash
       page; an INACTIVE slot's row is all-trash (release resets rows); an
       ACTIVE slot's mapped region ``[0, ceil(position / page_size))``
       contains no trash entry (decode would silently write into the trash
       page) and nothing past the region is mapped (a stale mapping holds a
       phantom ref).
    2. **refcount conservation** — for every page,
       ``page_refs[p] == (# active block-table mappings of p)
       + (# prefix-index entries holding p) + (# in-flight chunk holds)``.
       Growth pages decode allocated mid-block are counted by their
       block-table mapping, so the law covers them with no extra term.
    3. **non-negativity** — no refcount underflow (a double release).
    4. **host-mirror sanity** (when ``href`` is given) — the engine's
       admit-time hold mirror never exceeds the device truth
       (``href[p] <= refs[p]``; decode-growth pages legitimately have
       device refs with no mirror entry, never the reverse).
    5. **scale-leaf liveness** (int8 pools, ``state.scales`` present) — every
       attention scale leaf has the ``[R, n_pages + 1]`` shape, and every
       LIVE page (``refs > 0``) carries a finite, non-negative scale in every
       leaf.  The trash page's scale (index n_pages) is a write-only scratch
       and is never checked — it is never read by construction.

    ``index_pages`` / ``chunk_holds`` are iterables of page ids WITH
    multiplicity (one occurrence per hold).  Pure read-only host math over
    one sync of the small arrays — safe to run every N rounds in production
    and after every drain in tests.
    """
    refs = np.asarray(state.page_refs)  # fastpath: allow[FP001] audit-cadence sync (small array)
    bt = np.asarray(state.block_tables)  # fastpath: allow[FP001] audit-cadence sync (small array)
    active = np.asarray(state.active)  # fastpath: allow[FP001] audit-cadence sync (small array)
    positions = np.asarray(state.positions)  # fastpath: allow[FP001] audit-cadence sync (small array)
    n_pages = int(refs.shape[0])
    max_slots, pages_per_slot = bt.shape
    probs: List[str] = []

    expected = np.zeros(n_pages, np.int64)
    for p in index_pages:
        if 0 <= p < n_pages:
            expected[p] += 1
        else:
            probs.append(f"index holds out-of-range page {p}")
    for p in chunk_holds or ():
        if 0 <= p < n_pages:
            expected[p] += 1
        else:
            probs.append(f"chunk hold on out-of-range page {p}")

    for slot in range(max_slots):
        row = bt[slot]
        if (row < 0).any() or (row > n_pages).any():
            probs.append(f"slot {slot}: block-table entry out of range")
            continue
        if not active[slot]:
            if (row != n_pages).any():
                probs.append(
                    f"slot {slot}: inactive but still maps "
                    f"{int((row != n_pages).sum())} page(s) (phantom refs)"
                )
            continue
        n_mapped = -(-int(positions[slot]) // page_size)
        n_mapped = min(n_mapped, pages_per_slot)
        mapped, rest = row[:n_mapped], row[n_mapped:]
        if (mapped == n_pages).any():
            probs.append(
                f"slot {slot}: trash page inside the mapped region "
                f"(position {int(positions[slot])})"
            )
        if (rest != n_pages).any():
            probs.append(
                f"slot {slot}: {int((rest != n_pages).sum())} stale "
                f"mapping(s) past the write head (phantom refs)"
            )
        for p in mapped[mapped < n_pages]:
            expected[p] += 1

    neg = np.nonzero(refs < 0)[0]
    for p in neg[:8]:
        probs.append(f"page {int(p)}: negative refcount {int(refs[p])} (double release)")
    bad = np.nonzero(refs != expected)[0]
    for p in bad[:8]:
        probs.append(
            f"page {int(p)}: refs {int(refs[p])} != expected "
            f"{int(expected[p])} (mappings + index holds + chunk holds)"
        )
    if len(bad) > 8:
        probs.append(f"... and {len(bad) - 8} more refcount discrepancies")
    if href is not None:
        hbad = np.nonzero(np.asarray(href) > refs)[0]  # fastpath: allow[FP001] audit-cadence sync
        for p in hbad[:8]:
            probs.append(
                f"page {int(p)}: host hold mirror {int(href[p])} exceeds "
                f"device refs {int(refs[p])}"
            )
    if state.scales is not None:
        live = refs > 0
        for i, sc_leaf in enumerate(state.scales):
            if sc_leaf is None:
                continue
            for name in sorted(sc_leaf):
                sc = np.asarray(sc_leaf[name])  # fastpath: allow[FP001] audit-cadence sync (small scale leaf)
                if sc.ndim != 2 or sc.shape[1] != n_pages + 1:
                    probs.append(
                        f"scale leaf {i}/{name}: shape {sc.shape} != "
                        f"[R, n_pages + 1 = {n_pages + 1}]"
                    )
                    continue
                bad_sc = (~np.isfinite(sc[:, :n_pages])) | (sc[:, :n_pages] < 0)
                for p in np.nonzero(bad_sc.any(axis=0) & live)[0][:4]:
                    probs.append(
                        f"scale leaf {i}/{name}: live page {int(p)} has a "
                        f"non-finite or negative scale"
                    )
    return AuditReport(ok=not probs, n_pages=n_pages, discrepancies=probs)


def paged_kv_cache_bytes(
    cfg: ModelConfig, max_slots: int, n_pages: int, page_size: int, max_len: int = 0,
    kv_dtype: str = "fp32",
) -> int:
    """HBM footprint of the paged pools (incl. the trash page) + per-slot
    mamba state + the block tables and allocator arrays.

    ``kv_dtype="int8"`` counts attention leaves at 1 byte per element plus
    the ``[R, n_pages + 1]`` fp32 scale leaf each — the admission math the
    scheduler and benches use to size int8 pools at fixed HBM."""
    assert kv_dtype in KV_DTYPES, kv_dtype
    specs = M.init_paged_cache_specs(cfg, max_slots, n_pages + 1, page_size)
    pool = 0
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        for s in jax.tree.leaves(specs[i]):
            n = int(jnp.prod(jnp.array(s.shape)))
            if mixer == "attn" and kv_dtype == "int8":
                pool += n * 1 + cfg.n_repeats * (n_pages + 1) * 4
            else:
                pool += n * jnp.dtype(s.dtype).itemsize
    tables = n_pages * 4 + (max_slots * (max_len // page_size)) * 4
    return pool + tables
