"""Slot-based KV-cache management for continuous batching.

TPU-style serving wants static shapes: the decode engine owns a cache of
``max_slots`` rows x ``max_len`` positions per attention layer (JetStream-
style), plus per-slot lengths and active flags.  Prefill produces a
single-request cache which is *inserted* into a free slot — that insert is
the software form of the paper's prefill->decode KV handoff.

All cache trees follow the model layout: a list (one entry per pattern
position) of dicts of stacked [n_repeats, B, ...] arrays.

Device-resident invariants (the serving fast path)
--------------------------------------------------
``DecodeState`` bundles EVERYTHING the decode loop touches per step — the
slot caches, last-emitted tokens, write positions, active mask, and the
sampling PRNG key — into one pytree that lives on device across steps.  The
engine jits its step/admit/release transitions with ``donate_argnums`` on
the state, so XLA updates the KV cache in place instead of re-materializing
``max_slots * max_len`` KV bytes per token.  The host only syncs on the
emitted token block (once per ``decode_block`` tokens), never on the state.

Bucketed-prefill contract: a slot row inserted from a right-padded prefill
may contain garbage K/V at positions [true_len, bucket).  That is safe by
construction: decode starts writing at position true_len and the attention
mask only ever reads positions < pos, so every padded position is
overwritten before it is first attended.

Paged KV cache (the vLLM/SGLang layout, TPU-shaped)
---------------------------------------------------
The slab cache pins ``max_len`` positions per slot no matter how short the
request — exactly the HBM waste the paper's memory-bound Decode Chip cannot
afford.  ``PagedDecodeState`` replaces the per-slot attention slabs with:

* **page pools**: every attention cache leaf becomes
  ``[R, n_pages + 1, page_size, ...]`` — a pool of fixed-size pages shared by
  all slots.  Page index ``n_pages`` (the last one) is the *trash page*: all
  masked/out-of-range writes are steered there instead of being predicated,
  so every cache write lowers to one unconditional scatter/DUS.
* **block tables**: ``[max_slots, max_len // page_size]`` int32 mapping each
  slot's logical page j to a physical pool page; unmapped entries hold the
  trash index, so gathers through a partial table read (masked) trash.
* **a device-resident allocator**: ``page_owner`` ``[n_pages]`` int32
  (-1 = free, else owning slot).  Allocation = rank the first free pages with
  a sized ``jnp.nonzero``; release = one ``where`` over owners.  Both run
  inside the donated jitted transitions — the free list never syncs to host.

Mamba/conv state is fixed-size per request and stays per-slot
(``[R, max_slots, ...]``); only attention leaves page.

The bucketed-prefill garbage contract carries over per page: admit copies
whole prompt pages (including bucket garbage in the last partial page), and
decode overwrites position ``pos`` before any step attends it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model as M

Cache = Any


@dataclass
class SlotState:
    """Host-side slot bookkeeping (device arrays live in ``DecodeState``)."""

    max_slots: int
    max_len: int
    lengths: List[int] = field(default_factory=list)  # host mirror
    request_ids: List[Optional[int]] = field(default_factory=list)

    def __post_init__(self):
        self.lengths = [0] * self.max_slots
        self.request_ids = [None] * self.max_slots

    def alloc(self, rid: int) -> Optional[int]:
        for i, r in enumerate(self.request_ids):
            if r is None:
                self.request_ids[i] = rid
                self.lengths[i] = 0
                return i
        return None

    def free(self, slot: int):
        self.request_ids[slot] = None
        self.lengths[slot] = 0

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.request_ids)


class DecodeState(NamedTuple):
    """All decode-loop state, device-resident across steps (one pytree).

    caches     model cache tree, [R, max_slots, max_len, ...] per attn leaf
    tokens     [max_slots] int32   last emitted token per slot
    positions  [max_slots] int32   next cache write position per slot
    active     [max_slots] bool    slot currently owns a live request
    key        PRNG key consumed one split per decode step
    """

    caches: Cache
    tokens: jnp.ndarray
    positions: jnp.ndarray
    active: jnp.ndarray
    key: jnp.ndarray


def init_decode_state(cfg: ModelConfig, max_slots: int, max_len: int, key) -> DecodeState:
    return DecodeState(
        caches=batch_cache(cfg, max_slots, max_len),
        tokens=jnp.zeros((max_slots,), jnp.int32),
        positions=jnp.zeros((max_slots,), jnp.int32),
        active=jnp.zeros((max_slots,), bool),
        key=key,
    )


def batch_cache(cfg: ModelConfig, max_slots: int, max_len: int) -> Cache:
    """Zero-initialized slot cache [R, max_slots, max_len, ...]."""
    return M.zeros_cache(cfg, max_slots, max_len)


def insert_request(batch: Cache, single: Cache, slot, cfg: ModelConfig) -> Cache:
    """Insert a prefilled single-request cache (B=1) into ``slot``.

    Attention caches copy the prefix [L1] into the slot row; mamba caches
    (fixed size) replace the row.  ``slot`` may be a traced int32 — the
    engine jits this with the state donated so admits are in-place instead
    of an un-jitted tree-wide copy.
    """
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        b = batch[i]
        s = single[i]
        if mixer == "attn":
            def ins(dst, src):
                # dst [R, S, L, ...], src [R, 1, L1, ...]
                L1 = min(src.shape[2], dst.shape[2])
                pad = dst.shape[2] - L1
                row = jnp.pad(
                    src[:, 0, :L1], [(0, 0), (0, pad)] + [(0, 0)] * (src.ndim - 3)
                )
                return jax.lax.dynamic_update_index_in_dim(dst, row.astype(dst.dtype), slot, 1)
        else:
            def ins(dst, src):
                return jax.lax.dynamic_update_index_in_dim(dst, src[:, 0].astype(dst.dtype), slot, 1)
        out.append(jax.tree.map(ins, b, s))
    return out


def slice_request(batch: Cache, b) -> Cache:
    """Slice request ``b`` out of a batched prefill pack -> B=1 pack.

    ``b`` may be traced; used inside jitted admits from batched prefill."""
    return jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(a, b, 1, axis=1), batch)


def extract_request(batch: Cache, slot: int, length: int, cfg: ModelConfig) -> Cache:
    """Pull one request's cache back out (decode->prefill reallocation path)."""
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        b = batch[i]
        if mixer == "attn":
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1, :length], b))
        else:
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1], b))
    return out


def kv_cache_bytes(cfg: ModelConfig, max_slots: int, max_len: int) -> int:
    specs = M.init_cache_specs(cfg, max_slots, max_len)
    return sum(
        int(jnp.prod(jnp.array(s.shape))) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(specs)
    )


# ---------------------------------------------------------------------------
# Paged KV cache: pools + block tables + device-resident free-page allocator
# ---------------------------------------------------------------------------


class PagedDecodeState(NamedTuple):
    """Paged decode-loop state, device-resident across steps (one pytree).

    caches        attn leaves [R, n_pages+1, page_size, ...] (last page = trash);
                  mamba leaves [R, max_slots, ...] (fixed-size, per-slot)
    block_tables  [max_slots, max_len // page_size] int32; unmapped = n_pages
    page_owner    [n_pages] int32; -1 = free, else owning slot
    tokens        [max_slots] int32   last emitted token per slot
    positions     [max_slots] int32   next cache write position per slot
    active        [max_slots] bool    slot currently owns a live request
    key           PRNG key consumed one split per decode step
    """

    caches: Cache
    block_tables: jnp.ndarray
    page_owner: jnp.ndarray
    tokens: jnp.ndarray
    positions: jnp.ndarray
    active: jnp.ndarray
    key: jnp.ndarray


def init_paged_decode_state(
    cfg: ModelConfig, max_slots: int, max_len: int, page_size: int, n_pages: int, key
) -> PagedDecodeState:
    assert max_len % page_size == 0, (max_len, page_size)
    pages_per_slot = max_len // page_size
    return PagedDecodeState(
        caches=M.zeros_paged_cache(cfg, max_slots, n_pages + 1, page_size),
        block_tables=jnp.full((max_slots, pages_per_slot), n_pages, jnp.int32),
        page_owner=jnp.full((n_pages,), -1, jnp.int32),
        tokens=jnp.zeros((max_slots,), jnp.int32),
        positions=jnp.zeros((max_slots,), jnp.int32),
        active=jnp.zeros((max_slots,), bool),
        key=key,
    )


def alloc_decode_pages(page_owner, need):
    """Grab one free page per slot where ``need`` [max_slots] bool is set.

    Returns (new_owner, page_ids [max_slots] int32); slots that need nothing
    (or an exhausted pool — unreachable under the engine's reservation-based
    admission) get the trash index ``n_pages``.  Runs inside the fused decode
    scan: pure ranking arithmetic, no host sync.
    """
    n_pages = page_owner.shape[0]
    S = need.shape[0]
    (free_idx,) = jnp.nonzero(page_owner < 0, size=S, fill_value=n_pages)
    rank = jnp.clip(jnp.cumsum(need) - 1, 0, S - 1)
    pages = jnp.where(need, free_idx[rank], n_pages)
    owner = page_owner.at[pages].set(
        jnp.arange(S, dtype=page_owner.dtype), mode="drop"
    )
    return owner, pages.astype(jnp.int32)


def paged_admit(
    state: PagedDecodeState, single: Cache, slot, token, true_len, cfg: ModelConfig,
    *, page_size: int,
) -> PagedDecodeState:
    """Allocate ceil(true_len / page_size) pages for ``slot`` and scatter the
    prefilled single-request cache (B=1) into them (the paged KV handoff).

    ``slot``/``token``/``true_len`` may be traced — the engine jits this with
    the state donated.  Prompt pages are written whole; writes for logical
    pages past the allocation land on the trash page (see module docstring).
    """
    ps = page_size
    pages_per_slot = state.block_tables.shape[1]
    n_pages = state.page_owner.shape[0]
    n_need = (jnp.asarray(true_len, jnp.int32) + ps - 1) // ps
    (free_idx,) = jnp.nonzero(state.page_owner < 0, size=pages_per_slot, fill_value=n_pages)
    take = jnp.arange(pages_per_slot) < n_need
    page_ids = jnp.where(take, free_idx, n_pages).astype(jnp.int32)
    owner = state.page_owner.at[page_ids].set(
        jnp.asarray(slot, state.page_owner.dtype), mode="drop"
    )
    block_tables = state.block_tables.at[slot].set(page_ids)

    caches = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            def ins(dst, src):
                # dst [R, P+1, ps, ...], src [R, 1, L1, ...] -> ONE scatter of
                # all prompt pages; pages past the allocation (bucket garbage)
                # carry the trash index and land on the trash page
                L1 = src.shape[2]
                n_src = min(-(-L1 // ps), pages_per_slot)
                pad = n_src * ps - L1
                row = src[:, 0]
                if pad > 0:
                    row = jnp.pad(row, [(0, 0), (0, pad)] + [(0, 0)] * (row.ndim - 2))
                pages = row[:, : n_src * ps].reshape(
                    (row.shape[0], n_src, ps) + row.shape[2:]
                )
                return dst.at[:, page_ids[:n_src]].set(pages.astype(dst.dtype))
        else:
            def ins(dst, src):
                return jax.lax.dynamic_update_index_in_dim(dst, src[:, 0].astype(dst.dtype), slot, 1)
        caches.append(jax.tree.map(ins, state.caches[i], single[i]))

    return PagedDecodeState(
        caches=caches,
        block_tables=block_tables,
        page_owner=owner,
        tokens=state.tokens.at[slot].set(token),
        positions=state.positions.at[slot].set(true_len),
        active=state.active.at[slot].set(True),
        key=state.key,
    )


def paged_gather_view(caches: Cache, block_tables, cfg: ModelConfig) -> Cache:
    """Materialize the slab-layout view of the paged pools for one decode
    block: attn leaves [R, P+1, ps, ...] -> [R, S, max_len, ...] through the
    block tables; mamba leaves pass through (already per-slot).

    The fused decode block gathers this ONCE, runs its k steps against the
    view (byte-for-byte the slab math -> bit-identical streams), and writes
    the k fresh positions back to the pool with ``paged_writeback`` — so the
    per-step cost matches the slab engine and the gather/scatter amortizes
    over the block.  The view is a transient working buffer inside the jitted
    block (freed between blocks); persistent KV state is only the pool."""
    S = block_tables.shape[0]
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            def g(pool):
                rows = pool[:, block_tables]  # [R, S, n_pg, ps, ...]
                return rows.reshape(
                    (rows.shape[0], S, rows.shape[2] * rows.shape[3]) + rows.shape[4:]
                )
            out.append(jax.tree.map(g, caches[i]))
        else:
            out.append(caches[i])
    return out


def paged_writeback(
    caches: Cache, view: Cache, block_tables, pos0, k: int, cfg: ModelConfig
) -> Cache:
    """Copy the logical pages each slot wrote during the block — positions
    [pos0, pos0 + k) span at most (k-1)//page_size + 2 of them — from the
    view back into the page pools, WHOLE pages at a time (page-granular
    scatters of contiguous rows, not per-position writes).

    Copying a whole touched page is exact: positions before pos0 carry the
    values gathered from the pool at block start, and positions past the
    write head are garbage under the same overwrite-before-attend contract as
    bucketed prefill.  Slots whose pages are out of reach — frozen at
    max_len, released (trash-mapped) — land on the trash page.  Mamba leaves
    take the view's (updated, per-slot) state wholesale."""
    S = pos0.shape[0]
    n_pg = block_tables.shape[1]
    rows_idx = jnp.arange(S)
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            def wb(pool, v):
                ps = pool.shape[2]
                trash = pool.shape[1] - 1
                vp = v.reshape(v.shape[:2] + (n_pg, ps) + v.shape[3:])
                outp = pool
                # one flat-indexed scatter per touched logical page (rank-1
                # page indices with a contiguous page payload lower to block
                # copies; a single combined rank-2-indexed scatter does not)
                for j in range((k - 1) // ps + 2):
                    lp = pos0 // ps + j  # [S] logical page
                    valid = (lp * ps < pos0 + k) & (lp < n_pg)
                    lpc = jnp.clip(lp, 0, n_pg - 1)
                    page = jnp.take_along_axis(
                        vp, lpc.reshape((1, S, 1) + (1,) * (vp.ndim - 3)), axis=2
                    )[:, :, 0]  # [R, S, ps, ...]
                    pg = jnp.where(valid, block_tables[rows_idx, lpc], trash)
                    outp = outp.at[:, pg].set(page.astype(pool.dtype))
                return outp

            out.append(jax.tree.map(wb, caches[i], view[i]))
        else:
            out.append(view[i])
    return out


def paged_release(state: PagedDecodeState, keep) -> PagedDecodeState:
    """Free every page owned by slots with keep[slot] == False, reset their
    block-table rows to the trash sentinel, and deactivate them — one dispatch."""
    owner = state.page_owner
    S = keep.shape[0]
    n_pages = owner.shape[0]
    kept = jnp.where(owner >= 0, keep[jnp.clip(owner, 0, S - 1)], True)
    return state._replace(
        page_owner=jnp.where(kept, owner, -1),
        block_tables=jnp.where(
            keep[:, None], state.block_tables, jnp.int32(n_pages)
        ).astype(state.block_tables.dtype),
        active=state.active & keep,
    )


def paged_extract_request(
    state: PagedDecodeState, slot: int, length: int, cfg: ModelConfig, *, page_size: int
) -> Cache:
    """Gather one request's pages back into a contiguous B=1 pack
    (decode->prefill chip-reallocation path).  Host-side, concrete indices."""
    ps = page_size
    n_pg = -(-length // ps)
    bt = state.block_tables[slot, :n_pg]
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        c = state.caches[i]
        if mixer == "attn":
            def ex(pool):
                rows = pool[:, bt]  # [R, n_pg, ps, ...]
                flat = rows.reshape((rows.shape[0], n_pg * ps) + rows.shape[3:])
                return flat[:, None, :length]
            out.append(jax.tree.map(ex, c))
        else:
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1], c))
    return out


def paged_kv_cache_bytes(
    cfg: ModelConfig, max_slots: int, n_pages: int, page_size: int, max_len: int = 0
) -> int:
    """HBM footprint of the paged pools (incl. the trash page) + per-slot
    mamba state + the block tables and allocator arrays."""
    specs = M.init_paged_cache_specs(cfg, max_slots, n_pages + 1, page_size)
    pool = sum(
        int(jnp.prod(jnp.array(s.shape))) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(specs)
    )
    tables = n_pages * 4 + (max_slots * (max_len // page_size)) * 4
    return pool + tables
