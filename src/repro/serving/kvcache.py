"""Slot-based KV-cache management for continuous batching.

TPU-style serving wants static shapes: the decode engine owns a cache of
``max_slots`` rows x ``max_len`` positions per attention layer (JetStream-
style), plus per-slot lengths and active flags.  Prefill produces a
single-request cache which is *inserted* into a free slot — that insert is
the software form of the paper's prefill->decode KV handoff.

All cache trees follow the model layout: a list (one entry per pattern
position) of dicts of stacked [n_repeats, B, ...] arrays.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model as M

Cache = Any


@dataclass
class SlotState:
    """Host-side slot bookkeeping (device arrays live in the engine)."""

    max_slots: int
    max_len: int
    lengths: List[int] = field(default_factory=list)  # host mirror
    request_ids: List[Optional[int]] = field(default_factory=list)

    def __post_init__(self):
        self.lengths = [0] * self.max_slots
        self.request_ids = [None] * self.max_slots

    def alloc(self, rid: int) -> Optional[int]:
        for i, r in enumerate(self.request_ids):
            if r is None:
                self.request_ids[i] = rid
                self.lengths[i] = 0
                return i
        return None

    def free(self, slot: int):
        self.request_ids[slot] = None
        self.lengths[slot] = 0

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.request_ids)


def batch_cache(cfg: ModelConfig, max_slots: int, max_len: int) -> Cache:
    """Zero-initialized slot cache [R, max_slots, max_len, ...]."""
    return M.zeros_cache(cfg, max_slots, max_len)


def insert_request(batch: Cache, single: Cache, slot: int, cfg: ModelConfig) -> Cache:
    """Insert a prefilled single-request cache (B=1) into ``slot``.

    Attention caches copy the prefix [L1] into the slot row; mamba caches
    (fixed size) replace the row.
    """
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        b = batch[i]
        s = single[i]
        if mixer == "attn":
            def ins(dst, src):
                # dst [R, S, L, ...], src [R, 1, L1, ...]
                L1 = src.shape[2]
                pad = dst.shape[2] - L1
                row = jnp.pad(src[:, 0], [(0, 0), (0, pad)] + [(0, 0)] * (src.ndim - 3))
                return jax.lax.dynamic_update_index_in_dim(dst, row.astype(dst.dtype), slot, 1)
        else:
            def ins(dst, src):
                return jax.lax.dynamic_update_index_in_dim(dst, src[:, 0].astype(dst.dtype), slot, 1)
        out.append(jax.tree.map(ins, b, s))
    return out


def extract_request(batch: Cache, slot: int, length: int, cfg: ModelConfig) -> Cache:
    """Pull one request's cache back out (decode->prefill reallocation path)."""
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        b = batch[i]
        if mixer == "attn":
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1, :length], b))
        else:
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1], b))
    return out


def kv_cache_bytes(cfg: ModelConfig, max_slots: int, max_len: int) -> int:
    specs = M.init_cache_specs(cfg, max_slots, max_len)
    return sum(
        int(jnp.prod(jnp.array(s.shape))) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(specs)
    )
