"""Slot-based KV-cache management for continuous batching.

TPU-style serving wants static shapes: the decode engine owns a cache of
``max_slots`` rows x ``max_len`` positions per attention layer (JetStream-
style), plus per-slot lengths and active flags.  Prefill produces a
single-request cache which is *inserted* into a free slot — that insert is
the software form of the paper's prefill->decode KV handoff.

All cache trees follow the model layout: a list (one entry per pattern
position) of dicts of stacked [n_repeats, B, ...] arrays.

Device-resident invariants (the serving fast path)
--------------------------------------------------
``DecodeState`` bundles EVERYTHING the decode loop touches per step — the
slot caches, last-emitted tokens, write positions, active mask, and the
sampling PRNG key — into one pytree that lives on device across steps.  The
engine jits its step/admit/release transitions with ``donate_argnums`` on
the state, so XLA updates the KV cache in place instead of re-materializing
``max_slots * max_len`` KV bytes per token.  The host only syncs on the
emitted token block (once per ``decode_block`` tokens), never on the state.

Bucketed-prefill contract: a slot row inserted from a right-padded prefill
may contain garbage K/V at positions [true_len, bucket).  That is safe by
construction: decode starts writing at position true_len and the attention
mask only ever reads positions < pos, so every padded position is
overwritten before it is first attended.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import model as M

Cache = Any


@dataclass
class SlotState:
    """Host-side slot bookkeeping (device arrays live in ``DecodeState``)."""

    max_slots: int
    max_len: int
    lengths: List[int] = field(default_factory=list)  # host mirror
    request_ids: List[Optional[int]] = field(default_factory=list)

    def __post_init__(self):
        self.lengths = [0] * self.max_slots
        self.request_ids = [None] * self.max_slots

    def alloc(self, rid: int) -> Optional[int]:
        for i, r in enumerate(self.request_ids):
            if r is None:
                self.request_ids[i] = rid
                self.lengths[i] = 0
                return i
        return None

    def free(self, slot: int):
        self.request_ids[slot] = None
        self.lengths[slot] = 0

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.request_ids)


class DecodeState(NamedTuple):
    """All decode-loop state, device-resident across steps (one pytree).

    caches     model cache tree, [R, max_slots, max_len, ...] per attn leaf
    tokens     [max_slots] int32   last emitted token per slot
    positions  [max_slots] int32   next cache write position per slot
    active     [max_slots] bool    slot currently owns a live request
    key        PRNG key consumed one split per decode step
    """

    caches: Cache
    tokens: jnp.ndarray
    positions: jnp.ndarray
    active: jnp.ndarray
    key: jnp.ndarray


def init_decode_state(cfg: ModelConfig, max_slots: int, max_len: int, key) -> DecodeState:
    return DecodeState(
        caches=batch_cache(cfg, max_slots, max_len),
        tokens=jnp.zeros((max_slots,), jnp.int32),
        positions=jnp.zeros((max_slots,), jnp.int32),
        active=jnp.zeros((max_slots,), bool),
        key=key,
    )


def batch_cache(cfg: ModelConfig, max_slots: int, max_len: int) -> Cache:
    """Zero-initialized slot cache [R, max_slots, max_len, ...]."""
    return M.zeros_cache(cfg, max_slots, max_len)


def insert_request(batch: Cache, single: Cache, slot, cfg: ModelConfig) -> Cache:
    """Insert a prefilled single-request cache (B=1) into ``slot``.

    Attention caches copy the prefix [L1] into the slot row; mamba caches
    (fixed size) replace the row.  ``slot`` may be a traced int32 — the
    engine jits this with the state donated so admits are in-place instead
    of an un-jitted tree-wide copy.
    """
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        b = batch[i]
        s = single[i]
        if mixer == "attn":
            def ins(dst, src):
                # dst [R, S, L, ...], src [R, 1, L1, ...]
                L1 = min(src.shape[2], dst.shape[2])
                pad = dst.shape[2] - L1
                row = jnp.pad(
                    src[:, 0, :L1], [(0, 0), (0, pad)] + [(0, 0)] * (src.ndim - 3)
                )
                return jax.lax.dynamic_update_index_in_dim(dst, row.astype(dst.dtype), slot, 1)
        else:
            def ins(dst, src):
                return jax.lax.dynamic_update_index_in_dim(dst, src[:, 0].astype(dst.dtype), slot, 1)
        out.append(jax.tree.map(ins, b, s))
    return out


def slice_request(batch: Cache, b) -> Cache:
    """Slice request ``b`` out of a batched prefill pack -> B=1 pack.

    ``b`` may be traced; used inside jitted admits from batched prefill."""
    return jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(a, b, 1, axis=1), batch)


def extract_request(batch: Cache, slot: int, length: int, cfg: ModelConfig) -> Cache:
    """Pull one request's cache back out (decode->prefill reallocation path)."""
    out = []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        b = batch[i]
        if mixer == "attn":
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1, :length], b))
        else:
            out.append(jax.tree.map(lambda a: a[:, slot : slot + 1], b))
    return out


def kv_cache_bytes(cfg: ModelConfig, max_slots: int, max_len: int) -> int:
    specs = M.init_cache_specs(cfg, max_slots, max_len)
    return sum(
        int(jnp.prod(jnp.array(s.shape))) * jnp.dtype(s.dtype).itemsize
        for s in jax.tree.leaves(specs)
    )
