"""Multi-replica KV-aware router: the serving front door.

The paper's cluster story presupposes a routing layer in front of the
phase-specialized pods: N prefill-chip/decode-chip replica groups, with a
load balancer that knows where KV lives (cf. production-stack's KV-aware
router and the Nexus/TetriServe-style schedulers in PAPERS.md).  ``Router``
is that layer in this repo's single-process simulation: it owns N complete
``DisaggregatedServer`` replicas — each a prefill pool -> KV handoff ->
decode pool built from ONE shared ``EngineConfig`` — and decides, per
submit, which replica serves the request.

Routing signals, in priority order (lexicographic, so traces are
reproducible):

1. **Prefix-cache locality** — the chained page-chunk hashes computed at
   submit (the SAME hashes the in-replica KV-aware scheduler memoizes) are
   matched against every replica's ``PrefixIndex`` with ``touch=False``:
   pages matched in a replica's pool are pages its prefill never recomputes,
   so the longest hit wins outright.  The winning replica's hash memo is
   seeded with the router's hashes — the prompt is hashed once end to end.
2. **Free pages** — ties broken toward the replica whose decode pools have
   the most FREE PAGES (``DecodeEngine.free_pages``, the refcount-aware
   capacity measure), not merely free slots: a replica with open slots but
   an exhausted pool would only park the request in its waiting line.
3. **Queue depth** — remaining ties go to the replica with the fewest live
   requests (queued + waiting + swapped + decoding).
4. **Replica index** — the final tie-break is the lowest index, which makes
   the full decision function deterministic: same config + same submit
   sequence => bit-identical ``trace`` / ``assignments``.

The router is pure POLICY over intact replicas: each replica's own scheduler
still orders its queue, and greedy decode streams are schedule-independent,
so routed streams stay bit-identical to a single-replica FCFS run of the
same workload (the ``router`` bench section gates exactly that).

``submit`` returns a ``RequestHandle`` bound to the router; ``drain`` /
``run`` / ``run_round`` mirror the single-server contract (see
``DisaggregatedServer.drain``), driving every replica that still has work.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from ..configs.base import ModelConfig
from .config import EngineConfig
from .engine import (
    STATUS_CANCELLED,
    DisaggregatedServer,
    GenRequest,
    RequestHandle,
    RequestOutcome,
    SchedulerExhausted,
)
from .prefix_cache import chunk_hashes


@dataclass(frozen=True)
class RouteDecision:
    """One routing decision, recorded on ``Router.trace``.

    rid            the routed request
    replica        index of the chosen replica
    matched_pages  prefix pages the chosen replica already holds (0 = cold)
    scores         the full per-replica signal tuple the decision minimized:
                   ``(-matched_pages, -free_pages, queue_depth, index)`` per
                   replica — kept so a trace is auditable, not just replayable
    """

    rid: int
    replica: int
    matched_pages: int
    scores: Tuple[Tuple[int, int, int, int], ...]


class Router:
    """N ``DisaggregatedServer`` replicas behind one KV-aware submit().

    Accepts ONLY an ``EngineConfig`` (the loose-kwarg shim stops at the
    engine layer); replica ``i`` is built with the config's seed offset by
    ``i`` — see ``DisaggregatedServer.from_config``.
    """

    def __init__(
        self,
        params,
        cfg: ModelConfig,
        config: EngineConfig,
        *,
        replicas: int = 2,
        transfer=lambda kv: kv,
        n_prefills: int = 1,
        n_decodes: int = 1,
    ):
        if not isinstance(config, EngineConfig):
            raise TypeError(
                f"Router takes an EngineConfig, got {type(config).__name__} "
                f"(the loose engine kwargs are not accepted here)"
            )
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        self.config = config
        self.servers: List[DisaggregatedServer] = [
            DisaggregatedServer.from_config(
                params, cfg, config, transfer=transfer,
                n_prefills=n_prefills, n_decodes=n_decodes, replica=i,
            )
            for i in range(replicas)
        ]
        # rid -> owning replica index / request record; the router-level
        # bookkeeping mirrors the single-server surface so RequestHandle and
        # callers work identically against either owner
        self.assignments: Dict[int, int] = {}
        self.all_requests: Dict[int, GenRequest] = {}
        self.trace: List[RouteDecision] = []
        # (rid, page_size) -> chained chunk hashes: computed ONCE at routing
        # time and handed to the winning replica's memo (prompts are
        # immutable); dropped when the request is forgotten everywhere
        self._hash_memo: Dict[Tuple[int, int], List[bytes]] = {}

    # -- routing ------------------------------------------------------------

    def _hashes_for(self, req: GenRequest, page_size: int, max_chunks: int):
        hk = (req.rid, page_size)
        if hk not in self._hash_memo:
            self._hash_memo[hk] = chunk_hashes(req.prompt, page_size, max_chunks)
        return self._hash_memo[hk]

    def _signals(self, req: GenRequest):
        """Per-replica (matched_pages, free_pages, queue_depth) scan.

        A scan, not a take: prefix matches use ``touch=False`` (index
        recency moves only when the winning replica pins at prefill time),
        and nothing is reserved — the replica's own admission control still
        applies."""
        out = []
        for s in self.servers:
            matched = 0
            for d in s.decodes:
                if not getattr(d, "prefix_cache", False):
                    continue
                if not d.can_ever_admit(len(req.prompt), req.max_new_tokens):
                    continue
                h = self._hashes_for(req, d.page_size, d.pages_per_slot)
                m = d.match_prefix(req.prompt, hashes=h, touch=False)
                if m is not None and m.n_shared > matched:
                    matched = m.n_shared
            free = sum(
                d.free_pages for d in s.decodes if getattr(d, "paged", False)
            )
            depth = (
                len(s.scheduler.queue)
                + len(s.scheduler.waiting)
                + len(s.scheduler.swapped)
                + sum(d.slots.n_active for d in s.decodes)
            )
            out.append((matched, free, depth))
        return out

    def route(self, req: GenRequest) -> RouteDecision:
        """The routing decision for ``req`` — pure policy, no submission.

        Lexicographic minimum over ``(-matched_pages, -free_pages,
        queue_depth, replica_index)`` across replicas that could EVER admit
        the request; deterministic by construction.  Exposed separately from
        ``submit`` so tests and benches can audit decisions."""
        signals = self._signals(req)
        scores = tuple(
            (-matched, -free, depth, i)
            for i, (matched, free, depth) in enumerate(signals)
        )
        feasible = [
            i for i, s in enumerate(self.servers)
            if req.max_new_tokens <= 1 or any(
                d.can_ever_admit(len(req.prompt), req.max_new_tokens)
                for d in s.decodes
            )
        ]
        # no feasible replica: route to 0 so submit() raises the canonical
        # capacity error instead of inventing a router-specific one
        pick = min(feasible, key=lambda i: scores[i]) if feasible else 0
        return RouteDecision(
            rid=req.rid, replica=pick,
            matched_pages=-scores[pick][0], scores=scores,
        )

    def submit(self, req: GenRequest) -> RequestHandle:
        """Route and queue ``req`` on the chosen replica; returns a
        ``RequestHandle`` bound to the ROUTER (its ``result()`` / ``stream()``
        drive all replicas).  Validation errors propagate from the replica's
        ``submit`` before any routing state is recorded."""
        decision = self.route(req)
        srv = self.servers[decision.replica]
        srv.submit(req)
        # hand the routing-time hashes to the replica so its own KV-aware
        # scans (Scheduler.match_for) never re-hash this prompt
        for d in srv.decodes:
            if getattr(d, "prefix_cache", False):
                hk = (req.rid, d.page_size)
                if hk in self._hash_memo:
                    srv._hash_memo[hk] = self._hash_memo[hk]
        self.assignments[req.rid] = decision.replica
        self.all_requests[req.rid] = req
        self.trace.append(decision)
        return RequestHandle(req.rid, self)

    # -- the single-server driving surface, spanning all replicas -----------

    @property
    def replicas(self) -> int:
        return len(self.servers)

    def owner_of(self, rid: int) -> DisaggregatedServer:
        """The replica serving ``rid`` (raises KeyError for unknown rids)."""
        return self.servers[self.assignments[rid]]

    def load(self) -> List[int]:
        """Requests routed to each replica over the router's lifetime."""
        counts = [0] * len(self.servers)
        for i in self.assignments.values():
            counts[i] += 1
        return counts

    def pending(self) -> bool:
        return any(s.pending() for s in self.servers)

    def run_round(self) -> None:
        """One scheduling round on every replica that still has work, in
        replica order (the deterministic cluster-wide round)."""
        for s in self.servers:
            if s.pending():
                s.run_round()
        # drop routing-time hashes of requests that reached a terminal
        # status (the replicas' own memos are pruned by their _forget)
        if self._hash_memo:
            done = {rid for rid, req in self.all_requests.items() if req.done}
            for hk in [k for k in self._hash_memo if k[0] in done]:
                del self._hash_memo[hk]

    def drain(self, max_rounds: Optional[int] = None) -> Dict[int, RequestOutcome]:
        """Cluster-wide drain; same contract as ``DisaggregatedServer.drain``
        (documented there), with one router round = one round per busy
        replica."""
        rounds = 0
        while self.pending() and (max_rounds is None or rounds < max_rounds):
            rounds += 1
            self.run_round()
        return self.outcomes()

    def run(self, max_steps: int = 10_000) -> Dict[int, List[int]]:
        """Anchor-compatible alias of ``drain(max_steps)`` (mirrors
        ``DisaggregatedServer.run``): returns ``{rid: tokens}`` for terminal
        requests, raises a resumable ``SchedulerExhausted`` on leftovers."""
        self.drain(max_steps)
        if self.pending():
            done = {r: q.tokens for r, q in self.all_requests.items() if q.done}
            unfinished = sorted(
                r for r, q in self.all_requests.items() if not q.done
            )
            raise SchedulerExhausted(
                f"hit max_steps={max_steps} with {len(unfinished)} request(s) "
                f"unfinished: {unfinished[:8]}{'...' if len(unfinished) > 8 else ''}",
                done=done,
                unfinished=unfinished,
                statuses=self.outcomes(),
            )
        return {r: q.tokens for r, q in self.all_requests.items() if q.done}

    def cancel(self, rid: int, *, status: str = STATUS_CANCELLED) -> bool:
        """Delegates to the owning replica (bit-exact with the in-replica rid
        path); False for unknown/terminal rids, like the server's."""
        if rid not in self.assignments:
            return False
        ok = self.owner_of(rid).cancel(rid, status=status)
        if ok:
            self._forget_hashes(rid)
        return ok

    def outcomes(self) -> Dict[int, RequestOutcome]:
        """Merged rid -> ``RequestOutcome`` across replicas (disjoint rids:
        a request is owned by exactly one replica)."""
        out: Dict[int, RequestOutcome] = {}
        for s in self.servers:
            out.update(s.outcomes())
        return out

    def audit(self, strict: bool = False):
        """KV invariant audit across every replica's decode pools."""
        return [rep for s in self.servers for rep in s.audit(strict=strict)]

    def _stage_of(self, rid: int) -> str:
        if rid not in self.assignments:
            return "unknown"
        return self.owner_of(rid)._stage_of(rid)

    def rounds_since_submit(self, rid: int) -> int:
        """Scheduling rounds the OWNING replica has run since ``rid`` was
        submitted (the round-clock TTFT the API surface reports)."""
        s = self.owner_of(rid).scheduler
        return s.round - s.submit_round.get(rid, s.round)

    def _forget_hashes(self, rid: int) -> None:
        for hk in [k for k in self._hash_memo if k[0] == rid]:
            del self._hash_memo[hk]
