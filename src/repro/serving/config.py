"""``EngineConfig``: the one frozen bag of serving knobs.

The engine constructors accreted a kwarg sprawl across PRs 1-7 (paged /
prefix_cache / chunk_tokens / decode_block / audit_every / scheduler /
fault plan / ...), and every layer that builds engines — launcher, benches,
tests, examples — re-threaded the same dozen keywords.  ``EngineConfig``
consolidates them into ONE immutable, validated object:

* ``PrefillEngine(params, cfg, config=ec)`` / ``DecodeEngine(params, cfg,
  config=ec)`` build an engine from it (the loose kwargs remain as a
  compatibility shim — see the deprecation note on each constructor).
* ``DisaggregatedServer.from_config(params, cfg, ec)`` builds the whole
  single-replica stack (prefill pool -> handoff -> decode pool) from it.
* The NEW layers — ``serving.router.Router`` and ``serving.api.Client`` —
  accept ONLY a config object; they never take loose engine kwargs.

Validation happens at construction (``__post_init__``), so an impossible
combination (prefix cache without paging, chunk boundaries off the page
grid) fails where the config is written down rather than rounds later
inside an engine.

The config is frozen: replicas derive per-replica variants through
``replace()`` (e.g. ``cfg.replace(seed=cfg.seed + i)``) instead of
mutating a shared object, which is what makes routed traces reproducible
from the config alone.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple, Union

from .faults import FaultPlan
from .sampling import SamplingParams

# canonical prefill length buckets (re-exported by serving.engine; defined
# here so config does not import the engine module it configures)
DEFAULT_BUCKETS = (64, 128, 256, 512, 1024, 2048, 4096)


@dataclass(frozen=True)
class EngineConfig:
    """Frozen serving configuration: every engine/server knob in one place.

    Decode engine:
      max_slots      concurrent decode cache rows per replica
      max_len        per-request KV capacity (positions)
      decode_block   fused decode steps per host sync (1 = seed behaviour)
      donate         donate the decode state to the jitted step (in-place KV)
      paged          paged KV cache (page pools + block tables + allocator)
      page_size      KV positions per page (paged mode)
      n_pages        pool size in pages (None = slab-equivalent HBM)
      prefix_cache   refcounted prefix sharing + COW (requires ``paged``)
      kv_dtype       attention K/V page-pool storage dtype: "fp32" (bit-exact
                     reference) or "int8" (symmetric absmax per-page quant
                     with a parallel fp32 scale leaf; requires ``paged``).
                     fp32 configs are bit-identical everywhere; int8 configs
                     trade a bounded logit error for ~4x pages per HBM byte.

    Prefill engine:
      bucketed       pad prompts to length buckets (bounded jit cache)
      buckets        the bucket ladder
      chunk_tokens   chunked prefill threshold/quantum (requires ``paged``;
                     must be a multiple of ``page_size``), or the string
                     ``"auto"``: measure decode-block step time at startup
                     and pick the largest quantum whose chunk+decode round
                     fits ``tbt_target_ms`` (``serving.autotune``)

    Shared:
      sampling       SamplingParams for both phases (None = greedy)
      seed           PRNG seed: server prefill chain = PRNGKey(seed), decode
                     stream = fold_in(PRNGKey(seed), 1); replicas offset it

    Server:
      max_prefill_batch  max same-bucket prompts stacked per prefill call
      batch_dedup        dedup shared chained-chunk-hash prefixes WITHIN one
                         prefill group (requires ``prefix_cache``): the
                         shared prefix rows run once through the chunked
                         prefill path and the resulting pages fan out to
                         every group member's block table, so best-of-n and
                         system-prompt floods prefill the common prefix once
      scheduler          policy name for ``make_scheduler`` ("fcfs" is the
                         bit-exact regression anchor)
      scheduler_kwargs   extra policy kwargs (e.g. swap=True,
                         shed_after_rounds=3); stored as a tuple of pairs
                         internally so the config stays hashable
      faults             FaultPlan for seeded chaos injection (None = off)
      audit_every        run the strict KV invariant auditor every N rounds

    Unified batching (decode-maximal rounds; requires ``chunk_tokens``):
      unified_batching   batch page-aligned chunks of DIFFERENT chunked
                         requests into one prefill dispatch and coalesce
                         chunk work with the decode step under the round's
                         token budget (False keeps the serial one-chunk-
                         per-round schedule, the bit-exact regression
                         anchor)
      token_budget       per-round token budget shared by the decode block
                         and rider chunks: ``decode_tokens + chunk_tokens
                         <= token_budget``.  None derives the throughput
                         default ``max_slots * decode_block +
                         max_prefill_batch * chunk_tokens`` (the head chunk
                         never defers and riders fill idle prefill rows); a
                         TIGHTER budget sheds riders first, then makes
                         saturated rounds decode-only — the TBT lever.
      tbt_target_ms      inter-token-latency SLO target used by
                         ``chunk_tokens="auto"`` to size the chunk quantum
    """

    # -- decode engine ------------------------------------------------------
    max_slots: int = 8
    max_len: int = 512
    decode_block: int = 8
    donate: bool = True
    paged: bool = False
    page_size: int = 16
    n_pages: Optional[int] = None
    prefix_cache: bool = False
    kv_dtype: str = "fp32"
    # -- prefill engine -----------------------------------------------------
    bucketed: bool = True
    buckets: Tuple[int, ...] = DEFAULT_BUCKETS
    chunk_tokens: Union[int, str, None] = None
    # -- shared -------------------------------------------------------------
    sampling: Optional[SamplingParams] = None
    seed: int = 0
    # -- server -------------------------------------------------------------
    max_prefill_batch: int = 8
    batch_dedup: bool = False
    scheduler: str = "fcfs"
    scheduler_kwargs: Tuple[Tuple[str, Any], ...] = ()
    faults: Optional[FaultPlan] = None
    audit_every: Optional[int] = None
    # -- unified batching ---------------------------------------------------
    unified_batching: bool = False
    token_budget: Optional[int] = None
    tbt_target_ms: Optional[float] = None

    def __post_init__(self):
        if isinstance(self.scheduler_kwargs, dict):
            object.__setattr__(
                self, "scheduler_kwargs", tuple(sorted(self.scheduler_kwargs.items()))
            )
        object.__setattr__(self, "buckets", tuple(self.buckets))
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.prefix_cache and not self.paged:
            raise ValueError("prefix_cache=True requires paged=True "
                             "(prefix sharing lives in the page pool)")
        if self.kv_dtype not in ("fp32", "int8"):
            raise ValueError(
                f"kv_dtype must be 'fp32' or 'int8', got {self.kv_dtype!r}"
            )
        if self.kv_dtype != "fp32" and not self.paged:
            raise ValueError("kv_dtype='int8' requires paged=True (the quant "
                             "scale leaf rides the refcounted page pool)")
        if self.batch_dedup and not self.prefix_cache:
            raise ValueError(
                "batch_dedup=True requires prefix_cache=True: deduped prefix "
                "pages are registered/pinned through the prefix index"
            )
        if self.paged and self.max_len % self.page_size:
            raise ValueError(
                f"max_len {self.max_len} not a multiple of page_size {self.page_size}"
            )
        if isinstance(self.chunk_tokens, str):
            if self.chunk_tokens != "auto":
                raise ValueError(
                    f"chunk_tokens must be an int or 'auto', "
                    f"got {self.chunk_tokens!r}"
                )
            if not self.paged:
                raise ValueError("chunk_tokens requires paged=True (chunks "
                                 "stream into the paged pool)")
            if self.tbt_target_ms is None:
                raise ValueError(
                    "chunk_tokens='auto' requires tbt_target_ms: the tuner "
                    "sizes the chunk quantum so one chunk + one decode block "
                    "fits the inter-token-latency target"
                )
        elif self.chunk_tokens is not None:
            if self.chunk_tokens <= 0:
                raise ValueError(
                    f"chunk_tokens must be positive, got {self.chunk_tokens}"
                )
            if not self.paged:
                raise ValueError("chunk_tokens requires paged=True (chunks "
                                 "stream into the paged pool)")
            if self.chunk_tokens % self.page_size:
                raise ValueError(
                    f"chunk_tokens {self.chunk_tokens} must be a multiple of "
                    f"page_size {self.page_size} (chunk boundaries are "
                    f"page-aligned)"
                )
        if self.tbt_target_ms is not None and self.tbt_target_ms <= 0:
            raise ValueError(
                f"tbt_target_ms must be positive, got {self.tbt_target_ms}"
            )
        if self.unified_batching and self.chunk_tokens is None:
            raise ValueError(
                "unified_batching=True requires chunk_tokens: unified rounds "
                "coalesce CHUNK work with the decode step — without chunked "
                "prefill there is nothing to batch"
            )
        if self.token_budget is not None:
            if not self.unified_batching:
                raise ValueError(
                    "token_budget only applies with unified_batching=True "
                    "(serial rounds have no chunk/decode budget to share)"
                )
            # the budget must fit at least one decode block plus one chunk,
            # or every saturated round deadlocks: chunks defer forever
            # waiting for decode headroom that can never appear
            min_chunk = (
                self.page_size if self.chunk_tokens == "auto"
                else self.chunk_tokens
            )
            floor = self.decode_block + min_chunk
            if self.token_budget < floor:
                raise ValueError(
                    f"token_budget {self.token_budget} < decode_block + one "
                    f"chunk = {self.decode_block} + {min_chunk} = {floor}: "
                    f"a budget that cannot fit one decode block AND one "
                    f"chunk would starve chunked prefill forever"
                )
        # late import: scheduler.py never imports config, so this cannot cycle
        from .scheduler import SCHEDULERS

        if self.scheduler not in SCHEDULERS:
            raise ValueError(
                f"unknown scheduler {self.scheduler!r}; "
                f"choose from {sorted(SCHEDULERS)}"
            )

    # -- derived views ------------------------------------------------------

    def replace(self, **changes) -> "EngineConfig":
        """A copy with ``changes`` applied (the config itself is frozen)."""
        return dataclasses.replace(self, **changes)

    def prefill_args(self) -> Dict[str, Any]:
        """Constructor kwargs for one ``PrefillEngine``."""
        return {
            "sampling": self.sampling,
            "bucketed": self.bucketed,
            "buckets": self.buckets,
            "chunk_tokens": self.chunk_tokens,
        }

    def decode_args(self) -> Dict[str, Any]:
        """Constructor kwargs for one ``DecodeEngine``."""
        return {
            "max_slots": self.max_slots,
            "max_len": self.max_len,
            "sampling": self.sampling,
            "decode_block": self.decode_block,
            "donate": self.donate,
            "seed": self.seed,
            "paged": self.paged,
            "page_size": self.page_size,
            "n_pages": self.n_pages,
            "prefix_cache": self.prefix_cache,
            "kv_dtype": self.kv_dtype,
        }

    def build_scheduler(self):
        """A FRESH scheduler instance (policies are stateful: never share one
        object between servers)."""
        from .scheduler import make_scheduler

        return make_scheduler(self.scheduler, **dict(self.scheduler_kwargs))
