"""Optimizer: AdamW with global-norm clipping and schedules (pure JAX).

Implements the standard training substrate without external deps (no optax):
  adamw(lr_schedule, b1, b2, eps, weight_decay) -> (init, update)
  cosine / linear-warmup schedules
State is a pytree mirroring params (m, v) + a scalar step — checkpointable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray  # int32 scalar
    m: Any
    v: Any


def cosine_schedule(base_lr: float, warmup: int, total: int, min_frac: float = 0.1):
    def lr(step):
        step_f = step.astype(jnp.float32)
        warm = base_lr * step_f / max(warmup, 1)
        prog = jnp.clip((step_f - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step_f < warmup, warm, cos)

    return lr


def constant_schedule(base_lr: float):
    return lambda step: jnp.float32(base_lr)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


@dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(self, grads, state: AdamWState, params) -> Tuple[Any, AdamWState, dict]:
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        step = state.step + 1
        sf = step.astype(jnp.float32)
        lr = self.lr(step)
        bc1 = 1.0 - self.b1 ** sf
        bc2 = 1.0 - self.b2 ** sf

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = self.b1 * m + (1 - self.b1) * gf
            v2 = self.b2 * v + (1 - self.b2) * gf * gf
            mh = m2 / bc1
            vh = v2 / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:  # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p, strict=False)]
        new_p = tdef.unflatten([n[0] for n in new])
        new_m = tdef.unflatten([n[1] for n in new])
        new_v = tdef.unflatten([n[2] for n in new])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics


def adamw_for(cfg_total_steps: int, base_lr: float = 3e-4, warmup: int = 100) -> AdamW:
    return AdamW(lr=cosine_schedule(base_lr, warmup, cfg_total_steps))
