"""Training loop: jit'd step, checkpoint/resume, straggler & failure handling.

``Trainer`` is the single-host reference loop used by tests and examples;
``launch/train.py`` builds the multi-pod version (same step function, jit'd
with shardings over the production mesh).  Fault-tolerance posture:

  * checkpoints every ``ckpt_every`` steps (atomic; see checkpoint.py);
  * ``Trainer.resume`` restores params + optimizer state + data cursor and
    is bit-exact (tested by killing a run mid-flight);
  * the data pipeline is stateless-by-construction (batch = f(seed, step)),
    so restarts need no data-state reconciliation;
  * per-step wall-clock watchdog records stragglers (on real fleets this is
    where you would re-shard around a slow host; here we log and continue —
    the mechanism is exercised by tests with an injected slow step).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models import model as M
from . import checkpoint
from .data import DataConfig, make_batch
from .optimizer import AdamW, AdamWState, adamw_for


@dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    base_lr: float = 3e-4
    warmup: int = 10
    straggler_factor: float = 3.0  # step slower than factor x median -> straggler
    log_every: int = 10


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        data_cfg: DataConfig,
        tcfg: TrainerConfig,
        *,
        seed: int = 0,
        step_fn: Optional[Callable] = None,
    ):
        self.cfg = cfg
        self.data_cfg = data_cfg
        self.tcfg = tcfg
        self.opt = adamw_for(tcfg.total_steps, tcfg.base_lr, tcfg.warmup)
        key = jax.random.PRNGKey(seed)
        self.params = M.init_params(key, cfg)
        self.opt_state = self.opt.init(self.params)
        self.step = 0
        self.history: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []
        self._step_fn = step_fn or self._build_step()

    # ------------------------------------------------------------------
    def _build_step(self):
        cfg, opt = self.cfg, self.opt

        def train_step(params, opt_state, batch, labels):
            def loss_fn(p):
                return M.train_loss(p, batch, labels, cfg)

            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            new_params, new_opt_state, opt_metrics = opt.update(grads, opt_state, params)
            metrics = {**metrics, **opt_metrics, "loss": loss}
            return new_params, new_opt_state, metrics

        return jax.jit(train_step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    def resume(self) -> bool:
        """Restore latest checkpoint if present.  Returns True if resumed."""
        if not self.tcfg.ckpt_dir:
            return False
        got = checkpoint.restore_or_none(
            self.tcfg.ckpt_dir, {"params": self.params, "opt": self.opt_state}
        )
        if got is None:
            return False
        tree, step = got
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = step
        return True

    def save(self):
        if self.tcfg.ckpt_dir:
            checkpoint.save(
                self.tcfg.ckpt_dir,
                {"params": self.params, "opt": self.opt_state},
                self.step,
                keep=self.tcfg.ckpt_keep,
            )

    # ------------------------------------------------------------------
    def run(self, n_steps: Optional[int] = None, stop_after: Optional[int] = None) -> Dict[str, float]:
        """Train.  ``stop_after`` simulates a failure (for the FT drill)."""
        target = self.tcfg.total_steps if n_steps is None else self.step + n_steps
        durations: List[float] = []
        last = {}
        while self.step < target:
            batch_np, labels_np = make_batch(self.data_cfg, self.step)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, jnp.asarray(batch_np), jnp.asarray(labels_np)
            )
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            durations.append(dt)
            med = float(np.median(durations[-20:]))
            if len(durations) > 5 and dt > self.tcfg.straggler_factor * med:
                self.straggler_steps.append(self.step)
            self.step += 1
            metrics["step"] = self.step
            metrics["step_time_s"] = dt
            self.history.append(metrics)
            last = metrics
            if self.step % self.tcfg.ckpt_every == 0:
                self.save()
            if stop_after is not None and self.step >= stop_after:
                raise RuntimeError(f"injected failure at step {self.step}")
        self.save()
        return last
