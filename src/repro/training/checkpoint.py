"""Checkpointing: atomic save / restore / resume of (params, opt state, step).

Production posture without external deps:
  * atomic writes (tmp file + rename) so a crash mid-save never corrupts the
    latest checkpoint;
  * a ``latest`` pointer file + retention of the last N checkpoints;
  * tree structure stored alongside flat arrays (npz), dtype-preserving
    (bf16 saved via uint16 view);
  * ``restore_or_none`` for clean cold starts — the fault-tolerance drill in
    tests kills a run mid-flight and resumes bit-exact.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = jnp.dtype(jnp.bfloat16)


def _encode(arr) -> Tuple[np.ndarray, str]:
    a = np.asarray(arr)
    if a.dtype == _BF16:
        return a.view(np.uint16), "bfloat16"
    return a, str(a.dtype)


def _decode(a: np.ndarray, dtype: str):
    if dtype == "bfloat16":
        return a.view(_BF16)
    return a


def save(path: str, tree: Any, step: int, keep: int = 3) -> str:
    """Atomically write checkpoint ``step`` under ``path`` and prune old ones."""
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {}
    dtypes = []
    for i, leaf in enumerate(leaves):
        enc, dt = _encode(leaf)
        arrays[f"a{i}"] = enc
        dtypes.append(dt)
    meta = {"step": step, "n": len(leaves), "dtypes": dtypes, "treedef": str(treedef)}

    step_dir = os.path.join(path, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=path, prefix=".tmp_ckpt_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(step_dir):
            shutil.rmtree(step_dir)
        os.rename(tmp, step_dir)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic latest pointer
    ptr_tmp = os.path.join(path, ".latest.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(f"step_{step:08d}")
    os.replace(ptr_tmp, os.path.join(path, "latest"))
    _prune(path, keep)
    return step_dir


def _prune(path: str, keep: int):
    steps = sorted(d for d in os.listdir(path) if d.startswith("step_"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(path, d), ignore_errors=True)


def latest_step(path: str) -> Optional[int]:
    ptr = os.path.join(path, "latest")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(path, name)):
        return None
    return int(name.split("_")[1])


def restore(path: str, tree_like: Any, step: Optional[int] = None) -> Tuple[Any, int]:
    """Restore into the structure of ``tree_like`` (shape/dtype validated)."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {path}")
    step_dir = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(step_dir, "meta.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(step_dir, "arrays.npz"))
    leaves_like, treedef = jax.tree.flatten(tree_like)
    assert meta["n"] == len(leaves_like), (
        f"checkpoint has {meta['n']} leaves, expected {len(leaves_like)}"
    )
    leaves = []
    for i, (like, dt) in enumerate(zip(leaves_like, meta["dtypes"], strict=False)):
        arr = _decode(data[f"a{i}"], dt)
        assert tuple(arr.shape) == tuple(like.shape), (i, arr.shape, like.shape)
        leaves.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, leaves), meta["step"]


def restore_or_none(path: str, tree_like: Any):
    try:
        return restore(path, tree_like)
    except (FileNotFoundError, OSError):
        return None
