"""Training substrate: optimizer, data, checkpointing, fault-tolerant loop."""
from .checkpoint import latest_step, restore, restore_or_none, save  # noqa: F401
from .data import DataConfig, data_iterator, make_batch  # noqa: F401
from .optimizer import AdamW, AdamWState, adamw_for, cosine_schedule, global_norm  # noqa: F401
from .trainer import Trainer, TrainerConfig  # noqa: F401
