"""Synthetic data pipeline: deterministic, shardable, restart-safe.

A real deployment would stream tokenized corpora; here we generate
reproducible pseudo-corpus batches keyed by (seed, step) so a restarted
job resumes *exactly* where it left off (no data state to checkpoint
beyond the step counter).  Sequences follow a Zipf-ish unigram
distribution plus local structure (bigram coupling) so the loss actually
decreases during the example runs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend_dim: int = 0  # >0 -> emit embeddings instead of tokens (stub frontends)


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))


def make_batch(cfg: DataConfig, step: int) -> Tuple[np.ndarray, np.ndarray]:
    """(inputs, labels): tokens [B, S] int32 (or embeds [B,S,D] f32), labels [B,S]."""
    rng = _batch_rng(cfg, step)
    B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
    # zipf-ish unigram with bigram smoothing: t[i+1] ~ 0.5*zipf + 0.5*f(t[i])
    zipf = rng.zipf(1.3, size=(B, S + 1))
    toks = np.minimum(zipf - 1, V - 1).astype(np.int32)
    coupled = (toks[:, :-1] * 31 + 7) % V
    mix = rng.random((B, S)) < 0.5
    nxt = np.where(mix, toks[:, 1:], coupled).astype(np.int32)
    inputs_tok = toks[:, :-1]
    labels = nxt
    if cfg.frontend_dim:
        emb = rng.standard_normal((B, S, cfg.frontend_dim), dtype=np.float32) * 0.02
        # inject token identity so the mapping is learnable
        emb[..., 0] = inputs_tok / V
        return emb, labels
    return inputs_tok, labels


def data_iterator(cfg: DataConfig, start_step: int = 0) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, step)
        step += 1
