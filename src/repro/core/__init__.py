"""The paper's primary contribution: SPAD phase-specialized hardware models.

  hardware    chip specs, area / cost / TDP models (Table 3)
  perfmodel   LLMCompass-lite analytical operator latency model
  opgraph     ModelConfig -> operator graphs per phase/parallelism
  dse         less-is-more design space exploration (Figs 5/6)
  trace       workload synthesis calibrated to the Azure traces
  cluster     event-driven cluster simulator (Splitwise- & Sarathi-style)
  provision   SLO-constrained provisioning + adaptive reallocation (Tables 4-8)
"""
from . import cluster, dse, hardware, opgraph, perfmodel, provision, trace  # noqa: F401
from .hardware import A100, CHIPS, DECODE_CHIP, H100, H100_PCAP, PREFILL_CHIP  # noqa: F401
from .opgraph import Parallelism  # noqa: F401
