"""LLMCompass-lite: an analytical per-operator chip performance model.

Modeling choices (validated against the paper's own sensitivity claims in
``tests/test_paper_claims.py`` and ``benchmarks/fig2_prefill_bw.py`` etc.):

* **Matmul**: systolic-array tile mapping.  An output tile of
  (sys_rows x sys_cols) is produced per lane by streaming K values plus a
  pipeline fill of (rows + cols) cycles; tiles round-robin over all lanes.
  Memory time moves A, B, and C exactly once at their storage widths
  (weights are read once per op - perfect L2 blocking).
* **Serialization**: per-op latency = t_compute + t_memory (conservative
  no-overlap, like LLMCompass's staged tile pipeline).  This single choice
  reproduces BOTH headline sensitivities of paper §3: prefill latency
  +17% at 0.6x bandwidth (memory share ~25%) and decode latency +22% at
  0.5x cores (compute share ~15%), which a max(comp, mem) roofline cannot.
* **Vector ops** (softmax/LayerNorm/activations): elementwise streams with a
  flops term on the vector units and a bytes term on HBM; softmax
  materializes fp32 scores (pre-FlashAttention kernel behaviour, matching
  LLMCompass's operator library and the paper's "Softmax becomes the new
  bottleneck" observation for long prefills).
* **Memory-level parallelism**: effective bandwidth is capped at
  cores * per_core_bw (40 GB/s): core count cuts below ~100 start to hurt
  memory-bound phases too (paper Fig. 3 knee).
* **Collectives**: ring all-reduce 2(n-1)/n, all-gather/all-to-all (n-1)/n
  over the scale-up fabric, plus a per-hop latency.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .hardware import ChipSpec

LINK_LATENCY_S = 2.0e-6  # per collective hop (NVLink-class)
OP_OVERHEAD_S = 2.0e-6  # per-kernel launch/sync overhead (identical across chips)


# ---------------------------------------------------------------------------
# Operators
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Op:
    kind: str  # matmul | vector | memory | allreduce | allgather | alltoall | p2p
    name: str
    # matmul
    m: int = 0
    k: int = 0
    n: int = 0
    batch: int = 1  # instances (e.g. B*H attention matmuls)
    a_bytes: float = 2.0
    w_bytes: float = 2.0
    o_bytes: float = 2.0
    # vector/memory
    flops: float = 0.0
    bytes: float = 0.0
    # collectives
    comm_bytes: float = 0.0
    parties: int = 1


@dataclass
class OpTime:
    name: str
    kind: str
    t_compute: float
    t_memory: float
    t_network: float
    flops: float
    bytes: float
    comm_bytes: float

    t_overhead: float = OP_OVERHEAD_S

    @property
    def total(self) -> float:
        return self.t_compute + self.t_memory + self.t_network + self.t_overhead


@dataclass
class PhaseResult:
    total: float
    ops: List[OpTime]

    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for o in self.ops:
            out[o.kind] = out.get(o.kind, 0.0) + o.total
        return out

    def by_name(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for o in self.ops:
            out[o.name] = out.get(o.name, 0.0) + o.total
        return out


# ---------------------------------------------------------------------------
# Single-op latency
# ---------------------------------------------------------------------------


def matmul_time(chip: ChipSpec, op: Op) -> OpTime:
    rows, cols = chip.systolic_rows, chip.systolic_cols
    tiles = math.ceil(op.m / rows) * math.ceil(op.n / cols) * op.batch
    rounds = math.ceil(tiles / chip.lanes)
    cycles = rounds * (op.k + rows + cols)
    t_c = cycles / (chip.clock_tensor_ghz * 1e9)
    bytes_moved = op.batch * (
        op.m * op.k * op.a_bytes + op.k * op.n * op.w_bytes + op.m * op.n * op.o_bytes
    )
    t_m = bytes_moved / chip.effective_mem_bw
    flops = 2.0 * op.m * op.k * op.n * op.batch
    return OpTime(op.name, "matmul", t_c, t_m, 0.0, flops, bytes_moved, 0.0)


def vector_time(chip: ChipSpec, op: Op) -> OpTime:
    t_c = op.flops / chip.vector_flops
    t_m = op.bytes / chip.effective_mem_bw
    return OpTime(op.name, "vector", t_c, t_m, 0.0, op.flops, op.bytes, 0.0)


def memory_time(chip: ChipSpec, op: Op) -> OpTime:
    t_m = op.bytes / chip.effective_mem_bw
    return OpTime(op.name, "memory", 0.0, t_m, 0.0, 0.0, op.bytes, 0.0)


def collective_time(chip: ChipSpec, op: Op) -> OpTime:
    n = op.parties
    if n <= 1:
        return OpTime(op.name, op.kind, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0)
    bw = chip.scaleup_gbs * 1e9
    if op.kind == "allreduce":
        t = 2.0 * (n - 1) / n * op.comm_bytes / bw + (n - 1) * LINK_LATENCY_S
        wire = 2.0 * (n - 1) / n * op.comm_bytes
    elif op.kind in ("allgather", "reducescatter", "alltoall"):
        t = (n - 1) / n * op.comm_bytes / bw + (n - 1) * LINK_LATENCY_S
        wire = (n - 1) / n * op.comm_bytes
    elif op.kind == "p2p":
        t = op.comm_bytes / bw + LINK_LATENCY_S
        wire = op.comm_bytes
    else:
        raise ValueError(op.kind)
    return OpTime(op.name, op.kind, 0.0, 0.0, t, 0.0, 0.0, wire)


def op_time(chip: ChipSpec, op: Op) -> OpTime:
    if op.kind == "matmul":
        return matmul_time(chip, op)
    if op.kind == "vector":
        return vector_time(chip, op)
    if op.kind == "memory":
        return memory_time(chip, op)
    return collective_time(chip, op)


def run_graph(chip: ChipSpec, ops: List[Op]) -> PhaseResult:
    times = [op_time(chip, o) for o in ops]
    return PhaseResult(total=sum(t.total for t in times), ops=times)
