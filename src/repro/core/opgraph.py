"""Operator graphs: ModelConfig x (phase, batch, seq, parallelism) -> [Op].

These graphs feed the analytical chip model (``perfmodel``) — they are the
paper's LLMCompass-style workload description, built from the *same*
``ModelConfig`` objects that drive the executable JAX models, so the
simulated and executed systems cannot drift apart.

Conventions: all shapes are per-chip after parallelism is applied.
  tp — tensor parallel (heads / mlp sharded, 2 all-reduces per layer)
  ep — expert parallel (experts sharded, 2 all-to-alls per MoE layer;
       attention is data-parallel over ep)
  pp — pipeline parallel (layers divided; p2p activations between stages)
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

from ..configs.base import ModelConfig
from .perfmodel import Op

# per-element cost constants (calibrated; see DESIGN.md §perf-model)
NORM_FLOPS_PER_ELT = 8.0
NORM_BYTES_PER_ELT = 6.0  # read + write + stats pass (fp16)
SOFTMAX_FLOPS_PER_ELT = 6.0
SOFTMAX_BYTES_PER_ELT = 12.0  # fp32 scores materialized + fp16 probs (LLMCompass-style)
ACT_FLOPS_PER_ELT = 4.0
ROPE_FLOPS_PER_ELT = 6.0


@dataclass(frozen=True)
class Parallelism:
    tp: int = 8
    ep: int = 1
    pp: int = 1

    @property
    def n_chips(self) -> int:
        return self.tp * self.ep * self.pp


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


# ---------------------------------------------------------------------------
# Attention sub-graphs
# ---------------------------------------------------------------------------


def _attn_ops(cfg: ModelConfig, T: int, B: int, S_q: int, S_kv: int, par: Parallelism,
              ab: float, wb: float, decode: bool) -> List[Op]:
    """T = B*S_q tokens on this chip; S_kv = context length."""
    ops: List[Op] = []
    tp = par.tp
    d = cfg.d_model

    if cfg.attn_type == "mla":
        a = cfg.mla
        qh = a.qk_nope_head_dim + a.qk_rope_head_dim
        H = cfg.n_heads
        ops.append(Op("matmul", "attn_q_a", m=T, k=d, n=a.q_lora_rank, a_bytes=ab, w_bytes=wb, o_bytes=ab))
        ops.append(Op("matmul", "attn_q_b", m=T, k=a.q_lora_rank, n=H * qh // tp, a_bytes=ab, w_bytes=wb, o_bytes=ab))
        ops.append(Op("matmul", "attn_kv_a", m=T, k=d, n=a.kv_lora_rank + a.qk_rope_head_dim, a_bytes=ab, w_bytes=wb, o_bytes=ab))
        if decode:
            # matmul-absorbed decode over the compressed cache
            r = a.kv_lora_rank + a.qk_rope_head_dim
            ops.append(Op("matmul", "attn_q_absorb", m=T, k=qh, n=a.kv_lora_rank, batch=H // tp, a_bytes=ab, w_bytes=wb, o_bytes=ab))
            ops.append(Op("matmul", "attn_scores", m=H // tp, k=r, n=S_kv, batch=B, a_bytes=ab, w_bytes=ab, o_bytes=4))
            ops.append(Op("vector", "attn_softmax",
                          flops=SOFTMAX_FLOPS_PER_ELT * B * (H // tp) * S_kv,
                          bytes=SOFTMAX_BYTES_PER_ELT * B * (H // tp) * S_kv))
            ops.append(Op("matmul", "attn_av", m=H // tp, k=S_kv, n=a.kv_lora_rank, batch=B, a_bytes=ab, w_bytes=ab, o_bytes=ab))
            ops.append(Op("matmul", "attn_v_absorb", m=T, k=a.kv_lora_rank, n=a.v_head_dim, batch=H // tp, a_bytes=ab, w_bytes=wb, o_bytes=ab))
        else:
            ops.append(Op("matmul", "attn_kv_b", m=T, k=a.kv_lora_rank, n=H * (a.qk_nope_head_dim + a.v_head_dim) // tp, a_bytes=ab, w_bytes=wb, o_bytes=ab))
            ops.append(Op("matmul", "attn_scores", m=S_q, k=qh, n=S_kv, batch=B * H // tp, a_bytes=ab, w_bytes=ab, o_bytes=4))
            ops.append(Op("vector", "attn_softmax",
                          flops=SOFTMAX_FLOPS_PER_ELT * B * (H // tp) * S_q * S_kv,
                          bytes=SOFTMAX_BYTES_PER_ELT * B * (H // tp) * S_q * S_kv))
            ops.append(Op("matmul", "attn_av", m=S_q, k=S_kv, n=a.v_head_dim, batch=B * H // tp, a_bytes=ab, w_bytes=ab, o_bytes=ab))
        ops.append(Op("matmul", "attn_o", m=T, k=cfg.n_heads * a.v_head_dim // tp, n=d, a_bytes=ab, w_bytes=wb, o_bytes=ab))
        ops.append(Op("vector", "attn_rope", flops=ROPE_FLOPS_PER_ELT * T * (H // tp) * a.qk_rope_head_dim,
                      bytes=2 * ab * T * (H // tp) * a.qk_rope_head_dim))
        return ops

    # GQA / MHA
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    Ht, KVt = H // tp, max(1, KV // tp)
    G = H // KV
    ops.append(Op("matmul", "attn_qkv", m=T, k=d, n=(Ht + 2 * KVt) * dh, a_bytes=ab, w_bytes=wb, o_bytes=ab))
    if cfg.pos_emb == "rope":
        ops.append(Op("vector", "attn_rope", flops=ROPE_FLOPS_PER_ELT * T * (Ht + KVt) * dh,
                      bytes=2 * ab * T * (Ht + KVt) * dh))
    if decode:
        ops.append(Op("matmul", "attn_scores", m=G, k=dh, n=S_kv, batch=B * KVt, a_bytes=ab, w_bytes=ab, o_bytes=4))
        smax_e = B * Ht * S_kv
        ops.append(Op("vector", "attn_softmax", flops=SOFTMAX_FLOPS_PER_ELT * smax_e,
                      bytes=SOFTMAX_BYTES_PER_ELT * smax_e))
        ops.append(Op("matmul", "attn_av", m=G, k=S_kv, n=dh, batch=B * KVt, a_bytes=ab, w_bytes=ab, o_bytes=ab))
        ops.append(Op("memory", "kv_append", bytes=2 * B * KVt * dh * ab))
    else:
        ops.append(Op("matmul", "attn_scores", m=S_q, k=dh, n=S_kv, batch=B * Ht, a_bytes=ab, w_bytes=ab, o_bytes=4))
        smax_e = B * Ht * S_q * S_kv
        ops.append(Op("vector", "attn_softmax", flops=SOFTMAX_FLOPS_PER_ELT * smax_e,
                      bytes=SOFTMAX_BYTES_PER_ELT * smax_e))
        ops.append(Op("matmul", "attn_av", m=S_q, k=S_kv, n=dh, batch=B * Ht, a_bytes=ab, w_bytes=ab, o_bytes=ab))
    ops.append(Op("matmul", "attn_o", m=T, k=Ht * dh, n=d, a_bytes=ab, w_bytes=wb, o_bytes=ab))
    return ops


# ---------------------------------------------------------------------------
# FFN sub-graphs
# ---------------------------------------------------------------------------


def _mlp_ops(cfg: ModelConfig, T: int, par: Parallelism, ab: float, wb: float,
             d_ff: Optional[int] = None, tag: str = "mlp") -> List[Op]:
    d = cfg.d_model
    f = (d_ff or cfg.d_ff) // par.tp
    ops = [Op("matmul", f"{tag}_up", m=T, k=d, n=f, a_bytes=ab, w_bytes=wb, o_bytes=ab)]
    if cfg.gated_mlp:
        ops.append(Op("matmul", f"{tag}_gate", m=T, k=d, n=f, a_bytes=ab, w_bytes=wb, o_bytes=ab))
    ops.append(Op("vector", f"{tag}_act", flops=ACT_FLOPS_PER_ELT * T * f, bytes=3 * ab * T * f))
    ops.append(Op("matmul", f"{tag}_down", m=T, k=f, n=d, a_bytes=ab, w_bytes=wb, o_bytes=ab))
    return ops


def _moe_ops(cfg: ModelConfig, T: int, par: Parallelism, ab: float, wb: float) -> List[Op]:
    """T tokens on this chip *before* dispatch; experts sharded over ep."""
    m = cfg.moe
    d = cfg.d_model
    ops: List[Op] = [Op("matmul", "moe_router", m=T, k=d, n=m.n_experts, a_bytes=ab, w_bytes=4, o_bytes=4)]
    if par.ep > 1:
        ops.append(Op("alltoall", "moe_dispatch", comm_bytes=T * m.top_k * d * ab, parties=par.ep))
    # balanced dispatch: this chip hosts E/ep experts and receives T*top_k
    # token-slots total (same in as out under balance)
    e_local = max(1, m.n_experts // par.ep)
    tok_per_expert = _ceil_div(T * m.top_k, m.n_experts)
    f = m.d_expert // max(1, par.tp // par.ep) if par.tp > par.ep else m.d_expert
    ops.append(Op("matmul", "moe_up", m=tok_per_expert, k=d, n=f, batch=e_local, a_bytes=ab, w_bytes=wb, o_bytes=ab))
    if cfg.gated_mlp:
        ops.append(Op("matmul", "moe_gate", m=tok_per_expert, k=d, n=f, batch=e_local, a_bytes=ab, w_bytes=wb, o_bytes=ab))
    ops.append(Op("vector", "moe_act", flops=ACT_FLOPS_PER_ELT * tok_per_expert * f * e_local,
                  bytes=3 * ab * tok_per_expert * f * e_local))
    ops.append(Op("matmul", "moe_down", m=tok_per_expert, k=f, n=d, batch=e_local, a_bytes=ab, w_bytes=wb, o_bytes=ab))
    if par.ep > 1:
        ops.append(Op("alltoall", "moe_combine", comm_bytes=T * m.top_k * d * ab, parties=par.ep))
    if m.n_shared_experts:
        ops += _mlp_ops(cfg, T, par, ab, wb, d_ff=m.n_shared_experts * m.d_expert, tag="moe_shared")
    if cfg.dense_residual:
        ops += _mlp_ops(cfg, T, par, ab, wb, d_ff=cfg.d_ff_dense or cfg.d_ff, tag="moe_dense")
    return ops


# ---------------------------------------------------------------------------
# Mamba sub-graph
# ---------------------------------------------------------------------------


def _mamba_ops(cfg: ModelConfig, T: int, B: int, par: Parallelism, ab: float, wb: float,
               decode: bool) -> List[Op]:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d) // par.tp
    nh = max(1, s.n_heads(d) // par.tp)
    gdn = s.n_groups * s.d_state
    conv_ch = di + 2 * gdn
    ops: List[Op] = [
        Op("matmul", "ssm_in", m=T, k=d, n=2 * di + 2 * gdn + nh, a_bytes=ab, w_bytes=wb, o_bytes=ab),
        Op("vector", "ssm_conv", flops=2 * s.d_conv * T * conv_ch, bytes=3 * ab * T * conv_ch),
    ]
    if decode:
        # state update: B*nh states of [hd, N]
        elems = B * nh * s.head_dim * s.d_state
        ops.append(Op("vector", "ssm_step", flops=6 * elems, bytes=2 * 4 * elems))
    else:
        Q = s.chunk_size
        nc = _ceil_div(T // max(B, 1), Q) * B
        # intra-chunk: CB [Q,Q] + M@x [Q,hd]; inter-chunk: state rank-Q update
        ops.append(Op("matmul", "ssm_cb", m=Q, k=s.d_state, n=Q, batch=nc * s.n_groups, a_bytes=ab, w_bytes=ab, o_bytes=4))
        ops.append(Op("matmul", "ssm_diag", m=Q, k=Q, n=s.head_dim, batch=nc * nh, a_bytes=4, w_bytes=ab, o_bytes=ab))
        ops.append(Op("matmul", "ssm_state", m=s.head_dim, k=Q, n=s.d_state, batch=nc * nh, a_bytes=ab, w_bytes=ab, o_bytes=4))
        ops.append(Op("vector", "ssm_decay", flops=8 * T * nh * Q, bytes=4 * T * nh))
    ops.append(Op("vector", "ssm_gate_norm", flops=NORM_FLOPS_PER_ELT * T * di, bytes=NORM_BYTES_PER_ELT * T * di))
    ops.append(Op("matmul", "ssm_out", m=T, k=di, n=d, a_bytes=ab, w_bytes=wb, o_bytes=ab))
    return ops


# ---------------------------------------------------------------------------
# Full-phase graphs
# ---------------------------------------------------------------------------


def phase_ops(
    cfg: ModelConfig,
    *,
    phase: str,  # "prefill" | "decode"
    batch: int,
    seq: int,  # prompt length (prefill) or context length (decode)
    par: Parallelism,
    w_bytes: float = 2.0,
    a_bytes: float = 2.0,
) -> List[Op]:
    decode = phase == "decode"
    # attention data-parallel over ep (MoE deployments)
    B = _ceil_div(batch, par.ep)
    S_q = 1 if decode else seq
    S_kv = seq + 1 if decode else seq
    T = B * S_q
    d = cfg.d_model

    per_pattern: List[Op] = []
    for mixer, ffn in cfg.block_pattern:
        per_pattern.append(Op("vector", "norm", flops=NORM_FLOPS_PER_ELT * T * d, bytes=NORM_BYTES_PER_ELT * T * d))
        if mixer == "attn":
            per_pattern += _attn_ops(cfg, T, B, S_q, S_kv, par, a_bytes, w_bytes, decode)
            if par.tp > 1:
                per_pattern.append(Op("allreduce", "attn_ar", comm_bytes=T * d * a_bytes, parties=par.tp))
        elif mixer == "mamba":
            per_pattern += _mamba_ops(cfg, T, B, par, a_bytes, w_bytes, decode)
            if par.tp > 1:
                per_pattern.append(Op("allreduce", "ssm_ar", comm_bytes=T * d * a_bytes, parties=par.tp))
        if ffn != "none":
            per_pattern.append(Op("vector", "norm", flops=NORM_FLOPS_PER_ELT * T * d, bytes=NORM_BYTES_PER_ELT * T * d))
            if ffn == "mlp":
                per_pattern += _mlp_ops(cfg, T, par, a_bytes, w_bytes)
            else:
                per_pattern += _moe_ops(cfg, T, par, a_bytes, w_bytes)
            if par.tp > 1:
                per_pattern.append(Op("allreduce", "ffn_ar", comm_bytes=T * d * a_bytes, parties=par.tp))

    layers_per_stage = cfg.n_repeats // par.pp
    ops = per_pattern * layers_per_stage
    if par.pp > 1:
        ops.append(Op("p2p", "pp_send", comm_bytes=T * d * a_bytes, parties=2))

    # embedding lookup + final norm + LM head (last stage only; counted once)
    ops.insert(0, Op("memory", "embed", bytes=T * d * a_bytes))
    ops.append(Op("vector", "final_norm", flops=NORM_FLOPS_PER_ELT * T * d, bytes=NORM_BYTES_PER_ELT * T * d))
    T_head = B if not decode else T  # prefill only needs last-position logits
    ops.append(Op("matmul", "lm_head", m=T_head, k=d, n=cfg.vocab_size // par.tp, a_bytes=a_bytes, w_bytes=w_bytes, o_bytes=4))
    return ops


# ---------------------------------------------------------------------------
# Sizes (capacity / transfer modeling)
# ---------------------------------------------------------------------------


def kv_bytes_per_token(cfg: ModelConfig, a_bytes: float = 2.0) -> float:
    """KV-cache bytes per token across ALL chips (whole model)."""
    total = 0.0
    for mixer, _ in cfg.block_pattern:
        if mixer != "attn":
            continue
        if cfg.attn_type == "mla":
            total += (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * a_bytes
        else:
            total += 2 * cfg.n_kv_heads * cfg.d_head * a_bytes
    return total * cfg.n_repeats


def ssm_state_bytes(cfg: ModelConfig, batch: int) -> float:
    """Fixed-size recurrent state bytes (Mamba layers), whole model."""
    if cfg.ssm is None:
        return 0.0
    s = cfg.ssm
    n_mamba = cfg.mixer_counts().get("mamba", 0)
    per = s.n_heads(cfg.d_model) * s.head_dim * s.d_state * 4.0
    conv = (s.d_conv - 1) * (s.d_inner(cfg.d_model) + 2 * s.n_groups * s.d_state) * 2.0
    return n_mamba * batch * (per + conv)


def weight_bytes(cfg: ModelConfig, w_bytes: float = 2.0) -> float:
    return cfg.param_count()[0] * w_bytes
