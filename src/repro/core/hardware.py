"""Chip specifications + area / cost / TDP models (paper §5-6, Table 3).

``ChipSpec`` is an LLMCompass-style architectural description.  Derived
quantities (tensor PFLOPs, vector TFLOPs, bandwidth, capacity) follow the
paper's formulas and reproduce Table 3 exactly:

  tensor FLOP/s = cores * lanes * sys_rows * sys_cols * 2 * f_tensor
  vector FLOP/s = cores * lanes * vector_width * 2 * f_vector
  mem BW        = bus_bits * pin_Gbps / 8     (HBM3 uses the reported 3352)

The area model is a linear component model (per-MAC, per-vector-lane, per-KB
SRAM, per-package PHY, fixed uncore) *calibrated* so that the H100
configuration evaluates to its reported 814 mm^2 and the paper's Prefill /
Decode Chips evaluate to their published 784 / 520 mm^2 estimates (raw
component sum x 1.10 white-space overhead).  Die cost uses the classic
dies-per-300mm-wafer formula at $20k/wafer; memory cost is $/GB by protocol;
TDP = (die_area * H100 power density + memory power) / 0.90.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Optional

# ---------------------------------------------------------------------------
# Chip spec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChipSpec:
    name: str
    core_count: int
    lanes_per_core: int
    vector_width: int  # fp32 lanes per vector unit
    systolic_rows: int
    systolic_cols: int
    l1_kb_per_core: int
    l2_mb: float
    mem_protocol: str  # "GDDR7" | "HBM3" | "HBM2e"
    mem_bus_bits: int
    pin_speed_gbps: float
    mem_packages: int
    capacity_per_package_gb: int
    clock_tensor_ghz: float = 1.83
    clock_vector_ghz: float = 1.98
    mem_bw_override_gbs: Optional[float] = None  # use reported value if set
    scaleup_gbs: float = 900.0  # NVLink-class total per chip
    scaleout_gbs: float = 50.0  # Infiniband-class per chip
    reported_area_mm2: Optional[float] = None  # for reference chips (H100)
    reported_tdp_w: Optional[float] = None
    # bandwidth a single core can keep in flight (memory-level parallelism cap)
    per_core_bw_gbs: float = 45.0

    # ------------- derived -------------
    @property
    def lanes(self) -> int:
        return self.core_count * self.lanes_per_core

    @property
    def tensor_flops(self) -> float:
        return (
            self.lanes
            * self.systolic_rows
            * self.systolic_cols
            * 2
            * self.clock_tensor_ghz
            * 1e9
        )

    @property
    def vector_flops(self) -> float:
        return self.lanes * self.vector_width * 2 * self.clock_vector_ghz * 1e9

    @property
    def mem_bw(self) -> float:
        if self.mem_bw_override_gbs is not None:
            return self.mem_bw_override_gbs * 1e9
        return self.mem_bus_bits * self.pin_speed_gbps / 8 * 1e9

    @property
    def mem_capacity(self) -> float:
        return self.mem_packages * self.capacity_per_package_gb * 1e9

    @property
    def effective_mem_bw(self) -> float:
        """Bandwidth cap from per-core memory-level parallelism."""
        return min(self.mem_bw, self.core_count * self.per_core_bw_gbs * 1e9)


# ---------------------------------------------------------------------------
# Area model (calibrated to Table 3)
# ---------------------------------------------------------------------------

# fixed literature-guided constants (mm^2 @ TSMC 4nm)
A_L1_PER_KB = 0.0015
A_L2_PER_MB = 1.0
A_HBM_PHY_PER_PKG = 7.7
A_GDDR_PHY_PER_32B = 3.0
A_CORE_BASE = 0.3
WHITESPACE = 1.10

# calibrated (solved so H100 -> 814, Prefill -> 784, Decode -> 520 mm^2)
A_PER_MAC = 3.902e-4
A_PER_VEC_LANE = 1.4637e-2
A_UNCORE_FIXED = 208.4


def die_area_mm2(c: ChipSpec) -> float:
    """Modeled die area (includes the 10% white-space overhead)."""
    macs = c.lanes * c.systolic_rows * c.systolic_cols
    vec = c.lanes * c.vector_width
    per_core = A_CORE_BASE * c.core_count + A_L1_PER_KB * c.l1_kb_per_core * c.core_count
    phy = (
        A_HBM_PHY_PER_PKG * c.mem_packages
        if c.mem_protocol.startswith("HBM")
        else A_GDDR_PHY_PER_32B * (c.mem_bus_bits / 32)
    )
    raw = (
        A_UNCORE_FIXED
        + per_core
        + A_PER_MAC * macs
        + A_PER_VEC_LANE * vec
        + A_L2_PER_MB * c.l2_mb
        + phy
    )
    return raw * WHITESPACE


# ---------------------------------------------------------------------------
# Cost model (paper §6.1)
# ---------------------------------------------------------------------------

WAFER_COST = 20_000.0  # $ per 300mm 4nm wafer
WAFER_DIAMETER_MM = 300.0

MEM_COST_PER_GB = {"GDDR7": 3.0, "HBM3": 9.0, "HBM2e": 9.0}
HBM_PKG_POWER_W = 30.0
GDDR_PJ_PER_BIT = 4.5
TDP_OVERHEAD = 0.90  # VRM loss & peripherals: TDP = raw / 0.90

# H100 die power density: (700 * 0.9 - 30 * 5) W over 814 mm^2
H100_DIE_POWER_DENSITY = (700.0 * 0.90 - HBM_PKG_POWER_W * 5) / 814.0  # W/mm^2


def dies_per_wafer(area_mm2: float) -> float:
    d = WAFER_DIAMETER_MM
    return math.pi * (d / 2) ** 2 / area_mm2 - math.pi * d / math.sqrt(2 * area_mm2)


def die_cost(c: ChipSpec, *, use_reported_area: bool = True) -> float:
    area = c.reported_area_mm2 if (use_reported_area and c.reported_area_mm2) else die_area_mm2(c)
    return WAFER_COST / dies_per_wafer(area)


def memory_cost(c: ChipSpec, hbm_cost_per_gb: float = 9.0) -> float:
    gb = c.mem_capacity / 1e9
    if c.mem_protocol.startswith("HBM"):
        return hbm_cost_per_gb * gb
    return MEM_COST_PER_GB[c.mem_protocol] * gb


def hw_cost(c: ChipSpec, hbm_cost_per_gb: float = 9.0) -> float:
    return die_cost(c) + memory_cost(c, hbm_cost_per_gb)


def mem_power_w(c: ChipSpec) -> float:
    if c.mem_protocol.startswith("HBM"):
        return HBM_PKG_POWER_W * c.mem_packages
    # GDDR: pJ/bit * bits/s
    return GDDR_PJ_PER_BIT * 1e-12 * c.mem_bw * 8


def tdp_w(c: ChipSpec) -> float:
    if c.reported_tdp_w is not None:
        return c.reported_tdp_w
    area = c.reported_area_mm2 or die_area_mm2(c)
    return (area * H100_DIE_POWER_DENSITY + mem_power_w(c)) / TDP_OVERHEAD


# ---------------------------------------------------------------------------
# The chips (paper Table 3 + baselines)
# ---------------------------------------------------------------------------

H100 = ChipSpec(
    name="H100",
    core_count=132,
    lanes_per_core=4,
    vector_width=32,
    systolic_rows=16,
    systolic_cols=32,  # "equivalent to 16x32"
    l1_kb_per_core=256,
    l2_mb=50,
    mem_protocol="HBM3",
    mem_bus_bits=5120,
    pin_speed_gbps=5.2,
    mem_packages=5,
    capacity_per_package_gb=16,
    mem_bw_override_gbs=3352.0,
    reported_area_mm2=814.0,
    reported_tdp_w=700.0,
)

PREFILL_CHIP = ChipSpec(
    name="PrefillChip",
    core_count=128,
    lanes_per_core=4,
    vector_width=16,
    systolic_rows=32,
    systolic_cols=32,
    l1_kb_per_core=320,
    l2_mb=32,
    mem_protocol="GDDR7",
    mem_bus_bits=512,
    pin_speed_gbps=32.0,
    mem_packages=16,
    capacity_per_package_gb=4,
)

DECODE_CHIP = ChipSpec(
    name="DecodeChip",
    core_count=144,
    lanes_per_core=4,
    vector_width=8,
    systolic_rows=16,
    systolic_cols=16,
    l1_kb_per_core=128,
    l2_mb=30,
    mem_protocol="HBM3",
    mem_bus_bits=5120,
    pin_speed_gbps=5.2,
    mem_packages=5,
    capacity_per_package_gb=16,
    mem_bw_override_gbs=3352.0,
)

# A100 (Splitwise-hetero decode baseline): 108 SMs @1.41GHz, 312 TF fp16,
# 19.5 TF fp32, 2039 GB/s HBM2e, 80 GB.  Cost/TDP modeled as half an H100
# (paper Table 4 footnote).
A100 = ChipSpec(
    name="A100",
    core_count=108,
    lanes_per_core=4,
    vector_width=16,
    systolic_rows=16,
    systolic_cols=16,
    l1_kb_per_core=192,
    l2_mb=40,
    mem_protocol="HBM2e",
    mem_bus_bits=5120,
    pin_speed_gbps=3.2,
    mem_packages=5,
    capacity_per_package_gb=16,
    clock_tensor_ghz=1.41,
    clock_vector_ghz=1.41,
    mem_bw_override_gbs=2039.0,
    scaleup_gbs=600.0,
    reported_tdp_w=400.0,
)

# Hypothetical power-capped H100 (Splitwise-pcap decode baseline): 450 W,
# 76% of peak tensor FLOPs, same memory/interconnect as the 700 W H100.
H100_PCAP = replace(
    H100,
    name="H100-pcap450",
    clock_tensor_ghz=1.83 * 0.76,
    clock_vector_ghz=1.98 * 0.76,
    reported_tdp_w=450.0,
)

CHIPS = {c.name: c for c in [H100, PREFILL_CHIP, DECODE_CHIP, A100, H100_PCAP]}


def norm_hw_cost(c: ChipSpec, hbm_cost_per_gb: float = 9.0) -> float:
    """Hardware cost normalized to an H100 (paper Table 3 bottom)."""
    if c.name == "A100":
        return 0.5  # paper's assumption
    return hw_cost(c, hbm_cost_per_gb) / hw_cost(H100, hbm_cost_per_gb)


def norm_tdp(c: ChipSpec) -> float:
    if c.name == "A100":
        return 0.5
    return tdp_w(c) / tdp_w(H100)


@dataclass(frozen=True)
class MachineSpec:
    """An 8-chip inference machine (paper Fig. 4)."""

    chip: ChipSpec
    n_chips: int = 8

    @property
    def name(self) -> str:
        return f"8x{self.chip.name}"

    @property
    def mem_capacity(self) -> float:
        return self.n_chips * self.chip.mem_capacity

    def hw_cost(self, hbm_cost_per_gb: float = 9.0) -> float:
        return self.n_chips * hw_cost(self.chip, hbm_cost_per_gb)

    def norm_hw_cost(self) -> float:
        return norm_hw_cost(self.chip)

    def norm_tdp(self) -> float:
        return norm_tdp(self.chip)
