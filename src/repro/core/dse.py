"""Less-is-more design space exploration (paper §5.1-5.3, Figures 5 & 6).

Sweeps architectural knobs (core count, systolic array size, vector width,
L1/L2, memory system) around the H100 reference, evaluating each candidate's
prefill / decode latency (analytical model) and die area (area model).
The paper's Prefill / Decode Chips are Pareto points of these sweeps.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Iterable, List, Optional, Tuple

from ..configs.base import ModelConfig
from .hardware import H100, ChipSpec, die_area_mm2, hw_cost, tdp_w
from .opgraph import Parallelism, phase_ops
from .perfmodel import run_graph


@dataclass(frozen=True)
class DSEPoint:
    chip: ChipSpec
    area_mm2: float
    latency_s: float
    norm_latency: float  # vs H100
    hw_cost: float
    tdp_w: float


def _latency(chip: ChipSpec, cfg: ModelConfig, phase: str, batch: int, seq: int,
             par: Parallelism) -> float:
    return run_graph(chip, phase_ops(cfg, phase=phase, batch=batch, seq=seq, par=par)).total


def sweep(
    candidates: Iterable[ChipSpec],
    cfg: ModelConfig,
    *,
    phase: str,
    batch: int,
    seq: int = 1024,
    par: Optional[Parallelism] = None,
) -> List[DSEPoint]:
    par = par or Parallelism(tp=8)
    base = _latency(H100, cfg, phase, batch, seq, par)
    out = []
    for c in candidates:
        lat = _latency(c, cfg, phase, batch, seq, par)
        out.append(
            DSEPoint(
                chip=c,
                area_mm2=die_area_mm2(c),
                latency_s=lat,
                norm_latency=lat / base,
                hw_cost=hw_cost(c),
                tdp_w=tdp_w(c),
            )
        )
    return out


def prefill_candidates() -> List[ChipSpec]:
    """Fig. 5 sweep: GDDR7 memory system, vary compute fabric."""
    cands = []
    for cores in (96, 112, 128, 144):
        for sys in ((16, 16), (16, 32), (32, 32), (32, 64)):
            for vw in (8, 16, 32):
                for l2 in (24, 32, 40):
                    l1 = 128 + 64 * (sys[0] * sys[1] // 512)  # scale L1 with array
                    cands.append(
                        dataclasses.replace(
                            H100,
                            name=f"P-c{cores}-s{sys[0]}x{sys[1]}-v{vw}-l2_{l2}",
                            core_count=cores,
                            systolic_rows=sys[0],
                            systolic_cols=sys[1],
                            vector_width=vw,
                            l1_kb_per_core=min(l1, 512),
                            l2_mb=l2,
                            mem_protocol="GDDR7",
                            mem_bus_bits=512,
                            pin_speed_gbps=32.0,
                            mem_packages=16,
                            capacity_per_package_gb=4,
                            mem_bw_override_gbs=None,
                            reported_area_mm2=None,
                            reported_tdp_w=None,
                        )
                    )
    return cands


def decode_candidates() -> List[ChipSpec]:
    """Fig. 6 sweep: keep HBM3, cut compute/caches."""
    cands = []
    for cores in (96, 120, 144, 160):
        for sys in ((8, 8), (8, 16), (16, 16), (16, 32)):
            for vw in (4, 8, 16):
                for l2 in (20, 30, 40, 50):
                    cands.append(
                        dataclasses.replace(
                            H100,
                            name=f"D-c{cores}-s{sys[0]}x{sys[1]}-v{vw}-l2_{l2}",
                            core_count=cores,
                            systolic_rows=sys[0],
                            systolic_cols=sys[1],
                            vector_width=vw,
                            l1_kb_per_core=128,
                            l2_mb=l2,
                            reported_area_mm2=None,
                            reported_tdp_w=None,
                        )
                    )
    return cands


def pareto(points: List[DSEPoint]) -> List[DSEPoint]:
    """Area-latency Pareto frontier."""
    pts = sorted(points, key=lambda p: (p.area_mm2, p.latency_s))
    out: List[DSEPoint] = []
    best = float("inf")
    for p in pts:
        if p.latency_s < best:
            out.append(p)
            best = p.latency_s
    return out
