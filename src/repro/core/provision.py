"""Cluster provisioning and adaptive reallocation (paper §4, §7, Tables 4-8).

Given a workload trace, a model, and latency SLOs, find the minimum-cost
cluster design.  Designs are described by machine pools (prefill / decode /
co-located) of 8-chip machines; cost and TDP are per-machine multiples of the
chip-level models in ``hardware``.

``provision_disagg`` performs the paper's 2-D sweep (Fig. 9): for each
prefill-machine count near the utilization lower bound, grow the decode pool
until SLOs are met, and keep the cheapest feasible design.  ``max_rate``
binary-searches the highest sustainable request rate of a *fixed* cluster —
this drives the reallocation studies (Tables 7/8).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ModelConfig
from .cluster import SLO, ModelPerf, SimResult, simulate_colocated, simulate_disaggregated
from .hardware import ChipSpec, norm_hw_cost, norm_tdp
from .opgraph import Parallelism
from .trace import Request, WorkloadStats, synthesize


@dataclass(frozen=True)
class PoolSpec:
    """n machines of one chip type assigned to one phase."""

    chip_name: str
    perf: ModelPerf
    n: int

    @property
    def norm_cost(self) -> float:
        return self.n * norm_hw_cost(self.perf.chip)

    @property
    def norm_tdp(self) -> float:
        return self.n * norm_tdp(self.perf.chip)


@dataclass
class Design:
    name: str
    scheduler: str  # "disagg" | "coloc"
    prefill: List[PoolSpec] = field(default_factory=list)
    decode: List[PoolSpec] = field(default_factory=list)
    coloc: Optional[PoolSpec] = None

    @property
    def norm_cost(self) -> float:
        pools = self.prefill + self.decode + ([self.coloc] if self.coloc else [])
        return sum(p.norm_cost for p in pools)

    @property
    def norm_tdp(self) -> float:
        pools = self.prefill + self.decode + ([self.coloc] if self.coloc else [])
        return sum(p.norm_tdp for p in pools)

    def describe(self) -> str:
        if self.scheduler == "coloc":
            return f"{self.coloc.n} {self.coloc.chip_name}"
        p = " + ".join(f"{x.n}P:{x.chip_name}" for x in self.prefill)
        d = " + ".join(f"{x.n}D:{x.chip_name}" for x in self.decode)
        return f"{p} | {d}"


def evaluate(
    design: Design,
    reqs: Sequence[Request],
    ref_perf: ModelPerf,
    duration: float,
) -> SimResult:
    if design.scheduler == "coloc":
        return simulate_colocated(
            reqs, perf=design.coloc.perf, n_machines=design.coloc.n,
            ref_perf=ref_perf, duration=duration,
        )
    prefill_pool: List[ModelPerf] = []
    for p in design.prefill:
        prefill_pool.extend([p.perf] * p.n)
    decode_pool: List[ModelPerf] = []
    for p in design.decode:
        decode_pool.extend([p.perf] * p.n)
    return simulate_disaggregated(
        reqs, prefill_pool=prefill_pool, decode_pool=decode_pool,
        ref_perf=ref_perf, duration=duration,
    )


# ---------------------------------------------------------------------------
# Lower bounds (utilization math, paper's "workload-driven provisioning")
# ---------------------------------------------------------------------------


def _prefill_lower_bound(reqs, perf: ModelPerf) -> int:
    """Optimistic bound: batched-prefill throughput at 100% utilization."""
    dur = max(r.t_arrival for r in reqs) + 1e-9
    work = sum(perf.prefill_batch_time(2 * r.n_in, 2) / 2 for r in reqs)
    return max(1, math.ceil(work / (dur * perf.replicas_per_machine)))


def _decode_lower_bound(reqs, perf: ModelPerf) -> int:
    """Optimistic bound: max-batch decode throughput at 100% utilization."""
    dur = max(r.t_arrival for r in reqs) + 1e-9
    tokens = sum(r.n_out for r in reqs)
    avg_ctx = float(np.mean([r.n_in + r.n_out / 2 for r in reqs]))
    b = min(256, max(1, int(perf.max_kv_tokens / max(avg_ctx * 1.1, 1.0))))
    tput = b / perf.decode_time(b, avg_ctx)
    return max(1, math.ceil(tokens / (dur * tput * perf.replicas_per_machine)))


# ---------------------------------------------------------------------------
# Provisioning sweeps
# ---------------------------------------------------------------------------


def provision_disagg(
    *,
    name: str,
    prefill_perf: ModelPerf,
    decode_perf: ModelPerf,
    workload: WorkloadStats,
    rate: float,
    slo: SLO,
    ref_perf: ModelPerf,
    duration: float = 60.0,
    seed: int = 0,
    p_span: int = 4,
    d_span: int = 8,
) -> Optional[Design]:
    """2-D sweep (paper Fig. 9): cheapest (n_prefill, n_decode) meeting SLOs."""
    reqs = synthesize(workload, rate_rps=rate, duration_s=duration, seed=seed)
    p_lb = _prefill_lower_bound(reqs, prefill_perf)
    d_lb = _decode_lower_bound(reqs, decode_perf)
    best: Optional[Design] = None
    for n_p in range(p_lb, p_lb + p_span + 1):
        found = False
        for n_d in range(d_lb, d_lb + d_span + 1):
            design = Design(
                name, "disagg",
                prefill=[PoolSpec(prefill_perf.chip.name, prefill_perf, n_p)],
                decode=[PoolSpec(decode_perf.chip.name, decode_perf, n_d)],
            )
            if best is not None and design.norm_cost >= best.norm_cost:
                break  # can only get more expensive along n_d
            res = evaluate(design, reqs, ref_perf, duration)
            if res.meets(slo):
                if best is None or design.norm_cost < best.norm_cost:
                    best = design
                found = True
                break
        if not found and best is not None:
            continue
    return best


def provision_coloc(
    *,
    name: str,
    perf: ModelPerf,
    workload: WorkloadStats,
    rate: float,
    slo: SLO,
    ref_perf: ModelPerf,
    duration: float = 60.0,
    seed: int = 0,
    span: int = 24,
) -> Optional[Design]:
    reqs = synthesize(workload, rate_rps=rate, duration_s=duration, seed=seed)
    lb = max(_prefill_lower_bound(reqs, perf), _decode_lower_bound(reqs, perf))
    for n in range(lb, lb + span + 1):
        design = Design(name, "coloc", coloc=PoolSpec(perf.chip.name, perf, n))
        if evaluate(design, reqs, ref_perf, duration).meets(slo):
            return design
    return None


def max_rate(
    design: Design,
    *,
    workload: WorkloadStats,
    slo: SLO,
    ref_perf: ModelPerf,
    duration: float = 60.0,
    seed: int = 0,
    lo: float = 1.0,
    hi: float = 300.0,
    step: float = 10.0,
) -> float:
    """Highest request rate (req/s, ``step`` granularity) a fixed cluster meets."""

    def ok(rate: float) -> bool:
        reqs = synthesize(workload, rate_rps=rate, duration_s=duration, seed=seed)
        return evaluate(design, reqs, ref_perf, duration).meets(slo)

    if not ok(lo):
        return 0.0
    while hi - lo > step:
        mid = (lo + hi) / 2
        if ok(mid):
            lo = mid
        else:
            hi = mid
    return math.floor(lo / step) * step


# ---------------------------------------------------------------------------
# Reallocation (paper §7.2): move machines between phases, re-derive max rate
# ---------------------------------------------------------------------------


def reallocate(
    *,
    name: str,
    prefill_pools: List[Tuple[ModelPerf, int]],
    decode_pools: List[Tuple[ModelPerf, int]],
) -> Design:
    """Build a (possibly heterogeneous) disaggregated design from pool lists."""
    return Design(
        name,
        "disagg",
        prefill=[PoolSpec(p.chip.name, p, n) for p, n in prefill_pools if n > 0],
        decode=[PoolSpec(p.chip.name, p, n) for p, n in decode_pools if n > 0],
    )


def best_realloc_split(
    *,
    name: str,
    perf_p_prefill: ModelPerf,  # PrefillChip running prefill
    perf_p_decode: ModelPerf,  # PrefillChip running decode
    perf_d_prefill: ModelPerf,  # DecodeChip running prefill
    perf_d_decode: ModelPerf,  # DecodeChip running decode
    n_p_machines: int,
    n_d_machines: int,
    workload: WorkloadStats,
    slo: SLO,
    ref_perf: ModelPerf,
    duration: float = 60.0,
    seed: int = 0,
) -> Tuple[Design, float]:
    """Sweep how many machines of each type to flip to the other phase;
    return the split with the highest sustainable rate (paper Fig. 10)."""
    best_design, best_rate = None, -1.0
    for flip_p in range(0, n_p_machines + 1, max(1, n_p_machines // 3)):
        for flip_d in range(0, n_d_machines + 1, max(1, n_d_machines // 3)):
            if flip_p and flip_d:
                continue  # never flip both directions at once
            d = reallocate(
                name=name,
                prefill_pools=[(perf_p_prefill, n_p_machines - flip_p), (perf_d_prefill, flip_d)],
                decode_pools=[(perf_d_decode, n_d_machines - flip_d), (perf_p_decode, flip_p)],
            )
            if not d.prefill or not d.decode:
                continue
            r = max_rate(d, workload=workload, slo=slo, ref_perf=ref_perf,
                         duration=duration, seed=seed)
            if r > best_rate:
                best_design, best_rate = d, r
    return best_design, best_rate
