"""Event-driven cluster simulator (paper §6.2).

Two scheduler families, both driven by the same analytical chip model
(the repo's LLMCompass-lite) so comparisons are apples-to-apples:

  * ``simulate_disaggregated`` — Splitwise-style: prefill machine pool +
    decode machine pool, KV-cache transfer over the scale-out fabric,
    continuous batching on decode machines (join at iteration boundaries,
    KV-capacity-limited admission).
  * ``simulate_colocated`` — Sarathi-style: one homogeneous pool, chunked
    prefills mixed with decode batches every iteration (prefill-decode
    interference shows up as inflated TBT, exactly the paper's critique).

Latencies come from ``ModelPerf`` lookup tables precomputed from the
analytical model (log-log interpolation), so a full provisioning sweep runs
in seconds.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..configs.base import ModelConfig
from .hardware import ChipSpec, MachineSpec
from .opgraph import Parallelism, kv_bytes_per_token, phase_ops, weight_bytes
from .perfmodel import run_graph
from .trace import Request

# ---------------------------------------------------------------------------
# Cached analytical latencies
# ---------------------------------------------------------------------------

_PREFILL_GRID = [64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768]
_DECODE_B_GRID = [1, 2, 4, 8, 16, 32, 64, 128, 256]
_DECODE_CTX_GRID = [64, 256, 1024, 4096, 16384, 32768]


class ModelPerf:
    """Latency lookup tables for (chip, model, parallelism)."""

    def __init__(
        self,
        chip: ChipSpec,
        cfg: ModelConfig,
        par: Parallelism,
        *,
        w_bytes: float = 2.0,
        a_bytes: float = 2.0,
        mem_util: float = 0.9,
    ):
        self.chip = chip
        self.cfg = cfg
        self.par = par
        self.w_bytes = w_bytes
        self.a_bytes = a_bytes
        self.replicas_per_machine = max(1, 8 // par.n_chips)

        self._pre = np.array(
            [
                run_graph(chip, phase_ops(cfg, phase="prefill", batch=1, seq=s, par=par,
                                          w_bytes=w_bytes, a_bytes=a_bytes)).total
                for s in _PREFILL_GRID
            ]
        )
        # batched prefill (2 requests fused — Splitwise-style iteration batching;
        # indexed by TOTAL tokens)
        self._pre2 = np.array(
            [
                run_graph(chip, phase_ops(cfg, phase="prefill", batch=2, seq=max(s // 2, 32),
                                          par=par, w_bytes=w_bytes, a_bytes=a_bytes)).total
                for s in _PREFILL_GRID
            ]
        )
        self._dec = np.array(
            [
                [
                    run_graph(chip, phase_ops(cfg, phase="decode", batch=b, seq=c, par=par,
                                              w_bytes=w_bytes, a_bytes=a_bytes)).total
                    for c in _DECODE_CTX_GRID
                ]
                for b in _DECODE_B_GRID
            ]
        )
        # capacity per replica: weights first, then mem_util of the remainder
        # for KV (paper §B.1: 8xH100 ~66K BLOOM tokens, 8xPrefillChip ~35K)
        replica_mem = par.n_chips * chip.mem_capacity
        self.kv_per_token = kv_bytes_per_token(cfg, a_bytes)
        free = (replica_mem - weight_bytes(cfg, w_bytes)) * mem_util
        self.max_kv_tokens = int(max(0, free) / max(self.kv_per_token, 1.0)) if self.kv_per_token else 10**9
        self.fits = free > 0
        # scale-out transfer bandwidth for a whole replica (KV leaves via all chips)
        self.scaleout_bw = par.n_chips * chip.scaleout_gbs * 1e9

    # ---- lookups (log-space interpolation) ----
    def prefill_time(self, n_tokens: int) -> float:
        x = math.log(min(max(n_tokens, _PREFILL_GRID[0]), _PREFILL_GRID[-1]))
        xs = np.log(_PREFILL_GRID)
        return float(np.interp(x, xs, self._pre))

    def prefill_batch_time(self, total_tokens: int, n_reqs: int) -> float:
        if n_reqs <= 1:
            return self.prefill_time(total_tokens)
        x = math.log(min(max(total_tokens, _PREFILL_GRID[0]), _PREFILL_GRID[-1]))
        xs = np.log(_PREFILL_GRID)
        return float(np.interp(x, xs, self._pre2))

    def decode_time(self, batch: int, ctx: float) -> float:
        b = min(max(batch, 1), _DECODE_B_GRID[-1])
        c = min(max(ctx, _DECODE_CTX_GRID[0]), _DECODE_CTX_GRID[-1])
        lb = math.log(b)
        lc = math.log(c)
        bs = np.log(_DECODE_B_GRID)
        cs = np.log(_DECODE_CTX_GRID)
        i = min(np.searchsorted(bs, lb) - 1, len(bs) - 2)
        i = max(i, 0)
        j = min(np.searchsorted(cs, lc) - 1, len(cs) - 2)
        j = max(j, 0)
        tb = (lb - bs[i]) / (bs[i + 1] - bs[i])
        tc = (lc - cs[j]) / (cs[j + 1] - cs[j])
        d = self._dec
        return float(
            d[i, j] * (1 - tb) * (1 - tc)
            + d[i + 1, j] * tb * (1 - tc)
            + d[i, j + 1] * (1 - tb) * tc
            + d[i + 1, j + 1] * tb * tc
        )

    def kv_transfer_time(self, n_tokens: int) -> float:
        return n_tokens * self.kv_per_token / self.scaleout_bw

    def kv_read_time(self, batch: int, ctx: float) -> float:
        """Marginal decode-attention cost for mixed (Sarathi) batches."""
        bytes_ = batch * ctx * self.kv_per_token / self.par.n_chips
        return bytes_ / self.chip.effective_mem_bw * self.par.n_chips / max(self.par.tp, 1)


# ---------------------------------------------------------------------------
# Request bookkeeping
# ---------------------------------------------------------------------------


@dataclass
class ReqState:
    req: Request
    solo_ttft: float
    solo_tbt: float
    ttft: float = -1.0
    tbts: List[float] = field(default_factory=list)
    # decode runtime
    ctx: int = 0
    remaining: int = 0
    t_last: float = 0.0


@dataclass
class SimResult:
    n_requests: int
    n_completed: int
    norm_ttft: np.ndarray
    norm_tbt: np.ndarray

    def percentile(self, which: str, p: float) -> float:
        arr = self.norm_ttft if which == "ttft" else self.norm_tbt
        if len(arr) == 0:
            return float("inf")
        return float(np.percentile(arr, p))

    def meets(self, slo: "SLO") -> bool:
        return (
            self.n_completed == self.n_requests
            and self.percentile("tbt", 90) <= slo.p90_tbt
            and self.percentile("ttft", 90) <= slo.p90_ttft
            and self.percentile("tbt", 99) <= slo.p99_tbt
            and self.percentile("ttft", 99) <= slo.p99_ttft
        )


@dataclass(frozen=True)
class SLO:
    """Slowdowns relative to unbatched modeled-H100 execution (paper Table 5)."""

    name: str
    p90_tbt: float
    p90_ttft: float
    p99_tbt: float
    p99_ttft: float


SLOS = {
    "loose": SLO("loose", 2.5, 4.0, 6.0, 8.0),
    "normal": SLO("normal", 2.0, 3.0, 5.0, 6.0),
    "tight": SLO("tight", 1.5, 2.0, 3.0, 4.0),
}


def _prepare(reqs: Sequence[Request], ref: ModelPerf) -> List[ReqState]:
    """Solo-H100 reference latencies for SLO normalization."""
    out = []
    for r in reqs:
        solo_ttft = ref.prefill_time(r.n_in)
        solo_tbt = ref.decode_time(1, r.n_in + r.n_out / 2)
        out.append(ReqState(r, solo_ttft, solo_tbt))
    return out


def _collect(states: List[ReqState], duration: float) -> SimResult:
    """Metrics over the steady-state window (drop 10% warmup / 5% tail)."""
    t0, t1 = 0.10 * duration, 0.95 * duration
    ttfts, tbts = [], []
    completed = 0
    for s in states:
        if s.ttft >= 0 and s.remaining == 0:
            completed += 1
        if not (t0 <= s.req.t_arrival <= t1):
            continue
        if s.ttft >= 0:
            ttfts.append(s.ttft / s.solo_ttft)
            tbts.extend(t / s.solo_tbt for t in s.tbts)
        else:
            ttfts.append(float("inf"))
    return SimResult(len(states), completed, np.array(ttfts), np.array(tbts))


# ---------------------------------------------------------------------------
# Disaggregated (Splitwise-style)
# ---------------------------------------------------------------------------


@dataclass
class _DecodeReplica:
    rid: int
    perf: ModelPerf
    active: List[ReqState] = field(default_factory=list)
    tokens: int = 0
    busy: bool = False

    def capacity_ok(self, s: ReqState) -> bool:
        need = s.req.n_in + s.req.n_out
        return self.tokens + need <= self.perf.max_kv_tokens and len(self.active) < 256


@dataclass
class _PrefillReplica:
    rid: int
    perf: ModelPerf
    queue: List[ReqState] = field(default_factory=list)
    busy: bool = False
    running: List[ReqState] = field(default_factory=list)

    def backlog_s(self) -> float:
        return sum(self.perf.prefill_time(s.req.n_in) for s in self.queue)


PREFILL_MAX_BATCH = 2  # Splitwise-style iteration batching (paper Fig 2 uses B=2)


def simulate_disaggregated(
    reqs: Sequence[Request],
    *,
    prefill_pool: Sequence[ModelPerf],  # one entry per machine (heterogeneous ok)
    decode_pool: Sequence[ModelPerf],
    ref_perf: ModelPerf,
    duration: float,
    max_sim_time_factor: float = 4.0,
) -> SimResult:
    states = _prepare(reqs, ref_perf)
    idx_of = {id(s): i for i, s in enumerate(states)}
    horizon = duration * max_sim_time_factor

    pre_reps: List[_PrefillReplica] = []
    for p in prefill_pool:
        for _ in range(p.replicas_per_machine):
            pre_reps.append(_PrefillReplica(len(pre_reps), p))
    dec_reps: List[_DecodeReplica] = []
    for p in decode_pool:
        for _ in range(p.replicas_per_machine):
            dec_reps.append(_DecodeReplica(len(dec_reps), p))

    pending: List[ReqState] = []  # decode-ready but no KV capacity yet
    events: List[Tuple[float, int, str, int]] = []
    seq = 0

    def push(t: float, kind: str, ident: int):
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, ident))
        seq += 1

    for i, s in enumerate(states):
        push(s.req.t_arrival, "arrive", i)

    # ---- prefill side ----
    def start_prefill(rep: _PrefillReplica, t: float):
        if rep.busy or not rep.queue:
            return
        batch = rep.queue[:PREFILL_MAX_BATCH]
        del rep.queue[: len(batch)]
        rep.running = batch
        rep.busy = True
        total = sum(s.req.n_in for s in batch)
        push(t + rep.perf.prefill_batch_time(total, len(batch)), "pre_done", rep.rid)

    # ---- decode side ----
    def kick(rep: _DecodeReplica, t: float):
        if rep.active and not rep.busy:
            rep.busy = True
            ctx = sum(x.ctx for x in rep.active) / len(rep.active)
            push(t + rep.perf.decode_time(len(rep.active), ctx), "iter", rep.rid)

    def place(s: ReqState, t: float) -> bool:
        cands = [r for r in dec_reps if r.capacity_ok(s)]
        if not cands:
            return False
        rep = max(cands, key=lambda r: r.perf.max_kv_tokens - r.tokens)
        rep.active.append(s)
        rep.tokens += s.req.n_in + s.req.n_out
        s.t_last = t
        kick(rep, t)
        return True

    while events:
        t, _, kind, ident = heapq.heappop(events)
        if t > horizon:
            break
        if kind == "arrive":
            s = states[ident]
            rep = min(pre_reps, key=lambda r: r.backlog_s() + (0.05 if r.busy else 0.0))
            rep.queue.append(s)
            start_prefill(rep, t)
        elif kind == "pre_done":
            rep = pre_reps[ident]
            batch, rep.running, rep.busy = rep.running, [], False
            for s in batch:
                s.ttft = t - s.req.t_arrival
                s.ctx = s.req.n_in
                s.remaining = max(s.req.n_out - 1, 0)  # first token from prefill
                if s.remaining > 0:
                    push(t + rep.perf.kv_transfer_time(s.req.n_in), "ready", idx_of[id(s)])
            start_prefill(rep, t)
        elif kind == "ready":
            if not place(states[ident], t):
                pending.append(states[ident])
        else:  # decode iteration complete
            rep = dec_reps[ident]
            rep.busy = False
            done = []
            for s in rep.active:
                s.tbts.append(t - s.t_last)
                s.t_last = t
                s.ctx += 1
                s.remaining -= 1
                if s.remaining <= 0:
                    done.append(s)
            for s in done:
                rep.active.remove(s)
                rep.tokens -= s.req.n_in + s.req.n_out
            while pending and place(pending[0], t):
                pending.pop(0)
            kick(rep, t)

    return _collect(states, duration)


# ---------------------------------------------------------------------------
# Co-located (Sarathi-style chunked prefill + piggybacked decode)
# ---------------------------------------------------------------------------


def simulate_colocated(
    reqs: Sequence[Request],
    *,
    perf: ModelPerf,
    n_machines: int,
    ref_perf: ModelPerf,
    duration: float,
    chunk: int = 1024,
    max_sim_time_factor: float = 4.0,
) -> SimResult:
    states = _prepare(reqs, ref_perf)
    horizon = duration * max_sim_time_factor
    n_rep = n_machines * perf.replicas_per_machine

    @dataclass
    class Rep:
        rid: int
        prefill_q: List[List] = field(default_factory=list)  # [state, done] pairs
        active: List[ReqState] = field(default_factory=list)
        tokens: int = 0
        busy: bool = False
        backlog: float = 0.0  # outstanding prefill tokens (for placement)
        plan_takes: List[Tuple[List, int]] = field(default_factory=list)
        plan_active: List[ReqState] = field(default_factory=list)

    reps = [Rep(r) for r in range(n_rep)]
    events: List[Tuple[float, int, str, int]] = []
    seq = 0

    def schedule_iter(rep: Rep, t: float):
        """Plan one mixed iteration: a prefill chunk + all currently-active
        decodes.  The plan is frozen here; arrivals during the iteration wait."""
        nonlocal seq
        if rep.busy or (not rep.prefill_q and not rep.active):
            return
        rep.busy = True
        budget = chunk
        takes: List[Tuple[List, int]] = []
        for entry in rep.prefill_q:
            if budget <= 0:
                break
            s, done = entry
            take = min(budget, s.req.n_in - done)
            if take > 0:
                takes.append((entry, take))
                budget -= take
        chunk_tokens = sum(tk for _, tk in takes)
        rep.plan_takes = takes
        rep.plan_active = list(rep.active)
        n_active = len(rep.plan_active)
        avg_ctx = (sum(x.ctx for x in rep.plan_active) / n_active) if n_active else 0
        if chunk_tokens:
            # decode tokens piggyback on the chunk's weight streaming: their
            # marginal cost is the KV-cache attention reads (Sarathi's claim)
            t_iter = perf.prefill_time(chunk_tokens)
            if n_active:
                t_iter += perf.kv_read_time(n_active, avg_ctx)
        else:
            t_iter = perf.decode_time(n_active, avg_ctx)
        heapq.heappush(events, (t + t_iter, seq, "iter", rep.rid))
        seq += 1

    for i, s in enumerate(states):
        heapq.heappush(events, (s.req.t_arrival, seq, "arrive", i))
        seq += 1

    while events:
        t, _, kind, ident = heapq.heappop(events)
        if t > horizon:
            break
        if kind == "arrive":
            s = states[ident]
            rep = min(reps, key=lambda r: r.backlog + 50.0 * len(r.active))
            rep.prefill_q.append([s, 0])
            rep.backlog += s.req.n_in
            schedule_iter(rep, t)
        else:
            rep = reps[ident]
            rep.busy = False
            # 1) decode tokens for the active set the iteration actually ran
            done_reqs = []
            for s in rep.plan_active:
                s.tbts.append(t - s.t_last)
                s.t_last = t
                s.ctx += 1
                s.remaining -= 1
                if s.remaining <= 0:
                    done_reqs.append(s)
            for s in done_reqs:
                rep.active.remove(s)
                rep.tokens -= s.req.n_in + s.req.n_out
            # 2) apply the planned prefill chunk
            for entry, take in rep.plan_takes:
                s = entry[0]
                entry[1] += take
                rep.backlog -= take
                if entry[1] >= s.req.n_in:
                    s.ttft = t - s.req.t_arrival
                    s.ctx = s.req.n_in
                    s.remaining = max(s.req.n_out - 1, 0)
                    s.t_last = t
                    if s.remaining > 0:
                        rep.active.append(s)
                        rep.tokens += s.req.n_in + s.req.n_out
            rep.prefill_q = [e for e in rep.prefill_q if e[1] < e[0].req.n_in]
            rep.plan_takes, rep.plan_active = [], []
            schedule_iter(rep, t)

    return _collect(states, duration)
