"""Workload trace synthesis (paper §6.3).

The paper uses Azure LLM inference traces [12] for two applications:

  coding       — long prompts (median 1500 tokens), short outputs (median 13)
  conversation — medium prompts (median 1020), longer outputs (median 129)

The public dataset is not bundled offline, so we synthesize traces from
lognormal marginals calibrated to the published medians (and the heavy right
tails reported in the Splitwise paper), with Poisson arrivals.  The generator
is seeded and deterministic; all benchmarks record the seed.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List

import numpy as np


@dataclass(frozen=True)
class Request:
    rid: int
    t_arrival: float
    n_in: int
    n_out: int


@dataclass(frozen=True)
class WorkloadStats:
    name: str
    median_in: int
    sigma_in: float
    median_out: int
    sigma_out: float
    max_in: int = 16_384
    max_out: int = 2_048


# sigma calibrated so mean/median ratios match the Azure trace moments
# reported by Splitwise (coding: mean_in/med_in ~1.3, mean_out/med_out ~2.4;
# conversation: mean_in/med_in ~1.15, mean_out/med_out ~1.6).
CODING = WorkloadStats("coding", median_in=1500, sigma_in=0.70, median_out=13, sigma_out=1.30)
CONVERSATION = WorkloadStats(
    "conversation", median_in=1020, sigma_in=0.55, median_out=129, sigma_out=1.0
)

WORKLOADS = {"coding": CODING, "conversation": CONVERSATION}


def synthesize(
    workload: WorkloadStats,
    *,
    rate_rps: float,
    duration_s: float,
    seed: int = 0,
) -> List[Request]:
    """Poisson arrivals at ``rate_rps`` with lognormal length marginals."""
    rng = np.random.default_rng(seed)
    n_est = int(rate_rps * duration_s * 1.2) + 16
    gaps = rng.exponential(1.0 / rate_rps, size=n_est)
    t = np.cumsum(gaps)
    t = t[t < duration_s]
    n = len(t)
    n_in = np.clip(
        rng.lognormal(math.log(workload.median_in), workload.sigma_in, size=n),
        16, workload.max_in,
    ).astype(int)
    n_out = np.clip(
        rng.lognormal(math.log(workload.median_out), workload.sigma_out, size=n),
        1, workload.max_out,
    ).astype(int)
    return [Request(i, float(t[i]), int(n_in[i]), int(n_out[i])) for i in range(n)]


def summarize(reqs: List[Request]) -> dict:
    n_in = np.array([r.n_in for r in reqs])
    n_out = np.array([r.n_out for r in reqs])
    return {
        "n": len(reqs),
        "median_in": float(np.median(n_in)),
        "median_out": float(np.median(n_out)),
        "p90_in": float(np.percentile(n_in, 90)),
        "p90_out": float(np.percentile(n_out, 90)),
        "total_in_tokens": int(n_in.sum()),
        "total_out_tokens": int(n_out.sum()),
    }
