"""Full model assembly: one generic decoder/encoder over ``block_pattern``.

Every architecture (dense / MoE / hybrid / SSM / encoder-only / stub-frontend)
is the same machine: embed -> scan over ``n_repeats`` repeats of the pattern
-> final norm -> unembed.  Params and caches are *stacked* along a leading
``n_repeats`` axis per pattern position so the layer stack lowers to a single
``lax.scan`` (small HLO, dry-run-friendly; trip counts recovered by
``launch/hloanalysis``).

Entry points:
  init_params / param_axes            parameters + logical sharding axes
  forward_train(params, batch, cfg)   logits + aux (MoE losses)
  prefill(params, batch, cfg)         last-position logits + stacked caches
  decode_step(params, tok, cache,...) next-token logits + updated caches
  init_cache_specs / cache_axes       ShapeDtypeStruct cache tree (dry-run)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.partitioning import constrain
from . import attention as attn
from . import layers as L
from . import moe as moe_mod
from . import ssm as ssm_mod

Params = Any
Cache = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _mixer_init(key, cfg: ModelConfig, mixer: str):
    if mixer == "attn":
        return attn.mla_init(key, cfg) if cfg.attn_type == "mla" else attn.gqa_init(key, cfg)
    if mixer == "mamba":
        return ssm_mod.mamba_init(key, cfg)
    raise ValueError(mixer)


def _mixer_axes(cfg: ModelConfig, mixer: str):
    if mixer == "attn":
        return attn.mla_axes(cfg) if cfg.attn_type == "mla" else attn.gqa_axes(cfg)
    if mixer == "mamba":
        return ssm_mod.mamba_axes(cfg)
    raise ValueError(mixer)


def _ffn_init(key, cfg: ModelConfig, ffn: str):
    if ffn == "mlp":
        return L.mlp_init(key, cfg, cfg.d_ff)
    if ffn == "moe":
        return moe_mod.moe_init(key, cfg, cfg.moe)
    if ffn == "none":
        return {}
    raise ValueError(ffn)


def _ffn_axes(cfg: ModelConfig, ffn: str):
    if ffn == "mlp":
        return L.mlp_axes(cfg)
    if ffn == "moe":
        return moe_mod.moe_axes(cfg, cfg.moe)
    if ffn == "none":
        return {}
    raise ValueError(ffn)


def init_params(key, cfg: ModelConfig) -> Params:
    """Stacked-per-pattern-position parameter tree."""
    P = len(cfg.block_pattern)
    R = cfg.n_repeats
    k_emb, k_blocks, k_final = jax.random.split(key, 3)

    blocks = []
    pat_keys = jax.random.split(k_blocks, P)
    for i, (mixer, ffn) in enumerate(cfg.block_pattern):
        rep_keys = jax.random.split(pat_keys[i], R)

        def one(k, mixer=mixer, ffn=ffn):
            km, kf = jax.random.split(k)
            b = {
                "mixer": _mixer_init(km, cfg, mixer),
                "mixer_norm": L.norm_init(cfg),
            }
            if ffn != "none":
                b["ffn"] = _ffn_init(kf, cfg, ffn)
                b["ffn_norm"] = L.norm_init(cfg)
            return b

        blocks.append(jax.vmap(one)(rep_keys))

    return {
        "embed": L.embed_init(k_emb, cfg),
        "blocks": blocks,
        "final_norm": L.norm_init(cfg),
    }


def param_axes(cfg: ModelConfig) -> Params:
    """Same structure as init_params, leaves = logical-axis tuples.

    Stacked block params get a leading "layers" axis."""
    blocks = []
    for mixer, ffn in cfg.block_pattern:
        b = {
            "mixer": _mixer_axes(cfg, mixer),
            "mixer_norm": L.norm_axes(cfg),
        }
        if ffn != "none":
            b["ffn"] = _ffn_axes(cfg, ffn)
            b["ffn_norm"] = L.norm_axes(cfg)
        b = jax.tree.map(
            lambda a: ("layers",) + tuple(a),
            b,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x
            ),
        )
        blocks.append(b)
    return {
        "embed": L.embed_axes(cfg),
        "blocks": blocks,
        "final_norm": L.norm_axes(cfg),
    }


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------


def _mixer_cache_spec(cfg: ModelConfig, mixer: str, B: int, Lc: int):
    if mixer == "attn":
        if cfg.attn_type == "mla":
            return attn.mla_cache_shape(cfg, B, Lc)
        return attn.gqa_cache_shape(cfg, B, Lc)
    if mixer == "mamba":
        return ssm_mod.mamba_cache_shape(cfg, B)
    raise ValueError(mixer)


def _mixer_cache_axes(cfg: ModelConfig, mixer: str):
    if mixer == "attn":
        return attn.mla_cache_axes() if cfg.attn_type == "mla" else attn.gqa_cache_axes()
    if mixer == "mamba":
        return ssm_mod.mamba_cache_axes()
    raise ValueError(mixer)


def init_cache_specs(cfg: ModelConfig, B: int, Lc: int):
    """ShapeDtypeStruct cache tree (list per pattern position, stacked R)."""
    R = cfg.n_repeats
    out = []
    for mixer, _ in cfg.block_pattern:
        spec = _mixer_cache_spec(cfg, mixer, B, Lc)
        out.append(
            jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((R,) + tuple(s.shape), s.dtype), spec
            )
        )
    return out


def cache_axes(cfg: ModelConfig):
    out = []
    for mixer, _ in cfg.block_pattern:
        a = _mixer_cache_axes(cfg, mixer)
        a = jax.tree.map(
            lambda t: ("layers",) + tuple(t),
            a,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x
            ),
        )
        out.append(a)
    return out


def zeros_cache(cfg: ModelConfig, B: int, Lc: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), init_cache_specs(cfg, B, Lc))


def init_paged_cache_specs(cfg: ModelConfig, max_slots: int, n_pages: int, page_size: int):
    """Paged decode-cache tree: attention leaves become page pools
    ``[R, n_pages, page_size, ...]`` (slot rows -> block-table indirection,
    see serving/kvcache.py); mamba state is fixed-size per request and stays
    per-slot ``[R, max_slots, ...]``."""
    R = cfg.n_repeats
    out = []
    for mixer, _ in cfg.block_pattern:
        B = n_pages if mixer == "attn" else max_slots
        spec = _mixer_cache_spec(cfg, mixer, B, page_size)
        out.append(
            jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((R,) + tuple(s.shape), s.dtype), spec
            )
        )
    return out


def zeros_paged_cache(cfg: ModelConfig, max_slots: int, n_pages: int, page_size: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        init_paged_cache_specs(cfg, max_slots, n_pages, page_size),
    )


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _slopes(cfg: ModelConfig):
    return attn.alibi_slopes(cfg.n_heads) if cfg.pos_emb == "alibi" else None


def _embed_in(params, batch, cfg: ModelConfig, pos0=None):
    """batch: int tokens [B,S] or precomputed embeddings [B,S,D] (stub frontends).

    ``pos0`` [B]: per-request absolute position offset (prefix-offset prefill)
    for learned position embeddings; rope/alibi handle offsets in the mixers.
    """
    if jnp.issubdtype(batch.dtype, jnp.integer):
        x = L.embed_apply(params["embed"], batch, cfg)
    else:
        x = batch.astype(L.pdt(cfg))
    if pos0 is not None and cfg.pos_emb == "learned":
        S = x.shape[1]
        idx = pos0[:, None] + jnp.arange(S)[None, :]  # [B, S]
        x = x + jnp.take(params["embed"]["pos"], idx, axis=0)
    else:
        x = L.add_positions(params["embed"], x, cfg)
    return constrain(x, ("batch", "seq", None))


def _block_apply(
    bp, x, cfg: ModelConfig, mixer: str, ffn: str, *,
    mode: str,  # "train" | "prefill" | "decode"
    cache=None,
    pos=None,
    slopes=None,
    n_groups: int = 1,
    true_len=None,
    block_tables=None,
    prefix_kv=None,
    prefix_len=None,
    cache_scales=None,
):
    """One (mixer, ffn) block. Returns (x, new_cache, aux)."""
    aux = {}
    h = L.norm_apply(bp["mixer_norm"], x, cfg)
    if mixer == "attn":
        if mode == "decode":
            if cfg.attn_type == "mla":
                a_out, new_cache = attn.mla_decode(
                    bp["mixer"], h, cfg, cache, pos, block_tables=block_tables,
                    cache_scales=cache_scales,
                )
            else:
                a_out, new_cache = attn.gqa_decode(
                    bp["mixer"], h, cfg, cache, pos, slopes=slopes,
                    block_tables=block_tables, cache_scales=cache_scales,
                )
        else:
            want = mode == "prefill"
            if cfg.attn_type == "mla":
                a_out, new_cache = attn.mla_prefill(
                    bp["mixer"], h, cfg, want_cache=want, true_len=true_len,
                    prefix_kv=prefix_kv, prefix_len=prefix_len,
                )
            else:
                a_out, new_cache = attn.gqa_prefill(
                    bp["mixer"], h, cfg, slopes=slopes, want_cache=want, true_len=true_len,
                    prefix_kv=prefix_kv, prefix_len=prefix_len,
                )
    elif mixer == "mamba":
        if prefix_kv is not None and "conv" not in prefix_kv:
            raise ValueError(
                "prefix-offset prefill is attention-only: SSM state is a "
                "whole-prompt function — a mamba mixer accepts only a carried "
                "{conv, ssm} state (chunked prefill), never a K/V prefix "
                "(hybrid prefix SHARING uses the full-recompute pages-only path)"
            )
        if mode == "decode":
            a_out, new_cache = ssm_mod.mamba_decode(bp["mixer"], h, cfg, cache, pos)
        else:
            a_out, new_cache = ssm_mod.mamba_prefill(
                bp["mixer"], h, cfg, want_cache=mode == "prefill", true_len=true_len,
                initial_state=prefix_kv,
            )
    else:
        raise ValueError(mixer)
    x = x + a_out
    x = constrain(x, ("batch", "seq", None))

    if ffn != "none":
        h = L.norm_apply(bp["ffn_norm"], x, cfg)
        if ffn == "mlp":
            f_out = L.mlp_apply(bp["ffn"], h, cfg)
        else:
            f_out, aux = moe_mod.moe_apply(
                bp["ffn"], h, cfg, cfg.moe, n_groups=n_groups, train=mode == "train"
            )
        x = x + f_out
        x = constrain(x, ("batch", "seq", None))
    return x, new_cache, aux


def _zero_aux():
    return {"lb_loss": jnp.float32(0), "router_z": jnp.float32(0), "drop_frac": jnp.float32(0)}


def _run_stack(params, x, cfg: ModelConfig, *, mode, caches=None, pos=None, n_groups=1,
               remat: bool = False, true_len=None, block_tables=None,
               prefix_kv=None, prefix_len=None, cache_scales=None):
    """Scan over n_repeats; pattern positions applied sequentially in the body."""
    slopes = _slopes(cfg)
    P = len(cfg.block_pattern)

    def body(x, xs, prefix_reps=None, scale_reps=None):
        reps, cache_reps = xs
        new_caches = []
        aux_sum = _zero_aux()
        for i, (mixer, ffn) in enumerate(cfg.block_pattern):
            c = None if cache_reps is None else cache_reps[i]
            pk = None if prefix_reps is None else prefix_reps[i]
            cs = None if scale_reps is None else scale_reps[i]
            x_new, nc, aux = _block_apply(
                reps[i], x, cfg, mixer, ffn,
                mode=mode, cache=c, pos=pos, slopes=slopes, n_groups=n_groups,
                true_len=true_len, block_tables=block_tables,
                prefix_kv=pk, prefix_len=prefix_len, cache_scales=cs,
            )
            x = x_new
            new_caches.append(nc)
            if aux:
                aux_sum = {k: aux_sum[k] + aux[k] for k in aux_sum}
        return x, (new_caches, aux_sum)

    if caches is None and prefix_kv is not None:
        # prefix-offset prefill: the cached prefix K/V rides as read-only
        # scan xs alongside the params (same stacked-[R] layout as decode)
        def sbp(carry, xs_t):
            reps, pref = xs_t
            x, (ncs, aux) = body(carry, (reps, None), prefix_reps=pref)
            return x, (ncs, aux)

        x, (stacked_caches, aux_seq) = jax.lax.scan(
            sbp, x, (params["blocks"], prefix_kv)
        )
    elif caches is None:
        # scan only over params
        def sb(carry, reps):
            x, (ncs, aux) = body(carry, (reps, None))
            out_c = ncs if mode == "prefill" else None
            return x, (out_c, aux)

        if remat:
            sb = jax.checkpoint(sb, prevent_cse=False)
        x, (stacked_caches, aux_seq) = jax.lax.scan(sb, x, params["blocks"])
    else:
        # Decode: caches ride as read-only scan xs; the body emits tiny
        # per-layer deltas (the fresh token's K/V) as ys and the merge into
        # the cache happens ONCE after the scan (merge_cache_deltas).
        # Writing the cache inside the loop — whether as xs/ys or as a
        # DUS-updated carry — makes XLA materialize per-iteration copies of
        # the whole stacked cache (measured: ~700x the useful HBM traffic).
        # Quant scales ([R, P+1] per attn leaf) ride as extra read-only xs,
        # sliced to [P+1] per layer alongside the int8 pools.
        if cache_scales is not None:
            def scq(carry, xs_t):
                reps, cache_reps, scale_reps = xs_t
                return body(carry, (reps, cache_reps), scale_reps=scale_reps)

            x, (stacked_caches, aux_seq) = jax.lax.scan(
                scq, x, (params["blocks"], caches, cache_scales)
            )
        else:
            def sc(carry, xs_t):
                reps, cache_reps = xs_t
                return body(carry, (reps, cache_reps))

            x, (stacked_caches, aux_seq) = jax.lax.scan(sc, x, (params["blocks"], caches))

    aux = jax.tree.map(lambda a: jnp.sum(a), aux_seq)
    return x, stacked_caches, aux


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def forward_train(params, batch, cfg: ModelConfig, *, n_groups: int = 1, remat: bool = False):
    """batch: tokens [B,S] int32 or embeds [B,S,D] -> (logits [B,S,V], aux)."""
    x = _embed_in(params, batch, cfg)
    x, _, aux = _run_stack(params, x, cfg, mode="train", n_groups=n_groups, remat=remat)
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.unembed_apply(params["embed"], x, cfg)
    logits = constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux


def prefill(params, batch, cfg: ModelConfig, *, n_groups: int = 1,
            pad_cache_to: Optional[int] = None, true_len=None,
            prefix_kv=None, prefix_len=None):
    """Prefill pass.  Returns (last-position logits [B,V], caches).

    ``pad_cache_to``: right-pad attention KV caches to this length so decode
    can run in place (standard serving layout: prefill_len + max_new_tokens).

    ``true_len`` [B] int32: per-request prompt length for right-padded
    (bucketed) batches.  Attention and SSM mixers mask positions beyond it
    in-kernel, and the returned logits are taken at position true_len-1 per
    row instead of the last padded position.  Rows with true_len == 0 are
    dummy (batch padding); their logits/caches are garbage by contract.

    ``prefix_kv`` (list per pattern position) + ``prefix_len`` [B] int32
    switch to prefix-offset (tail/chunk) prefill: ``batch`` holds only each
    prompt's uncomputed slice, queries run at absolute positions
    prefix_len[b] + j, attention entries ([R, B, Lp, ...] cached K/V leaves)
    are attended as [cached prefix ‖ slice], and the returned attention
    caches cover the slice only.  ``true_len`` then counts slice tokens
    (logits at slice position true_len - 1, i.e. absolute
    prefix_len + true_len - 1).  Mamba pattern positions take a carried
    {conv, ssm} state (chunked prefill resumes the recurrence mid-prompt;
    the returned entry is the carry for the next chunk) — a K/V-style
    prefix raises, since SSM state is a whole-prompt function.
    """
    x = _embed_in(params, batch, cfg,
                  pos0=None if prefix_len is None else jnp.asarray(prefix_len))
    x, caches, aux = _run_stack(params, x, cfg, mode="prefill", n_groups=n_groups,
                                true_len=true_len, prefix_kv=prefix_kv,
                                prefix_len=None if prefix_len is None
                                else jnp.asarray(prefix_len))
    x = L.norm_apply(params["final_norm"], x, cfg)
    if true_len is None:
        last = x[:, -1]
    else:
        tl = jnp.asarray(true_len)
        last_idx = jnp.maximum(tl - 1, 0)  # [B]
        last = jnp.take_along_axis(x, last_idx[:, None, None], axis=1)[:, 0]
    logits = L.unembed_apply(params["embed"], last, cfg)
    logits = constrain(logits, ("batch", "vocab"))

    if pad_cache_to is not None:
        # attention caches have a seq axis at dim 2 (after the layers dim);
        # mamba caches are fixed-size and pass through unchanged.
        S = batch.shape[1]
        extra = pad_cache_to - S
        padded = []
        for i, (mixer, _) in enumerate(cfg.block_pattern):
            c = caches[i]
            if mixer == "attn" and extra > 0:
                c = jax.tree.map(
                    lambda a: jnp.pad(
                        a, [(0, 0), (0, 0), (0, extra)] + [(0, 0)] * (a.ndim - 3)
                    ),
                    c,
                )
            padded.append(c)
        caches = padded
    return logits, caches, aux


def merge_cache_deltas(cfg: ModelConfig, caches, deltas, pos, B: int, *, block_tables=None,
                       scales=None):
    """Write every layer's fresh-token K/V into the caches in one pass.

    Attention deltas are [R, B, ...] (one token per row).  Slab caches are
    [R, B, L, ...]: a single masked select per cache tensor keeps the update
    shard-local under any sequence sharding (positions >= L match nothing and
    are dropped — overshoot writes cannot clamp onto the last position).

    With ``block_tables`` [B, n_pg] the caches are page pools
    [R, n_pages+1, page_size, ...]: the write scatters each row's delta into
    (block_tables[b, pos // ps], pos % ps); rows whose position is out of
    range — released slots (trash-mapped tables) or positions past max_len —
    land on the trash page.  Mamba deltas are the full (fixed-size) new
    states and simply replace the old cache.

    With ``scales`` (int8 pools, requires ``block_tables``) the write is a
    whole-page read-modify-write: dequantize the touched page, splice the
    fresh token at its offset, zero the garbage positions PAST the write head
    (they are overwritten before ever being attended, and masking them keeps
    bucket-pad garbage from inflating the absmax), and requantize the page
    with a FRESH absmax — so quant error never compounds across blocks.
    Returns (caches, scales) in that case, plain caches otherwise."""
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    out = []
    out_scales = None if scales is None else []
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            if block_tables is None:
                def wr(cache, d):
                    Lc = cache.shape[2]
                    mask = jnp.arange(Lc)[None, :] == pos_b[:, None]  # [B, L]
                    mask = mask.reshape((1,) + mask.shape + (1,) * (cache.ndim - 3))
                    return jnp.where(mask, d[:, :, None].astype(cache.dtype), cache)
            else:
                n_pg = block_tables.shape[1]

                def wr(cache, d):
                    ps = cache.shape[2]
                    trash = cache.shape[1] - 1
                    pg = block_tables[
                        jnp.arange(B), jnp.clip(pos_b // ps, 0, n_pg - 1)
                    ]
                    pg = jnp.where(pos_b < n_pg * ps, pg, trash)
                    return cache.at[:, pg, pos_b % ps].set(d.astype(cache.dtype))

            if scales is not None:
                n_pg = block_tables.shape[1]

                def wr_q(cache, d, sc):
                    ps = cache.shape[2]
                    trash = cache.shape[1] - 1
                    pg = block_tables[
                        jnp.arange(B), jnp.clip(pos_b // ps, 0, n_pg - 1)
                    ]
                    pg = jnp.where(pos_b < n_pg * ps, pg, trash)
                    off = pos_b % ps
                    # page [R, B, ps, ...] — gather, dequant, splice, requant
                    page = attn.dequantize_pages(cache[:, pg], sc[:, pg])
                    idx = jnp.arange(ps)[None, :]  # [1, ps]
                    is_new = idx == off[:, None]  # [B, ps]
                    is_old = idx < off[:, None]
                    shp = (1, B, ps) + (1,) * (page.ndim - 3)
                    page = jnp.where(
                        is_new.reshape(shp),
                        d[:, :, None].astype(jnp.float32),
                        jnp.where(is_old.reshape(shp), page, 0.0),
                    )
                    qv, s = attn.quantize_pages(page)  # [R, B, ps, ...], [R, B]
                    return cache.at[:, pg].set(qv), sc.at[:, pg].set(s)

                leaf, sc_leaf = {}, {}
                for kk in caches[i]:
                    leaf[kk], sc_leaf[kk] = wr_q(
                        caches[i][kk], deltas[i][kk], scales[i][kk]
                    )
                out.append(leaf)
                out_scales.append(sc_leaf)
            else:
                out.append(jax.tree.map(wr, caches[i], deltas[i]))
        else:
            out.append(deltas[i])
            if out_scales is not None:
                out_scales.append(None)
    if scales is not None:
        return out, out_scales
    return out


def decode_step(params, tok, caches, pos, cfg: ModelConfig, *, n_groups: int = 1,
                block_tables=None, scales=None):
    """One decode step.  tok [B] int32 (or [B,1,D] embeds); pos scalar or [B].

    ``block_tables`` [B, n_pg] switches attention caches to the paged layout
    (page pools + per-request block tables, see serving/kvcache.py); the
    attention mixers gather K/V pages through the table and the fresh-token
    write scatters into (page, offset).

    ``scales`` (the PagedDecodeState scale tree, requires ``block_tables``)
    switches the attention pools to int8 payloads: attention dequantizes in
    the gather and the fresh-token write requantizes its whole page with a
    fresh absmax (see merge_cache_deltas).

    Returns (logits [B,V], new caches), plus the updated scales as a third
    element when ``scales`` is given."""
    if jnp.issubdtype(tok.dtype, jnp.integer):
        x = L.embed_apply(params["embed"], tok[:, None], cfg)
    else:
        x = tok.astype(L.pdt(cfg))
    B = x.shape[0]
    if cfg.pos_emb == "learned":
        # per-request positions: gather the pos row(s)
        pos_v = jnp.broadcast_to(jnp.asarray(pos), (B,))
        x = x + jnp.take(params["embed"]["pos"], pos_v, axis=0)[:, None]
    x = constrain(x, ("batch", None, None))
    x, deltas, _ = _run_stack(params, x, cfg, mode="decode", caches=caches, pos=pos,
                              n_groups=n_groups, block_tables=block_tables,
                              cache_scales=scales)
    if scales is not None:
        new_caches, new_scales = merge_cache_deltas(
            cfg, caches, deltas, pos, B, block_tables=block_tables, scales=scales
        )
    else:
        new_caches = merge_cache_deltas(cfg, caches, deltas, pos, B, block_tables=block_tables)
    x = L.norm_apply(params["final_norm"], x, cfg)
    logits = L.unembed_apply(params["embed"], x[:, 0], cfg)
    logits = constrain(logits, ("batch", "vocab"))
    if scales is not None:
        return logits, new_caches, new_scales
    return logits, new_caches


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def cross_entropy(logits, labels, *, z_loss_coef: float = 0.0):
    """Mean CE over all positions; labels < 0 are masked out."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    mask = (labels >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if z_loss_coef:
        loss = loss + z_loss_coef * jnp.sum(jnp.square(lse) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss


def train_loss(params, batch, labels, cfg: ModelConfig, *, n_groups: int = 1, remat: bool = False):
    logits, aux = forward_train(params, batch, cfg, n_groups=n_groups, remat=remat)
    loss = cross_entropy(logits, labels)
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_coef * aux["lb_loss"] + 1e-3 * aux["router_z"]
    metrics = {"ce": loss, **aux}
    return loss, metrics
