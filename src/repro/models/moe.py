"""Top-k Mixture-of-Experts with group-local capacity dispatch.

Dispatch is *group-local* (groups map to data-parallel shards, GShard-style):
positions-within-expert are computed with a chunked running-count scan (no
global sort, no O(T*k*E) one-hot materialization), then tokens are scattered
into per-group [E, C, D] buffers, experts run as batched einsums with the
expert dim sharded over the "model" mesh axis, and outputs are gathered back
with top-k gate weighting.  Tokens beyond capacity are dropped (standard
capacity-factor semantics).

Supports shared experts (DeepSeek-V2) and Arctic's parallel dense-FFN
residual branch.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from ..sharding.partitioning import constrain
from .layers import _normal, act_fn, mlp_apply, mlp_axes, mlp_init, pdt


def moe_init(key, cfg: ModelConfig, moe: MoEConfig):
    d, E, F = cfg.d_model, moe.n_experts, moe.d_expert
    ks = jax.random.split(key, 6)
    p = {
        "router": _normal(ks[0], (d, E), d ** -0.5, jnp.float32),
        "w_in": _normal(ks[1], (E, d, F), d ** -0.5, pdt(cfg)),
        "w_out": _normal(ks[2], (E, F, d), F ** -0.5, pdt(cfg)),
    }
    if cfg.gated_mlp:
        p["w_gate"] = _normal(ks[3], (E, d, F), d ** -0.5, pdt(cfg))
    if moe.n_shared_experts:
        import dataclasses

        shared_cfg = cfg  # same activation/gating
        p["shared"] = mlp_init(ks[4], shared_cfg, moe.n_shared_experts * F)
    if cfg.dense_residual:
        p["dense"] = mlp_init(ks[5], cfg, cfg.d_ff_dense or cfg.d_ff)
    return p


def moe_axes(cfg: ModelConfig, moe: MoEConfig):
    a = {
        "router": ("embed", "expert"),
        "w_in": ("expert", "embed", "mlp"),
        "w_out": ("expert", "mlp", "embed"),
    }
    if cfg.gated_mlp:
        a["w_gate"] = ("expert", "embed", "mlp")
    if moe.n_shared_experts:
        a["shared"] = mlp_axes(cfg)
    if cfg.dense_residual:
        a["dense"] = mlp_axes(cfg)
    return a


def capacity(tokens_per_group: int, moe: MoEConfig) -> int:
    c = math.ceil(tokens_per_group * moe.top_k * moe.capacity_factor / moe.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def _positions_in_expert(idx_flat, n_experts: int, chunk: int = 2048):
    """idx_flat [G, T] int32 -> positions [G, T] (running count per expert).

    Chunked scan keeps the one-hot working set to [G, chunk, E].
    """
    G, T = idx_flat.shape
    c = min(chunk, T)
    while T % c:
        c -= 1
    nc = T // c
    xs = jnp.moveaxis(idx_flat.reshape(G, nc, c), 1, 0)

    def body(counts, ic):  # counts [G, E]; ic [G, c]
        oh = jax.nn.one_hot(ic, n_experts, dtype=jnp.int32)  # [G, c, E]
        before_in_chunk = jnp.cumsum(oh, axis=1) - oh
        within = jnp.take_along_axis(before_in_chunk, ic[..., None], -1)[..., 0]
        base = jnp.take_along_axis(
            jnp.broadcast_to(counts[:, None, :], oh.shape), ic[..., None], -1
        )[..., 0]
        return counts + oh.sum(axis=1), within + base

    _, pos = jax.lax.scan(body, jnp.zeros((G, n_experts), jnp.int32), xs)
    return jnp.moveaxis(pos, 0, 1).reshape(G, T)


def moe_apply(p, x, cfg: ModelConfig, moe: MoEConfig, *, n_groups: int = 1, train: bool = False):
    """x [B, S, D] -> (y [B, S, D], aux dict of scalars)."""
    B, S, D = x.shape
    T = B * S
    G = math.gcd(T, n_groups)
    Tg = T // G
    E, k = moe.n_experts, moe.top_k
    xg = x.reshape(G, Tg, D)
    xg = constrain(xg, ("group", None, None))

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1)
    gates, idx = jax.lax.top_k(probs, k)  # [G, Tg, k]
    gates = gates / jnp.sum(gates, -1, keepdims=True)

    # ---- dispatch positions (group-local) ----
    C = capacity(Tg, moe)
    idx_flat = idx.reshape(G, Tg * k).astype(jnp.int32)
    pos_flat = _positions_in_expert(idx_flat, E)
    keep = pos_flat < C
    pos_safe = jnp.where(keep, pos_flat, C)  # C is out-of-bounds -> dropped

    # ---- scatter tokens into [G, E, C, D] ----
    tok_ids = jnp.repeat(jnp.arange(Tg), k)[None].repeat(G, 0)  # [G, Tg*k]

    def scatter_group(xg_g, e_g, p_g, t_g):
        src = jnp.take(xg_g, t_g, axis=0)  # [Tg*k, D]
        return jnp.zeros((E, C, D), xg_g.dtype).at[e_g, p_g].set(src, mode="drop")

    buf = jax.vmap(scatter_group)(xg, idx_flat, pos_safe, tok_ids)
    buf = constrain(buf, ("group", "expert", None, None))

    # ---- expert FFN (batched einsum; expert dim sharded over "model") ----
    act = act_fn(cfg.activation)
    h = jnp.einsum("gecd,edf->gecf", buf, p["w_in"])
    if cfg.gated_mlp:
        h = act(jnp.einsum("gecd,edf->gecf", buf, p["w_gate"])) * h
    else:
        h = act(h)
    out = jnp.einsum("gecf,efd->gecd", h, p["w_out"])
    out = constrain(out, ("group", "expert", None, None))

    # ---- gather back with gate weighting ----
    def gather_group(out_g, e_g, p_g):
        return out_g[e_g, jnp.minimum(p_g, C - 1)]  # [Tg*k, D]

    ytok = jax.vmap(gather_group)(out, idx_flat, pos_safe)
    ytok = jnp.where(keep[..., None], ytok, 0)
    gates_flat = gates.reshape(G, Tg * k, 1).astype(ytok.dtype)
    y = jnp.sum((ytok * gates_flat).reshape(G, Tg, k, D), axis=2)
    y = y.reshape(B, S, D)

    # ---- auxiliary losses (Switch-style load balance + router z) ----
    me = jnp.mean(probs, axis=(0, 1))  # [E] mean router prob
    ce = jnp.mean(
        jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(2), axis=(0, 1)
    ) / k  # fraction of tokens per expert
    lb = E * jnp.sum(me * ce)
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"lb_loss": lb, "router_z": zl, "drop_frac": dropped}

    # ---- shared experts / dense residual branches ----
    if moe.n_shared_experts:
        y = y + mlp_apply(p["shared"], x, cfg)
    if cfg.dense_residual:
        y = y + mlp_apply(p["dense"], x, cfg)
    return y, aux
