"""Attention: GQA/MHA and MLA (DeepSeek/MiniCPM), RoPE/ALiBi, KV caches.

XLA-path implementations (pure jnp) used for CPU execution, tests and the
dry-run; on real TPU hardware the hot paths are replaced by the Pallas kernels
in ``repro.kernels`` (same math, validated against each other).

Prefill attention is q-chunked (flash-style streaming over query blocks) so
that 32k-token prefill never materializes an O(S^2) score tensor.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.partitioning import constrain
from .layers import _normal, pdt

# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------


def rope_cos_sin(positions, d: int, theta: float):
    """positions [S] or [B, S] (int) -> cos, sin [..., d/2] float32."""
    inv = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [B, S, H, d]; cos/sin [S, d/2], or [B, S, d/2] for per-request
    absolute positions (prefix-offset prefill).  Half-rotation, llama-style."""
    d2 = x.shape[-1] // 2
    x1 = x[..., :d2].astype(jnp.float32)
    x2 = x[..., d2:].astype(jnp.float32)
    if cos.ndim == 3:
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    else:
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


def apply_rope_vec(x, cos, sin):
    """x [B, 1, H, d]; cos/sin [B, d/2] (per-request decode positions)."""
    d2 = x.shape[-1] // 2
    x1 = x[..., :d2].astype(jnp.float32)
    x2 = x[..., d2:].astype(jnp.float32)
    c = cos[:, None, None, :]
    s = sin[:, None, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], -1).astype(x.dtype)


def _norm_pos(pos, B: int):
    """Normalize decode position to ([B] vector, is_scalar flag)."""
    pos = jnp.asarray(pos)
    scalar = pos.ndim == 0
    return (jnp.broadcast_to(pos, (B,)), scalar)


def _cache_write(cache_arr, new, pos, scalar: bool):
    """Write one token per batch row at position(s) ``pos``.

    Formulated as an elementwise masked select rather than a scatter/DUS:
    under SPMD a scatter along a sharded sequence axis lowers to scatter
    routing (collective-permutes + full-cache rematerialization), whereas a
    select is shard-local by construction for ANY cache sharding."""
    L = cache_arr.shape[1]
    mask = jnp.arange(L)[None, :] == pos[:, None]  # [B, L]
    mask = mask.reshape(mask.shape + (1,) * (cache_arr.ndim - 2))
    return jnp.where(mask, new.astype(cache_arr.dtype), cache_arr)


def quantize_pages(pages):
    """Symmetric absmax int8 quantization, one scale per leading-2-dim slice.

    ``pages`` [A, N, page_size, ...] float -> (payload int8 same shape,
    scales [A, N] float32) with ``scale = absmax / 127`` over each [A, N]
    slice's trailing dims.  An all-zero page gets scale 0 and an all-zero
    payload (the safe-divide below), so dequant reproduces it exactly.
    Roundtrip error is <= scale / 2 elementwise (round-to-nearest of
    ``x / scale``; the absmax element maps to exactly +/-127)."""
    f = pages.astype(jnp.float32)
    red = tuple(range(2, f.ndim))
    absmax = jnp.max(jnp.abs(f), axis=red)
    scale = absmax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.clip(
        jnp.round(f / safe.reshape(safe.shape + (1,) * (f.ndim - 2))), -127, 127
    ).astype(jnp.int8)
    return q, scale


def dequantize_pages(q, scale):
    """Inverse of ``quantize_pages``: int8 payload * broadcast scale -> f32.

    ``scale``'s dims must be a leading prefix of ``q``'s (trailing page-content
    dims broadcast), so the same helper serves pool-shaped [R, N, ps, ...] and
    gathered [R, B, n_pg, ps, ...] payloads."""
    return q.astype(jnp.float32) * scale.reshape(
        scale.shape + (1,) * (q.ndim - scale.ndim)
    )


def gather_pages(pool, block_tables):
    """Paged-KV view: pool [P, ps, ...] + block_tables [B, n_pg] -> [B, n_pg*ps, ...].

    Unmapped table entries point at the trash page; those positions are
    always >= the request's write position and masked by the decode kernels
    (the mask reads strictly < pos), so trash contents are never attended.

    Gather-free: the page lookup is a one-hot contraction, not a
    fancy-indexing gather — XLA:CPU lowers general gathers to a scalar
    element loop that dominates decode wall time, while a [B, n_pg, P]
    one-hot times the pool is a dense matmul (vectorized on every backend).
    The result is bit-identical to the gather: each output row sums exactly
    one nonzero term, and ``x * 1 + 0 * y`` is exact for finite pools."""
    P = pool.shape[0]
    oh = jax.nn.one_hot(block_tables, P, dtype=pool.dtype)  # [B, n_pg, P]
    rows = jnp.einsum("bnp,p...->bn...", oh, pool)  # [B, n_pg, ps, ...]
    B, n_pg, ps = rows.shape[:3]
    return rows.reshape((B, n_pg * ps) + rows.shape[3:])


def gather_pages_dequant(pool, scales, block_tables):
    """``gather_pages`` for int8 pools: pool [P, ps, ...] int8 + per-page
    ``scales`` [P] f32 + block_tables [B, n_pg] -> [B, n_pg*ps, ...] f32.

    Same gather-free one-hot contraction; the per-page scale is gathered by
    the SAME one-hot and multiplied onto the page rows, which equals
    dequantize-then-gather exactly (each output row sums one nonzero term,
    and that term is ``payload * scale``)."""
    P = pool.shape[0]
    oh = jax.nn.one_hot(block_tables, P, dtype=jnp.float32)  # [B, n_pg, P]
    rows = jnp.einsum("bnp,p...->bn...", oh, pool.astype(jnp.float32))
    srow = jnp.einsum("bnp,p->bn", oh, scales.astype(jnp.float32))  # [B, n_pg]
    rows = rows * srow.reshape(srow.shape + (1,) * (rows.ndim - 2))
    B, n_pg, ps = rows.shape[:3]
    return rows.reshape((B, n_pg * ps) + rows.shape[3:])


def alibi_slopes(n_heads: int):
    """Standard ALiBi slopes for any head count (BLOOM uses 112 heads)."""

    def pow2_slopes(n):
        start = 2.0 ** (-(2.0 ** -(math.log2(n) - 3)))
        return [start * (start ** i) for i in range(n)]

    if math.log2(n_heads).is_integer():
        s = pow2_slopes(n_heads)
    else:
        p = 2 ** math.floor(math.log2(n_heads))
        s = pow2_slopes(p)
        extra = pow2_slopes(2 * p)[0::2][: n_heads - p]
        s = s + extra
    return jnp.asarray(s, jnp.float32)


# ---------------------------------------------------------------------------
# Attention cores (XLA path)
# ---------------------------------------------------------------------------


def _mask_bias(q_pos, k_pos, causal: bool, kv_len=None, slopes=None, kv_heads=1, groups=1):
    """Additive f32 bias [KV, G, q, k] (broadcastable) from mask + alibi."""
    valid = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        valid &= k_pos[None, :] <= q_pos[:, None]
    if kv_len is not None:
        valid &= k_pos[None, :] < kv_len
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[None, None]
    if slopes is not None:
        dist = (k_pos[None, :] - q_pos[:, None]).astype(jnp.float32)
        ab = slopes.reshape(kv_heads, groups)[:, :, None, None] * dist[None, None]
        ab = jnp.where(valid[None, None], ab, 0.0)
        bias = bias + ab
    return bias


def _mask_bias_b(q_pos, k_pos, k_valid, causal: bool, slopes=None, kv_heads=1, groups=1):
    """Batched-positions twin of ``_mask_bias`` for prefix-offset prefill:
    q_pos/k_pos [B, q]/[B, k] absolute positions, k_valid [B, k] explicit key
    validity -> bias [B, KV|1, G|1, q, k]."""
    valid = k_valid[:, None, :]
    if causal:
        valid = valid & (k_pos[:, None, :] <= q_pos[:, :, None])
    bias = jnp.where(valid, 0.0, -1e30).astype(jnp.float32)[:, None, None]
    if slopes is not None:
        dist = (k_pos[:, None, :] - q_pos[:, :, None]).astype(jnp.float32)
        ab = slopes.reshape(1, kv_heads, groups, 1, 1) * dist[:, None, None]
        ab = jnp.where(valid[:, None, None], ab, 0.0)
        bias = bias + ab
    return bias


def attn_core(
    q,
    k,
    v,
    *,
    causal: bool,
    q_positions,
    k_positions,
    kv_len=None,
    true_len=None,
    k_valid=None,
    slopes=None,
    q_chunk: Optional[int] = None,
    scale: Optional[float] = None,
):
    """q [B,Sq,H,dq]; k [B,Skv,KV,dq]; v [B,Skv,KV,dv] -> [B,Sq,H,dv].

    Exact softmax attention; q is processed in chunks via lax.scan when
    ``q_chunk`` is set (bounds peak memory to O(chunk * Skv)).

    ``true_len`` [B] masks keys at positions >= true_len[b] — the per-request
    length mask for right-padded (bucketed) prefill batches.  Padding keys get
    -1e30 before the softmax, so exp underflows to exactly 0 and real-token
    outputs are bit-identical to the unpadded computation.

    Prefix-offset (tail-only) prefill passes per-request ABSOLUTE positions:
    q_positions/k_positions [B, Sq]/[B, Skv] plus an explicit ``k_valid``
    [B, Skv] key mask (prefix-length + tail-length validity); ``kv_len`` and
    ``true_len`` are the 1D-positions path's masks and are ignored there."""
    B, Sq, H, dq = q.shape
    KV = k.shape[2]
    G = H // KV
    dv = v.shape[-1]
    scale = scale if scale is not None else dq ** -0.5
    qg = q.reshape(B, Sq, KV, G, dq)
    batched_pos = jnp.asarray(q_positions).ndim == 2
    kv_valid = None
    if not batched_pos and true_len is not None:
        tl = jnp.asarray(true_len)
        kv_valid = k_positions[None, :] < tl[:, None]  # [B, Skv]

    def block(qb, qpos):
        # qb [B, c, KV, G, dq] -> out [B, c, KV, G, dv]
        s = jnp.einsum("bqkgd,bskd->bkgqs", qb, k, preferred_element_type=jnp.float32)
        s = s * scale
        if batched_pos:
            s = s + _mask_bias_b(qpos, k_positions, k_valid, causal, slopes, KV, G)
        else:
            s = s + _mask_bias(qpos, k_positions, causal, kv_len, slopes, KV, G)
            if kv_valid is not None:
                s = jnp.where(kv_valid[:, None, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)

    if q_chunk is None or q_chunk >= Sq:
        out = block(qg, q_positions)
    else:
        assert Sq % q_chunk == 0, (Sq, q_chunk)
        nc = Sq // q_chunk
        qs = jnp.moveaxis(qg.reshape(B, nc, q_chunk, KV, G, dq), 1, 0)
        if batched_pos:
            ps = jnp.moveaxis(q_positions.reshape(B, nc, q_chunk), 1, 0)
        else:
            ps = q_positions.reshape(nc, q_chunk)

        def body(_, xs):
            qb, qpos = xs
            return None, block(qb, qpos)

        _, outs = jax.lax.scan(body, None, (qs, ps))
        out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, G, dv)
    return out.reshape(B, Sq, H, dv)


def default_q_chunk(S: int) -> Optional[int]:
    """Bound per-step score memory to ~ chunk*S <= 2^22 elements."""
    if S <= 4096:
        return None
    c = max(128, (1 << 22) // S)
    while S % c:
        c //= 2
    return max(c, 128) if S % max(c, 128) == 0 else 128


# ---------------------------------------------------------------------------
# GQA layer
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig):
    d, H, KV, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": _normal(ks[0], (d, H, dh), sc, pdt(cfg)),
        "wk": _normal(ks[1], (d, KV, dh), sc, pdt(cfg)),
        "wv": _normal(ks[2], (d, KV, dh), sc, pdt(cfg)),
        "wo": _normal(ks[3], (H, dh, d), (H * dh) ** -0.5, pdt(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, dh), pdt(cfg))
        p["bk"] = jnp.zeros((KV, dh), pdt(cfg))
        p["bv"] = jnp.zeros((KV, dh), pdt(cfg))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), pdt(cfg))
        p["k_norm"] = jnp.ones((dh,), pdt(cfg))
    return p


def gqa_axes(cfg: ModelConfig):
    a = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        a["bq"] = ("heads", "head_dim")
        a["bk"] = ("kv_heads", "head_dim")
        a["bv"] = ("kv_heads", "head_dim")
    if cfg.qk_norm:
        a["q_norm"] = ("head_dim",)
        a["k_norm"] = ("head_dim",)
    return a


def _rms_head(x, scale, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(x.dtype)


def _qkv(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = _rms_head(q, p["q_norm"], cfg.norm_eps)
        k = _rms_head(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_prefill(p, x, cfg: ModelConfig, *, slopes=None, want_cache: bool, true_len=None,
                prefix_kv=None, prefix_len=None):
    """x [B,S,D] -> (out [B,S,D], cache {k,v:[B,S,KV,dh]} or None).

    ``true_len`` [B]: per-request valid prefix for right-padded batches; keys
    beyond it are masked (cache rows beyond it are overwritten by decode
    before they are ever attended, see serving/kvcache.py).

    ``prefix_kv`` {k,v: [B, Lp, KV, dh]} + ``prefix_len`` [B] switch to
    prefix-offset (tail-only) prefill: ``x`` holds only the UNCACHED tail of
    each prompt, queries/keys sit at absolute positions prefix_len[b] + j,
    and attention runs over [cached prefix ‖ fresh tail].  Prefix keys are
    already roped (the cache stores post-RoPE K); entries at or past
    prefix_len[b] — gather padding — are masked to exact zeros, so the tail
    computation is bit-identical to a full-prompt prefill of the same tokens.
    ``true_len`` then counts TAIL tokens and the returned cache is tail-only.
    """
    B, S, _ = x.shape
    if prefix_kv is not None:
        pos = prefix_len[:, None] + jnp.arange(S)[None, :]  # [B, S] absolute
    else:
        pos = jnp.arange(S)
    q, k, v = _qkv(p, x, cfg)
    if cfg.pos_emb == "rope":
        cos, sin = rope_cos_sin(pos, cfg.d_head, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    # pin attention-activation shardings here so any resharding happens ONCE
    # per layer (instead of inside every q-chunk scan iteration)
    q = constrain(q, ("batch", None, "heads", "head_dim"))
    k = constrain(k, ("batch", None, "kv_heads", "head_dim"))
    v = constrain(v, ("batch", None, "kv_heads", "head_dim"))
    cache = {"k": k, "v": v} if want_cache else None
    if prefix_kv is not None:
        pk = prefix_kv["k"].astype(k.dtype)
        pv = prefix_kv["v"].astype(v.dtype)
        Lp = pk.shape[1]
        lp_idx = jnp.arange(Lp)
        k_all = jnp.concatenate([pk, k], axis=1)
        v_all = jnp.concatenate([pv, v], axis=1)
        k_positions = jnp.concatenate(
            [jnp.broadcast_to(lp_idx[None, :], (B, Lp)), pos], axis=1
        )
        k_valid = jnp.concatenate(
            [lp_idx[None, :] < prefix_len[:, None],
             jnp.arange(S)[None, :] < jnp.asarray(true_len)[:, None]],
            axis=1,
        )
        o = attn_core(
            q, k_all, v_all,
            causal=cfg.causal,
            q_positions=pos,
            k_positions=k_positions,
            k_valid=k_valid,
            slopes=slopes,
            q_chunk=default_q_chunk(S),
        )
    else:
        o = attn_core(
            q, k, v,
            causal=cfg.causal,
            q_positions=pos,
            k_positions=pos,
            true_len=true_len,
            slopes=slopes,
            q_chunk=default_q_chunk(S),
        )
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, cache


def gqa_decode(p, x, cfg: ModelConfig, cache, pos, *, slopes=None, block_tables=None,
               cache_scales=None):
    """x [B,1,D]; cache {k,v:[B,L,KV,dh]}; pos scalar or [B] -> (out, delta).

    The cache is consumed READ-ONLY: the fresh token's K/V contribute via a
    separate rank-1 softmax term, and the returned delta {k,v: [B,KV,dh]} is
    merged into the cache once per step *outside* the layer scan
    (model.merge_cache_deltas).  Writing inside the scan makes XLA
    materialize per-iteration copies of the whole stacked cache.

    ``block_tables`` [B, n_pg] switches the cache to the paged layout
    {k,v: [P, ps, KV, dh]}: K/V rows are gathered per request through the
    table (the XLA path; on TPU the Pallas kernel in
    kernels/decode_attention.py streams pages without materializing the
    gather).  The attention math past the gather is byte-for-byte the slab
    path, so paged and slab decode emit bit-identical streams.

    ``cache_scales`` {k,v: [P] f32} (with ``block_tables``) switches the
    pools to int8 payloads with per-page absmax scales: the gather dequantizes
    (``gather_pages_dequant``, or scalar-prefetched scales in the int8 Pallas
    kernel variant) and everything past it is the same fp32 math.
    """
    B = x.shape[0]
    pos_b, scalar = _norm_pos(pos, B)
    q, k, v = _qkv(p, x, cfg)
    if cfg.pos_emb == "rope":
        cos, sin = rope_cos_sin(pos_b, cfg.d_head, cfg.rope_theta)  # [B, d/2]
        q = apply_rope_vec(q, cos, sin)
        k = apply_rope_vec(k, cos, sin)
    k = constrain(k, ("batch", None, "kv_heads", "head_dim"))
    v = constrain(v, ("batch", None, "kv_heads", "head_dim"))
    if block_tables is not None and slopes is None:
        # Default paged path on TPU: the block-table Pallas kernel streams
        # pages via scalar-prefetched tables (kernels/decode_attention.py) —
        # no gather materialization at all.  The XLA gather path below stays
        # the bit-identity reference (and the CPU path); interpret-mode tests
        # force this branch off-TPU via kernels.ops.set_impl.
        from ..kernels import ops as kops

        if kops.paged_decode_via_pallas():
            out = _paged_decode_pallas(
                p, q, k, v, cfg, pos_b, cache, block_tables, cache_scales
            )
            return out, {"k": k[:, 0], "v": v[:, 0]}
    if cache_scales is not None:
        # cast to the fresh K/V dtype: the fp32 cache stores exactly this, so
        # everything past the gather is dtype-identical to the unquantized path
        ck = gather_pages_dequant(cache["k"], cache_scales["k"], block_tables).astype(k.dtype)
        cv = gather_pages_dequant(cache["v"], cache_scales["v"], block_tables).astype(v.dtype)
    else:
        ck = cache["k"] if block_tables is None else gather_pages(cache["k"], block_tables)
        cv = cache["v"] if block_tables is None else gather_pages(cache["v"], block_tables)
    ck = constrain(ck, ("batch", "kv_seq", "kv_heads", "head_dim"))
    cv = constrain(cv, ("batch", "kv_seq", "kv_heads", "head_dim"))
    L = ck.shape[1]
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    scale = cfg.d_head ** -0.5
    qg = q.reshape(B, KV, G, cfg.d_head)
    qg = constrain(qg, ("batch", "kv_heads", "q_groups", "head_dim"))
    s = jnp.einsum("bkgd,bskd->bkgs", qg, ck, preferred_element_type=jnp.float32)
    s = constrain(s, ("batch", "kv_heads", "q_groups", "kv_seq"))
    s = s * scale
    kpos = jnp.arange(L)
    if slopes is not None:
        dist = (kpos[None, :] - pos_b[:, None]).astype(jnp.float32)  # [B, L]
        s = s + slopes.reshape(1, KV, G, 1) * dist[:, None, None, :]
    mask = kpos[None, :] < pos_b[:, None]  # [B, L] — strictly prior tokens
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    # the current token attends to itself through a separate term
    s_new = jnp.einsum("bkgd,bkd->bkg", qg, k[:, 0], preferred_element_type=jnp.float32)
    s_new = s_new * scale  # alibi distance 0 -> no bias term
    m = jnp.maximum(jnp.max(s, -1), s_new)  # [B,KV,G]
    e = jnp.exp(s - m[..., None])
    e_new = jnp.exp(s_new - m)
    denom = jnp.sum(e, -1) + e_new
    o = jnp.einsum("bkgs,bskd->bkgd", e.astype(cv.dtype), cv)
    o = o + e_new[..., None].astype(v.dtype) * v[:, 0][:, :, None, :]
    o = (o / denom[..., None].astype(o.dtype)).reshape(B, 1, H, cfg.d_head)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, {"k": k[:, 0], "v": v[:, 0]}


def _paged_decode_pallas(p, q, k, v, cfg: ModelConfig, pos_b, cache, block_tables,
                         cache_scales=None):
    """Paged GQA decode via the block-table Pallas kernel (view-free).

    The kernel streams K/V pages through scalar-prefetched block tables and
    returns UNNORMALIZED online-softmax partials (acc, m, l) in f32 over the
    strictly-prior tokens (lengths = pos_b, same mask as the XLA path); the
    fresh token's rank-1 term is merged here, mirroring the XLA path's
    separate ``s_new`` term.  A request at position 0 has m = -inf partials
    whose exp-weight underflows to exactly 0, so it attends only to itself.
    With ``cache_scales`` the int8 kernel variant streams int8 pages and
    dequantizes in-kernel via scalar-prefetched per-page scales.
    """
    from ..kernels import ops as kops

    B = q.shape[0]
    H, KV = cfg.n_heads, cfg.n_kv_heads
    G = H // KV
    scale = cfg.d_head ** -0.5
    # kernel head order is KV-major (h = kv*G + g), matching q.reshape below
    if cache_scales is not None:
        acc, m, l = kops.decode_attention_paged_partials_quant(
            q[:, 0], cache["k"], cache["v"], cache_scales["k"],
            cache_scales["v"], block_tables, pos_b
        )
    else:
        acc, m, l = kops.decode_attention_paged_partials(
            q[:, 0], cache["k"], cache["v"], block_tables, pos_b
        )
    acc = acc.reshape(B, KV, G, cfg.d_head)
    m = m.reshape(B, KV, G)
    l = l.reshape(B, KV, G)
    qg = q.reshape(B, KV, G, cfg.d_head)
    s_new = jnp.einsum("bkgd,bkd->bkg", qg, k[:, 0], preferred_element_type=jnp.float32)
    s_new = s_new * scale
    m2 = jnp.maximum(m, s_new)
    alpha = jnp.exp(m - m2)
    beta = jnp.exp(s_new - m2)
    o = acc * alpha[..., None] + beta[..., None] * v[:, 0][:, :, None, :].astype(acc.dtype)
    o = o / (l * alpha + beta)[..., None]
    o = o.reshape(B, 1, H, cfg.d_head).astype(q.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def gqa_cache_shape(cfg: ModelConfig, B: int, L: int):
    dt = pdt(cfg)
    kv = (B, L, cfg.n_kv_heads, cfg.d_head)
    return {"k": jax.ShapeDtypeStruct(kv, dt), "v": jax.ShapeDtypeStruct(kv, dt)}


def gqa_cache_axes():
    a = ("batch", "seq", "kv_heads", "head_dim")
    return {"k": a, "v": a}


# ---------------------------------------------------------------------------
# MLA layer (DeepSeek-V2 / MiniCPM3)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig):
    a = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    qh = a.qk_nope_head_dim + a.qk_rope_head_dim
    return {
        "wq_a": _normal(ks[0], (d, a.q_lora_rank), d ** -0.5, pdt(cfg)),
        "q_ln": jnp.ones((a.q_lora_rank,), pdt(cfg)),
        "wq_b": _normal(ks[1], (a.q_lora_rank, H, qh), a.q_lora_rank ** -0.5, pdt(cfg)),
        "wkv_a": _normal(ks[2], (d, a.kv_lora_rank + a.qk_rope_head_dim), d ** -0.5, pdt(cfg)),
        "kv_ln": jnp.ones((a.kv_lora_rank,), pdt(cfg)),
        "wkv_b": _normal(
            ks[3],
            (a.kv_lora_rank, H, a.qk_nope_head_dim + a.v_head_dim),
            a.kv_lora_rank ** -0.5,
            pdt(cfg),
        ),
        "wo": _normal(ks[4], (H, a.v_head_dim, d), (H * a.v_head_dim) ** -0.5, pdt(cfg)),
    }


def mla_axes(cfg: ModelConfig):
    return {
        "wq_a": ("embed", "q_lora"),
        "q_ln": ("q_lora",),
        "wq_b": ("q_lora", "heads", "head_dim"),
        "wkv_a": ("embed", "kv_lora"),
        "kv_ln": ("kv_lora",),
        "wkv_b": ("kv_lora", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }


def _mla_q(p, x, cfg, cos, sin):
    a = cfg.mla
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    cq = _rms_head(cq, p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    q_nope = q[..., : a.qk_nope_head_dim]
    q_rope = apply_rope(q[..., a.qk_nope_head_dim :], cos, sin)
    return q_nope, q_rope


def _mla_ckv(p, x, cfg, cos, sin):
    a = cfg.mla
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv = _rms_head(ckv_full[..., : a.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    k_rope = ckv_full[..., a.kv_lora_rank :][:, :, None, :]  # single shared head
    k_rope = apply_rope(k_rope, cos, sin)[:, :, 0, :]
    return ckv, k_rope


def mla_prefill(p, x, cfg: ModelConfig, *, want_cache: bool, true_len=None,
                prefix_kv=None, prefix_len=None):
    """Naive (expanded) MLA for prefill; caches the compressed ckv.

    ``prefix_kv`` {ckv: [B, Lp, r], k_rope: [B, Lp, rd]} + ``prefix_len`` [B]
    run prefix-offset (tail-only) prefill: the cached compressed prefix is
    expanded through ``wkv_b`` (the same einsum a full prefill applies, so
    the bits match) and attended ahead of the fresh tail — see gqa_prefill.
    """
    a = cfg.mla
    B, S, _ = x.shape
    if prefix_kv is not None:
        pos = prefix_len[:, None] + jnp.arange(S)[None, :]  # [B, S] absolute
    else:
        pos = jnp.arange(S)
    cos, sin = rope_cos_sin(pos, a.qk_rope_head_dim, cfg.rope_theta)
    q_nope, q_rope = _mla_q(p, x, cfg, cos, sin)
    ckv, k_rope = _mla_ckv(p, x, cfg, cos, sin)
    kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"])
    k_nope = kv[..., : a.qk_nope_head_dim]
    v = kv[..., a.qk_nope_head_dim :]
    H = cfg.n_heads
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None], (B, S, H, a.qk_rope_head_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    # hoist any head-resharding out of the q-chunk scan (see gqa_prefill)
    q = constrain(q, ("batch", None, "heads", None))
    k = constrain(k, ("batch", None, "heads", None))
    v = constrain(v, ("batch", None, "heads", None))
    cache = {"ckv": ckv, "k_rope": k_rope} if want_cache else None
    scale = (a.qk_nope_head_dim + a.qk_rope_head_dim) ** -0.5
    if prefix_kv is not None:
        pckv = prefix_kv["ckv"].astype(ckv.dtype)
        pkrope = prefix_kv["k_rope"].astype(k_rope.dtype)
        Lp = pckv.shape[1]
        kv_p = jnp.einsum("bsr,rhk->bshk", pckv, p["wkv_b"])
        k_p = jnp.concatenate(
            [kv_p[..., : a.qk_nope_head_dim],
             jnp.broadcast_to(pkrope[:, :, None], (B, Lp, H, a.qk_rope_head_dim))],
            -1,
        )
        v_p = kv_p[..., a.qk_nope_head_dim :]
        lp_idx = jnp.arange(Lp)
        k_all = jnp.concatenate([k_p, k], axis=1)
        v_all = jnp.concatenate([v_p, v], axis=1)
        k_positions = jnp.concatenate(
            [jnp.broadcast_to(lp_idx[None, :], (B, Lp)), pos], axis=1
        )
        k_valid = jnp.concatenate(
            [lp_idx[None, :] < prefix_len[:, None],
             jnp.arange(S)[None, :] < jnp.asarray(true_len)[:, None]],
            axis=1,
        )
        o = attn_core(
            q, k_all, v_all,
            causal=cfg.causal,
            q_positions=pos,
            k_positions=k_positions,
            k_valid=k_valid,
            q_chunk=default_q_chunk(S),
            scale=scale,
        )
    else:
        o = attn_core(
            q, k, v,
            causal=cfg.causal,
            q_positions=pos,
            k_positions=pos,
            true_len=true_len,
            q_chunk=default_q_chunk(S),
            scale=scale,
        )
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return out, cache


def mla_decode(p, x, cfg: ModelConfig, cache, pos, *, block_tables=None,
               cache_scales=None):
    """Matmul-absorbed MLA decode over the compressed cache (TPU-native path).

    Mathematically identical to expanding K/V (unit-tested); per-step cost is
    O(S * kv_lora) per head instead of O(S * (nope+v)) plus no expanded cache.
    Cache is read-only; returns delta {ckv, k_rope: [B, r]} (see gqa_decode).
    ``block_tables`` gathers the compressed cache through page tables (paged
    layout {ckv, k_rope: [P, ps, r]}), same contract as gqa_decode.
    ``cache_scales`` {ckv, k_rope: [P] f32} dequantizes int8 pools in the
    gather (per-page absmax scales, see gather_pages_dequant).
    """
    a = cfg.mla
    B = x.shape[0]
    pos_b, scalar = _norm_pos(pos, B)
    cos, sin = rope_cos_sin(pos_b, a.qk_rope_head_dim, cfg.rope_theta)  # [B, d/2]

    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"])
    cq = _rms_head(cq, p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wq_b"])
    q_nope = q[..., : a.qk_nope_head_dim]
    q_rope = apply_rope_vec(q[..., a.qk_nope_head_dim :], cos, sin)

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv_new = _rms_head(ckv_full[..., : a.kv_lora_rank], p["kv_ln"], cfg.norm_eps)
    krope_new = apply_rope_vec(ckv_full[..., a.kv_lora_rank :][:, :, None, :], cos, sin)[:, :, 0, :]

    if cache_scales is not None:
        # cast to the fresh compressed-KV dtype (what the fp32 cache stores)
        ckv = gather_pages_dequant(
            cache["ckv"], cache_scales["ckv"], block_tables
        ).astype(ckv_new.dtype)
        krope = gather_pages_dequant(
            cache["k_rope"], cache_scales["k_rope"], block_tables
        ).astype(krope_new.dtype)
    else:
        ckv = cache["ckv"] if block_tables is None else gather_pages(cache["ckv"], block_tables)
        krope = (
            cache["k_rope"] if block_tables is None else gather_pages(cache["k_rope"], block_tables)
        )
    ckv = constrain(ckv, ("batch", "kv_seq", "kv_lora"))
    krope = constrain(krope, ("batch", "kv_seq", None))
    wk_b = p["wkv_b"][..., : a.qk_nope_head_dim]  # [r, H, nope]
    wv_b = p["wkv_b"][..., a.qk_nope_head_dim :]  # [r, H, v]
    q_eff = jnp.einsum("bqhn,rhn->bqhr", q_nope, wk_b)
    q_eff = constrain(q_eff, ("batch", None, "heads", "kv_lora"))
    scale = (a.qk_nope_head_dim + a.qk_rope_head_dim) ** -0.5
    s = jnp.einsum("bqhr,bsr->bhqs", q_eff, ckv, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bqhk,bsk->bhqs", q_rope, krope, preferred_element_type=jnp.float32)
    s = constrain(s, ("batch", "heads", None, "kv_seq"))
    s = s * scale
    L = ckv.shape[1]
    mask = jnp.arange(L)[None, None, None, :] < pos_b[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    # current-token term against its own compressed kv
    s_new = jnp.einsum("bqhr,br->bhq", q_eff, ckv_new[:, 0], preferred_element_type=jnp.float32)
    s_new = s_new + jnp.einsum("bqhk,bk->bhq", q_rope, krope_new[:, 0], preferred_element_type=jnp.float32)
    s_new = s_new * scale
    m = jnp.maximum(jnp.max(s, -1), s_new)  # [B,H,1]
    e = jnp.exp(s - m[..., None])
    e_new = jnp.exp(s_new - m)
    denom = jnp.sum(e, -1) + e_new
    ctx = jnp.einsum("bhqs,bsr->bqhr", e.astype(ckv.dtype), ckv)
    ctx = ctx + e_new[..., None].transpose(0, 2, 1, 3).astype(ctx.dtype) * ckv_new[:, 0][:, None, None, :]
    ctx = ctx / denom.transpose(0, 2, 1)[..., None].astype(ctx.dtype)
    o = jnp.einsum("bqhr,rhv->bqhv", ctx, wv_b)
    out = jnp.einsum("bqhv,hvd->bqd", o, p["wo"])
    return out, {"ckv": ckv_new[:, 0], "k_rope": krope_new[:, 0]}


def mla_cache_shape(cfg: ModelConfig, B: int, L: int):
    a = cfg.mla
    dt = pdt(cfg)
    return {
        "ckv": jax.ShapeDtypeStruct((B, L, a.kv_lora_rank), dt),
        "k_rope": jax.ShapeDtypeStruct((B, L, a.qk_rope_head_dim), dt),
    }


def mla_cache_axes():
    return {"ckv": ("batch", "seq", "kv_lora"), "k_rope": ("batch", "seq", None)}
