"""Basic layers: norms, embeddings, dense FFNs.

Every layer exposes ``init(key, cfg, ...) -> params`` and ``axes(cfg) -> same
structure of logical-axis tuples`` (consumed by sharding.partitioning).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def pdt(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _normal(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(cfg: ModelConfig, d: int | None = None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), pdt(cfg))}
    if cfg.norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), pdt(cfg))
    return p


def norm_axes(cfg: ModelConfig):
    a = {"scale": ("embed",)}
    if cfg.norm_type == "layernorm":
        a["bias"] = ("embed",)
    return a


def norm_apply(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        var = jnp.mean(jnp.square(xf), -1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "sq_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# ---------------------------------------------------------------------------
# Dense FFN (gated or plain)
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: int):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    scale_in = d ** -0.5
    scale_out = d_ff ** -0.5
    p = {
        "wi": _normal(ks[0], (d, d_ff), scale_in, pdt(cfg)),
        "wo": _normal(ks[1], (d_ff, d), scale_out, pdt(cfg)),
    }
    if cfg.gated_mlp:
        p["wg"] = _normal(ks[2], (d, d_ff), scale_in, pdt(cfg))
    return p


def mlp_axes(cfg: ModelConfig):
    a = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed")}
    if cfg.gated_mlp:
        a["wg"] = ("embed", "mlp")
    return a


def mlp_apply(p, x, cfg: ModelConfig):
    act = act_fn(cfg.activation)
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    if cfg.gated_mlp:
        g = jnp.einsum("...d,df->...f", x, p["wg"])
        h = act(g) * h
    else:
        h = act(h)
    return jnp.einsum("...f,fd->...d", h, p["wo"])


# ---------------------------------------------------------------------------
# Embeddings
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 3)
    p = {"tokens": _normal(ks[0], (cfg.vocab_size, cfg.d_model), 1.0, pdt(cfg))}
    if cfg.pos_emb == "learned":
        p["pos"] = _normal(ks[1], (cfg.max_seq_len, cfg.d_model), 0.02, pdt(cfg))
    if not cfg.tie_embeddings:
        p["lm_head"] = _normal(ks[2], (cfg.d_model, cfg.vocab_size), cfg.d_model ** -0.5, pdt(cfg))
    return p


def embed_axes(cfg: ModelConfig):
    a = {"tokens": ("vocab", "embed")}
    if cfg.pos_emb == "learned":
        a["pos"] = ("pos", "embed")
    if not cfg.tie_embeddings:
        a["lm_head"] = ("embed", "vocab")
    return a


def embed_apply(p, tokens, cfg: ModelConfig):
    return jnp.take(p["tokens"], tokens, axis=0)


def add_positions(p, x, cfg: ModelConfig, offset: int | jnp.ndarray = 0):
    if cfg.pos_emb == "learned":
        S = x.shape[1]
        pos = jax.lax.dynamic_slice_in_dim(p["pos"], offset, S, axis=0) if not isinstance(
            offset, int
        ) else p["pos"][offset : offset + S]
        x = x + pos[None]
    return x


def unembed_apply(p, x, cfg: ModelConfig):
    w = p["tokens"].T if cfg.tie_embeddings else p["lm_head"]
    return jnp.einsum("...d,dv->...v", x, w)
