"""Mamba-2 (SSD) block: prefill via the chunked SSD algorithm, O(1)-state decode.

The SSD core dispatches through ``repro.kernels.ops.ssd`` (Pallas kernel on
TPU, pure-jnp chunked reference elsewhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.partitioning import constrain
from .layers import _normal, pdt


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    gdn = s.n_groups * s.d_state
    conv_ch = di + 2 * gdn
    return s, d, di, nh, gdn, conv_ch


def mamba_init(key, cfg: ModelConfig):
    s, d, di, nh, gdn, conv_ch = _dims(cfg)
    ks = jax.random.split(key, 5)
    dt = jnp.exp(
        jax.random.uniform(ks[2], (nh,), jnp.float32) * (jnp.log(0.1) - jnp.log(0.001))
        + jnp.log(0.001)
    )
    return {
        "in_proj": _normal(ks[0], (d, 2 * di + 2 * gdn + nh), d ** -0.5, pdt(cfg)),
        "conv_w": _normal(ks[1], (s.d_conv, conv_ch), s.d_conv ** -0.5, pdt(cfg)),
        "conv_b": jnp.zeros((conv_ch,), pdt(cfg)),
        "A_log": jnp.log(
            jax.random.uniform(ks[3], (nh,), jnp.float32, minval=1.0, maxval=16.0)
        ),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),  # softplus^-1(dt)
        "norm": jnp.ones((di,), pdt(cfg)),
        "out_proj": _normal(ks[4], (di, d), di ** -0.5, pdt(cfg)),
    }


def mamba_axes(cfg: ModelConfig):
    return {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": ("conv", "conv_ch"),
        "conv_b": ("conv_ch",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "dt_bias": ("ssm_heads",),
        "norm": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }


def _split(zxbcdt, cfg: ModelConfig):
    s, d, di, nh, gdn, conv_ch = _dims(cfg)
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : di + conv_ch]
    dt = zxbcdt[..., di + conv_ch :]
    return z, xBC, dt


def _gated_norm(y, z, scale, eps):
    g = y * jax.nn.silu(z)
    gf = g.astype(jnp.float32)
    n = gf * jax.lax.rsqrt(jnp.mean(gf * gf, -1, keepdims=True) + eps)
    return (n * scale.astype(jnp.float32)).astype(y.dtype)


def mamba_prefill(p, x, cfg: ModelConfig, *, want_cache: bool, true_len=None,
                  initial_state=None):
    """x [B,L,D] -> (out [B,L,D], cache {conv:[B,dc-1,ch], ssm:[B,nh,hd,N]}).

    ``true_len`` [B]: for right-padded batches, padding tokens are neutralized
    in the state recurrence by zeroing their dt (decay exp(0*A)=1, update
    dt*x*B=0 — an exact identity step), so the final SSM state equals the
    unpadded one; the conv cache gathers the last ``d_conv-1`` *real*
    positions per row.  Outputs at padded positions are garbage and must be
    discarded by the caller (prefill gathers logits at true_len-1).

    ``initial_state`` {conv:[B,dc-1,ch], ssm:[B,nh,hd,N]} resumes the
    recurrence mid-prompt (chunked prefill): the conv window replaces the
    implicit left zero-padding and the SSD scan seeds from the carried
    state, so running a prompt in ``chunk_tokens``-sized slices — boundaries
    aligned to ``ssm.chunk_size`` — is bit-identical to one monolithic pass
    (same chunk-body ops in the same order, padded steps are exact
    identities).  The returned cache is the carry for the next chunk."""
    from ..kernels import ops as kops

    s, d, di, nh, gdn, conv_ch = _dims(cfg)
    B, L, _ = x.shape
    zxbcdt = jnp.einsum("bld,dk->blk", x, p["in_proj"])
    z, xBC, dt = _split(zxbcdt, cfg)

    # causal depthwise conv (left pad d_conv-1: zeros at the prompt start,
    # the previous chunk's last real positions when resuming mid-prompt)
    if initial_state is None:
        pad = jnp.zeros((B, s.d_conv - 1, conv_ch), xBC.dtype)
    else:
        pad = initial_state["conv"].astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    conv = sum(
        xp[:, i : i + L] * p["conv_w"][i][None, None] for i in range(s.d_conv)
    ) + p["conv_b"][None, None]
    conv = jax.nn.silu(conv)

    xh = conv[..., :di].reshape(B, L, nh, s.head_dim)
    Bm = conv[..., di : di + gdn].reshape(B, L, s.n_groups, s.d_state)
    Cm = conv[..., di + gdn :].reshape(B, L, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    if true_len is not None:
        valid = jnp.arange(L)[None, :] < jnp.asarray(true_len)[:, None]  # [B, L]
        dt = dt * valid[..., None]
    A = -jnp.exp(p["A_log"])

    y, final_state = kops.ssd(
        xh, dt, A, Bm, Cm, chunk=s.chunk_size,
        initial_state=None if initial_state is None else initial_state["ssm"],
    )
    y = y + p["D"][None, None, :, None].astype(y.dtype) * xh
    y = _gated_norm(y.reshape(B, L, di), z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"])

    cache = None
    if want_cache:
        if true_len is None:
            conv_cache = xBC[:, L - (s.d_conv - 1) :, :]
        else:
            # last d_conv-1 REAL positions per row; indices before the start
            # of this slice read the left context — the implicit zero padding
            # at the prompt start, the carried conv window mid-prompt (a
            # resumed chunk may be shorter than the window).
            tl = jnp.asarray(true_len)
            idx = tl[:, None] - (s.d_conv - 1) + jnp.arange(s.d_conv - 1)[None]  # [B, dc-1]
            got = jnp.take_along_axis(xBC, jnp.clip(idx, 0, L - 1)[..., None], axis=1)
            if initial_state is None:
                left = jnp.zeros_like(got)
            else:
                carry = initial_state["conv"].astype(xBC.dtype)  # [B, dc-1, ch]
                left = jnp.take_along_axis(
                    carry, jnp.clip(idx + (s.d_conv - 1), 0, s.d_conv - 2)[..., None], axis=1
                )
            conv_cache = jnp.where((idx >= 0)[..., None], got, left)
        cache = {
            "conv": conv_cache.astype(pdt(cfg)),
            "ssm": final_state.astype(jnp.float32),
        }
    return out, cache


def mamba_decode(p, x, cfg: ModelConfig, cache, pos):
    """Single-token step.  x [B,1,D]; cache {conv [B,dc-1,ch], ssm [B,nh,hd,N]}."""
    s, d, di, nh, gdn, conv_ch = _dims(cfg)
    B = x.shape[0]
    zxbcdt = jnp.einsum("bld,dk->blk", x, p["in_proj"])
    z, xBC, dt = _split(zxbcdt, cfg)
    xBC = xBC[:, 0]  # [B, ch]

    window = jnp.concatenate([cache["conv"], xBC[:, None]], axis=1)  # [B, dc, ch]
    conv = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), p["conv_w"].astype(jnp.float32))
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32)).astype(x.dtype)

    xh = conv[:, :di].reshape(B, nh, s.head_dim)
    Bm = conv[:, di : di + gdn].reshape(B, s.n_groups, s.d_state)
    Cm = conv[:, di + gdn :].reshape(B, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None])  # [B, nh]
    A = -jnp.exp(p["A_log"])  # [nh]

    hpg = nh // s.n_groups
    Bh = jnp.repeat(Bm, hpg, axis=1)  # [B, nh, N]
    Ch = jnp.repeat(Cm, hpg, axis=1)
    decay = jnp.exp(dtv * A[None])  # [B, nh]
    state = cache["ssm"]
    state = state * decay[..., None, None] + (
        (dtv[..., None] * xh.astype(jnp.float32))[..., None] * Bh[:, :, None, :]
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
    y = _gated_norm(y.reshape(B, 1, di).astype(x.dtype), z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("blk,kd->bld", y, p["out_proj"])
    new_cache = {"conv": window[:, 1:].astype(cache["conv"].dtype), "ssm": state}
    return out, new_cache


def mamba_cache_shape(cfg: ModelConfig, B: int):
    s, d, di, nh, gdn, conv_ch = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((B, s.d_conv - 1, conv_ch), pdt(cfg)),
        "ssm": jax.ShapeDtypeStruct((B, nh, s.head_dim, s.d_state), jnp.float32),
    }


def mamba_cache_axes():
    return {
        "conv": ("batch", None, "conv_ch"),
        "ssm": ("batch", "ssm_heads", "head_dim", "ssm_state"),
    }
