"""jit'd public wrappers for the kernels.

``impl`` selects between the Pallas TPU kernels and the pure-jnp references:
  - "auto": Pallas on TPU backends, reference elsewhere (CPU dry-run/tests)
  - "pallas": force Pallas (compiled)
  - "interpret": Pallas in interpret mode (CPU-executable kernel body)
  - "ref": pure-jnp oracle
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref

_IMPL = "auto"


def set_impl(impl: str):
    global _IMPL
    assert impl in ("auto", "pallas", "interpret", "ref")
    _IMPL = impl


def _use_pallas() -> bool:
    if _IMPL == "ref":
        return False
    if _IMPL in ("pallas", "interpret"):
        return True
    return jax.default_backend() == "tpu"


def _interpret() -> bool:
    return _IMPL == "interpret" or (_IMPL == "auto" and jax.default_backend() != "tpu")


# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("causal", "scale"))
def flash_attention(q, k, v, lengths=None, *, causal: bool = True, scale: Optional[float] = None):
    """``lengths`` [B] (optional): bucketed-prefill valid key prefix per request."""
    if _use_pallas():
        from .flash_attention import flash_attention_pallas

        return flash_attention_pallas(
            q, k, v, lengths, causal=causal, scale=scale, interpret=_interpret()
        )
    return _ref.flash_attention_ref(q, k, v, lengths, causal=causal, scale=scale)


@jax.jit
def decode_attention(q, k_cache, v_cache, lengths):
    if _use_pallas():
        from .decode_attention import decode_attention_pallas

        return decode_attention_pallas(q, k_cache, v_cache, lengths, interpret=_interpret())
    return _ref.decode_attention_ref(q, k_cache, v_cache, lengths)


@jax.jit
def decode_attention_paged(q, k_pool, v_pool, block_tables, lengths):
    """Paged decode attention: K/V pages gathered via per-request block tables.

    q [B,H,d]; k_pool/v_pool [P, page_size, KV, d]; block_tables [B, n_pg]
    int32; lengths [B] (valid prefix, same masking as the slab kernel)."""
    if _use_pallas():
        from .decode_attention import decode_attention_paged_pallas

        return decode_attention_paged_pallas(
            q, k_pool, v_pool, block_tables, lengths, interpret=_interpret()
        )
    return _ref.decode_attention_paged_ref(q, k_pool, v_pool, block_tables, lengths)


def paged_decode_via_pallas() -> bool:
    """Whether the serving decode step should route paged GQA attention
    through the block-table Pallas kernel (the default on TPU; forceable with
    set_impl for CPU interpret-mode tests).  Decided at trace time — the
    XLA gather path stays the bit-identity reference everywhere else."""
    return _use_pallas()


def decode_attention_paged_partials(q, k_pool, v_pool, block_tables, lengths):
    """Unnormalized paged decode partials (acc, m, l) for the in-step merge
    with the fresh token's rank-1 term.  Dispatched inside model code
    (already under jit); Pallas-only — callers must gate on
    ``paged_decode_via_pallas()``."""
    from .decode_attention import decode_attention_paged_pallas

    return decode_attention_paged_pallas(
        q, k_pool, v_pool, block_tables, lengths,
        interpret=_interpret(), return_partials=True,
    )


@jax.jit
def decode_attention_paged_quant(
    q, k_pool, v_pool, k_scales, v_scales, block_tables, lengths
):
    """Int8 paged decode attention: int8 page pools dequantized against
    per-page scales (``[P] f32``, scalar-prefetched on the Pallas path,
    broadcast-multiplied on the reference path).

    q [B,H,d]; k_pool/v_pool [P, page_size, KV, d] int8; block_tables
    [B, n_pg] int32; lengths [B]."""
    if _use_pallas():
        from .decode_attention import decode_attention_paged_pallas_quant

        return decode_attention_paged_pallas_quant(
            q, k_pool, v_pool, k_scales, v_scales, block_tables, lengths,
            interpret=_interpret(),
        )
    return _ref.decode_attention_paged_quant_ref(
        q, k_pool, v_pool, k_scales, v_scales, block_tables, lengths
    )


def decode_attention_paged_partials_quant(
    q, k_pool, v_pool, k_scales, v_scales, block_tables, lengths
):
    """Int8 twin of ``decode_attention_paged_partials``: unnormalized
    (acc, m, l) over int8 pages dequantized in-kernel via scalar-prefetched
    per-page scales.  Pallas-only — callers must gate on
    ``paged_decode_via_pallas()``."""
    from .decode_attention import decode_attention_paged_pallas_quant

    return decode_attention_paged_pallas_quant(
        q, k_pool, v_pool, k_scales, v_scales, block_tables, lengths,
        interpret=_interpret(), return_partials=True,
    )


def ssd(x, dt, A, B, C, *, chunk: int = 128, initial_state=None):
    """Dispatched inside model code (already under jit)."""
    if _use_pallas() and _IMPL in ("pallas", "interpret"):
        from .ssd_scan import ssd_pallas

        return ssd_pallas(x, dt, A, B, C, chunk=chunk, initial_state=initial_state,
                          interpret=_interpret())
    if _use_pallas():  # auto + TPU
        from .ssd_scan import ssd_pallas

        return ssd_pallas(x, dt, A, B, C, chunk=chunk, initial_state=initial_state,
                          interpret=False)
    return _ref.ssd_ref(x, dt, A, B, C, chunk=chunk, initial_state=initial_state)
