"""Prefill flash attention as a Pallas TPU kernel.

This is the repo's instantiation of the paper's "Prefill Chip" argument at the
kernel level: prefill is compute-bound, so the kernel is built around *large*
MXU-aligned blocks (default 512x512 q/k tiles) that keep the systolic array
busy and amortize VMEM traffic, exactly the trade the paper makes by doubling
the systolic array (32x32) on the Prefill Chip.

Layout: q/k/v are passed [B, H, S, d] (head-major) so every BlockSpec tile is
contiguous in (seq, head_dim).  GQA is handled in the index map (query head h
reads kv head h // G).  Online softmax state (m, l, acc) lives in VMEM scratch
and is carried across the sequential k-block grid dimension; causal blocks
entirely above the diagonal are skipped with ``pl.when``.

Bucketed serving support: ``lengths`` [B] (scalar-prefetch SMEM, like the
decode kernel) is the per-request true prompt length for right-padded
batches.  Keys at positions >= lengths[b] are masked to -inf, and k blocks
entirely past the valid prefix are skipped — so a short prompt in a large
bucket pays for its own length, not the bucket's.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512
NEG_INF = -1e30


def _fa_kernel(
    *refs,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
    nk: int,
    seq_off: int,
    has_lengths: bool,
):
    if has_lengths:
        lengths_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    else:
        lengths_ref = None
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = refs
    # q/k/v blocks [1, 1, bq|bk, d]; scratch [bq, 1], [bq, 1], [bq, d] f32 VMEM
    b = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal: skip k blocks entirely above the diagonal.
    q_last = qi * block_q + (block_q - 1) + seq_off
    run = (ki * block_k <= q_last) if causal else (ki >= 0)
    if lengths_ref is not None:
        # skip k blocks entirely past the valid prefix (bucket padding)
        run = jnp.logical_and(run, ki * block_k < lengths_ref[b])

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)  # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        k_pos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1
        )
        if causal:
            q_pos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0
            ) + seq_off
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        if lengths_ref is not None:
            s = jnp.where(k_pos < lengths_ref[b], s, NEG_INF)

        m_prev = m_scr[...]  # [bq, 1]
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)  # [bq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)  # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)  # [bq, 1]
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> 0 output
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention_pallas(
    q, k, v,
    lengths=None,
    *,
    causal: bool = True,
    scale: Optional[float] = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
):
    """q [B,Sq,H,d]; k,v [B,Skv,KV,d] -> [B,Sq,H,d] (same semantics as ref).

    ``lengths`` [B] int32 (optional): per-request valid key prefix for
    right-padded (bucketed) prefill batches; keys at positions >=
    lengths[b] are masked and their k blocks skipped entirely.  Query rows
    at padded positions produce garbage by contract (the caller gathers
    logits at true_len-1)."""
    B, Sq, H, d = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    scale = scale if scale is not None else d ** -0.5

    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    # Pad sequence lengths up to block multiples (k-padding is masked out by
    # the causal/validity mask below via NEG_INF on out-of-range positions).
    pad_q = (-Sq) % bq
    pad_k = (-Skv) % bk

    qt = jnp.moveaxis(q, 2, 1)  # [B, H, Sq, d]
    kt = jnp.moveaxis(k, 2, 1)  # [B, KV, Skv, d]
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # pad keys with a value that the causal mask excludes: use position
        # masking via seq_off (padding sits at positions >= Skv which only
        # unmasked when q_pos >= k_pos; padded q rows are sliced away).
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    Sq_p, Skv_p = Sq + pad_q, Skv + pad_k
    if not causal and pad_k and lengths is None:
        raise NotImplementedError("non-causal with padded kv needs lengths")

    nq = Sq_p // bq
    nk = Skv_p // bk
    seq_off = Skv - Sq  # query i attends to keys <= i + seq_off

    kernel = functools.partial(
        _fa_kernel,
        scale=scale,
        causal=causal,
        block_q=bq,
        block_k=bk,
        nk=nk,
        seq_off=seq_off,
        has_lengths=lengths is not None,
    )
    out_shape = jax.ShapeDtypeStruct((B, H, Sq_p, d), q.dtype)
    scratch = [
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, 1), jnp.float32),
        pltpu.VMEM((bq, d), jnp.float32),
    ]
    if lengths is None:
        out = pl.pallas_call(
            kernel,
            grid=(B, H, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), lambda b, h, qi, ki: (b, h, qi, 0)),
                pl.BlockSpec((1, 1, bk, d), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
                pl.BlockSpec((1, 1, bk, d), lambda b, h, qi, ki, G=G: (b, h // G, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
        )(qt, kt, vt)
    else:
        # lengths ride in scalar-prefetch SMEM (index maps see the scalar
        # refs as trailing args, same pattern as the decode kernel).
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, H, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, bq, d), lambda b, h, qi, ki, *_: (b, h, qi, 0)),
                pl.BlockSpec((1, 1, bk, d), lambda b, h, qi, ki, *_, G=G: (b, h // G, ki, 0)),
                pl.BlockSpec((1, 1, bk, d), lambda b, h, qi, ki, *_, G=G: (b, h // G, ki, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, d), lambda b, h, qi, ki, *_: (b, h, qi, 0)),
            scratch_shapes=scratch,
        )
        out = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=out_shape,
            interpret=interpret,
        )(jnp.asarray(lengths, jnp.int32), qt, kt, vt)
    if pad_q:
        out = out[:, :, :Sq]
    return jnp.moveaxis(out, 1, 2)  # [B, Sq, H, d]
