"""Mamba-2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

The SSD trick: split the sequence into chunks of Q tokens; within a chunk the
recurrence is computed as a *quadratic* (masked) matmul that maps onto the MXU,
while the O(L) part is a per-chunk rank-1 state update carried across chunks.
The per-(batch, head) running state [head_dim, d_state] lives in VMEM scratch
and is carried across the sequential chunk grid dimension — the Pallas
equivalent of the paper's observation that long-context decode wants a small,
bandwidth-friendly working set rather than a big systolic array.

Layouts (wrapper transposes): x [b, h, L, p]; dt [b, h, L]; B/C [b, g, L, n];
A [h] rides in scalar-prefetch SMEM.  y is [b, h, L, p]; final state
[b, h, p, n] is written by the last chunk.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    A_ref,  # [h] f32 (scalar prefetch, SMEM)
    x_ref,  # [1, 1, Q, p]
    dt_ref,  # [1, 1, Q]
    b_ref,  # [1, 1, Q, n]
    c_ref,  # [1, 1, Q, n]
    s0_ref,  # [1, 1, p, n] initial state
    y_ref,  # [1, 1, Q, p]
    sf_ref,  # [1, 1, p, n] final state
    state_scr,  # [p, n] f32
    *,
    chunk: int,
    nc: int,
):
    h = pl.program_id(1)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    xq = x_ref[0, 0].astype(jnp.float32)  # [Q, p]
    dtq = dt_ref[0, 0].astype(jnp.float32)  # [Q]
    Bq = b_ref[0, 0].astype(jnp.float32)  # [Q, n]
    Cq = c_ref[0, 0].astype(jnp.float32)  # [Q, n]
    A = A_ref[h]  # scalar (negative decay rate)

    dA = dtq * A  # [Q]
    cs = jnp.cumsum(dA)  # [Q]
    # ---- intra-chunk quadratic part (MXU) ----
    diff = cs[:, None] - cs[None, :]  # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(jj <= ii, jnp.exp(diff), 0.0)  # causal decay mask
    CB = jax.lax.dot_general(
        Cq, Bq, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, Q]
    M = CB * Lmat * dtq[None, :]
    Yd = jax.lax.dot_general(
        M, xq, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # [Q, p]
    # ---- inbound state contribution ----
    state = state_scr[...]  # [p, n]
    Yoff = jax.lax.dot_general(
        Cq, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cs)[:, None]  # [Q, p]
    y_ref[0, 0] = (Yd + Yoff).astype(y_ref.dtype)
    # ---- state update (rank-Q correction, one matmul) ----
    decay = jnp.exp(cs[chunk - 1] - cs) * dtq  # [Q]
    S_new = jax.lax.dot_general(
        xq * decay[:, None], Bq, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # [p, n]
    state_scr[...] = state * jnp.exp(cs[chunk - 1]) + S_new

    @pl.when(ci == nc - 1)
    def _final():
        sf_ref[0, 0] = state_scr[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_pallas(
    x, dt, A, B, C,
    *,
    chunk: int = 128,
    initial_state=None,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Same contract as ``ref.ssd_ref``.

    x [b,L,h,p]; dt [b,L,h]; A [h]; B/C [b,L,g,n] -> (y [b,L,h,p], state [b,h,p,n]).
    """
    b, L, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    r = h // g
    Q = min(chunk, L)
    pad = (-L) % Q
    Lp = L + pad

    xt = jnp.moveaxis(x, 1, 2)  # [b, h, L, p]
    dtt = jnp.moveaxis(dt, 1, 2)  # [b, h, L]
    Bt = jnp.moveaxis(B, 1, 2)  # [b, g, L, n]
    Ct = jnp.moveaxis(C, 1, 2)
    if pad:
        xt = jnp.pad(xt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        dtt = jnp.pad(dtt, ((0, 0), (0, 0), (0, pad)))  # dt=0 -> no-op steps
        Bt = jnp.pad(Bt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = Lp // Q
    s0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    kernel = functools.partial(_ssd_kernel, chunk=Q, nc=nc)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, Q, p), lambda bi, hi, ci, *_: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, Q), lambda bi, hi, ci, *_: (bi, hi, ci)),
            pl.BlockSpec((1, 1, Q, n), lambda bi, hi, ci, *_, r=r: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1, Q, n), lambda bi, hi, ci, *_, r=r: (bi, hi // r, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci, *_: (bi, hi, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, p), lambda bi, hi, ci, *_: (bi, hi, ci, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci, *_: (bi, hi, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
    )
    y, sf = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, Lp, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(A.astype(jnp.float32), xt, dtt, Bt, Ct, s0)
    if pad:
        y = y[:, :, :L]
    return jnp.moveaxis(y, 1, 2), sf  # [b, L, h, p], [b, h, p, n]
