"""Pure-jnp oracles for every Pallas kernel (the correctness references).

These are also the XLA-path implementations used on CPU and in the dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Flash attention oracle (exact softmax attention)
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, lengths=None, *, causal: bool = True,
                        scale: Optional[float] = None):
    """q [B,Sq,H,d]; k,v [B,Skv,KV,d] -> [B,Sq,H,d].  GQA by head grouping.

    ``lengths`` [B] (optional): per-request valid key prefix for right-padded
    bucketed prefill batches (keys >= lengths[b] are masked)."""
    B, Sq, H, d = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else d ** -0.5
    qg = q.reshape(B, Sq, KV, G, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32) * scale
    Skv = k.shape[1]
    if causal:
        mask = jnp.arange(Skv)[None, :] <= (jnp.arange(Sq)[:, None] + (Skv - Sq))
        s = jnp.where(mask[None, None, None], s, -1e30)
    if lengths is not None:
        valid = jnp.arange(Skv)[None, :] < jnp.asarray(lengths)[:, None]  # [B, Skv]
        s = jnp.where(valid[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, Sq, H, d)


# ---------------------------------------------------------------------------
# Decode attention oracle (single query over a KV cache)
# ---------------------------------------------------------------------------


def decode_attention_ref(q, k_cache, v_cache, lengths):
    """q [B,H,d]; k_cache/v_cache [B,L,KV,d]; lengths [B] (valid entries).

    Returns [B,H,d]."""
    B, H, d = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    L = k_cache.shape[1]
    qg = q.reshape(B, KV, G, d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32)
    s = s * (d ** -0.5)
    mask = jnp.arange(L)[None, :] < lengths[:, None]  # [B, L]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(B, H, d)


def decode_attention_paged_ref(q, k_pool, v_pool, block_tables, lengths):
    """Paged decode oracle: gather pages through the block table, then the
    contiguous decode reference.

    q [B,H,d]; k_pool/v_pool [P, ps, KV, d]; block_tables [B, n_pg] int32;
    lengths [B].  Table entries past a request's length may point anywhere
    valid (typically the trash page) — those positions are masked."""
    B = q.shape[0]
    n_pg = block_tables.shape[1]
    ps = k_pool.shape[1]

    def gather(pool):
        rows = pool[block_tables]  # [B, n_pg, ps, KV, d]
        return rows.reshape((B, n_pg * ps) + rows.shape[3:])

    return decode_attention_ref(q, gather(k_pool), gather(v_pool), lengths)


def decode_attention_paged_quant_ref(
    q, k_pool, v_pool, k_scales, v_scales, block_tables, lengths
):
    """Int8 paged decode oracle: dequantize the whole pools against their
    per-page scales, then the fp32 paged reference — the dequantize-then-
    gather ground truth the in-kernel dequant path is validated against.

    k_pool/v_pool [P, ps, KV, d] int8; k_scales/v_scales [P] f32."""
    kf = k_pool.astype(jnp.float32) * k_scales.astype(jnp.float32)[:, None, None, None]
    vf = v_pool.astype(jnp.float32) * v_scales.astype(jnp.float32)[:, None, None, None]
    return decode_attention_paged_ref(q, kf, vf, block_tables, lengths)


# ---------------------------------------------------------------------------
# Mamba-2 SSD oracle (chunked scan, f32 internals, memory-bounded)
# ---------------------------------------------------------------------------


def ssd_ref(
    x,
    dt,
    A,
    B,
    C,
    *,
    chunk: int = 128,
    initial_state=None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD (state-space duality) forward.

    x  [b, L, h, p]   head inputs
    dt [b, L, h]      softplus'd timesteps (float32)
    A  [h]            negative decay rates (float32)
    B  [b, L, g, n]   input projections (g groups, h % g == 0)
    C  [b, L, g, n]   output projections

    Returns (y [b, L, h, p], final_state [b, h, p, n]).
    """
    b, L, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    r = h // g
    Q = min(chunk, L)
    pad = (-L) % Q
    Lp = L + pad
    if pad:
        zf = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, dt, B, C = zf(x), zf(dt), zf(B), zf(C)
    nc = Lp // Q

    xf = x.astype(jnp.float32).reshape(b, nc, Q, g, r, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, Q, g, r)
    Bf = B.astype(jnp.float32).reshape(b, nc, Q, g, n)
    Cf = C.astype(jnp.float32).reshape(b, nc, Q, g, n)
    Af = A.astype(jnp.float32).reshape(g, r)

    tril = jnp.tril(jnp.ones((Q, Q), bool))
    state0 = (
        jnp.zeros((b, g, r, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32).reshape(b, g, r, p, n)
    )

    def body(state, inp):
        xq, dtq, Bq, Cq = inp  # [b,Q,g,r,p], [b,Q,g,r], [b,Q,g,n], [b,Q,g,n]
        dA = dtq * Af[None, None]  # [b,Q,g,r]
        cs = jnp.cumsum(dA, axis=1)
        # intra-chunk (quadratic within chunk)
        diff = cs[:, :, None] - cs[:, None, :]  # [b,i,j,g,r]
        Lmat = jnp.where(tril[None, :, :, None, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bign,bjgn->bijg", Cq, Bq)
        M = CB[..., None] * Lmat * dtq[:, None]  # [b,i,j,g,r]
        Yd = jnp.einsum("bijgr,bjgrp->bigrp", M, xq)
        # inbound-state contribution
        Yoff = jnp.einsum("bign,bgrpn,bigr->bigrp", Cq, state, jnp.exp(cs))
        # state update
        decay_states = jnp.exp(cs[:, -1:] - cs)  # [b,Q,g,r]
        S_new = jnp.einsum("bjgn,bjgr,bjgrp->bgrpn", Bq, decay_states * dtq, xq)
        state = state * jnp.exp(cs[:, -1])[..., None, None] + S_new
        return state, Yd + Yoff

    inputs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), (xf, dtf, Bf, Cf))
    final, ys = jax.lax.scan(body, state0, inputs)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, Lp, h, p)[:, :L]
    return y.astype(x.dtype), final.reshape(b, h, p, n)


def ssd_sequential_ref(x, dt, A, B, C, *, initial_state=None):
    """O(L)-step sequential oracle (ground truth for the chunked versions)."""
    b, L, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    r = h // g
    state = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    Bh = jnp.repeat(B.astype(jnp.float32), r, axis=2)  # [b,L,h,n]
    Ch = jnp.repeat(C.astype(jnp.float32), r, axis=2)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def body(state, inp):
        xt, dtt, Bt, Ct = inp  # [b,h,p], [b,h], [b,h,n], [b,h,n]
        decay = jnp.exp(dtt * Af[None])
        state = state * decay[..., None, None] + (
            (dtt[..., None] * xt.astype(jnp.float32))[..., None] * Bt[:, :, None, :]
        )
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    inputs = jax.tree.map(
        lambda a: jnp.moveaxis(a, 1, 0), (x.astype(jnp.float32), dtf, Bh, Ch)
    )
    final, ys = jax.lax.scan(body, state, inputs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), final
