"""Decode attention (one query token over a long KV cache) as a Pallas kernel.

This is the "Decode Chip" counterpart of the prefill kernel: decode attention
is memory-bandwidth-bound (every KV byte is read once, arithmetic intensity
~O(1)), so the kernel is a *split-K streaming* design — small compute tiles,
KV read exactly once HBM->VMEM, online-softmax partials merged across the
sequential split dimension.  The MXU tiles are deliberately small (the G x bk
score matmul), mirroring the paper's 16x16-systolic-array Decode Chip: a
bigger tile would not go faster, the kernel is bandwidth-limited.

Layouts: q [B, KV, G, d] (grouped heads contiguous), caches [B, KV, L, d].
``lengths`` [B] rides in scalar-prefetch SMEM and masks the valid cache prefix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 1024
NEG_INF = -1e30


def _dec_kernel(
    lengths_ref,  # [B] int32 (scalar prefetch, SMEM)
    q_ref,  # [1, 1, G, d]
    k_ref,  # [1, 1, bs, d]
    v_ref,  # [1, 1, bs, d]
    o_ref,  # [1, 1, G, d]
    m_scr, l_scr, acc_scr,  # [G, 1], [G, 1], [G, d] f32
    *,
    scale: float,
    block_s: int,
    ns: int,
):
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    # Skip splits entirely past the valid prefix (bandwidth saver: the DMA for
    # a skipped block is still issued by the pipeline, but no FLOPs happen —
    # on real HW one would bound the grid by max length instead).
    @pl.when(si * block_s < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [bs, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [G, bs]
        k_pos = si * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "max_length", "interpret"))
def decode_attention_pallas(
    q, k_cache, v_cache, lengths,
    *,
    block_s: int = DEFAULT_BLOCK_S,
    max_length: int = None,
    interpret: bool = False,
):
    """q [B,H,d]; k_cache/v_cache [B,L,KV,d]; lengths [B] -> [B,H,d].

    ``max_length``: static host-known upper bound on ``lengths``.  The split
    grid (and thus the per-block DMA pipeline) is capped at
    ceil(max_length / block_s) splits instead of covering the whole cache
    allocation — serving engines know the longest admitted sequence, so the
    bandwidth-bound kernel never streams cache rows no request can reach.
    """
    B, H, d = q.shape
    L, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    scale = d ** -0.5

    # fastpath: allow[FP001] int() of a static Python scalar at trace time, not a traced value
    L_eff = L if max_length is None else max(1, min(L, int(max_length)))
    bs = min(block_s, L_eff)
    ns = -(-L_eff // bs)  # bounded split count; blocks past it are never read
    # pad only up to the grid's reach — when max_length bounds ns below the
    # cache allocation, the tail of the cache is never touched, not copied
    pad_s = max(0, ns * bs - L)
    qt = q.reshape(B, KV, G, d)
    kt = jnp.moveaxis(k_cache, 2, 1)  # [B, KV, L, d]
    vt = jnp.moveaxis(v_cache, 2, 1)
    if pad_s:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_s), (0, 0)))

    kernel = functools.partial(_dec_kernel, scale=scale, block_s=bs, ns=ns)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, ns),
        in_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, kv, si, *_: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b, kv, si, *_: (b, kv, si, 0)),
            pl.BlockSpec((1, 1, bs, d), lambda b, kv, si, *_: (b, kv, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, kv, si, *_: (b, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qt, kt, vt)
    return out.reshape(B, H, d)


# ---------------------------------------------------------------------------
# Paged decode attention: K/V pages gathered through a block table
# ---------------------------------------------------------------------------


def _paged_dec_kernel(
    lengths_ref,  # [B] int32 (scalar prefetch, SMEM)
    bt_ref,  # [B, n_pg] int32 (scalar prefetch, SMEM)
    q_ref,  # [1, 1, G, d]
    k_ref,  # [1, 1, ps, d]  -- the page bt_ref[b, si], DMA'd via the index map
    v_ref,  # [1, 1, ps, d]
    o_ref,  # [1, 1, G, d]
    m_scr, l_scr, acc_scr,  # [G, 1], [G, 1], [G, d] f32
    *,
    scale: float,
    page_size: int,
    ns: int,
):
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]

    @pl.when(si * page_size < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [ps, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [G, ps]
        k_pos = si * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def _paged_dec_partials_kernel(
    lengths_ref,  # [B] int32 (scalar prefetch, SMEM)
    bt_ref,  # [B, n_pg] int32 (scalar prefetch, SMEM)
    q_ref,  # [1, 1, G, d]
    k_ref,  # [1, 1, ps, d]
    v_ref,  # [1, 1, ps, d]
    acc_ref,  # [1, 1, G, d] f32 — UNNORMALIZED numerator
    m_ref,  # [1, 1, G, 1] f32 — running max
    l_ref,  # [1, 1, G, 1] f32 — softmax denominator over the cached prefix
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    page_size: int,
    ns: int,
):
    """The paged split-K body, finalized to online-softmax PARTIALS instead of
    a normalized output: the serving decode step merges the fresh token's
    rank-1 contribution outside the kernel (the cache is read-only there), so
    it needs (acc, m, l) rather than acc / l."""
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]

    @pl.when(si * page_size < length)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)  # [G, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [ps, d]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [G, ps]
        k_pos = si * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        acc_ref[0, 0] = acc_scr[...]
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


@functools.partial(
    jax.jit, static_argnames=("max_length", "interpret", "return_partials")
)
def decode_attention_paged_pallas(
    q, k_pool, v_pool, block_tables, lengths,
    *,
    max_length: int = None,
    interpret: bool = False,
    return_partials: bool = False,
):
    """q [B,H,d]; k_pool/v_pool [P, ps, KV, d]; block_tables [B, n_pg] int32;
    lengths [B] -> [B,H,d].

    The paged form of the split-K streaming kernel: one grid split per KV
    *page*, with the physical page id gathered from the scalar-prefetched
    block table inside the BlockSpec index map — so the DMA pipeline streams
    exactly the pages the block table names, no gather materialization.
    ``lengths`` masking is unchanged from the slab kernel; table entries past
    a request's length may point anywhere valid (e.g. the trash page), their
    scores are masked to -inf before the online-softmax merge.

    Prefix sharing rides on the same contract: two rows may alias the SAME
    physical page (refcounted in the serving allocator) and a row's table may
    be REMAPPED between calls by copy-on-write — the kernel re-reads the
    scalar-prefetched table every call and carries no per-row state, so both
    are transparent here (guarded by
    tests/test_prefix_sharing.py::test_paged_kernel_honors_shared_tables).

    ``max_length``: static upper bound on ``lengths`` — caps the split grid
    at ceil(max_length / page_size) pages, exactly like the slab kernel's
    split bound.

    ``return_partials=True`` returns the UNNORMALIZED online-softmax partials
    over the cached prefix — ``(acc [B,H,d], m [B,H], l [B,H])``, all f32 —
    instead of the normalized output.  The serving decode step uses this: the
    fresh token's K/V contribute through a separate rank-1 term merged
    OUTSIDE the kernel (the cache is consumed read-only per step), so the
    kernel must not normalize.  A row whose ``lengths`` entry is 0 returns
    (0, -1e30, 0): its exp-weight underflows to exactly 0 in the merge.
    """
    B, H, d = q.shape
    P, ps, KV = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    n_pg = block_tables.shape[1]
    G = H // KV
    scale = d ** -0.5

    # fastpath: allow[FP001] int() of a static Python scalar at trace time, not a traced value
    ns = n_pg if max_length is None else max(1, min(n_pg, -(-int(max_length) // ps)))
    qt = q.reshape(B, KV, G, d)
    kt = jnp.moveaxis(k_pool, 2, 1)  # [P, KV, ps, d]
    vt = jnp.moveaxis(v_pool, 2, 1)

    in_specs = [
        pl.BlockSpec((1, 1, G, d), lambda b, kv, si, *_: (b, kv, 0, 0)),
        pl.BlockSpec(
            (1, 1, ps, d), lambda b, kv, si, lens, bt: (bt[b, si], kv, 0, 0)
        ),
        pl.BlockSpec(
            (1, 1, ps, d), lambda b, kv, si, lens, bt: (bt[b, si], kv, 0, 0)
        ),
    ]
    scratch_shapes = [
        pltpu.VMEM((G, 1), jnp.float32),
        pltpu.VMEM((G, 1), jnp.float32),
        pltpu.VMEM((G, d), jnp.float32),
    ]
    if return_partials:
        kernel = functools.partial(
            _paged_dec_partials_kernel, scale=scale, page_size=ps, ns=ns
        )
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B, KV, ns),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, 1, G, d), lambda b, kv, si, *_: (b, kv, 0, 0)),
                pl.BlockSpec((1, 1, G, 1), lambda b, kv, si, *_: (b, kv, 0, 0)),
                pl.BlockSpec((1, 1, G, 1), lambda b, kv, si, *_: (b, kv, 0, 0)),
            ],
            scratch_shapes=scratch_shapes,
        )
        acc, m, l = pl.pallas_call(
            kernel,
            grid_spec=grid_spec,
            out_shape=[
                jax.ShapeDtypeStruct((B, KV, G, d), jnp.float32),
                jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
                jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
            ],
            interpret=interpret,
        )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32), qt, kt, vt)
        return (
            acc.reshape(B, H, d), m.reshape(B, H), l.reshape(B, H)
        )

    kernel = functools.partial(_paged_dec_kernel, scale=scale, page_size=ps, ns=ns)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, ns),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, d), lambda b, kv, si, *_: (b, kv, 0, 0)),
        scratch_shapes=scratch_shapes,
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, d), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), block_tables.astype(jnp.int32), qt, kt, vt)
    return out.reshape(B, H, d)


# ---------------------------------------------------------------------------
# Quantized paged decode attention: int8 pages + scalar-prefetched scales
# ---------------------------------------------------------------------------


def _paged_dec_quant_partials_kernel(
    lengths_ref,  # [B] int32 (scalar prefetch, SMEM)
    bt_ref,  # [B, n_pg] int32 (scalar prefetch, SMEM)
    ks_ref,  # [P+1] f32 per-page K scales (scalar prefetch, SMEM)
    vs_ref,  # [P+1] f32 per-page V scales (scalar prefetch, SMEM)
    q_ref,  # [1, 1, G, d]
    k_ref,  # [1, 1, ps, d] int8 — the page bt_ref[b, si], DMA'd via the index map
    v_ref,  # [1, 1, ps, d] int8
    acc_ref,  # [1, 1, G, d] f32 — UNNORMALIZED numerator
    m_ref,  # [1, 1, G, 1] f32
    l_ref,  # [1, 1, G, 1] f32
    m_scr, l_scr, acc_scr,
    *,
    scale: float,
    page_size: int,
    ns: int,
):
    """Int8 twin of ``_paged_dec_partials_kernel``: the K/V pages stream as
    int8 payloads (quarter the HBM traffic of fp32 — the whole point on a
    bandwidth-bound Decode Chip) and dequantize in-register against the
    per-PAGE scales riding in scalar-prefetch SMEM, looked up through the
    same block table that steered the page DMA.  Past the dequant multiply
    the online-softmax body is identical to the fp32 kernel."""
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]

    @pl.when(si * page_size < length)
    def _body():
        phys = bt_ref[b, si]
        q = q_ref[0, 0].astype(jnp.float32)  # [G, d]
        k = k_ref[0, 0].astype(jnp.float32) * ks_ref[phys]  # [ps, d] dequant
        v = v_ref[0, 0].astype(jnp.float32) * vs_ref[phys]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [G, ps]
        k_pos = si * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
        s = jnp.where(k_pos < length, s, NEG_INF)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(si == ns - 1)
    def _finalize():
        acc_ref[0, 0] = acc_scr[...]
        m_ref[0, 0] = m_scr[...]
        l_ref[0, 0] = l_scr[...]


@functools.partial(
    jax.jit, static_argnames=("max_length", "interpret", "return_partials")
)
def decode_attention_paged_pallas_quant(
    q, k_pool, v_pool, k_scales, v_scales, block_tables, lengths,
    *,
    max_length: int = None,
    interpret: bool = False,
    return_partials: bool = False,
):
    """Int8 variant of ``decode_attention_paged_pallas``.

    q [B,H,d]; k_pool/v_pool [P, ps, KV, d] int8; k_scales/v_scales [P] f32
    (one symmetric-absmax scale per physical page, trash page included);
    block_tables [B, n_pg] int32; lengths [B].

    The per-page scales ride in scalar-prefetch SMEM next to the block table:
    the index map steers the int8 page DMA exactly as the fp32 kernel, and
    the body dequantizes in-register (``payload * scales[bt[b, si]]``) before
    the score matmul — bit-identical to gathering a dequantized fp32 pool
    through the same table (one multiply per element, then the same fp32
    online-softmax).  NOTE: on real TPU hardware int8 VMEM tiles want
    (32, 128) min granularity; the repo's page sizes target interpret-mode
    validation, production shapes would pad ``ps``/``d`` up accordingly.

    ``return_partials=True`` returns (acc [B,H,d], m [B,H], l [B,H]), all
    f32, exactly as the fp32 kernel; ``False`` normalizes outside the kernel
    (``acc / l`` with the l == 0 guard), matching the fp32 kernel's
    finalize."""
    B, H, d = q.shape
    P, ps, KV = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    n_pg = block_tables.shape[1]
    G = H // KV
    scale = d ** -0.5

    # fastpath: allow[FP001] int() of a static Python scalar at trace time, not a traced value
    ns = n_pg if max_length is None else max(1, min(n_pg, -(-int(max_length) // ps)))
    qt = q.reshape(B, KV, G, d)
    kt = jnp.moveaxis(k_pool, 2, 1)  # [P, KV, ps, d]
    vt = jnp.moveaxis(v_pool, 2, 1)

    in_specs = [
        pl.BlockSpec((1, 1, G, d), lambda b, kv, si, *_: (b, kv, 0, 0)),
        pl.BlockSpec(
            (1, 1, ps, d), lambda b, kv, si, lens, bt, ks, vs: (bt[b, si], kv, 0, 0)
        ),
        pl.BlockSpec(
            (1, 1, ps, d), lambda b, kv, si, lens, bt, ks, vs: (bt[b, si], kv, 0, 0)
        ),
    ]
    kernel = functools.partial(
        _paged_dec_quant_partials_kernel, scale=scale, page_size=ps, ns=ns
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(B, KV, ns),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, G, d), lambda b, kv, si, *_: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, kv, si, *_: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, kv, si, *_: (b, kv, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, d), jnp.float32),
        ],
    )
    acc, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, d), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, KV, G, 1), jnp.float32),
        ],
        interpret=interpret,
    )(
        lengths.astype(jnp.int32),
        block_tables.astype(jnp.int32),
        k_scales.astype(jnp.float32),
        v_scales.astype(jnp.float32),
        qt, kt, vt,
    )
    if return_partials:
        return acc.reshape(B, H, d), m.reshape(B, H), l.reshape(B, H)
    ln = jnp.where(l == 0.0, 1.0, l)
    return (acc / ln).astype(q.dtype).reshape(B, H, d)
