"""Llama3-70B — paper reallocation study model (Table 8).  [arXiv:2407.21783]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-70b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28_672,
    vocab_size=128_256,
    activation="silu",
    gated_mlp=True,
    attn_type="gqa",
    pos_emb="rope",
    rope_theta=500_000.0,
    notes="paper reallocation model (GQA)",
)
