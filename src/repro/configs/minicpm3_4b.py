"""minicpm3-4b — 62L d_model=2560 40H d_ff=6400 vocab=73448, MLA.
[hf:openbmb/MiniCPM3-4B; hf]

Multi-head Latent Attention with MiniCPM3's ranks (q_lora=768, kv_lora=256,
nope=64, rope=32, v=64).  MiniCPM's mup-style residual/embedding scaling is
omitted (noted in DESIGN.md) — it does not change compute/communication shape.
"""
from .base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,  # MLA: effective per-head KV after expansion
    d_head=96,  # nope+rope for q/k
    d_ff=6400,
    vocab_size=73_448,
    activation="silu",
    gated_mlp=True,
    attn_type="mla",
    pos_emb="rope",
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    notes="MLA compresses KV cache ~(kv_lora+rope)/(2*H*dh); quadratic attn -> long_500k skipped",
)
