"""hubert-xlarge — 48L d_model=1280 16H (MHA kv=16) d_ff=5120 vocab=504,
encoder-only, same arch as wav2vec2.  [arXiv:2106.07447; unverified]

Encoder-only (bidirectional) transformer; the convolutional waveform frontend is
a stub — ``input_specs()`` provides precomputed frame embeddings.  vocab=504 is
the HuBERT masked-unit-prediction codebook.  No decode phase exists.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_head=80,
    d_ff=5120,
    vocab_size=504,
    activation="gelu",
    gated_mlp=False,
    attn_type="gqa",
    pos_emb="learned",
    causal=False,
    norm_type="layernorm",
    frontend="audio_stub",
    max_seq_len=32_768,
    notes="encoder-only: decode shapes skipped; audio frontend stubbed",
)
