"""granite-8b — 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152,
llama-arch, code.  [arXiv:2405.04324; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14_336,
    vocab_size=49_152,
    activation="silu",
    gated_mlp=True,
    attn_type="gqa",
    pos_emb="rope",
    rope_theta=10_000_000.0,
    tie_embeddings=True,
    notes="full quadratic attention -> long_500k skipped",
)
