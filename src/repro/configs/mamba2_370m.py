"""mamba2-370m — 48L d_model=1024 (attn-free) vocab=50280, ssm_state=128,
SSD (state-space duality).  [arXiv:2405.21060; unverified]

Pure Mamba-2: no attention, no FFN (the Mamba block doubles as the mixer+MLP).
"""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab_size=50_280,
    attn_type="none",
    pos_emb="none",
    tie_embeddings=True,
    block_pattern=(("mamba", "none"),),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1),
    notes="attn-free: all shapes incl. long_500k run; decode state is O(1) in seq_len",
)
