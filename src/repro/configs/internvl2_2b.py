"""internvl2-2b — 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553,
InternViT + InternLM2.  [arXiv:2404.16821; hf]

Per the assignment, only the transformer BACKBONE (InternLM2-1.8B-style decoder)
is modeled; the InternViT frontend is a stub — ``input_specs()`` provides
precomputed patch embeddings of shape [batch, seq, d_model].
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=92_553,
    activation="silu",
    gated_mlp=True,
    attn_type="gqa",
    pos_emb="rope",
    frontend="vision_stub",
    notes="vision frontend stubbed; quadratic attn -> long_500k skipped",
)
