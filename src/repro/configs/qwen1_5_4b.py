"""qwen1.5-4b — 40L d_model=2560 20H (GQA kv=20 = MHA) d_ff=6912 vocab=151936,
QKV bias.  [hf:Qwen/Qwen1.5-0.5B family; hf]
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_head=128,
    d_ff=6912,
    vocab_size=151_936,
    activation="silu",
    gated_mlp=True,
    qkv_bias=True,
    attn_type="gqa",
    pos_emb="rope",
    notes="full quadratic attention -> long_500k skipped",
)
