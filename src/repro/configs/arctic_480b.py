"""arctic-480b — 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual.  [hf:Snowflake/snowflake-arctic-base; hf]

Arctic's signature dense-MoE hybrid: a dense FFN residual branch runs in
parallel with the routed top-2 MoE on every layer.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=4864,
    vocab_size=32_000,
    activation="silu",
    gated_mlp=True,
    attn_type="gqa",
    pos_emb="rope",
    block_pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864),
    dense_residual=True,
    d_ff_dense=4864,
    notes="full quadratic attention -> long_500k skipped; dense residual branch",
)
