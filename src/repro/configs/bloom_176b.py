"""BLOOM-176B — the paper's primary evaluation model (Table 3 / Figs 2,3,5-7).
[arXiv:2211.05100]

70L, d_model=14336, 112 MHA heads, ALiBi positions, GELU MLP (ungated),
LayerNorm, tied embeddings, vocab 250880.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="bloom-176b",
    family="dense",
    n_layers=70,
    d_model=14_336,
    n_heads=112,
    n_kv_heads=112,
    d_head=128,
    d_ff=57_344,
    vocab_size=250_880,
    activation="gelu",
    gated_mlp=False,
    qkv_bias=True,
    attn_type="gqa",
    pos_emb="alibi",
    norm_type="layernorm",
    tie_embeddings=True,
    notes="paper's primary model; MHA",
)
