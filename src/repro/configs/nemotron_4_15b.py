"""nemotron-4-15b — 32L d_model=6144 48H (GQA kv=8) d_ff=24576 vocab=256000,
GQA, squared-ReLU.  [arXiv:2402.16819; unverified]

Nemotron-4 uses an ungated squared-ReLU MLP and LayerNorm.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=24_576,
    vocab_size=256_000,
    activation="sq_relu",
    gated_mlp=False,
    attn_type="gqa",
    pos_emb="rope",
    norm_type="layernorm",
    notes="full quadratic attention -> long_500k skipped",
)
