"""Model / shape configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``.  The layer stack
is described by ``block_pattern`` — a repeating unit of ``(mixer, ffn)`` pairs —
so that dense, MoE, hybrid (Jamba) and attention-free (Mamba-2) stacks all share
one generic scan-over-layers implementation.

mixer ∈ {"attn", "mamba"};  ffn ∈ {"mlp", "moe", "none"}.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    # load-balancing aux loss coefficient (used in training)
    aux_loss_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


# ---------------------------------------------------------------------------
# Main model config
# ---------------------------------------------------------------------------

BlockSpec = Tuple[str, str]  # (mixer, ffn)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    activation: str = "silu"  # silu | gelu | sq_relu
    gated_mlp: bool = True
    qkv_bias: bool = False
    attn_type: str = "gqa"  # gqa | mla | none
    pos_emb: str = "rope"  # rope | alibi | learned | none
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    causal: bool = True
    tie_embeddings: bool = False
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5

    # Repeating unit of (mixer, ffn) pairs; n_layers % len(block_pattern) == 0.
    block_pattern: Tuple[BlockSpec, ...] = (("attn", "mlp"),)
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # Arctic-style dense FFN residual branch run in parallel with the MoE FFN.
    dense_residual: bool = False
    d_ff_dense: int = 0

    frontend: str = "none"  # none | vision_stub | audio_stub
    max_seq_len: int = 524_288
    dtype: str = "bfloat16"

    # notes recorded for DESIGN.md §Arch-applicability
    notes: str = ""

    # ---------------- derived quantities ----------------
    @property
    def n_repeats(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}"
        )
        return self.n_layers // len(self.block_pattern)

    @property
    def attn_free(self) -> bool:
        return all(m != "attn" for m, _ in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run 500k-token decode (SSM / hybrid)."""
        return any(m == "mamba" for m, _ in self.block_pattern) or self.attn_free

    @property
    def encoder_only(self) -> bool:
        return not self.causal

    def mixer_counts(self) -> dict:
        c: dict = {}
        for m, _ in self.block_pattern:
            c[m] = c.get(m, 0) + 1
        return {k: v * self.n_repeats for k, v in c.items()}

    # ---------------- parameter counting ----------------
    def _attn_params(self) -> int:
        d = self.d_model
        if self.attn_type == "mla":
            a = self.mla
            qh = a.qk_nope_head_dim + a.qk_rope_head_dim
            p = d * a.q_lora_rank + a.q_lora_rank * self.n_heads * qh
            p += d * (a.kv_lora_rank + a.qk_rope_head_dim)
            p += a.kv_lora_rank * self.n_heads * (a.qk_nope_head_dim + a.v_head_dim)
            p += self.n_heads * a.v_head_dim * d
            return p
        p = d * self.n_heads * self.d_head  # q
        p += 2 * d * self.n_kv_heads * self.d_head  # k, v
        p += self.n_heads * self.d_head * d  # o
        if self.qkv_bias:
            p += (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        return p

    def _mamba_params(self) -> int:
        s = self.ssm
        d = self.d_model
        di = s.d_inner(d)
        nh = s.n_heads(d)
        gdn = s.n_groups * s.d_state
        p = d * (2 * di + 2 * gdn + nh)  # in_proj
        p += s.d_conv * (di + 2 * gdn)  # conv
        p += 3 * nh  # A_log, D, dt_bias
        p += di  # gated norm
        p += di * d  # out_proj
        return p

    def _mlp_params(self, d_ff: int) -> int:
        mult = 3 if self.gated_mlp else 2
        return mult * self.d_model * d_ff

    def _moe_params(self) -> Tuple[int, int]:
        """(total, active) params of one MoE FFN layer."""
        m = self.moe
        per_expert = self._mlp_params(m.d_expert)
        total = self.d_model * m.n_experts + m.n_experts * per_expert
        active = self.d_model * m.n_experts + m.top_k * per_expert
        if m.n_shared_experts:
            shared = m.n_shared_experts * per_expert
            total += shared
            active += shared
        if self.dense_residual:
            dense = self._mlp_params(self.d_ff_dense or self.d_ff)
            total += dense
            active += dense
        return total, active

    def param_count(self) -> Tuple[int, int]:
        """(total_params, active_params) excluding embeddings? -> including."""
        total = active = 0
        for mixer, ffn in self.block_pattern:
            if mixer == "attn":
                p = self._attn_params()
            elif mixer == "mamba":
                p = self._mamba_params()
            else:
                raise ValueError(mixer)
            total += p
            active += p
            if ffn == "mlp":
                p = self._mlp_params(self.d_ff)
                total += p
                active += p
            elif ffn == "moe":
                t, a = self._moe_params()
                total += t
                active += a
            total += 2 * self.d_model  # 2 norms (approx; counts scale only)
            active += 2 * self.d_model
        total *= self.n_repeats
        active *= self.n_repeats
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        total += emb + head + self.d_model
        active += emb + head + self.d_model
        return total, active


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def pad_heads_for_tp(cfg: ModelConfig, tp: int) -> ModelConfig:
    """Beyond-paper optimization: pad attention head counts up to a multiple
    of the tensor-parallel degree so heads shard cleanly (Megatron-style).

    Extra heads are functionally inert when their q/o projections are zero;
    for the dry-run (shape-level) this is a pure layout transform.  No-op
    when heads already divide tp or the arch is attention-free."""
    if cfg.attn_type == "none" or cfg.n_heads == 0 or cfg.n_heads % tp == 0:
        return cfg
    Hp = -(-cfg.n_heads // tp) * tp
    KVp = Hp if cfg.n_kv_heads == cfg.n_heads else cfg.n_kv_heads
    if KVp and Hp % KVp:
        KVp = Hp  # degenerate fallback: MHA
    return dataclasses.replace(cfg, n_heads=Hp, n_kv_heads=KVp)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell is well-defined, and why not if not."""
    if shape.kind == "decode" and cfg.encoder_only:
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (full-attention arch)"
    return True, ""


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------


def reduced(cfg: ModelConfig, *, d_model: int = 64, n_layers: Optional[int] = None) -> ModelConfig:
    """A tiny config of the same family, runnable on CPU in a smoke test."""
    pat = len(cfg.block_pattern)
    if n_layers is None:
        n_layers = pat  # one repeat of the full pattern
    n_heads = 4
    n_kv = max(1, min(cfg.n_kv_heads, (n_heads if cfg.n_kv_heads >= cfg.n_heads else 2)))
    changes = {
        "name": cfg.name + "-reduced",
        "n_layers": n_layers,
        "d_model": d_model,
        "n_heads": n_heads,
        "n_kv_heads": n_kv,
        "d_head": 16,
        "d_ff": d_model * 2,
        "vocab_size": 256,
        "max_seq_len": 512,
    }
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(
            n_experts=4,
            top_k=min(2, cfg.moe.top_k),
            d_expert=d_model,
            n_shared_experts=min(1, cfg.moe.n_shared_experts),
            capacity_factor=2.0,
        )
    if cfg.mla is not None:
        changes["mla"] = MLAConfig(
            q_lora_rank=32,
            kv_lora_rank=16,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        )
    if cfg.ssm is not None:
        changes["ssm"] = SSMConfig(
            d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1, chunk_size=16
        )
    if cfg.dense_residual:
        changes["d_ff_dense"] = d_model * 2
    return dataclasses.replace(cfg, **changes)
