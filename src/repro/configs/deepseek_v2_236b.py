"""DeepSeek-V2-236B — paper reallocation study model (Table 8).  [arXiv:2405.04434]

MLA + DeepSeekMoE (160 routed experts top-6 + 2 shared).  The paper deploys it
FP8/EP=8.  The first dense layer of the real model is approximated by using the
MoE pattern throughout (same dominant compute/communication shape; noted here).
"""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=192,  # nope+rope
    d_ff=1536,
    vocab_size=102_400,
    activation="silu",
    gated_mlp=True,
    attn_type="mla",
    pos_emb="rope",
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    block_pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=160, top_k=6, d_expert=1536, n_shared_experts=2),
    notes="paper reallocation model (MLA + MoE)",
)
