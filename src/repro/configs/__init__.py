"""Architecture registry: the 10 assigned archs + the paper's 3 models."""
from __future__ import annotations

from .base import (
    SHAPES,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    SSMConfig,
    reduced,
    shape_applicable,
)

from . import (  # noqa: E402
    arctic_480b,
    bloom_176b,
    deepseek_v2_236b,
    granite_8b,
    hubert_xlarge,
    internvl2_2b,
    jamba_1_5_large_398b,
    llama3_70b,
    mamba2_370m,
    minicpm3_4b,
    nemotron_4_15b,
    qwen1_5_4b,
    qwen3_moe_235b,
)

# The 10 assigned architectures (graded matrix)
ASSIGNED_ARCHS = {
    "qwen3-moe-235b-a22b": qwen3_moe_235b.CONFIG,
    "arctic-480b": arctic_480b.CONFIG,
    "jamba-1.5-large-398b": jamba_1_5_large_398b.CONFIG,
    "granite-8b": granite_8b.CONFIG,
    "nemotron-4-15b": nemotron_4_15b.CONFIG,
    "qwen1.5-4b": qwen1_5_4b.CONFIG,
    "minicpm3-4b": minicpm3_4b.CONFIG,
    "mamba2-370m": mamba2_370m.CONFIG,
    "internvl2-2b": internvl2_2b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
}

# The paper's own evaluation models
PAPER_ARCHS = {
    "bloom-176b": bloom_176b.CONFIG,
    "llama3-70b": llama3_70b.CONFIG,
    "deepseek-v2-236b": deepseek_v2_236b.CONFIG,
}

ARCHS = {**ASSIGNED_ARCHS, **PAPER_ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs(assigned_only: bool = False):
    return sorted(ASSIGNED_ARCHS if assigned_only else ARCHS)


__all__ = [
    "ARCHS",
    "ASSIGNED_ARCHS",
    "PAPER_ARCHS",
    "SHAPES",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeConfig",
    "get_config",
    "list_archs",
    "reduced",
    "shape_applicable",
]
