"""qwen3-moe-235b-a22b — 94L d_model=4096 64H (GQA kv=4) d_ff=1536 vocab=151936,
MoE 128e top-8.  [hf:Qwen/Qwen3-30B-A3B family; hf]

Qwen3 specifics modeled: head_dim=128 (> d_model/n_heads), QK-norm, RoPE theta 1e6,
no shared expert, gated SiLU experts.
"""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,  # per-expert FFN width
    vocab_size=151_936,
    activation="silu",
    gated_mlp=True,
    attn_type="gqa",
    pos_emb="rope",
    rope_theta=1_000_000.0,
    qk_norm=True,
    block_pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
    notes="full quadratic attention -> long_500k skipped",
)
