"""jamba-1.5-large-398b — 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536,
MoE 16e top-2, Mamba:attn 7:1 interleave.  [arXiv:2403.19887; hf]

Layout follows the Jamba paper: attention every 8th layer (offset 4), MoE every
2nd layer (offset 1).  Modeling simplification (noted in DESIGN.md): the Mamba
layers use our Mamba-2 (SSD) block with d_state=128 instead of Mamba-1 d_state=16;
this preserves the state-size-independent-of-seq-len property the assignment
exercises (long_500k) while sharing one SSM implementation.
"""
from .base import ModelConfig, MoEConfig, SSMConfig

# period-8 unit: pos4 = attention; odd positions are MoE
_PATTERN = (
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
    ("attn", "mlp"),
    ("mamba", "moe"),
    ("mamba", "mlp"),
    ("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24_576,
    vocab_size=65_536,
    activation="silu",
    gated_mlp=True,
    attn_type="gqa",
    pos_emb="none",  # Jamba uses no positional embedding (Mamba provides position)
    block_pattern=_PATTERN,
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24_576),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=128, n_groups=1),
    notes="hybrid: long_500k runs (sub-quadratic); attn 1:7 interleave",
)
