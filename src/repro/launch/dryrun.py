import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, record memory/cost analysis + scan-corrected HLO stats.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # everything
  ... --arch qwen3-moe-235b-a22b --shape train_4k --mesh pod   # one cell
  ... --list                                                   # show the matrix

Results land in results/dryrun/<arch>__<shape>__<mesh>.json; the roofline
report (launch/roofline.py, benchmarks/roofline.py) reads these.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCHS, ASSIGNED_ARCHS, SHAPES, shape_applicable
from repro.launch import hloanalysis
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")

MESHES = {"single": {"multi_pod": False}, "pod": {"multi_pod": True}}


def cells(archs=None, shapes=None, assigned_only=True):
    pool = ASSIGNED_ARCHS if assigned_only else ARCHS
    for a, cfg in pool.items():
        if archs and a not in archs:
            continue
        for s, shape in SHAPES.items():
            if shapes and s not in shapes:
                continue
            ok, why = shape_applicable(cfg, shape)
            yield a, s, ok, why


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             sharding: str = "baseline") -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(**MESHES[mesh_name])
    n_chips = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_chips": int(n_chips),
        "kind": shape.kind,
        "sharding": sharding,
        "status": "pending",
    }
    t0 = time.time()
    try:
        with mesh:
            step, args = build_step(cfg, shape, mesh, sharding=sharding)
            lowered = step.lower(*args)
            rec["t_lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["t_compile_s"] = round(time.time() - t1, 1)

            mem = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            rec["cost"] = {
                k: float(v)
                for k, v in (cost or {}).items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "bytes accessed", "optimal_seconds")
                    or k.startswith("bytes accessed")
                )
            }
            hlo = compiled.as_text()
            stats = hloanalysis.analyze(hlo)
            rec["hlo"] = {
                "flops_scan_corrected": stats.flops,
                "hbm_bytes": stats.hbm_bytes,
                "collective_bytes": dict(stats.collective_bytes),
                "collective_counts": dict(stats.collective_counts),
                "while_trip_counts": stats.while_trip_counts,
                "top_collectives": dict(sorted(
                    stats.collective_bytes_by_meta.items(), key=lambda kv: -kv[1]
                )[:8]),
                "top_traffic": dict(sorted(
                    stats.hbm_bytes_by_meta.items(), key=lambda kv: -kv[1]
                )[:8]),
            }
            import gzip

            os.makedirs(out_dir, exist_ok=True)
            sfx = "" if sharding == "baseline" else f".{sharding}"
            with gzip.open(
                os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}{sfx}.hlo.gz"),
                "wt",
            ) as zf:
                zf.write(hlo)
            # scan correction factor for cost_analysis numbers
            trips = stats.while_trip_counts
            rec["scan_factor"] = max(trips.values()) if trips else 1
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record and continue
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["t_total_s"] = round(time.time() - t0, 1)

    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if sharding == "baseline" else f".{sharding}"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=None)
    ap.add_argument("--shape", nargs="*", default=None)
    ap.add_argument("--mesh", nargs="*", default=["single", "pod"], choices=["single", "pod"])
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--sharding", default="baseline", choices=["baseline", "optimized"])
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        f"dry-run needs 512 placeholder devices, got {jax.device_count()} "
        "(XLA_FLAGS must be set before jax init)"
    )

    matrix = list(cells(args.arch, args.shape))
    if args.list:
        for a, s, ok, why in matrix:
            print(f"{a:26s} {s:12s} {'RUN' if ok else 'SKIP: ' + why}")
        return

    n_ok = n_err = n_skip = 0
    for a, s, ok, why in matrix:
        if not ok:
            print(f"SKIP  {a} x {s}: {why}")
            n_skip += 1
            continue
        sfx = "" if args.sharding == "baseline" else f".{args.sharding}"
        for m in args.mesh:
            path = os.path.join(args.out, f"{a}__{s}__{m}{sfx}.json")
            if args.skip_done and os.path.exists(path):
                with open(path) as f:
                    if json.load(f).get("status") == "ok":
                        print(f"DONE  {a} x {s} x {m} (cached)")
                        n_ok += 1
                        continue
            rec = run_cell(a, s, m, args.out, sharding=args.sharding)
            tag = "OK  " if rec["status"] == "ok" else "ERR "
            extra = (
                f"lower {rec.get('t_lower_s')}s compile {rec.get('t_compile_s')}s"
                if rec["status"] == "ok"
                else rec.get("error", "")[:120]
            )
            print(f"{tag}  {a} x {s} x {m}  [{extra}]", flush=True)
            n_ok += rec["status"] == "ok"
            n_err += rec["status"] != "ok"
    print(f"\ndry-run: {n_ok} ok, {n_err} errors, {n_skip} skipped cells")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
