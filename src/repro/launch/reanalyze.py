"""Re-run the HLO analyzer over saved dry-run artifacts (.hlo.gz) and
refresh the hlo section of each results JSON — lets analyzer fixes improve
the roofline without recompiling 62 cells."""
import glob
import gzip
import json
import os
import sys

from repro.launch import hloanalysis


def main(d):
    for hpath in sorted(glob.glob(os.path.join(d, "*.hlo.gz"))):
        jpath = hpath.replace(".hlo.gz", ".json")
        if not os.path.exists(jpath):
            continue
        with gzip.open(hpath, "rt") as f:
            hlo = f.read()
        stats = hloanalysis.analyze(hlo)
        with open(jpath) as f:
            rec = json.load(f)
        rec["hlo"] = {
            "flops_scan_corrected": stats.flops,
            "hbm_bytes": stats.hbm_bytes,
            "collective_bytes": dict(stats.collective_bytes),
            "collective_counts": dict(stats.collective_counts),
            "while_trip_counts": stats.while_trip_counts,
            "top_collectives": dict(sorted(stats.collective_bytes_by_meta.items(), key=lambda kv: -kv[1])[:8]),
            "top_traffic": dict(sorted(stats.hbm_bytes_by_meta.items(), key=lambda kv: -kv[1])[:8]),
        }
        trips = stats.while_trip_counts
        rec["scan_factor"] = max(trips.values()) if trips else 1
        with open(jpath, "w") as f:
            json.dump(rec, f, indent=1)
        print(f"re-analyzed {os.path.basename(jpath)}: hbm={stats.hbm_bytes/1e9:.1f}GB "
              f"coll={sum(stats.collective_bytes.values())/1e9:.2f}GB")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")))
