"""While-aware HLO analysis: scan-corrected FLOPs, bytes, collective bytes.

XLA's ``compiled.cost_analysis()`` counts the body of a ``while`` op ONCE,
but our models lower the layer stack as ``lax.scan`` -> a while loop with a
``known_trip_count`` backend config.  This module parses the optimized HLO
text of ``compiled.as_text()``:

  * splits the module into computations (ENTRY + fusions + loop bodies),
  * builds the call graph (``body=`` / ``condition=`` / ``to_apply=`` /
    ``calls=``) and propagates while trip counts down it,
  * attributes dot FLOPs to their computation x multiplier,
  * sums result bytes of every collective (all-gather, all-reduce,
    reduce-scatter, all-to-all, collective-permute), scan-corrected, split
    by op kind — the source of truth for the collective roofline term.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_RESULT_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\])")


def _shape_elems_bytes(shape_str: str) -> Tuple[float, float]:
    elems = 0.0
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


def _shape_bytes(shape_str: str) -> float:
    return _shape_elems_bytes(shape_str)[1]


@dataclass
class HLOStats:
    flops: float = 0.0  # scan-corrected dot flops (per device)
    hbm_bytes: float = 0.0  # scan-corrected materialized-buffer traffic (per device)
    collective_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    while_trip_counts: Dict[str, int] = field(default_factory=dict)
    dot_flops_by_meta: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_bytes_by_meta: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    hbm_bytes_by_meta: Dict[str, float] = field(default_factory=lambda: defaultdict(float))

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _parse_computations(hlo: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if line and not line[0].isspace():
            m = _HDR_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
            elif line.startswith("}"):
                cur = None
            continue
        if cur is not None:
            if line.strip().startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _lhs_dot_shape(line: str, defs: Dict[str, str]) -> str:
    """Shape string of a dot's lhs operand.

    Two HLO text layouts exist: newer XLA inlines operand shapes at the call
    site (``dot(f32[64,128]{1,0} %a, ...)``), older text has bare operand
    names (``dot(%a, %b)``) whose shapes live at their definition sites."""
    par = re.search(r"\bdot\(([^)]*)\)", line)
    if not par:
        return ""
    inner = par.group(1).strip()
    sm = _SHAPE_RE.match(inner)
    if sm:
        return sm.group(0)
    nm = re.search(r"%([\w.\-]+)", inner)
    return defs.get(nm.group(1), "") if nm else ""


def _meta_name(line: str) -> str:
    m = re.search(r'op_name="([^"]+)"', line)
    return m.group(1) if m else "?"


def analyze(hlo: str, operand_shapes: Optional[Dict[str, str]] = None) -> HLOStats:
    stats = HLOStats()
    comps = _parse_computations(hlo)

    # operand definitions: map %name -> shape string (for dot contraction dims)
    defs: Dict[str, str] = {}
    for lines in comps.values():
        for line in lines:
            m = re.match(r"\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\])", line)
            if m:
                defs[m.group(1)] = m.group(2)
    # parameters in headers
    for raw in hlo.splitlines():
        if raw and not raw[0].isspace() and _HDR_RE.match(raw):
            for pm in re.finditer(r"([\w.\-]+):\s*([a-z0-9]+\[[0-9,]*\])", raw):
                defs[pm.group(1)] = pm.group(2)

    # while trip counts
    trip_by_body: Dict[str, int] = {}
    for lines in comps.values():
        for line in lines:
            if re.search(r"\bwhile\(", line):
                body_m = re.search(r"body=%?([\w.\-]+)", line)
                n_m = re.search(r'known_trip_count\\?":\{\\?"n\\?":\\?"(\d+)', line) or re.search(
                    r'known_trip_count[^0-9]{0,8}(\d+)', line
                )
                if body_m:
                    trip_by_body[body_m.group(1)] = int(n_m.group(1)) if n_m else 1
    stats.while_trip_counts = dict(trip_by_body)

    # call graph: callee -> caller.  Computations entered via calls=/to_apply=
    # are fusion/reduction bodies: their internals live in registers/VMEM and
    # must NOT contribute to HBM traffic (the fusion op itself does).
    caller_of: Dict[str, str] = {}
    fusion_internal: set = set()
    for comp, lines in comps.items():
        for line in lines:
            for m in re.finditer(r"(?:body|condition)=%?([\w.\-]+)", line):
                caller_of.setdefault(m.group(1), comp)
            for m in re.finditer(r"(?:to_apply|calls)=%?([\w.\-]+)", line):
                caller_of.setdefault(m.group(1), comp)
                fusion_internal.add(m.group(1))
            for m in re.finditer(r"(?:branch_computations|called_computations)=\{([^}]*)\}", line):
                for name in re.findall(r"%?([\w.\-]+)", m.group(1)):
                    caller_of.setdefault(name, comp)
    # transitively mark computations only reachable through fusion internals
    changed = True
    while changed:
        changed = False
        for callee, caller in caller_of.items():
            if caller in fusion_internal and callee not in fusion_internal:
                fusion_internal.add(callee)
                changed = True

    mult_cache: Dict[str, int] = {}

    def mult(comp: str, depth: int = 0) -> int:
        if comp in mult_cache:
            return mult_cache[comp]
        if depth > 64:
            return 1
        base = trip_by_body.get(comp, 1)
        caller = caller_of.get(comp)
        m = base * (mult(caller, depth + 1) if caller else 1)
        mult_cache[comp] = m
        return m

    # ---- HBM traffic model ----
    # Every buffer materialized at a top-level op boundary (ENTRY, while
    # bodies, conditional branches) counts once: operands read + output
    # written.  Fusion internals are free (registers/VMEM).  Slicing ops
    # count the slice, not the sliced-into tensor.
    _NO_TRAFFIC = (
        "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
        "after-all", "while", "conditional", "partition-id", "replica-id",
        "reshape",
    )

    def _op_kind(ls: str) -> str:
        m_ = re.match(r"\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+([\w\-]+)", ls)
        return m_.group(1) if m_ else "?"

    def _operand_bytes(ls: str) -> float:
        par = re.search(r"\b[\w\-]+\(([^)]*)\)", ls)
        if not par:
            return 0.0
        total = 0.0
        for name in re.findall(r"%([\w.\-]+)", par.group(1)):
            if name in defs:
                total += _shape_bytes(defs[name])
        return total

    # Per-fusion effective operand bytes: when a fusion parameter is only
    # consumed by (dynamic-)slice ops inside the fusion body, the fusion
    # reads the SLICE, not the whole operand (XLA fuses cache slicing).
    fusion_param_bytes: Dict[str, Dict[int, float]] = {}

    def _fusion_params(comp_name: str) -> Dict[int, float]:
        if comp_name in fusion_param_bytes:
            return fusion_param_bytes[comp_name]
        out: Dict[int, float] = {}
        lines = comps.get(comp_name, [])
        params: Dict[str, int] = {}
        for ls in lines:
            pm = re.match(r"\s*%?(param_(\d+)[\w.\-]*)\s*=", ls)
            if pm:
                params[pm.group(1)] = int(pm.group(2))
        for pname, pidx in params.items():
            uses = [l for l in lines if re.search(rf"\(.*%{re.escape(pname)}\b", l)]
            slice_uses = [
                l for l in uses
                if re.search(rf"(?:dynamic-slice|slice)\(\s*%{re.escape(pname)}\b", l)
            ]
            if uses and len(slice_uses) == len(uses):
                b = 0.0
                for l in slice_uses:
                    om = _RESULT_RE.match(l.strip())
                    if om:
                        b += _shape_bytes(om.group(1))
                out[pidx] = b
        fusion_param_bytes[comp_name] = out
        return out

    def _fusion_traffic(ls: str) -> float:
        out_b = _out_bytes(ls)
        callee_m = re.search(r"calls=%?([\w.\-]+)", ls)
        par = re.search(r"\bfusion\(([^)]*)\)", ls)
        if not par:
            return out_b
        names = re.findall(r"%([\w.\-]+)", par.group(1))
        sliced = _fusion_params(callee_m.group(1)) if callee_m else {}
        total = out_b
        for i, name in enumerate(names):
            if name not in defs:
                continue
            total += sliced.get(i, _shape_bytes(defs[name]))
        return total

    def _out_bytes(ls: str) -> float:
        out_m = _RESULT_RE.match(ls)
        return _shape_bytes(out_m.group(1)) if out_m else 0.0

    def _traffic(ls: str) -> float:
        kind = _op_kind(ls)
        if kind in _NO_TRAFFIC:
            return 0.0
        out_b = _out_bytes(ls)
        if kind in ("dynamic-slice", "gather", "slice"):
            return 2.0 * out_b  # read slice + write result
        if kind in ("dynamic-update-slice", "scatter"):
            # read + write the update region (operand 1), done in place
            par = re.search(r"\(([^)]*)\)", ls)
            names = re.findall(r"%([\w.\-]+)", par.group(1)) if par else []
            if len(names) >= 2 and names[1] in defs:
                return 2.0 * _shape_bytes(defs[names[1]])
            return 2.0 * out_b
        if kind in ("broadcast", "iota"):
            return out_b  # write only
        if kind == "fusion":
            return _fusion_traffic(ls)
        return _operand_bytes(ls) + out_b

    # walk ops
    for comp, lines in comps.items():
        m = mult(comp)
        count_traffic = comp not in fusion_internal
        for line in lines:
            ls = line.strip()
            if count_traffic:
                t = _traffic(ls)
                if t:
                    stats.hbm_bytes += m * t
                    stats.hbm_bytes_by_meta[_meta_name(ls)] += m * t
            if re.search(r"=\s*[a-z0-9]+\[[0-9,]*\]\{[^}]*\}\s+dot\(", ls) or " dot(" in ls:
                lhs_shape = _lhs_dot_shape(ls, defs)
                out_m = _RESULT_RE.match(ls)
                if not out_m:
                    continue
                out_elems, _ = _shape_elems_bytes(out_m.group(1))
                k = 1
                lhs_dims_m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ls)
                if lhs_dims_m and lhs_shape:
                    lhs = [int(d) for d in _SHAPE_RE.match(lhs_shape).group(2).split(",") if d]
                    for idx in lhs_dims_m.group(1).split(","):
                        if idx and int(idx) < len(lhs):
                            k *= lhs[int(idx)]
                f = 2.0 * out_elems * k
                stats.flops += m * f
                stats.dot_flops_by_meta[_meta_name(ls)] += m * f
                continue
            for coll in _COLLECTIVES:
                if re.search(rf"\b{coll}(?:-start)?\(", ls) and f"{coll}-done" not in ls:
                    out_m = _RESULT_RE.match(ls)
                    b = _shape_bytes(out_m.group(1)) if out_m else 0.0
                    stats.collective_bytes[coll] += m * b
                    stats.collective_counts[coll] += m
                    stats.collective_bytes_by_meta[_meta_name(ls)] += m * b
                    break
    return stats


def roofline_terms(
    *,
    flops: float,
    bytes_hbm: float,
    collective_bytes: float,
    n_chips: int,
    peak_flops: float = 197e12,
    hbm_bw: float = 819e9,
    link_bw: float = 50e9,
    per_device: bool = True,
) -> Dict[str, float]:
    """The three roofline terms (seconds) for one step.

    ``flops``/``bytes`` from the compiled module are PER-DEVICE under SPMD
    (the module is the per-device program); collective bytes likewise.
    """
    div = 1 if per_device else n_chips
    t_compute = flops / (peak_flops * div)
    t_memory = bytes_hbm / (hbm_bw * div)
    t_collective = collective_bytes / (link_bw * div)
    dom = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dom,
    }
