"""Roofline analysis from dry-run artifacts (deliverable g).

Reads results/dryrun/*.json (written by launch/dryrun.py) and derives, per
(arch x shape x mesh):

  compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
  memory term     = HLO_bytes / (chips x 819 GB/s)
  collective term = collective_bytes / (chips x 50 GB/s/link)

Per-device semantics: the compiled module is the per-device SPMD program, so
cost_analysis flops/bytes and the parsed collective bytes are already
per-device — the terms divide by per-chip peaks directly (calibrated in
tests/test_hloanalysis.py against an analytic matmul).

FLOPs: primary = while-aware parsed dot FLOPs (exact on the calibration
case); we also report cost_analysis x scan_factor.  Bytes: cost_analysis
'bytes accessed' x scan_factor (upper bound: assumes all traffic is
in-loop, which holds to first order for >=24-layer stacks).

MODEL_FLOPS = 6·N_active·D for train steps, 2·N_active·D for serve steps
(D = tokens processed globally); the ratio MODEL_FLOPS / HLO_FLOPs_total
exposes remat/replication waste (<1x means the compiled program does
redundant or reshard-induced work).
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from ..configs import ARCHS, SHAPES

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e-class)
HBM_BW = 819e9  # B/s per chip
LINK_BW = 50e9  # B/s per ICI link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


@dataclass
class CellRoofline:
    sharding: str
    arch: str
    shape: str
    mesh: str
    n_chips: int
    kind: str
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    hlo_flops_dev: float
    hlo_bytes_dev: float
    coll_bytes_dev: float
    model_flops: float
    useful_ratio: float  # MODEL_FLOPS / (HLO_FLOPs_dev * chips)
    bound_s: float  # max of the three terms = roofline-optimal step time

    def row(self) -> str:
        return (
            f"{self.arch:26s} {self.shape:12s} {self.mesh:6s} "
            f"{self.t_compute*1e3:9.3f} {self.t_memory*1e3:9.3f} {self.t_collective*1e3:11.3f} "
            f"{self.dominant:10s} {self.useful_ratio:7.3f}"
        )


def model_flops(arch: str, shape_name: str) -> float:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    total, active = cfg.param_count()
    if shape.kind == "train":
        D = shape.global_batch * shape.seq_len
        return 6.0 * active * D
    if shape.kind == "prefill":
        D = shape.global_batch * shape.seq_len
        return 2.0 * active * D
    D = shape.global_batch  # decode: one token per request
    return 2.0 * active * D


def load_cell(path: str) -> Optional[CellRoofline]:
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        return None
    n = rec["n_chips"]
    scan = rec.get("scan_factor", 1)
    cost_flops = rec["cost"].get("flops", 0.0)
    parsed_flops = rec["hlo"].get("flops_scan_corrected", 0.0)
    flops_dev = max(parsed_flops, cost_flops)  # parsed is scan-corrected
    # primary: materialized-buffer traffic from the while-aware HLO walk;
    # fallback: cost_analysis bytes x scan factor (known over-count for
    # dynamic-slice-into-stacked-cache patterns)
    bytes_dev = rec["hlo"].get("hbm_bytes") or rec["cost"].get("bytes accessed", 0.0) * scan
    coll_dev = sum(rec["hlo"].get("collective_bytes", {}).values())
    t_c = flops_dev / PEAK_FLOPS
    t_m = bytes_dev / HBM_BW
    t_x = coll_dev / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    return CellRoofline(
        sharding=rec.get("sharding", "baseline"),
        arch=rec["arch"],
        shape=rec["shape"],
        mesh=rec["mesh"],
        n_chips=n,
        kind=rec["kind"],
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        dominant=dom,
        hlo_flops_dev=flops_dev,
        hlo_bytes_dev=bytes_dev,
        coll_bytes_dev=coll_dev,
        model_flops=mf,
        useful_ratio=mf / max(flops_dev * n, 1.0),
        bound_s=max(t_c, t_m, t_x),
    )


def load_all(results_dir: Optional[str] = None, mesh: Optional[str] = "single",
             sharding: str = "baseline") -> List[CellRoofline]:
    d = os.path.abspath(results_dir or RESULTS_DIR)
    out = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        c = load_cell(p)
        if c and (mesh is None or c.mesh == mesh) and c.sharding == sharding:
            out.append(c)
    return out


def report(results_dir: Optional[str] = None, mesh: str = "single",
           sharding: str = "baseline") -> str:
    cells = load_all(results_dir, mesh, sharding)
    hdr = (
        f"{'arch':26s} {'shape':12s} {'mesh':6s} "
        f"{'comp(ms)':>9s} {'mem(ms)':>9s} {'coll(ms)':>11s} {'dominant':10s} {'useful':>7s}"
    )
    lines = [hdr, "-" * len(hdr)]
    lines += [c.row() for c in cells]
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(report(mesh=mesh))
