"""Launch: meshes, distributed step builders, dry-run, roofline, drivers."""
