"""Training launcher.

Single-host demo / CI entry point: trains a (reduced) architecture for a few
hundred steps with checkpointing + resume.  On a real fleet the same
``make_train_step`` is jit'd over ``make_production_mesh()`` — the dry-run
(launch/dryrun.py) proves that lowering for every assigned architecture.

  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from ..configs import ARCHS, reduced as reduce_cfg
from ..training import DataConfig, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", help="reduced config (CPU-sized)")
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg, d_model=args.d_model)
    dcfg = DataConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq,
        global_batch=args.batch,
        seed=args.seed,
        frontend_dim=cfg.d_model if cfg.frontend != "none" else 0,
    )
    tcfg = TrainerConfig(
        total_steps=args.steps,
        ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir,
        base_lr=args.lr,
        warmup=max(args.steps // 20, 1),
    )
    tr = Trainer(cfg, dcfg, tcfg, seed=args.seed)
    if args.resume and tr.resume():
        print(f"resumed from step {tr.step}")
    t0 = time.time()
    n_params = sum(x.size for x in jax.tree.leaves(tr.params))
    print(f"training {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{args.batch}x{args.seq} tokens/step, {args.steps} steps")
    last = tr.run()
    dt = time.time() - t0
    first_loss = tr.history[0]["loss"] if tr.history else float("nan")
    print(json.dumps({
        "arch": cfg.name,
        "steps": tr.step,
        "first_loss": round(first_loss, 4),
        "final_loss": round(last.get("loss", float("nan")), 4),
        "wall_s": round(dt, 1),
        "tokens_per_s": round(args.batch * args.seq * len(tr.history) / dt, 1),
        "stragglers": tr.straggler_steps,
    }))


if __name__ == "__main__":
    main()
