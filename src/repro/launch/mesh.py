"""Production meshes.

Single pod:  (16, 16)  axes ("data", "model")  = 256 chips
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init.

SPAD mapping: in the disaggregated deployment the "pod" axis separates the
prefill pod from the decode pod; ``make_phase_meshes`` carves one mesh per
phase out of the device grid so each phase gets its own (data, model) layout
(the software form of the paper's Prefill/Decode machine pools).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def make_phase_meshes(
    *,
    prefill_shape: Tuple[int, int] = (16, 16),
    decode_shape: Tuple[int, int] = (16, 16),
):
    """Two disjoint (data, model) meshes: a prefill pod and a decode pod.

    Requires prefill+decode device counts <= available devices (the 512-way
    dry-run grid holds both pods)."""
    devs = np.array(jax.devices())
    n_p = int(np.prod(prefill_shape))
    n_d = int(np.prod(decode_shape))
    assert n_p + n_d <= devs.size, (n_p, n_d, devs.size)
    mesh_p = Mesh(devs[:n_p].reshape(prefill_shape), ("data", "model"))
    mesh_d = Mesh(devs[n_p : n_p + n_d].reshape(decode_shape), ("data", "model"))
    return mesh_p, mesh_d
