"""Distributed step builders: jit + shardings for every (arch x shape) cell.

``input_specs(cfg, shape)`` produces weak-type-correct ShapeDtypeStruct
stand-ins for every input of the step the shape exercises (train_step for
``train_*``, prefill_step for ``prefill_*``, decode_step a.k.a. serve_step
for ``decode_*`` / ``long_*``) — no device allocation, dry-run-safe.

``build_step(cfg, shape, mesh)`` returns (jitted_fn, example_inputs) with
in/out shardings resolved from the logical-axis rules in
``sharding.partitioning``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig, pad_heads_for_tp, shape_applicable
from ..models import model as M
from ..sharding.partitioning import (
    DEFAULT_RULES,
    OPT_DECODE_RULES,
    OPT_PREFILL_RULES,
    resolve_spec,
    rules_profile,
    spec_tree,
)
from ..training.optimizer import AdamW, adamw_for

_AXES_LEAF = lambda x: isinstance(x, tuple) and all(e is None or isinstance(e, str) for e in x)


def _data_size(mesh: Mesh) -> int:
    s = dict(mesh.shape)
    return s.get("pod", 1) * s.get("data", 1)


def _shardings_like(axes_tree, shape_tree, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda a, s: NamedSharding(mesh, resolve_spec(tuple(a), tuple(s.shape), mesh, rules)),
        axes_tree,
        shape_tree,
        is_leaf=_AXES_LEAF,
    )


# ---------------------------------------------------------------------------
# Input specs
# ---------------------------------------------------------------------------


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))


def opt_specs(cfg: ModelConfig, opt: AdamW):
    p = params_specs(cfg)
    return jax.eval_shape(opt.init, p)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the step inputs of this (arch, shape)."""
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape.name} not applicable: {why}")
    B, S = shape.global_batch, shape.seq_len
    tok_dt = jnp.int32
    if shape.kind == "train":
        if cfg.frontend != "none":
            batch = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)
        else:
            batch = jax.ShapeDtypeStruct((B, S), tok_dt)
        return {"batch": batch, "labels": jax.ShapeDtypeStruct((B, S), tok_dt)}
    if shape.kind == "prefill":
        if cfg.frontend != "none":
            return {"batch": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.float32)}
        return {"batch": jax.ShapeDtypeStruct((B, S), tok_dt)}
    # decode: one new token against a cache of length >= S+1, rounded up to
    # a 512 multiple so the sequence axis shards cleanly (serving allocates
    # round cache slabs anyway)
    L = -(-(S + 1) // 512) * 512
    return {
        "tok": jax.ShapeDtypeStruct((B,), tok_dt),
        "caches": M.init_cache_specs(cfg, B, L),
        "pos": jax.ShapeDtypeStruct((B,), tok_dt),
    }


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, mesh: Mesh, B: int, S: int, *, remat: bool = True,
                    total_steps: int = 10_000, rules=None):
    """(params, opt_state, batch, labels) -> (params, opt_state, metrics)."""
    opt = adamw_for(total_steps)
    n_groups = _data_size(mesh)

    def train_step(params, opt_state, batch, labels):
        with rules_profile(rules or DEFAULT_RULES):
            def loss_fn(p):
                return M.train_loss(p, batch, labels, cfg, n_groups=n_groups, remat=remat)

            (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            new_params, new_opt_state, opt_metrics = opt.update(grads, opt_state, params)
            return new_params, new_opt_state, {**metrics, **opt_metrics, "loss": loss}

    p_specs = params_specs(cfg)
    p_shard = _shardings_like(M.param_axes(cfg), p_specs, mesh, rules)
    # m/v mirror params; step scalar replicated
    from ..training.optimizer import AdamWState

    o_shard = AdamWState(step=NamedSharding(mesh, P()), m=p_shard, v=p_shard)
    tok_shard = NamedSharding(mesh, resolve_spec(("batch", None), (B, S), mesh))
    emb_shard = NamedSharding(mesh, resolve_spec(("batch", None, None), (B, S, cfg.d_model), mesh))
    batch_shard = emb_shard if cfg.frontend != "none" else tok_shard
    jitted = jax.jit(
        train_step,
        in_shardings=(p_shard, o_shard, batch_shard, tok_shard),
        out_shardings=(p_shard, o_shard, None),
        donate_argnums=(0, 1),
    )
    return jitted, opt


def make_prefill_step(cfg: ModelConfig, mesh: Mesh, B: int, S: int, rules=None):
    """(params, batch) -> (logits, caches).  Encoder-only: (params, batch) -> logits."""
    n_groups = _data_size(mesh)

    if cfg.encoder_only:

        def prefill_step(params, batch):
            with rules_profile(rules or DEFAULT_RULES):
                logits, aux = M.forward_train(params, batch, cfg, n_groups=n_groups)
                return logits

    else:

        def prefill_step(params, batch):
            with rules_profile(rules or DEFAULT_RULES):
                logits, caches, _ = M.prefill(params, batch, cfg, n_groups=n_groups)
                return logits, caches

    p_specs = params_specs(cfg)
    p_shard = _shardings_like(M.param_axes(cfg), p_specs, mesh, rules)
    if cfg.frontend != "none":
        batch_shard = NamedSharding(mesh, resolve_spec(("batch", None, None), (B, S, cfg.d_model), mesh))
    else:
        batch_shard = NamedSharding(mesh, resolve_spec(("batch", None), (B, S), mesh))
    return jax.jit(prefill_step, in_shardings=(p_shard, batch_shard))


def make_decode_step(cfg: ModelConfig, mesh: Mesh, B: int, L: int, rules=None,
                     weight_rules=None):
    """serve_step: (params, tok, caches, pos) -> (logits, new_caches)."""
    n_groups = _data_size(mesh)

    def decode_step(params, tok, caches, pos):
        with rules_profile(rules or DEFAULT_RULES):
            return M.decode_step(params, tok, caches, pos, cfg, n_groups=n_groups)

    p_specs = params_specs(cfg)
    # weights keep TP sharding even in the split-K decode profile — only the
    # activation/cache constraints change
    p_shard = _shardings_like(M.param_axes(cfg), p_specs, mesh, weight_rules or rules)
    cache_specs = M.init_cache_specs(cfg, B, L)
    cache_shard = _shardings_like(M.cache_axes(cfg), cache_specs, mesh, rules)
    vec_shard = NamedSharding(mesh, resolve_spec(("batch",), (B,), mesh, rules))
    logits_shard = NamedSharding(
        mesh, resolve_spec(("batch", "vocab"), (B, cfg.vocab_size), mesh, rules)
    )
    return jax.jit(
        decode_step,
        in_shardings=(p_shard, vec_shard, cache_shard, vec_shard),
        out_shardings=(logits_shard, cache_shard),
        donate_argnums=(2,),
    )


# ---------------------------------------------------------------------------
# One entry point for the dry-run
# ---------------------------------------------------------------------------


def build_step(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, *, remat: bool = True,
               sharding: str = "baseline"):
    """Returns (jitted step, args of ShapeDtypeStructs to lower with).

    ``sharding="optimized"`` activates the beyond-paper profile (§Perf):
    TP head padding, no head_dim fallback, hoisted attention resharding,
    split-K (sequence-sharded-KV) decode.
    """
    assert sharding in ("baseline", "optimized")
    opt_mode = sharding == "optimized"
    if opt_mode:
        tp = dict(mesh.shape).get("model", 1)
        cfg = pad_heads_for_tp(cfg, tp)
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        rules = OPT_PREFILL_RULES if opt_mode else None
        step, opt = make_train_step(cfg, mesh, shape.global_batch, shape.seq_len,
                                    remat=remat, rules=rules)
        p = params_specs(cfg)
        o = opt_specs(cfg, opt)
        return step, (p, o, specs["batch"], specs["labels"])
    if shape.kind == "prefill":
        rules = OPT_PREFILL_RULES if opt_mode else None
        step = make_prefill_step(cfg, mesh, shape.global_batch, shape.seq_len, rules=rules)
        return step, (params_specs(cfg), specs["batch"])
    rules = OPT_DECODE_RULES if opt_mode else None
    wrules = OPT_PREFILL_RULES if opt_mode else None
    L = -(-(shape.seq_len + 1) // 512) * 512
    step = make_decode_step(cfg, mesh, shape.global_batch, L,
                            rules=rules, weight_rules=wrules)
    return step, (params_specs(cfg), specs["tok"], specs["caches"], specs["pos"])
