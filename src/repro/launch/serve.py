"""Serving launcher: disaggregated prefill/decode (the paper's architecture).

Runs the DisaggregatedServer on a (reduced) architecture: N prefill engines +
M decode engines, a KV handoff between them, continuous batching, and prints
throughput + per-request latency stats.  On a real cluster the engines jit
over two disjoint phase meshes (``mesh.make_phase_meshes``) — prefill pods
built from Prefill-Chip machines and decode pods from Decode-Chip machines,
provisioned by ``core.provision`` (see examples/provisioning.py).

Scheduling policy is pluggable (``--scheduler {fcfs,kv-aware,priority}``;
``--swap`` adds page-level preemption under the priority policy) and the
per-request queue-wait percentiles + preemption counts are reported next to
throughput.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --reduced \
      --requests 16 --max-new 12 --paged --scheduler kv-aware
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from ..configs import ARCHS, reduced as reduce_cfg
from ..serving import (
    DisaggregatedServer,
    EngineConfig,
    GenRequest,
    Router,
    SamplingParams,
)
from ..models import model as M
from ..serving.faults import FAULT_SITES, FaultPlan
from ..serving.scheduler import SCHEDULERS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=1,
                    help="complete server replicas behind the KV-aware "
                         "Router (prefix-locality -> free-pages -> queue-"
                         "depth routing); 1 = single server, no router")
    ap.add_argument("--prefill-engines", type=int, default=1)
    ap.add_argument("--decode-engines", type=int, default=1)
    ap.add_argument("--max-slots", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-block", type=int, default=8,
                    help="fused decode steps per host sync (1 = seed behaviour)")
    ap.add_argument("--prefill-batch", type=int, default=8,
                    help="max same-bucket prompts prefilled per batch")
    ap.add_argument("--no-donate", action="store_true",
                    help="disable decode-state buffer donation (debugging)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV cache: page pools + block tables + "
                         "device-resident allocator (vs per-slot slabs)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV positions per page (paged mode)")
    ap.add_argument("--pages", type=int, default=None,
                    help="pool size in pages (default: slab-equivalent HBM)")
    ap.add_argument("--chunk-tokens", type=str, default=None,
                    help="chunked prefill (requires --paged): prompts longer "
                         "than this prefill in page-aligned chunks, each "
                         "chunk's KV streamed into the decode pool "
                         "immediately, so short requests interleave between "
                         "a long prompt's chunks instead of queueing behind "
                         "one monolithic compile; must be a multiple of "
                         "--page-size, or 'auto' to size the quantum from "
                         "measured decode-block time against --tbt-target-ms")
    ap.add_argument("--tbt-target-ms", type=float, default=None,
                    help="inter-token-latency SLO target (ms): with "
                         "--chunk-tokens auto the startup tuner picks the "
                         "largest chunk quantum whose chunk + decode block "
                         "fits this")
    ap.add_argument("--unified-batching", action="store_true",
                    help="decode-maximal rounds (requires --chunk-tokens): "
                         "batch chunks of DIFFERENT requests into one "
                         "prefill dispatch and coalesce chunk work with the "
                         "decode step under the round token budget")
    ap.add_argument("--token-budget", type=int, default=None,
                    help="per-round token budget shared by the decode block "
                         "and rider chunks (unified batching); default "
                         "max_slots*decode_block + prefill_batch*"
                         "chunk_tokens fills idle prefill rows with riders "
                         "— a tighter budget trades chunk progress for "
                         "decode TBT")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="refcounted prefix sharing + copy-on-write (paged "
                         "mode): requests whose prompts share a page-aligned "
                         "prefix map the cached pages instead of recomputing "
                         "them; prefill runs only the uncached tail")
    ap.add_argument("--kv-dtype", default="fp32", choices=["fp32", "int8"],
                    help="attention KV page pool storage: int8 stores 1-byte "
                         "payloads + one fp32 absmax scale per page (~2x "
                         "pages per HBM byte, bounded-error decode; see "
                         "docs/serving.md §9); requires --paged")
    ap.add_argument("--batch-dedup", action="store_true",
                    help="batch-level prefix dedup: requests in the SAME "
                         "bucketed prefill dispatch sharing a page-aligned "
                         "prefix with each other prefill it once; requires "
                         "--prefix-cache")
    ap.add_argument("--scheduler", default="fcfs",
                    choices=sorted(SCHEDULERS),
                    help="admission policy: fcfs (oldest first, the seed "
                         "behaviour), kv-aware (smallest reserved-page "
                         "footprint first with an aging bound), priority "
                         "(GenRequest.priority, higher first; every 4th "
                         "request here is tagged priority 1 for the demo)")
    ap.add_argument("--swap", action="store_true",
                    help="priority scheduler only: preempt the lowest-"
                         "priority running request via page-level swap "
                         "(private KV pages to host, prefix-shared pages "
                         "stay pooled) when a higher-priority request is "
                         "blocked; requires --paged")
    ap.add_argument("--deadline-rounds", type=int, default=None,
                    help="cancel (status DEADLINE) any request still "
                         "unfinished this many scheduling rounds after "
                         "submit")
    ap.add_argument("--ttft-deadline", type=int, default=None,
                    help="cancel (status DEADLINE) any request without a "
                         "FIRST token this many rounds after submit")
    ap.add_argument("--shed-after-rounds", type=int, default=None,
                    help="load shedding: cancel (status SHED) queued "
                         "requests that have waited this many rounds "
                         "without starting prefill")
    ap.add_argument("--audit-every", type=int, default=None,
                    help="run the KV invariant auditor (refcount "
                         "conservation, block-table validity, trash-page "
                         "isolation) every N rounds; any discrepancy "
                         "raises")
    ap.add_argument("--fault-rate", type=float, default=None,
                    help="chaos mode: inject this failure probability at "
                         "every lifecycle seam (chunk append, admit, "
                         "swap in/out), deterministically from "
                         "--fault-seed; greedy streams stay bit-identical")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="seed for the fault-injection schedule (printed; "
                         "replay any chaos run with the same seed)")
    ap.add_argument("--crash-round", type=int, default=None,
                    help="simulate a decode-engine crash at this round; "
                         "in-flight requests are recovered (replay, or "
                         "host-stash resubmission with --preserve-kv)")
    ap.add_argument("--preserve-kv", action="store_true",
                    help="crash recovery mode: the dead engine's HBM is "
                         "still readable, so in-flight KV is extracted to "
                         "host stashes instead of replaying from prompts")
    args = ap.parse_args()
    if args.prefix_cache and not args.paged:
        ap.error("--prefix-cache requires --paged")
    if args.kv_dtype != "fp32" and not args.paged:
        ap.error("--kv-dtype int8 requires --paged (per-page scales live in "
                 "the page pools)")
    if args.batch_dedup and not args.prefix_cache:
        ap.error("--batch-dedup requires --prefix-cache (deduped prefixes "
                 "fan out through the prefix index)")
    if args.chunk_tokens is not None:
        if not args.paged:
            ap.error("--chunk-tokens requires --paged (chunks stream into the "
                     "paged pool)")
        if args.chunk_tokens != "auto":
            try:
                args.chunk_tokens = int(args.chunk_tokens)
            except ValueError:
                ap.error("--chunk-tokens must be an integer or 'auto'")
            if args.chunk_tokens % args.page_size:
                ap.error("--chunk-tokens must be a multiple of --page-size "
                         "(chunk boundaries are page-aligned)")
        elif args.tbt_target_ms is None:
            ap.error("--chunk-tokens auto needs --tbt-target-ms (the SLO the "
                     "tuner sizes the quantum against)")
    if args.unified_batching and args.chunk_tokens is None:
        ap.error("--unified-batching requires --chunk-tokens (rider chunks "
                 "are what the round batches)")
    if args.token_budget is not None and not args.unified_batching:
        ap.error("--token-budget requires --unified-batching")
    if args.swap and args.scheduler != "priority":
        ap.error("--swap requires --scheduler priority")
    if args.swap and not args.paged:
        ap.error("--swap requires --paged (page-level preemption)")
    if args.preserve_kv and args.crash_round is None:
        ap.error("--preserve-kv only makes sense with --crash-round")
    if args.preserve_kv and not args.paged:
        ap.error("--preserve-kv requires --paged (page-granular extraction)")

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduce_cfg(cfg)
    params = M.init_params(jax.random.PRNGKey(args.seed), cfg)
    faults = None
    if args.fault_rate is not None or args.crash_round is not None:
        rates = (
            {s: args.fault_rate for s in FAULT_SITES}
            if args.fault_rate else {}
        )
        faults = FaultPlan(seed=args.fault_seed, rates=rates,
                           crash_round=args.crash_round,
                           preserve_kv=args.preserve_kv)
        print(f"# chaos: fault seed {args.fault_seed} "
              f"(replay with --fault-seed {args.fault_seed})")
    ec = EngineConfig(
        max_slots=args.max_slots, max_len=args.max_len,
        decode_block=args.decode_block, donate=not args.no_donate,
        paged=args.paged, page_size=args.page_size, n_pages=args.pages,
        prefix_cache=args.prefix_cache, kv_dtype=args.kv_dtype,
        batch_dedup=args.batch_dedup,
        chunk_tokens=args.chunk_tokens,
        tbt_target_ms=args.tbt_target_ms,
        unified_batching=args.unified_batching,
        token_budget=args.token_budget,
        sampling=SamplingParams(temperature=args.temperature),
        seed=args.seed, max_prefill_batch=args.prefill_batch,
        scheduler=args.scheduler,
        scheduler_kwargs={"swap": args.swap,
                          "shed_after_rounds": args.shed_after_rounds},
        faults=faults, audit_every=args.audit_every,
    )
    if args.replicas > 1:
        srv = Router(params, cfg, ec, replicas=args.replicas,
                     n_prefills=args.prefill_engines,
                     n_decodes=args.decode_engines)
        sched = srv.servers[0].scheduler
    else:
        srv = DisaggregatedServer.from_config(
            params, cfg, ec,
            n_prefills=args.prefill_engines, n_decodes=args.decode_engines)
        sched = srv.scheduler

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 64)))
        prio = 1 if (args.scheduler == "priority" and i % 4 == 0) else 0
        srv.submit(GenRequest(i, prompt, max_new_tokens=args.max_new,
                              priority=prio,
                              deadline_rounds=args.deadline_rounds,
                              ttft_deadline=args.ttft_deadline))
    t0 = time.time()
    results = srv.run()
    dt = time.time() - t0
    outcomes = srv.outcomes()
    statuses: dict = {}
    for o in outcomes.values():
        statuses[o.status] = statuses.get(o.status, 0) + 1
    n_tok = sum(len(v) for v in results.values())
    servers = srv.servers if args.replicas > 1 else [srv]
    scheds = [s.scheduler for s in servers]
    waits = sorted(w for sc in scheds for w in sc.queue_wait_rounds.values())
    report = {
        "arch": cfg.name,
        "scheduler": sched.name,
        "requests": len(results),
        "statuses": statuses,
        "total_new_tokens": n_tok,
        "wall_s": round(dt, 2),
        "tokens_per_s": round(n_tok / dt, 1),
        "queue_wait_rounds": {
            "p50": float(np.percentile(waits, 50)) if waits else 0.0,
            "p99": float(np.percentile(waits, 99)) if waits else 0.0,
        },
        "preemptions": sum(sc.stats["preemptions"] for sc in scheds),
        "swap_ins": sum(sc.stats["swap_ins"] for sc in scheds),
        "shed": sum(sc.stats["shed"] for sc in scheds),
    }
    if args.replicas > 1:
        report["replicas"] = args.replicas
        report["per_replica_requests"] = srv.load()
        report["routed_prefix_pages"] = sum(
            d.matched_pages for d in srv.trace
        )
    if faults is not None:
        report["faults"] = {
            "seed": args.fault_seed,
            "injected": sum(s.faults.stats["injected"] for s in servers),
            "crash_events": [e for s in servers for e in s.crash_events],
        }
    if args.audit_every:
        report["audit"] = "clean"  # audit(strict=True) would have raised
    print(json.dumps(report))
    assert len(results) == args.requests, "not all requests completed"


if __name__ == "__main__":
    main()
