"""FP001–FP005 rule implementations (AST layer).

Each rule is a class with a stable ``ID`` and a ``check(analysis) ->
list[Finding]`` method.  Rules are flow-insensitive and name-based by
design — they over-approximate, and legitimate findings are annotated with
``# fastpath: allow[FPxxx] <reason>`` so every exception is audited and
counted (see docs/analysis.md).

Rule summary:

- FP001 host-sync call reachable from the decode loop or a jit region
- FP002 use-after-donate: a donated argument read again in the caller
- FP003 unbounded jit-cache key: a ``len()``-derived scalar keys a jit cache
  without passing through a bucketing function
- FP004 acquire/release pairing: every hold increment needs a release path
  that funnels through ``_forget``
- FP005 unseeded ``np.random`` in serving/faults code
"""

from __future__ import annotations

import ast
from dataclasses import dataclass

from repro.analysis.callgraph import Analysis, FuncInfo, own_nodes

# FP001 -----------------------------------------------------------------
NUMPY_SYNC_FUNCS = {"asarray", "array"}
SYNC_METHODS = {"item", "block_until_ready"}
# FP003 -----------------------------------------------------------------
BOUNDER_NAMES = {"_bucket", "_pad_len", "bucket"}
JIT_CACHE_ATTR_SUFFIX = "fns"
# FP004 -----------------------------------------------------------------
HOLD_COUNTERS = {"_href", "_chunk_holds", "_scale_refs"}  # incremented hold structures
PIN_ACQUIRES = {"pin", "pin_prefix", "swap_pin"}
PIN_RELEASES = {"unpin", "release_prefix_pin", "swap_unpin"}
RELEASE_FUNNEL = "_forget"


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - very old nodes
        return "<expr>"


def _is_numpy_call(call: ast.Call, numpy_aliases: set[str], names: set[str]) -> bool:
    f = call.func
    return (
        isinstance(f, ast.Attribute)
        and f.attr in names
        and isinstance(f.value, ast.Name)
        and f.value.id in numpy_aliases
    )


class RuleFP001:
    """Host-sync calls reachable from the decode loop or a jit region."""

    ID = "FP001"

    def check(self, an: Analysis) -> list[Finding]:
        out = []
        hot = an.jit_set | an.loop_set
        for fn in an.funcs:
            if fn.qual not in hot:
                continue
            mod = an.modules[fn.path]
            in_jit = fn.qual in an.jit_set
            for node in own_nodes(fn):
                if not isinstance(node, ast.Call):
                    continue
                msg = self._sync_kind(node, mod.numpy_aliases, mod.jax_aliases, in_jit)
                if msg:
                    out.append(
                        Finding(
                            self.ID, fn.path, node.lineno, node.col_offset,
                            f"host sync `{msg}` on the decode/jit path "
                            f"(in {fn.name})",
                        )
                    )
        return out

    @staticmethod
    def _sync_kind(call, numpy_aliases, jax_aliases, in_jit) -> str | None:
        f = call.func
        if _is_numpy_call(call, numpy_aliases, NUMPY_SYNC_FUNCS):
            return _unparse(f)
        if isinstance(f, ast.Attribute):
            if f.attr == "device_get" and (
                isinstance(f.value, ast.Name) and f.value.id in jax_aliases
            ):
                return _unparse(f)
            if f.attr in SYNC_METHODS and not call.args:
                return f".{f.attr}()"
        elif isinstance(f, ast.Name):
            if f.id == "device_get":
                return "device_get"
            # int()/float() force a concrete value: only a sync when the
            # enclosing code is actually traced (inside a jit region)
            if (
                in_jit
                and f.id in ("int", "float")
                and call.args
                and not isinstance(call.args[0], ast.Constant)
            ):
                return f"{f.id}(...)"
        return None


# ---------------------------------------------------------------------------
# FP002: use-after-donate
# ---------------------------------------------------------------------------


def _donated_positions(call: ast.Call, wrappers: dict[str, int]) -> tuple | None:
    """If `call` builds a donating jitted callable, return donated positions."""
    f = call.func
    name = f.attr if isinstance(f, ast.Attribute) else getattr(f, "id", None)
    if name == "jit":
        for kw in call.keywords:
            if kw.arg == "donate_argnums":
                v = kw.value
                if isinstance(v, ast.Tuple):
                    return tuple(
                        e.value for e in v.elts if isinstance(e, ast.Constant)
                    )
                if isinstance(v, ast.Constant):
                    return (v.value,)
                return ()  # dynamic tuple: positions unknown
        return None
    if name in wrappers:
        for kw in call.keywords:
            if kw.arg == "donate_state_argnum" and isinstance(kw.value, ast.Constant):
                return (kw.value.value,)
        return (wrappers[name],)
    return None


def _donation_wrappers(an: Analysis) -> dict[str, int]:
    """Functions returning jax.jit(..., donate_argnums=(param,)) — name -> default."""
    out = {}
    for fn in an.funcs:
        params = getattr(fn.node, "args", None)
        if params is None:
            continue
        names = [a.arg for a in params.args]
        defaults = params.defaults
        for node in own_nodes(fn):
            if not (isinstance(node, ast.Return) and isinstance(node.value, ast.Call)):
                continue
            call = node.value
            cname = (
                call.func.attr
                if isinstance(call.func, ast.Attribute)
                else getattr(call.func, "id", None)
            )
            if cname != "jit":
                continue
            for kw in call.keywords:
                if kw.arg != "donate_argnums":
                    continue
                for ref in ast.walk(kw.value):
                    if isinstance(ref, ast.Name) and ref.id in names:
                        idx = names.index(ref.id)
                        off = idx - (len(names) - len(defaults))
                        default = 0
                        if 0 <= off < len(defaults) and isinstance(
                            defaults[off], ast.Constant
                        ):
                            default = defaults[off].value
                        out[fn.name] = default
    return out


class _DonationMap:
    """attr / dict-attr / factory names -> donated positions, per class."""

    def __init__(self, an: Analysis):
        self.wrappers = _donation_wrappers(an)
        self.attr: dict[str, tuple] = {}  # self.<name>(...) donates
        self.dict_attr: dict[str, tuple] = {}  # self.<name>[k](...) donates
        self.factory: dict[str, tuple] = {}  # self.<name>(k)(...) donates

        # donating-callable assignments can sit anywhere: module level
        # (`step = jax.jit(f, donate_argnums=...)`) or inside methods
        # (`self._release = self._jit(...)`)
        for mod in an.modules.values():
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Assign) or not isinstance(
                    node.value, ast.Call
                ):
                    continue
                pos = _donated_positions(node.value, self.wrappers)
                if pos is None:
                    continue
                for tgt in node.targets:
                    if isinstance(tgt, ast.Attribute):
                        self.attr[tgt.attr] = pos
                    elif isinstance(tgt, ast.Subscript) and isinstance(
                        tgt.value, ast.Attribute
                    ):
                        self.dict_attr[tgt.value.attr] = pos
                    elif isinstance(tgt, ast.Name):
                        self.attr[tgt.id] = pos

        # factory: a method whose body returns self._D[...] for a donating _D
        for fn in an.funcs:
            for node in own_nodes(fn):
                if not (
                    isinstance(node, ast.Return)
                    and isinstance(node.value, ast.Subscript)
                    and isinstance(node.value.value, ast.Attribute)
                ):
                    continue
                dname = node.value.value.attr
                if dname in self.dict_attr:
                    self.factory[fn.name] = self.dict_attr[dname]

    def positions_for(self, call: ast.Call) -> tuple | None:
        """Donated positions if `call` invokes a donating callable."""
        f = call.func
        if isinstance(f, ast.Name) and f.id in self.attr:
            return self.attr[f.id]
        if isinstance(f, ast.Attribute) and f.attr in self.attr:
            return self.attr[f.attr]
        if (
            isinstance(f, ast.Subscript)
            and isinstance(f.value, ast.Attribute)
            and f.value.attr in self.dict_attr
        ):
            return self.dict_attr[f.value.attr]
        if (
            isinstance(f, ast.Call)
            and isinstance(f.func, ast.Attribute)
            and f.func.attr in self.factory
        ):
            return self.factory[f.func.attr]
        return None


def _assigned_names(stmt: ast.AST) -> set[str]:
    """Unparsed targets this statement (re)binds, flattening tuples."""
    out = set()
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.For):
        targets = [stmt.target]
    for t in targets:
        if isinstance(t, (ast.Tuple, ast.List)):
            out.update(_unparse(e) for e in t.elts)
        else:
            out.add(_unparse(t))
    return out


def _reads_in(stmt: ast.AST, name: str) -> ast.AST | None:
    """First Load of `name` (an unparsed Name/Attribute chain) in stmt."""
    for node in ast.walk(stmt):
        if isinstance(node, (ast.Name, ast.Attribute)):
            if isinstance(getattr(node, "ctx", None), ast.Load):
                if _unparse(node) == name:
                    return node
    return None


_SIMPLE_STMTS = (ast.Assign, ast.AnnAssign, ast.AugAssign, ast.Expr, ast.Return)


def _blocks_of(fn_node: ast.AST):
    """Yield every statement list in the function, not descending into
    nested defs (those are separate FuncInfos with their own blocks)."""
    pending = [getattr(fn_node, "body", [])]
    while pending:
        block = pending.pop()
        yield block
        for stmt in block:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for fname in ("body", "orelse", "finalbody"):
                child = getattr(stmt, fname, None)
                if child:
                    pending.append(child)
            for handler in getattr(stmt, "handlers", []):
                pending.append(handler.body)


class RuleFP002:
    """A value passed through a donated position, then read again.

    Flow-insensitive within each statement block: the donated name must be
    rebound by the donating statement itself (the ``x = f(x)`` safe idiom)
    or never read again in the block.  A read inside a later nested block
    counts as a read — over-approximate on purpose.
    """

    ID = "FP002"

    def check(self, an: Analysis) -> list[Finding]:
        dm = _DonationMap(an)
        out = []
        for fn in an.funcs:
            for block in _blocks_of(fn.node):
                out.extend(self._check_block(dm, fn, block))
        return out

    def _check_block(self, dm, fn: FuncInfo, block) -> list[Finding]:
        findings = []
        for i, stmt in enumerate(block):
            if not isinstance(stmt, _SIMPLE_STMTS):
                continue
            for call in ast.walk(stmt):
                if not isinstance(call, ast.Call):
                    continue
                pos = dm.positions_for(call)
                if not pos:
                    continue
                for p in pos:
                    if not isinstance(p, int) or p >= len(call.args):
                        continue
                    arg = call.args[p]
                    if not isinstance(arg, (ast.Name, ast.Attribute)):
                        continue
                    name = _unparse(arg)
                    if name in _assigned_names(stmt):
                        continue  # donated-and-reassigned: the safe idiom
                    hit = self._later_read(block[i + 1:], name)
                    if hit is not None:
                        findings.append(
                            Finding(
                                self.ID, fn.path, hit.lineno, hit.col_offset,
                                f"`{name}` read after being donated at "
                                f"line {call.lineno} (in {fn.name})",
                            )
                        )
        return findings

    @staticmethod
    def _later_read(stmts, name):
        for stmt in stmts:
            if isinstance(stmt, _SIMPLE_STMTS) and name in _assigned_names(stmt):
                # a self-referencing rebind (x = f(x)) still reads first
                if isinstance(stmt, ast.Assign):
                    return _reads_in(stmt.value, name)
                return None
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    break
                if isinstance(node, (ast.Name, ast.Attribute)):
                    if isinstance(getattr(node, "ctx", None), ast.Load):
                        if _unparse(node) == name:
                            return node
        return None


# ---------------------------------------------------------------------------
# FP003: unbounded jit-cache keys
# ---------------------------------------------------------------------------


class RuleFP003:
    """len()-derived scalars keying a jit cache without bucketing."""

    ID = "FP003"

    def check(self, an: Analysis) -> list[Finding]:
        out = []
        for fn in an.funcs:
            out.extend(self._check_func(fn))
        return out

    def _check_func(self, fn: FuncInfo) -> list[Finding]:
        unbounded: set[str] = set()
        findings = []
        reported: set[str] = set()
        for stmt in ast.walk(fn.node):
            if isinstance(stmt, ast.Assign):
                names = _assigned_names(stmt)
                if self._expr_unbounded(stmt.value, unbounded):
                    unbounded |= names
                else:
                    unbounded -= names
        sites = []
        for node in own_nodes(fn):
            if not isinstance(node, ast.Subscript):
                continue
            base = node.value
            if not (
                isinstance(base, ast.Attribute)
                and base.attr.endswith(JIT_CACHE_ATTR_SUFFIX)
            ):
                continue
            if self._expr_unbounded(node.slice, unbounded):
                sites.append((node.lineno, node.col_offset, base.attr, node.slice))
        # one finding per distinct key, at its first (source-order) use
        for lineno, col, attr, key_node in sorted(sites, key=lambda s: (s[0], s[1])):
            key = _unparse(key_node)
            if key in reported:
                continue
            reported.add(key)
            findings.append(
                Finding(
                    self.ID, fn.path, lineno, col,
                    f"jit cache `{attr}` keyed by unbounded "
                    f"`{key}` (no bucketing; in {fn.name})",
                )
            )
        return findings

    def _expr_unbounded(self, expr: ast.AST, unbounded: set[str]) -> bool:
        """True when expr derives from len() without a bounding function."""
        if isinstance(expr, ast.Call):
            f = expr.func
            name = f.attr if isinstance(f, ast.Attribute) else getattr(f, "id", "")
            if name in BOUNDER_NAMES:
                return False
            if name == "min":
                return all(self._expr_unbounded(a, unbounded) for a in expr.args)
            if name == "len":
                return True
            return any(self._expr_unbounded(a, unbounded) for a in expr.args)
        if isinstance(expr, ast.Name):
            return expr.id in unbounded
        if isinstance(expr, ast.Attribute):
            return False  # config attrs / .shape: statically fixed
        if isinstance(expr, ast.Constant):
            return False
        return any(
            self._expr_unbounded(c, unbounded) for c in ast.iter_child_nodes(expr)
        )


# ---------------------------------------------------------------------------
# FP004: acquire/release pairing through _forget
# ---------------------------------------------------------------------------


class RuleFP004:
    """Every hold increment needs a release reachable from the _forget funnel."""

    ID = "FP004"

    def check(self, an: Analysis) -> list[Finding]:
        acquires: list[tuple[str, FuncInfo, ast.AST]] = []  # (kind, fn, node)
        releases: dict[str, list[FuncInfo]] = {}

        for fn in an.funcs:
            for node in own_nodes(fn):
                kind = self._acquire_kind(node)
                if kind:
                    acquires.append((kind, fn, node))
                for rkind in self._release_kinds(node):
                    releases.setdefault(rkind, []).append(fn)

        if not acquires:
            return []

        # the funnel: _forget itself, everything it (transitively) calls, and
        # its direct callers (cancel/abort wrappers route through it)
        funnel_roots = {f.qual for f in an.funcs if f.name == RELEASE_FUNNEL}
        funnel_roots |= {f.qual for f in an.callers_of(RELEASE_FUNNEL)}
        funnel = an.reachable(funnel_roots)

        out = []
        for kind, fn, node in acquires:
            ok = any(rf.qual in funnel for rf in releases.get(kind, []))
            if not ok:
                out.append(
                    Finding(
                        self.ID, fn.path, node.lineno, node.col_offset,
                        f"`{kind}` hold acquired here has no release path "
                        f"through `{RELEASE_FUNNEL}` (in {fn.name})",
                    )
                )
        return out

    @staticmethod
    def _counter_name(target: ast.AST) -> str | None:
        if isinstance(target, ast.Subscript) and isinstance(
            target.value, ast.Attribute
        ):
            if target.value.attr in HOLD_COUNTERS:
                return target.value.attr
        return None

    def _acquire_kind(self, node: ast.AST) -> str | None:
        if isinstance(node, ast.AugAssign) and isinstance(node.op, ast.Add):
            return self._counter_name(node.target)
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.BinOp):
            if isinstance(node.value.op, ast.Add):
                for tgt in node.targets:
                    name = self._counter_name(tgt)
                    if name:
                        return name
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in PIN_ACQUIRES:
                return "pin"
        return None

    def _release_kinds(self, node: ast.AST):
        if isinstance(node, ast.AugAssign) and isinstance(node.op, ast.Sub):
            name = self._counter_name(node.target)
            if name:
                yield name
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.BinOp):
            if isinstance(node.value.op, ast.Sub):
                for tgt in node.targets:
                    name = self._counter_name(tgt)
                    if name:
                        yield name
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            if node.func.attr in PIN_RELEASES:
                yield "pin"
            if node.func.attr == "pop" and isinstance(node.func.value, ast.Attribute):
                if node.func.value.attr in HOLD_COUNTERS:
                    yield node.func.value.attr
        # decrement written via .get(p, 0) - 1 then reassigned
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Sub):
            if isinstance(node.left, ast.Call) and isinstance(
                node.left.func, ast.Attribute
            ):
                inner = node.left.func
                if inner.attr == "get" and isinstance(inner.value, ast.Attribute):
                    if inner.value.attr in HOLD_COUNTERS:
                        yield inner.value.attr


# ---------------------------------------------------------------------------
# FP005: unseeded randomness in serving/faults code
# ---------------------------------------------------------------------------


class RuleFP005:
    """np.random.* outside default_rng(seed) breaks deterministic chaos."""

    ID = "FP005"
    SCOPE = ("serving", "faults")

    def check(self, an: Analysis) -> list[Finding]:
        out = []
        for mod in an.modules.values():
            if not any(part in mod.path for part in self.SCOPE):
                continue
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                f = node.func
                if not (
                    isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Attribute)
                    and f.value.attr == "random"
                    and isinstance(f.value.value, ast.Name)
                    and f.value.value.id in mod.numpy_aliases
                ):
                    continue
                if f.attr == "default_rng" and node.args:
                    continue  # seeded generator: the sanctioned entry point
                out.append(
                    Finding(
                        self.ID, mod.path, node.lineno, node.col_offset,
                        f"unseeded `np.random.{f.attr}` in serving/faults "
                        "code (use default_rng(seed))",
                    )
                )
        return out


ALL_RULES = (RuleFP001, RuleFP002, RuleFP003, RuleFP004, RuleFP005)
RULE_IDS = tuple(r.ID for r in ALL_RULES)
