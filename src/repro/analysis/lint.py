"""Lint driver: run FP rules over files, apply allow-comments, build a report.

Allow syntax (one per comment, reason mandatory)::

    x = np.asarray(tok)  # fastpath: allow[FP001] first-token readback
    # fastpath: allow[FP003] seed-compat mode trades cache boundedness
    key_ = (S, 0)

An allow on its own line targets the next line.  Every allow must suppress
at least one finding of its rule on its target line — a stale allow (clean
line) is itself an error, so the audit trail can never rot.
"""

from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path

from repro.analysis.callgraph import Analysis
from repro.analysis.rules import ALL_RULES, RULE_IDS, Finding

ALLOW_RE = re.compile(r"#\s*fastpath:\s*allow\[(FP\d{3})\]\s*(.*)$")


@dataclass(frozen=True)
class Allow:
    rule: str
    path: str
    comment_line: int  # where the comment sits
    target_line: int  # the line it suppresses
    reason: str


@dataclass
class Report:
    findings: list[Finding] = field(default_factory=list)  # unsuppressed
    allowed: list[tuple[Allow, Finding]] = field(default_factory=list)
    errors: list[Finding] = field(default_factory=list)  # stale / malformed allows

    @property
    def failed(self) -> bool:
        return bool(self.findings or self.errors)

    def counts(self) -> dict[str, dict[str, int]]:
        """{rule: {"findings": n, "allowed": n}} for the summary table."""
        out: dict[str, dict[str, int]] = {
            r: {"findings": 0, "allowed": 0} for r in RULE_IDS
        }
        for f in self.findings:
            out.setdefault(f.rule, {"findings": 0, "allowed": 0})["findings"] += 1
        for _, f in self.allowed:
            out.setdefault(f.rule, {"findings": 0, "allowed": 0})["allowed"] += 1
        return out


def parse_allows(path: str, src: str) -> tuple[list[Allow], list[Finding]]:
    """Extract allow-comments from real COMMENT tokens (docstrings that merely
    *mention* the syntax, like this module's, are not comments)."""
    allows, errors = [], []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except tokenize.TokenizeError:  # unparseable file: the AST pass reports it
        return allows, errors
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        i = tok.start[0]
        m = ALLOW_RE.search(tok.string)
        if m is None:
            if "fastpath:" in tok.string and "allow" in tok.string:
                errors.append(
                    Finding(
                        "FP000", path, i, 0,
                        "malformed fastpath allow comment (expected "
                        "`# fastpath: allow[FPxxx] <reason>`)",
                    )
                )
            continue
        rule, reason = m.group(1), m.group(2).strip()
        if not reason:
            errors.append(
                Finding(
                    "FP000", path, i, 0,
                    f"allow[{rule}] has no reason — every exception must "
                    "say why it is legitimate",
                )
            )
            continue
        own_line = tok.line.lstrip().startswith("#")
        target = i + 1 if own_line else i
        allows.append(Allow(rule, path, i, target, reason))
    return allows, errors


def collect_files(paths: list[str]) -> dict[str, str]:
    files: dict[str, str] = {}
    for p in paths:
        root = Path(p)
        if root.is_dir():
            for f in sorted(root.rglob("*.py")):
                files[str(f)] = f.read_text()
        elif root.suffix == ".py":
            files[str(root)] = root.read_text()
    return files


def lint_files(files: dict[str, str], select: set[str] | None = None) -> Report:
    """Run the rules over {path: source}; apply allows; return the report."""
    report = Report()
    an = Analysis(files)

    raw: list[Finding] = []
    for rule_cls in ALL_RULES:
        if select and rule_cls.ID not in select:
            continue
        raw.extend(rule_cls().check(an))

    allows: list[Allow] = []
    for path, src in files.items():
        file_allows, errors = parse_allows(path, src)
        allows.extend(file_allows)
        report.errors.extend(errors)

    by_site: dict[tuple[str, int, str], list[Allow]] = {}
    for a in allows:
        by_site.setdefault((a.path, a.target_line, a.rule), []).append(a)

    used: set[int] = set()
    for f in sorted(raw, key=lambda f: (f.path, f.line, f.col)):
        site = by_site.get((f.path, f.line, f.rule), [])
        if site:
            report.allowed.append((site[0], f))
            used.add(id(site[0]))
        else:
            report.findings.append(f)

    for a in allows:
        if select and a.rule not in select:
            continue
        if id(a) not in used:
            report.errors.append(
                Finding(
                    "FP000", a.path, a.comment_line, 0,
                    f"stale allow[{a.rule}]: no {a.rule} finding on line "
                    f"{a.target_line} — remove the comment",
                )
            )
    return report


def lint_paths(paths: list[str], select: set[str] | None = None) -> Report:
    return lint_files(collect_files(paths), select=select)
