"""Fast-path invariant analyzer.

Two layers:

- :mod:`repro.analysis.lint` — AST-level rules FP001..FP005 over the source
  tree (no jax import needed; runs anywhere in milliseconds).
- :mod:`repro.analysis.trace_verify` — jaxpr/executable-level verification of
  the real engine (donation aliasing, no host-sync primitives in the decode
  body, bounded compile counts).  Imports jax + the serving engine.

CLI front end: ``tools/fastpath_lint.py``.  Rules and the allow-comment
syntax are documented in ``docs/analysis.md``.
"""

from repro.analysis.lint import Report, lint_files, lint_paths  # noqa: F401
from repro.analysis.rules import Finding  # noqa: F401
