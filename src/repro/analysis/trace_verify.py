"""Layer 2: jaxpr/executable-level verification of the real engine.

Three checks, all against *lowered artifacts* rather than source text, so a
refactor cannot silently regress the fast-path contracts:

- **decode-body purity**: the jaxpr of the fused ``step_block`` body must
  contain no host-callback or device-transfer primitives — nothing inside
  the scanned decode loop may talk to the host.
- **donation aliasing**: for every jitted donated transition (``step_block``,
  admit, release, ``paged_append_chunk`` — including the unified-batching
  B>1 chunk-group variant) the compiled executable must
  report an ``input_output_alias`` entry for every donated state leaf.  A
  donation that XLA declined (shape/dtype mismatch after a refactor) would
  double KV memory and break the bytes-touched-once argument — this check
  turns that into a test failure.
- **compile-count boundedness**: replaying a sweep of prompt lengths through
  the bucketed prefill must create at most ``len(buckets)`` cache entries.

Everything runs on CPU XLA with a reduced config (a few seconds), so it can
sit in the tier-1 matrix; ``tools/fastpath_lint.py --trace`` runs the same
checks from the CLI.
"""

from __future__ import annotations

import re

# primitives that move data to/from the host or call back into Python; none
# of these may appear inside the scanned decode body
BANNED_PRIMITIVES = (
    "io_callback",
    "pure_callback",
    "python_callback",
    "callback",
    "host_callback",
    "outside_call",
    "device_put",
    "infeed",
    "outfeed",
    "debug_print",
)

_ALIAS_RE = re.compile(r"\{([\d,\s]*)\}:\s*\((\d+)")


def _jaxpr_primitives(jaxpr) -> set[str]:
    """All primitive names in a (closed) jaxpr, recursing into sub-jaxprs
    (pjit/scan/while bodies live in eqn.params).

    ``device_put`` of a *literal* is constant placement (jnp.int32(1) inside
    a traced body — folded at compile time, no runtime transfer) and is not
    counted; ``device_put`` of a traced var is.
    """
    from jax.core import Literal

    names: set[str] = set()

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "device_put" and all(
                isinstance(v, Literal) for v in eqn.invars
            ):
                continue
            names.add(eqn.primitive.name)
            for v in eqn.params.values():
                inner = getattr(v, "jaxpr", None)
                if inner is not None:
                    walk(inner)
                elif hasattr(v, "eqns"):
                    walk(v)

    walk(getattr(jaxpr, "jaxpr", jaxpr))
    return names


def decode_body_violations(engine, k: int | None = None) -> list[str]:
    """Banned-primitive scan of the fused decode block's jaxpr."""
    import jax

    k = k if k is not None else engine.decode_block
    fn = engine._block_fn(k)
    jaxpr = jax.make_jaxpr(fn)(engine.params, engine.state)
    hits = sorted(_jaxpr_primitives(jaxpr) & set(BANNED_PRIMITIVES))
    return [
        f"decode body (step_block k={k}) contains host-sync primitive `{p}`"
        for p in hits
    ]


def _aliased_param_indices(fn, *args) -> set[int]:
    """Flat parameter indices the compiled executable aliases to an output.

    The first line of the compiled HLO carries
    ``input_output_alias={ {out}: (param, {}, may-alias), ... }``.
    """
    compiled = fn.lower(*args).compile()
    text = compiled.as_text().splitlines()[0]
    return {int(param) for _out, param in _ALIAS_RE.findall(text)}


def _leaf_names(tree) -> list[str]:
    """Key-path names for every leaf of a pytree, in flatten order."""
    import jax

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [jax.tree_util.keystr(path) for path, _leaf in flat]


def donation_violations(fn, donate_pos: int, tag: str, *args) -> list[str]:
    """Every leaf of args[donate_pos] must be aliased to an output."""
    import jax

    aliased = _aliased_param_indices(fn, *args)
    problems = []
    offset = 0
    for i, arg in enumerate(args):
        n_leaves = len(jax.tree_util.tree_leaves(arg))
        if i == donate_pos:
            names = _leaf_names(arg)
            for j in range(n_leaves):
                if offset + j not in aliased:
                    problems.append(
                        f"{tag}: donated leaf `{names[j]}` (flat param "
                        f"{offset + j}) has no input_output_alias — "
                        "donation silently degraded to a copy"
                    )
        offset += n_leaves
    return problems


def engine_donation_violations(engine, kv_pack=None) -> list[str]:
    """Donation-aliasing check for every donated engine transition."""
    import jax.numpy as jnp

    problems = []
    k = engine.decode_block
    problems += donation_violations(
        engine._block_fn(k), 1, f"step_block(k={k})", engine.params, engine.state
    )
    keep = jnp.ones((engine.max_slots,), bool)
    problems += donation_violations(
        engine._release, 0, "release", engine.state, keep
    )
    if kv_pack is not None:
        args = (
            engine.state,
            kv_pack,
            jnp.int32(0),
            jnp.int32(0),
            jnp.int32(1),
            jnp.int32(1),
        )
        if engine.paged:
            import numpy as np

            pps = engine.pages_per_slot
            args += (
                jnp.asarray(np.full((pps,), -1, np.int32)),
                jnp.int32(0),
                jnp.asarray(np.zeros((pps,), bool)),
                jnp.int32(0),
            )
        problems += donation_violations(
            engine._admit_fn(kv_pack), 0, "admit", *args
        )
    return problems


def unified_donation_violations(prefill, decode, n_tokens: int = 32) -> list[str]:
    """Donation-aliasing check for the unified batched-chunk transition.

    A unified round's device work is ``prefill_chunk_group`` (pure — the
    pack is a fresh output) followed by one ``append_chunk(kv_group,
    batch_index=i)`` per row: the same donated ``paged_append_chunk``
    closure as serial chunked prefill, but compiled against a B>1 pack.
    A declined donation here copies the whole page pool once per rider
    row — exactly the cost unified batching exists to avoid — so prove
    the aliasing on the lowered executable, not the source."""
    import jax
    import jax.numpy as jnp

    if not decode.paged:
        return ["unified donation check needs a paged DecodeEngine"]
    reqs = [_gen_request(i, list(range(1, n_tokens + 1))) for i in (1, 2)]
    kv_group = prefill.prefill_chunk_group(
        [(r, 0) for r in reqs], n_tokens, jax.random.PRNGKey(2),
        pad_to=n_tokens,
    )
    B = jax.tree_util.tree_leaves(kv_group)[0].shape[1]
    pages = decode.append_chunk(kv_group, n_tokens, batch_index=0)
    if pages is None:
        return ["unified donation check: pool cannot hold the probe chunk"]
    decode.release_chunk_holds(pages)
    n_alloc = n_tokens // decode.page_size
    keys = [k for k in decode._append_fns if k[1] == B and k[2] == n_alloc]
    return donation_violations(
        decode._append_fns[keys[-1]], 0, f"unified append_chunk(B={B})",
        decode.state, kv_group, jnp.int32(0),
    )


def compile_count_violations(prefill, lengths) -> list[str]:
    """Replaying `lengths` through the bucketed prefill must stay within the
    bucket list (one jit-cache entry per touched bucket)."""
    if not prefill.bucketed:
        return ["compile-count check needs a bucketed PrefillEngine"]
    touched = {prefill._pad_len(n) for n in lengths}
    before = len(prefill._fns)
    import jax

    for n in lengths:
        req_tokens = list(range(1, n + 1))
        prefill.prefill(_gen_request(0, req_tokens), jax.random.PRNGKey(0))
    grown = len(prefill._fns) - before
    problems = []
    if grown > len(touched):
        problems.append(
            f"prefill compiled {grown} entries for {len(lengths)} lengths "
            f"spanning {len(touched)} buckets — jit-cache key is unbounded"
        )
    if len(prefill._fns) > 2 * len(prefill.buckets):
        problems.append(
            f"prefill jit cache has {len(prefill._fns)} entries for "
            f"{len(prefill.buckets)} buckets"
        )
    return problems


def _gen_request(rid, tokens):
    import numpy as np

    from repro.serving.engine import GenRequest

    return GenRequest(rid, np.asarray(tokens, np.int32), 4)


def build_tiny_engines(paged: bool = True):
    """(prefill, decode, kv_pack) on a reduced config — shared by the CLI
    and tests/test_donation_aliasing.py."""
    import jax

    from repro.configs import ARCHS, reduced
    from repro.models import model as M
    from repro.serving import DecodeEngine, PrefillEngine, SamplingParams

    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sp = SamplingParams(temperature=0.0)
    prefill = PrefillEngine(params, cfg, sp)
    decode = DecodeEngine(
        params, cfg, max_slots=2, max_len=64, sampling=sp,
        decode_block=2, paged=paged, page_size=16,
    )
    _tok, kv_pack, _tl = prefill.prefill(
        _gen_request(0, list(range(1, 9))), jax.random.PRNGKey(1)
    )
    return prefill, decode, kv_pack


def verify_all() -> list[str]:
    """Run every layer-2 check; returns a list of violations (empty = clean)."""
    prefill, decode, kv_pack = build_tiny_engines(paged=True)
    problems = decode_body_violations(decode)
    problems += engine_donation_violations(decode, kv_pack)
    problems += unified_donation_violations(prefill, decode)
    problems += compile_count_violations(prefill, [3, 5, 9, 17, 20])
    return problems
