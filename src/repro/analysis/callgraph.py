"""Name-based call graph + jit-region detection over a set of Python files.

This is deliberately a *name-based* (duck-typed) call graph: ``self.m(...)``
resolves to methods named ``m`` — same class first, then any analyzed class;
``f(...)`` resolves to module-level functions named ``f`` — same module first,
then any analyzed module.  That over-approximates reachability, which is the
right bias for a lint (a host sync that *might* be on the decode path should
be annotated, not invisible).

Jit regions: a function is a *jit entry* when it is passed to ``jax.jit`` /
``jax.lax.scan`` (directly, via a decorator, or via a local wrapper like
``DecodeEngine._jit`` whose body returns ``jax.jit(...)``).  Functions
lexically nested inside a jit entry are traced too.  The transitive closure
of the call graph from jit entries is the JIT set; the closure from the
decode-loop roots (``step_block`` / ``run_round`` / ``run`` / ``step``) is
the LOOP set.  FP001 only fires inside JIT ∪ LOOP.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass, field

# Functions whose bodies start the decode loop: anything reachable from these
# runs per-token (or per-block) in steady state.
DECODE_ROOTS = ("step_block", "run_round", "run", "step")


@dataclass
class FuncInfo:
    """One function/method in the analyzed set."""

    path: str
    cls: str | None
    name: str
    node: ast.AST  # FunctionDef | AsyncFunctionDef | Lambda
    lineno: int
    jit_entry: bool = False  # body is traced (passed to jit/scan or nested in one)
    # (kind, name, base): base is the attribute base for method calls
    # ("self", a module alias, or another object name), else None
    calls: list[tuple[str, str, str | None]] = field(default_factory=list)

    @property
    def qual(self) -> str:
        owner = f"{self.cls}." if self.cls else ""
        return f"{self.path}::{owner}{self.name}"


@dataclass
class ModuleInfo:
    path: str
    tree: ast.Module
    src: str
    lines: list[str]
    numpy_aliases: set[str] = field(default_factory=set)  # e.g. {"np"}
    jax_aliases: set[str] = field(default_factory=set)  # e.g. {"jax"}
    module_aliases: set[str] = field(default_factory=set)  # all imported names
    funcs: list[FuncInfo] = field(default_factory=list)


def _collect_imports(mod: ModuleInfo) -> None:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                bound = alias.asname or alias.name.split(".")[0]
                mod.module_aliases.add(bound)
                if alias.name == "numpy":
                    mod.numpy_aliases.add(bound)
                if alias.name == "jax":
                    mod.jax_aliases.add(bound)
        elif isinstance(node, ast.ImportFrom):
            # `from jax import numpy as jnp` must NOT count as numpy: jnp is
            # device-side.  Only `from numpy import ...` would, and the repo
            # never does that for asarray.
            for alias in node.names:
                mod.module_aliases.add(alias.asname or alias.name)


def _collect_funcs(mod: ModuleInfo) -> None:
    """Populate mod.funcs with lexical class ownership."""

    def visit(node: ast.AST, cls: str | None) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.ClassDef):
                visit(child, child.name)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                mod.funcs.append(
                    FuncInfo(mod.path, cls, child.name, child, child.lineno)
                )
                # nested defs keep the lexical class owner (methods defining
                # local closures); good enough for name-based resolution
                visit(child, cls)
            else:
                visit(child, cls)

    visit(mod.tree, None)


def own_nodes(func: FuncInfo):
    """Yield AST nodes of *this* function body only, not nested defs."""
    stack = list(ast.iter_child_nodes(func.node))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def _callee_name(call: ast.Call) -> tuple[str, str, str | None] | None:
    """Classify a call target: ("method", name, base) / ("func", name, None)."""
    f = call.func
    if isinstance(f, ast.Attribute):
        base = f.value.id if isinstance(f.value, ast.Name) else None
        return ("method", f.attr, base)
    if isinstance(f, ast.Name):
        return ("func", f.id, None)
    return None


def _jit_wrapper_names(funcs: list[FuncInfo]) -> set[str]:
    """Functions whose body returns jax.jit(...) — e.g. DecodeEngine._jit."""
    out = set()
    for fn in funcs:
        for node in own_nodes(fn):
            if not (isinstance(node, ast.Return) and isinstance(node.value, ast.Call)):
                continue
            callee = node.value.func
            if isinstance(callee, ast.Attribute) and callee.attr == "jit":
                out.add(fn.name)
            elif isinstance(callee, ast.Name) and callee.id == "jit":
                out.add(fn.name)
    return out


def _is_jit_caller(call: ast.Call, wrappers: set[str]) -> bool:
    """True when `call` is jax.jit(f...), lax.scan(f...), or a wrapper(f...)."""
    f = call.func
    if isinstance(f, ast.Attribute):
        if f.attr in ("jit", "scan", "fori_loop", "while_loop", "cond", "switch"):
            return True
        if f.attr in wrappers:
            return True
    elif isinstance(f, ast.Name):
        if f.id in ("jit",) or f.id in wrappers:
            return True
    return False


class Analysis:
    """Parsed modules + call graph + JIT/LOOP reachability sets."""

    def __init__(self, files: dict[str, str]):
        """files: {path: source}."""
        self.modules: dict[str, ModuleInfo] = {}
        for path, src in sorted(files.items()):
            tree = ast.parse(src, filename=path)
            mod = ModuleInfo(path, tree, src, src.splitlines())
            _collect_imports(mod)
            _collect_funcs(mod)
            self.modules[path] = mod

        self.funcs: list[FuncInfo] = [
            f for m in self.modules.values() for f in m.funcs
        ]
        self.jit_wrappers = _jit_wrapper_names(self.funcs)
        self._mark_jit_entries()
        self._build_edges()
        self.jit_set = self.reachable(
            {f.qual for f in self.funcs if f.jit_entry}
        )
        self.loop_set = self.reachable(
            {
                f.qual
                for f in self.funcs
                if f.name in DECODE_ROOTS and "serving" in f.path
            }
        )

    # ----------------------------------------------------------- jit regions
    def _mark_jit_entries(self) -> None:
        by_key: dict[tuple[str, str], list[FuncInfo]] = {}
        for fn in self.funcs:
            by_key.setdefault((fn.path, fn.name), []).append(fn)

        for mod in self.modules.values():
            for node in ast.walk(mod.tree):
                if not isinstance(node, ast.Call):
                    continue
                if not _is_jit_caller(node, self.jit_wrappers):
                    continue
                for arg in node.args[:1]:  # traced callable is arg 0
                    if isinstance(arg, ast.Name):
                        for fn in by_key.get((mod.path, arg.id), []):
                            fn.jit_entry = True

        # decorators: @jax.jit / @jit / @partial(jax.jit, ...)
        for fn in self.funcs:
            decorators = getattr(fn.node, "decorator_list", [])
            for dec in decorators:
                target = dec.func if isinstance(dec, ast.Call) else dec
                names = [target] + (dec.args if isinstance(dec, ast.Call) else [])
                for n in names:
                    if (isinstance(n, ast.Attribute) and n.attr == "jit") or (
                        isinstance(n, ast.Name) and n.id == "jit"
                    ):
                        fn.jit_entry = True

        # lexical nesting: a def inside a jit entry is traced when called
        changed = True
        while changed:
            changed = False
            for fn in self.funcs:
                if fn.jit_entry:
                    continue
                for other in self.funcs:
                    if other.jit_entry and other.path == fn.path:
                        if _encloses(other.node, fn.node):
                            fn.jit_entry = True
                            changed = True
                            break

    # ------------------------------------------------------------ call graph
    def _build_edges(self) -> None:
        for fn in self.funcs:
            for node in own_nodes(fn):
                if isinstance(node, ast.Call):
                    name = _callee_name(node)
                    if name:
                        fn.calls.append(name)

        # resolution indexes
        self._methods: dict[str, list[FuncInfo]] = {}
        self._module_funcs: dict[tuple[str, str], list[FuncInfo]] = {}
        self._any_funcs: dict[str, list[FuncInfo]] = {}
        for fn in self.funcs:
            if fn.cls:
                self._methods.setdefault(fn.name, []).append(fn)
            else:
                self._module_funcs.setdefault((fn.path, fn.name), []).append(fn)
            self._any_funcs.setdefault(fn.name, []).append(fn)

    def resolve(
        self, caller: FuncInfo, kind: str, name: str, base: str | None = None
    ) -> list[FuncInfo]:
        if kind == "method":
            # `mod.func(...)`: the base is an imported module alias — resolve
            # to module-level functions named `name` (prefer `<base>.py`)
            if base and base != "self":
                mod = self.modules.get(caller.path)
                if mod and base in mod.module_aliases:
                    cands = [
                        f for f in self._any_funcs.get(name, []) if f.cls is None
                    ]
                    best = [f for f in cands if f.path.endswith(f"{base}.py")]
                    if best or cands:
                        return best or cands
            if base == "self":
                same_cls = [
                    f
                    for f in self._methods.get(name, [])
                    if f.cls == caller.cls and f.path == caller.path
                ]
                return same_cls or self._methods.get(name, [])
            # unknown object: duck-type to every method of that name (plus
            # module-level functions — `obj` may be a module we missed)
            return self._methods.get(name, []) + [
                f for f in self._any_funcs.get(name, []) if f.cls is None
            ]
        local = self._module_funcs.get((caller.path, name), [])
        return local or self._any_funcs.get(name, [])

    def reachable(self, roots: set[str]) -> set[str]:
        by_qual = {f.qual: f for f in self.funcs}
        seen = set()
        frontier = [by_qual[q] for q in roots if q in by_qual]
        while frontier:
            fn = frontier.pop()
            if fn.qual in seen:
                continue
            seen.add(fn.qual)
            for kind, name, base in fn.calls:
                for callee in self.resolve(fn, kind, name, base):
                    if callee.qual not in seen:
                        frontier.append(callee)
        return seen

    def callers_of(self, name: str) -> list[FuncInfo]:
        """Functions with a call edge to any function/method named `name`."""
        out = []
        for fn in self.funcs:
            if any(n == name for _, n, _b in fn.calls):
                out.append(fn)
        return out


def _encloses(outer: ast.AST, inner: ast.AST) -> bool:
    if outer is inner:
        return False
    for node in ast.walk(outer):
        if node is inner:
            return True
    return False
