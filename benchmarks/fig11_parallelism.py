"""Fig. 11: chip performance under various TP / PP configurations."""
from repro.configs import get_config
from repro.core import DECODE_CHIP, H100, PREFILL_CHIP, Parallelism
from repro.core.opgraph import phase_ops
from repro.core.perfmodel import run_graph

from .common import Bench


def main():
    b = Bench("fig11_parallelism")
    bloom = get_config("bloom-176b")
    for tp, pp in [(8, 1), (4, 2), (2, 4)]:
        par = Parallelism(tp=tp, pp=pp)
        for phase, batch, chip in [
            ("prefill", 2, PREFILL_CHIP),
            ("decode", 64, DECODE_CHIP),
        ]:
            ops = phase_ops(bloom, phase=phase, batch=batch, seq=1024, par=par)
            ours = run_graph(chip, ops).total
            h = run_graph(H100, ops).total
            b.row(f"tp{tp}_pp{pp}_{chip.name}_{phase}_rel", h / ours,
                  "paper fig11: consistent across parallelisms")
    return b.dump()


if __name__ == "__main__":
    main()
