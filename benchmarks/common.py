"""Shared benchmark plumbing: cached perf tables, timing, row printing."""
from __future__ import annotations

import functools
import os
import time
from typing import Dict, List

from repro.configs import get_config
from repro.core import A100, DECODE_CHIP, H100, H100_PCAP, PREFILL_CHIP, Parallelism
from repro.core.cluster import ModelPerf

FAST = os.environ.get("BENCH_FAST", "") not in ("", "0")
SIM_DURATION = 25.0 if FAST else 40.0
RATE = 70.0

_CACHE: Dict[tuple, ModelPerf] = {}


def perf(chip, model: str = "bloom-176b", tp: int = 8, ep: int = 1, w_bytes: float = 2.0) -> ModelPerf:
    key = (chip.name, model, tp, ep, w_bytes)
    if key not in _CACHE:
        _CACHE[key] = ModelPerf(
            chip, get_config(model), Parallelism(tp=tp, ep=ep), w_bytes=w_bytes
        )
    return _CACHE[key]


class Bench:
    """Collects (name, value, derived) rows and prints a table."""

    def __init__(self, title: str):
        self.title = title
        self.rows: List[tuple] = []
        self.t0 = time.time()

    def row(self, name: str, value, derived: str = ""):
        self.rows.append((name, value, derived))

    def dump(self) -> List[str]:
        out = [f"== {self.title} ==  ({time.time()-self.t0:.1f}s)"]
        for name, value, derived in self.rows:
            if isinstance(value, float):
                value = f"{value:.4g}"
            out.append(f"{name},{value},{derived}")
        print("\n".join(out), flush=True)
        return out
