"""Fig. 2: simulated prefill latency under varying memory bandwidth."""
import dataclasses

from repro.configs import get_config
from repro.core import H100, Parallelism
from repro.core.opgraph import phase_ops
from repro.core.perfmodel import run_graph

from .common import Bench


def main():
    b = Bench("fig2_prefill_bw")
    bloom = get_config("bloom-176b")
    ops = phase_ops(bloom, phase="prefill", batch=2, seq=1024, par=Parallelism(tp=8))
    base = run_graph(H100, ops).total
    b.row("h100_prefill_ms", base * 1e3, "B=2 S=1024 TP=8 FP16")
    paper = {2500: "+8%", 2000: "+17%", 1500: "+32%"}
    for bw in [1000, 1500, 2000, 2500, 3000, 3352, 4000]:
        t = run_graph(dataclasses.replace(H100, mem_bw_override_gbs=float(bw)), ops).total
        b.row(f"bw_{bw}GBs_rel_latency", t / base,
              f"paper: {paper.get(bw, '')}")
    return b.dump()


if __name__ == "__main__":
    main()
