"""Fig. 6: Decode Chip design space exploration (area vs decode latency)."""
from repro.configs import get_config
from repro.core import DECODE_CHIP
from repro.core.dse import decode_candidates, pareto, sweep

from .common import Bench, FAST


def main():
    b = Bench("fig6_decode_dse")
    cands = decode_candidates()
    if FAST:
        cands = cands[:: max(1, len(cands) // 48)]
    pts = sweep(cands, get_config("bloom-176b"), phase="decode", batch=64, seq=1024)
    front = pareto(pts)
    b.row("candidates", len(pts))
    b.row("pareto_points", len(front))
    for p in front[:12]:
        b.row(f"pareto_{p.chip.name}", p.norm_latency, f"area={p.area_mm2:.0f}mm2")
    chosen = sweep([DECODE_CHIP], get_config("bloom-176b"), phase="decode", batch=64, seq=1024)[0]
    b.row("chosen_decode_chip", chosen.norm_latency,
          f"area={chosen.area_mm2:.0f}mm2 (paper: 0.97x perf at 520mm2)")
    return b.dump()


if __name__ == "__main__":
    main()
