"""Table 3: full chip specification + cost/TDP model reproduction."""
from repro.core import A100, DECODE_CHIP, H100, H100_PCAP, PREFILL_CHIP
from repro.core.hardware import (
    die_area_mm2,
    die_cost,
    hw_cost,
    memory_cost,
    norm_hw_cost,
    norm_tdp,
    tdp_w,
)

from .common import Bench

PAPER = {  # (PFLOPs, vecTF, area, die$, mem$, tdp, norm_cost)
    "PrefillChip": (1.92, 32.4, 784, 301, 192, 596, 0.48),
    "DecodeChip": (0.54, 18.2, 520, 187, 720, 507, 0.88),
    "H100": (0.99, 66.9, 814, 315, 720, 700, 1.00),
}


def main():
    b = Bench("table3_chips")
    for chip in (PREFILL_CHIP, DECODE_CHIP, H100):
        p = PAPER[chip.name]
        b.row(f"{chip.name}_tensor_pflops", chip.tensor_flops / 1e15, f"paper {p[0]}")
        b.row(f"{chip.name}_vector_tflops", chip.vector_flops / 1e12, f"paper {p[1]}")
        b.row(f"{chip.name}_die_area_mm2", die_area_mm2(chip), f"paper {p[2]}")
        b.row(f"{chip.name}_die_cost_usd", die_cost(chip), f"paper {p[3]}")
        b.row(f"{chip.name}_mem_cost_usd", memory_cost(chip), f"paper {p[4]}")
        b.row(f"{chip.name}_tdp_w", tdp_w(chip), f"paper {p[5]}")
        b.row(f"{chip.name}_norm_hw_cost", norm_hw_cost(chip), f"paper {p[6]}")
    return b.dump()


if __name__ == "__main__":
    main()
