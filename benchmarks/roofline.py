"""Roofline report (deliverable g): reads the dry-run artifacts and prints
the three-term roofline per (arch x shape) on the single-pod mesh."""
import os

from repro.launch.roofline import RESULTS_DIR, load_all, report

from .common import Bench


def main():
    b = Bench("roofline")
    d = os.path.abspath(RESULTS_DIR)
    cells = load_all(d, mesh="single", sharding="baseline")
    if not cells:
        b.row("status", "no dry-run artifacts",
              "run: PYTHONPATH=src python -m repro.launch.dryrun")
        return b.dump()
    opt = {(c.arch, c.shape): c for c in load_all(d, mesh="single", sharding="optimized")}
    for c in cells:
        o = opt.get((c.arch, c.shape))
        extra = f" | OPT bound={o.bound_s:.2f}s ({c.bound_s/max(o.bound_s,1e-9):.1f}x)" if o else ""
        b.row(
            f"{c.arch}__{c.shape}",
            round(c.bound_s, 4),
            f"dom={c.dominant} comp={c.t_compute*1e3:.1f}ms mem={c.t_memory*1e3:.1f}ms "
            f"coll={c.t_collective*1e3:.1f}ms useful={c.useful_ratio:.3f}{extra}",
        )
    doms = {}
    for c in cells:
        doms[c.dominant] = doms.get(c.dominant, 0) + 1
    b.row("dominant_histogram", str(doms).replace(",", ";"), "")
    if opt:
        import math

        ratios = [
            c.bound_s / max(opt[(c.arch, c.shape)].bound_s, 1e-9)
            for c in cells
            if (c.arch, c.shape) in opt
        ]
        if ratios:
            gm = math.exp(sum(math.log(r) for r in ratios) / len(ratios))
            b.row("optimized_geomean_speedup", round(gm, 2),
                  f"over {len(ratios)} cells (roofline bound, single mesh)")
    print(report(d, mesh="single"))
    return b.dump()


if __name__ == "__main__":
    main()
