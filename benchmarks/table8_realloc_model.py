"""Table 8: adaptive reallocation after a model change (workload fixed).

SPAD clusters provisioned for BLOOM-176B serve Llama3-70B (GQA, TP=4 -> 2
replicas/machine) and DeepSeek-V2 (MLA+MoE, FP8, EP=8) after reallocation.
"""
from repro.core import DECODE_CHIP, H100, PREFILL_CHIP
from repro.core.cluster import SLOS
from repro.core.provision import best_realloc_split, provision_disagg
from repro.core.trace import CODING, CONVERSATION

from .common import SIM_DURATION, Bench, perf

CASES = [
    # (cluster tag, nP, nD, model, tp, ep, w_bytes, workload, paper note)
    ("18P7D_llama3", 18, 7, "llama3-70b", 4, 1, 2.0, CODING,
     "paper: 188 rps, 43% HW / 22% TDP saving"),
    ("8P17D_llama3", 8, 17, "llama3-70b", 4, 1, 2.0, CONVERSATION,
     "paper: 171 rps, 31% HW / 29% TDP saving"),
    ("18P7D_deepseek", 18, 7, "deepseek-v2-236b", 1, 8, 1.0, CODING,
     "paper: 103 rps, 36% HW / 11% TDP saving"),
    ("8P17D_deepseek", 8, 17, "deepseek-v2-236b", 1, 8, 1.0, CONVERSATION,
     "paper: 183 rps, 22% HW / 20% TDP saving"),
]


def main():
    b = Bench("table8_realloc_model")
    slo = SLOS["normal"]
    for tag, n_p, n_d, model, tp, ep, wb, wl, note in CASES:
        ref = perf(H100, model, tp=tp, ep=ep, w_bytes=wb)
        design, rate = best_realloc_split(
            name=tag,
            perf_p_prefill=perf(PREFILL_CHIP, model, tp=tp, ep=ep, w_bytes=wb),
            perf_p_decode=perf(PREFILL_CHIP, model, tp=tp, ep=ep, w_bytes=wb),
            perf_d_prefill=perf(DECODE_CHIP, model, tp=tp, ep=ep, w_bytes=wb),
            perf_d_decode=perf(DECODE_CHIP, model, tp=tp, ep=ep, w_bytes=wb),
            n_p_machines=n_p,
            n_d_machines=n_d,
            workload=wl,
            slo=slo,
            ref_perf=ref,
            duration=SIM_DURATION,
        )
        b.row(f"{tag}_rate_rps", rate, f"{design.describe() if design else '-'} | {note}")
        if rate <= 0:
            continue
        baseline = provision_disagg(
            name="homo", prefill_perf=ref, decode_perf=ref,
            workload=wl, rate=max(rate, 5.0), slo=slo, ref_perf=ref,
            duration=SIM_DURATION,
        )
        if baseline:
            b.row(f"{tag}_hw_saving", 1 - design.norm_cost / baseline.norm_cost,
                  f"baseline {baseline.describe()}")
            b.row(f"{tag}_tdp_saving", 1 - design.norm_tdp / baseline.norm_tdp, "")
    return b.dump()


if __name__ == "__main__":
    main()
