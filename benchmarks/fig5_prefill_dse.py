"""Fig. 5: Prefill Chip design space exploration (area vs prefill latency)."""
from repro.configs import get_config
from repro.core import PREFILL_CHIP
from repro.core.dse import pareto, prefill_candidates, sweep

from .common import Bench, FAST


def main():
    b = Bench("fig5_prefill_dse")
    cands = prefill_candidates()
    if FAST:
        cands = cands[:: max(1, len(cands) // 48)]
    pts = sweep(cands, get_config("bloom-176b"), phase="prefill", batch=2, seq=1024)
    front = pareto(pts)
    b.row("candidates", len(pts))
    b.row("pareto_points", len(front))
    for p in front[:12]:
        b.row(f"pareto_{p.chip.name}", p.norm_latency, f"area={p.area_mm2:.0f}mm2")
    chosen = sweep([PREFILL_CHIP], get_config("bloom-176b"), phase="prefill", batch=2, seq=1024)[0]
    b.row("chosen_prefill_chip", chosen.norm_latency,
          f"area={chosen.area_mm2:.0f}mm2 (paper: 1.08x faster at 784mm2)")
    # the chosen chip must not be dominated by any candidate
    dominated = any(
        p.area_mm2 < chosen.area_mm2 and p.latency_s < chosen.latency_s for p in pts
    )
    b.row("chosen_dominated", int(dominated), "0 = on/near the frontier")
    return b.dump()


if __name__ == "__main__":
    main()
