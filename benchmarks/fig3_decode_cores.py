"""Fig. 3: simulated decode latency under varying core counts."""
import dataclasses

from repro.configs import get_config
from repro.core import H100, Parallelism
from repro.core.opgraph import phase_ops
from repro.core.perfmodel import run_graph

from .common import Bench


def main():
    b = Bench("fig3_decode_cores")
    bloom = get_config("bloom-176b")
    ops = phase_ops(bloom, phase="decode", batch=64, seq=1024, par=Parallelism(tp=8))
    base = run_graph(H100, ops).total
    b.row("h100_decode_ms", base * 1e3, "B=64 S=1024 TP=8 FP16")
    paper = {108: "+2%", 66: "+22%"}
    for cores in [160, 132, 108, 88, 66, 44]:
        t = run_graph(dataclasses.replace(H100, core_count=cores), ops).total
        b.row(f"cores_{cores}_rel_latency", t / base, f"paper: {paper.get(cores, '')}")
    return b.dump()


if __name__ == "__main__":
    main()
