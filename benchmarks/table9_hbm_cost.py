"""Table 9: chip cost under various HBM cost assumptions ($6/$9/$12 per GB)."""
from repro.core import DECODE_CHIP, H100, PREFILL_CHIP
from repro.core.hardware import hw_cost

from .common import Bench

PAPER = {6: (667, 795), 9: (907, 1035), 12: (1147, 1275)}


def main():
    b = Bench("table9_hbm_cost")
    for price, (dec, h100) in PAPER.items():
        b.row(f"decode_chip_cost_hbm{price}", hw_cost(DECODE_CHIP, price), f"paper ${dec}")
        b.row(f"h100_cost_hbm{price}", hw_cost(H100, price), f"paper ${h100}")
        b.row(f"prefill_chip_cost_hbm{price}", hw_cost(PREFILL_CHIP, price),
              "GDDR: insensitive to HBM price")
        b.row(f"decode_vs_h100_hbm{price}",
              hw_cost(DECODE_CHIP, price) / hw_cost(H100, price), "")
    return b.dump()


if __name__ == "__main__":
    main()
