"""Benchmark harness: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig2 table4
  BENCH_FAST=1 ... python -m benchmarks.run          # reduced sweeps

Output: CSV-ish ``name,value,derived`` rows per benchmark (paper reference
in the derived column).
"""
import importlib
import sys
import time

MODULES = [
    "fig1_intensity",
    "fig2_prefill_bw",
    "fig3_decode_cores",
    "fig5_prefill_dse",
    "fig6_decode_dse",
    "fig7_chip_perf",
    "table3_chips",
    "table9_hbm_cost",
    "fig11_parallelism",
    "kernels_bench",
    "serving_bench",
    "roofline",
    "table4_provisioning",
    "table6_slos",
    "table7_realloc_workload",
    "table8_realloc_model",
]


def main() -> None:
    picks = [a for a in sys.argv[1:] if not a.startswith("-")]
    mods = [m for m in MODULES if not picks or any(p in m for p in picks)]
    t0 = time.time()
    failures = []
    for name in mods:
        mod = importlib.import_module(f"benchmarks.{name}")
        try:
            mod.main()
        except Exception as e:  # noqa: BLE001
            failures.append((name, repr(e)))
            print(f"== {name} ==\nERROR,{e!r}", flush=True)
        print(flush=True)
    print(f"benchmarks: {len(mods) - len(failures)}/{len(mods)} ok in {time.time()-t0:.0f}s")
    if failures:
        for n, e in failures:
            print(f"  FAILED {n}: {e}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
