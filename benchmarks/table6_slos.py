"""Table 6: provisioning under loose / normal / tight SLOs (SPAD vs homo)."""
from repro.core import DECODE_CHIP, H100, PREFILL_CHIP
from repro.core.cluster import SLOS
from repro.core.provision import provision_disagg
from repro.core.trace import WORKLOADS

from .common import RATE, SIM_DURATION, Bench, perf

PAPER = {
    ("coding", "loose"): "homo 24, spad 18+6 (42%)",
    ("coding", "normal"): "homo 25, spad 18+7 (41%)",
    ("coding", "tight"): "homo 27, spad 21+7 (40%)",
    ("conversation", "loose"): "homo 22, spad 8+17 (15-28%)",
    ("conversation", "normal"): "homo 23, spad 8+17 (19-31%)",
    ("conversation", "tight"): "homo 27, spad 13+14 (32-46%)",
}


def main():
    b = Bench("table6_slos")
    h100 = perf(H100)
    for wl_name, wl in WORKLOADS.items():
        for slo_name in ("loose", "normal", "tight"):
            kw = {"workload": wl, "rate": RATE, "slo": SLOS[slo_name], "ref_perf": h100,
                  "duration": SIM_DURATION}
            homo = provision_disagg(name="homo", prefill_perf=h100, decode_perf=h100, **kw)
            spad = provision_disagg(name="spad", prefill_perf=perf(PREFILL_CHIP),
                                    decode_perf=perf(DECODE_CHIP), **kw)
            note = f"paper: {PAPER[(wl_name, slo_name)]}"
            if homo and spad:
                save = 1 - spad.norm_cost / homo.norm_cost
                b.row(f"{wl_name}_{slo_name}_saving", save,
                      f"homo {homo.describe()} vs spad {spad.describe()} | {note}")
            else:
                b.row(f"{wl_name}_{slo_name}", "infeasible", note)
    return b.dump()


if __name__ == "__main__":
    main()
