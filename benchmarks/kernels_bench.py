"""Kernel microbenchmarks: XLA-path wall time (CPU) + modeled TPU roofline
properties of each Pallas kernel's BlockSpec tiling."""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ref

from .common import Bench


def _time(f, *args, n=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def main():
    b = Bench("kernels_bench")
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    # flash attention (XLA reference path on CPU; Pallas targets TPU)
    B, S, H, KV, d = 1, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, d), jnp.bfloat16)
    fa = jax.jit(lambda a, c, e: ref.flash_attention_ref(a, c, e, causal=True))
    t = _time(fa, q, k, v)
    flops = 4 * B * S * S * H * d / 2  # causal
    b.row("flash_attn_ref_us", t * 1e6, f"{flops/t/1e9:.1f} GFLOP/s CPU (B1 S1024 H8 d64)")
    # Pallas tiling properties (TPU target): VMEM working set per block
    bq = bk = 512
    vmem = (bq * d + 2 * bk * d) * 2 + bq * d * 4 + 2 * bq * 4
    b.row("flash_attn_vmem_block_kb", vmem / 1024, "bq=bk=512 q+k+v+acc+m/l")
    b.row("flash_attn_block_intensity", (2 * bq * bk * d * 2) / ((bq + 2 * bk) * d * 2),
          "flops/byte per block >> v5e ridge 240")

    # decode attention
    L = 8192
    kc = jax.random.normal(ks[1], (4, L, KV, d), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (4, L, KV, d), jnp.bfloat16)
    qd = jax.random.normal(ks[0], (4, H, d), jnp.bfloat16)
    lens = jnp.full((4,), L, jnp.int32)
    da = jax.jit(ref.decode_attention_ref)
    t = _time(da, qd, kc, vc, lens)
    bytes_ = 2 * 4 * L * KV * d * 2
    b.row("decode_attn_ref_us", t * 1e6, f"{bytes_/t/1e9:.1f} GB/s CPU (B4 L8192)")
    b.row("decode_attn_intensity", (2 * 2 * H * d * L * 4) / bytes_,
          "flops/byte ~ G: bandwidth-bound by design")

    # SSD
    b_, L2, h, p, g, n = 2, 2048, 8, 64, 1, 128
    x = jax.random.normal(ks[0], (b_, L2, h, p), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b_, L2, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    Bm = jax.random.normal(ks[3], (b_, L2, g, n), jnp.bfloat16)
    Cm = jax.random.normal(ks[4], (b_, L2, g, n), jnp.bfloat16)
    sf = jax.jit(lambda *a: ref.ssd_ref(*a, chunk=128))
    t = _time(sf, x, dt, A, Bm, Cm)
    b.row("ssd_ref_us", t * 1e6, f"B2 L2048 h8 p64 n128 chunk128")
    Q = 128
    vmem_ssd = (Q * p + 2 * Q * n + Q) * 4 + p * n * 4 + Q * Q * 4
    b.row("ssd_vmem_block_kb", vmem_ssd / 1024, "x+B/C+dt + state + Q^2 scratch")
    return b.dump()


if __name__ == "__main__":
    main()
