"""Fig. 7: chip performance grids (batch x seq), normalized to modeled H100."""
import numpy as np

from repro.configs import get_config
from repro.core import DECODE_CHIP, H100, PREFILL_CHIP, Parallelism
from repro.core.opgraph import kv_bytes_per_token, phase_ops, weight_bytes
from repro.core.perfmodel import run_graph

from .common import Bench

PB, PS = [1, 2, 4, 8, 16], [64, 256, 1024, 2048, 4096, 8192, 12288, 16384]
DB, DS = [16, 32, 64, 128, 256], [256, 1024, 2048, 4096, 8192]


def grid(chip, phase, batches, seqs, cfg, par):
    rows = []
    for b_ in batches:
        for s in seqs:
            need = weight_bytes(cfg) + kv_bytes_per_token(cfg) * b_ * s
            if need > min(8 * chip.mem_capacity, 8 * H100.mem_capacity) * 0.9:
                continue
            ops = phase_ops(cfg, phase=phase, batch=b_, seq=s, par=par)
            rows.append((b_, s, run_graph(H100, ops).total / run_graph(chip, ops).total))
    return rows


def main():
    b = Bench("fig7_chip_perf")
    cfg = get_config("bloom-176b")
    par = Parallelism(tp=8)
    cases = [
        ("7a_prefill_chip_prefill", PREFILL_CHIP, "prefill", PB, PS, "paper avg 1.08"),
        ("7b_prefill_chip_decode", PREFILL_CHIP, "decode", DB, DS, "paper avg 0.80"),
        ("7c_decode_chip_prefill", DECODE_CHIP, "prefill", PB, PS, "paper avg 0.69"),
        ("7d_decode_chip_decode", DECODE_CHIP, "decode", DB, DS, "paper avg 0.97"),
    ]
    for name, chip, phase, bb, ss, note in cases:
        rows = grid(chip, phase, bb, ss, cfg, par)
        vals = np.array([r[2] for r in rows])
        b.row(f"{name}_mean", float(vals.mean()), note)
        b.row(f"{name}_min", float(vals.min()),
              f"worst cell B={rows[int(vals.argmin())][0]} S={rows[int(vals.argmin())][1]}")
        b.row(f"{name}_max", float(vals.max()), "")
    return b.dump()


if __name__ == "__main__":
    main()
