"""Table 7: adaptive reallocation after a workload change (model fixed).

A SPAD cluster provisioned for coding@70 (paper: 18P+7D) is repurposed for
conversation by flipping prefill machines to decode duty (and vice versa);
the achievable rate is compared against the minimum homogeneous-H100 cluster
reaching the same rate.
"""
from repro.core import DECODE_CHIP, H100, PREFILL_CHIP
from repro.core.cluster import SLOS
from repro.core.provision import best_realloc_split, max_rate, provision_disagg, reallocate
from repro.core.trace import CODING, CONVERSATION

from .common import SIM_DURATION, Bench, perf


def realloc_case(b, name, n_p, n_d, target_wl, paper_note):
    h100 = perf(H100)
    slo = SLOS["normal"]
    design, rate = best_realloc_split(
        name=name,
        perf_p_prefill=perf(PREFILL_CHIP),
        perf_p_decode=perf(PREFILL_CHIP),
        perf_d_prefill=perf(DECODE_CHIP),
        perf_d_decode=perf(DECODE_CHIP),
        n_p_machines=n_p,
        n_d_machines=n_d,
        workload=target_wl,
        slo=slo,
        ref_perf=h100,
        duration=SIM_DURATION,
    )
    b.row(f"{name}_realloc_rate_rps", rate, f"{design.describe()} | {paper_note}")
    if rate <= 0:
        return
    # homogeneous baseline reaching the same rate
    baseline = provision_disagg(
        name="homo", prefill_perf=h100, decode_perf=h100,
        workload=target_wl, rate=max(rate, 5.0), slo=slo, ref_perf=h100,
        duration=SIM_DURATION,
    )
    if baseline:
        b.row(f"{name}_hw_saving", 1 - design.norm_cost / baseline.norm_cost,
              f"baseline {baseline.describe()} ({baseline.norm_cost:.1f})")
        b.row(f"{name}_tdp_saving", 1 - design.norm_tdp / baseline.norm_tdp, "")


def main():
    b = Bench("table7_realloc_workload")
    # paper: coding-opt 18P+7D -> conversation @55 rps, saving (23%, -7%)
    realloc_case(b, "coding_opt_to_conversation", 18, 7, CONVERSATION,
                 "paper: 55 rps, 23% HW saving")
    # paper: conversation-opt 8P+17D -> coding @60 rps, saving (11%, 9%)
    realloc_case(b, "conversation_opt_to_coding", 8, 17, CODING,
                 "paper: 60 rps, 11% HW saving")
    return b.dump()


if __name__ == "__main__":
    main()
