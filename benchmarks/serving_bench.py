"""Serving fast-path benchmark: device-resident decode vs the seed engine.

Measures, on the reduced CPU test config, exactly what the paper's hardware
argument predicts the software loop should deliver once decode state stays
device-resident and prefill runs in big bucketed batches:

  * end-to-end generated tokens/sec through the DisaggregatedServer
    (seed mode: unbucketed single-request prefill, step-at-a-time decode
    without donation  vs  fast mode: bucketed batched prefill, donated
    fused decode blocks),
  * decode step walltime per token (steady-state, slots full),
  * prefill jit recompile count over 20 mixed-length prompts
    (seed: one compile per exact length; fast: <= number of buckets),
  * the paged KV cache vs the slab fast path: identical token streams,
    decode tokens/s (acceptance: within +-10%), KV bytes reserved per served
    request, and max concurrent requests at a fixed HBM budget (short
    requests stop pinning max_len positions each),
  * refcounted prefix sharing (``prefix_cache=True``): identical token
    streams to the unshared paged engine on a shared-system-prompt workload,
    NEW KV bytes reserved per request (acceptance: >= 30% lower), and peak
    concurrency at a fixed small pool (shared pages stop counting against
    every request),
  * scheduler policies on a mixed short/long trace: queue-wait p50/p99 (in
    deterministic scheduling rounds AND wall seconds) under FCFS vs the
    KV-aware policy (acceptance: p99 reduced, tokens/s within +-10%), plus
    priority preemption via page-level swap (preemption count, high-priority
    admission latency with/without swap, and bit-exactness of the preempted
    requests' resumed streams).

Writes ``BENCH_serving.json`` into the working directory, including a
``smoke_reference`` section that ``benchmarks/check_regression.py`` diffs
fresh ``--smoke`` runs against in CI.

``--smoke`` runs a seconds-scale slice (fast slab vs paged vs shared-prefix
equivalence, no baselines, no BENCH file) — exercised by a tier-1 test so
benchmark rot is caught in-tree; ``--json PATH`` dumps the smoke metrics for
the regression check.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    EngineConfig,
    FaultPlan,
    GenRequest,
    PrefillEngine,
    Router,
    make_scheduler,
)
from repro.serving.kvcache import kv_cache_bytes, paged_kv_cache_bytes

from .common import FAST, Bench

ARCH = "granite-8b"
DECODE_BLOCK = 8
MAX_SLOTS = 4
MAX_LEN = 128
PAGE_SIZE = 16
PREFIX_LEN = 32  # shared system-prompt tokens (2 pages)
MAX_NEW = 8 if FAST else 24
N_REQUESTS = 8 if FAST else 16
SCHED_SLOTS = 8   # scheduler-policy trace: slots are plentiful,
SCHED_POOL = 16   # pages are the binding limit (2 page-hungry reqs fill it)
CHUNK_TOKENS = 64   # chunked-prefill section: one "8k-prompt-shaped" long
CHUNK_LONG = 256    # request (4 chunks) ahead of a burst of shorts
CHUNK_MAX_LEN = 512
# robustness section: its OWN constants (never the --smoke-rebound MAX_NEW /
# N_REQUESTS) so smoke and full runs produce IDENTICAL deterministic numbers
# — check_regression compares them exactly
ROB_MAX_NEW = 6
ROB_SLOTS = 4
ROB_LONG = 96        # 3 chunks of ROB_CHUNK: the crash hits mid-stream work
ROB_CHUNK = 32
ROB_SHORTS = 4
ROB_CRASH_ROUND = 3
ROB_FAULT_RATES = {"chunk_append": 0.1, "admit": 0.1,
                   "swap_in": 0.1, "swap_out": 0.1}
ROB_SHED_AFTER = 3   # overload run: shed queued requests waiting > 3 rounds
ROB_SHED_REQUESTS = 10
# router section: its OWN constants too (same rule as the robustness
# section) — the multi-replica routed trace is fully deterministic and
# check_regression compares it exactly between smoke and full runs
RTR_REPLICAS = 2
RTR_MAX_NEW = 6
RTR_MATCHED_PER_FAMILY = 3   # skewed wave: 3 requests per prefix family
RTR_UNSKEWED = 6             # control wave: unique prompts, no matches
RTR_IMBALANCE_BOUND = 1.25   # max/mean per-replica requests (committed)
# unified-batching section: decode-maximal rounds under a TIGHT token budget
# (the TBT lever) — its OWN constants (same rule as robustness/router) so
# smoke and full runs produce identical deterministic round/budget numbers
UNI_SLOTS = 4        # shorts saturate every decode slot...
UNI_CHUNK = 32
UNI_LONG = 96        # ...then a 3-chunk prompt lands mid-decode
UNI_MAX_NEW = 16     # shorts keep decoding across the chunk window
UNI_DECODE_BLOCK = 4
UNI_BUDGET = UNI_DECODE_BLOCK + UNI_CHUNK  # floor: chunks defer while saturated
HBM_PAIRS = 2        # fixed-HBM speedup: best of N interleaved slab/paged pairs
# quantized-KV section: its OWN constants (same rule as robustness/router) —
# the page-capacity math, logit-error drive, and dedup schedule are
# deterministic and check_regression compares them exactly
QNT_POOL_PAGES = 18  # fixed-HBM budget: the fp32 pool this many pages buys
QNT_SLOTS = 16       # slots plentiful: pool pages are the binding limit
QNT_MAX_NEW = 24     # keeps requests in flight across scheduling rounds
QNT_STEPS = 23       # logit drive: stays inside the one admitted 64-pos page
QNT_DEDUP_N = 4      # same-batch requests sharing the 2-page system prompt


def _requests(cfg, n, max_new=None, seed=0):
    # resolve MAX_NEW at call time, not def time — --smoke rebinds it
    max_new = MAX_NEW if max_new is None else max_new
    rng = np.random.default_rng(seed)
    return [
        GenRequest(i, rng.integers(0, cfg.vocab_size, size=int(rng.integers(5, 48))),
                   max_new_tokens=max_new)
        for i in range(n)
    ]


def _shared_requests(cfg, n, base=0, max_new=None, seed=11):
    """n requests sharing a PREFIX_LEN-token system prompt + unique tails."""
    max_new = MAX_NEW if max_new is None else max_new
    rng = np.random.default_rng(seed)
    common = rng.integers(0, cfg.vocab_size, size=PREFIX_LEN)
    tails = np.random.default_rng(seed + base + 1)
    return [
        GenRequest(
            base + i,
            np.concatenate(
                [common, tails.integers(0, cfg.vocab_size, size=int(tails.integers(4, 16)))]
            ),
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _build_server(params, cfg, fast: bool, *, paged: bool = False,
                  prefix: bool = False, max_slots: int = MAX_SLOTS,
                  n_pages=None) -> DisaggregatedServer:
    if fast:
        pre = PrefillEngine(params, cfg, bucketed=True)
        dec = DecodeEngine(params, cfg, max_slots=max_slots, max_len=MAX_LEN,
                           decode_block=DECODE_BLOCK, donate=True, paged=paged,
                           page_size=PAGE_SIZE, prefix_cache=prefix,
                           n_pages=n_pages if n_pages is not None
                           else MAX_SLOTS * MAX_LEN // PAGE_SIZE)
        # feed as many prompts per round as the engine has slots: a paged
        # engine run with 2x the slots at the same HBM budget only realizes
        # its 2x-tokens-per-dispatch advantage if admission keeps up
        return DisaggregatedServer([pre], [dec], max_prefill_batch=max_slots)
    pre = PrefillEngine(params, cfg, bucketed=False)
    dec = DecodeEngine(params, cfg, max_slots=max_slots, max_len=MAX_LEN,
                       decode_block=1, donate=False)
    return DisaggregatedServer([pre], [dec], max_prefill_batch=1)


def _end_to_end(params, cfg, fast: bool, *, paged: bool = False):
    """Warm up compiles on a small batch, then time the real workload."""
    srv = _build_server(params, cfg, fast, paged=paged)
    for r in _requests(cfg, 2, max_new=4, seed=99):
        r.rid += 10_000
        srv.submit(r)
    srv.run()
    reqs = _requests(cfg, N_REQUESTS)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    srv.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in reqs)
    streams = {r.rid: list(r.tokens) for r in reqs}
    return n_tok / dt, dt, streams


def _decode_walltime(params, cfg, fast: bool, *, paged: bool = False,
                     kv_dtype: str = "fp32"):
    """Steady-state decode walltime per token, slots full the whole time."""
    eng = DecodeEngine(
        params, cfg, max_slots=MAX_SLOTS, max_len=MAX_LEN,
        decode_block=DECODE_BLOCK if fast else 1, donate=fast,
        paged=paged, page_size=PAGE_SIZE, kv_dtype=kv_dtype,
    )
    pre = PrefillEngine(params, cfg, bucketed=True)
    key = jax.random.PRNGKey(0)
    reqs = _requests(cfg, MAX_SLOTS)
    for r in reqs:
        r.max_new_tokens = MAX_LEN - len(r.prompt)  # fits the cache exactly
    for r in reqs:
        key, k = jax.random.split(key)
        tok, kv, tl = pre.prefill(r, k)
        eng.admit(r, kv, tok, tl)
        # keep slots full for the whole measurement: the host never marks the
        # request done (positions freeze at max_len; per-step work is the
        # steady-state full-window attention either way)
        r.max_new_tokens = 10**9
    n_blocks = 4 if FAST else 8
    k_steps = DECODE_BLOCK if fast else 1
    eng.step_block(k_steps)  # warm up the block compile
    # median of several timing windows: single-window numbers swing with
    # machine noise far more than the effects being measured
    samples = []
    produced = 0
    for _ in range(3 if FAST else 5):
        t0 = time.perf_counter()
        got = 0
        for _ in range(n_blocks):
            got += len(eng.step_block(k_steps))
        samples.append((time.perf_counter() - t0) / max(got, 1))
        produced += got
    return float(np.median(samples)), produced


def _prefill_recompiles(params, cfg, fast: bool):
    """20 mixed-length prompts; count distinct compiled prefill shapes."""
    rng = np.random.default_rng(1)
    lengths = rng.integers(5, 120, size=20)
    reqs = [GenRequest(i, rng.integers(0, cfg.vocab_size, size=int(s)), 1)
            for i, s in enumerate(lengths)]
    eng = PrefillEngine(params, cfg, bucketed=fast)
    key = jax.random.PRNGKey(0)
    if fast:
        from repro.serving.engine import _bucket

        by_bucket = {}
        for r in reqs:
            by_bucket.setdefault(_bucket(len(r.prompt)), []).append(r)
        for group in by_bucket.values():
            for i in range(0, len(group), MAX_SLOTS):
                key, k = jax.random.split(key)
                eng.prefill_batch(group[i : i + MAX_SLOTS], k, pad_to=MAX_SLOTS)
        n_buckets = len(by_bucket)
    else:
        for r in reqs:
            key, k = jax.random.split(key)
            eng.prefill(r, k)
        n_buckets = len({_bucket_of(len(r.prompt)) for r in reqs})
    return eng.n_compiles, n_buckets


def _bucket_of(n):
    from repro.serving.engine import _bucket

    return _bucket(n)


def _kv_bytes_per_request(cfg, reqs, paged_engine: DecodeEngine):
    """KV bytes a request pins for its lifetime: the slab always reserves
    max_len positions; the paged engine reserves prompt + growth pages."""
    per_pos = kv_cache_bytes(cfg, 1, 1)  # bytes per KV position (B=1, L=1)
    slab = MAX_LEN * per_pos
    paged = np.mean([
        paged_engine._pages_needed(len(r.prompt), r.max_new_tokens) * PAGE_SIZE
        for r in reqs
    ]) * per_pos
    return float(slab), float(paged)


def _decode_tps_fixed_hbm(params, cfg, paged: bool):
    """Aggregate decode tokens/s at a FIXED persistent KV HBM budget (the
    pool the slab engine's MAX_SLOTS x MAX_LEN slabs occupy).  The slab
    engine is capped at MAX_SLOTS concurrent rows; the paged engine spends
    the same pool bytes on 2x the slots for this short-request workload, so
    its fused block emits 2x the tokens per dispatch.  Decode is VIEW-FREE
    on both backends: the TPU path runs the paged Pallas kernel off the
    pools, the XLA fallback gathers pages as a one-hot contraction (no
    scalar-loop gather, no slab-sized transient)."""
    srv = _build_server(params, cfg, fast=True, paged=paged,
                        max_slots=MAX_SLOTS * 2 if paged else MAX_SLOTS)

    def batch(base):
        rng = np.random.default_rng(3)
        return [
            GenRequest(base + i,
                       rng.integers(0, cfg.vocab_size, size=int(rng.integers(5, 32))),
                       max_new_tokens=8)
            for i in range(16)
        ]

    for r in batch(10_000):  # warm the compile caches (full wide-engine rounds)
        srv.submit(r)
    srv.run()
    # the timed region is small (16 shorts x 8 tokens), so a single scheduler
    # stall can swamp it on the 1-vCPU runner: replay the identical workload
    # on the warm server and keep the best throughput (stalls only deflate)
    best = 0.0
    for rep in range(3):
        reqs = batch(rep * 100)
        t0 = time.perf_counter()
        for r in reqs:
            srv.submit(r)
        srv.run()
        dt = time.perf_counter() - t0
        n_tok = sum(len(r.tokens) for r in reqs)
        best = max(best, n_tok / dt)
    return best


def _fixed_hbm_speedup(params, cfg, pairs=HBM_PAIRS):
    """Paged/slab tokens-per-s ratio at a fixed persistent-KV HBM budget,
    best of ``pairs`` interleaved slab/paged pairs: the 1-vCPU CI runner is
    co-tenant-noisy and the noise only ever deflates a ratio, so the best
    pair is the closest view of the machine-independent speedup."""
    ratios, walls = [], []
    for _ in range(pairs):
        s = _decode_tps_fixed_hbm(params, cfg, paged=False)
        p = _decode_tps_fixed_hbm(params, cfg, paged=True)
        ratios.append(p / s)
        walls.append((s, p))
    i = int(np.argmax(ratios))
    return {"slab": walls[i][0], "paged": walls[i][1],
            "speedup": ratios[i], "ratios": ratios}


def _quant_pages_at_budget(cfg):
    """How many int8 pages the fp32 pool's HBM budget buys.

    The fp32 pool stores the model compute dtype; int8 stores 1-byte payloads
    plus a tiny [R, n_pages+1] fp32 scale leaf per attention cache tensor, so
    the same bytes hold ~itemsize× the pages.  Pure reservation math —
    deterministic, compared exactly by check_regression."""
    budget = paged_kv_cache_bytes(cfg, QNT_SLOTS, QNT_POOL_PAGES, PAGE_SIZE,
                                  max_len=MAX_LEN)
    n = QNT_POOL_PAGES
    while paged_kv_cache_bytes(cfg, QNT_SLOTS, n + 1, PAGE_SIZE,
                               max_len=MAX_LEN, kv_dtype="int8") <= budget:
        n += 1
    return n, budget


def _quant_server(params, cfg, kv_dtype, n_pages=None):
    pre = PrefillEngine(params, cfg, bucketed=True)
    dec = DecodeEngine(params, cfg, max_slots=QNT_SLOTS, max_len=MAX_LEN,
                       decode_block=DECODE_BLOCK, paged=True,
                       page_size=PAGE_SIZE, n_pages=n_pages, kv_dtype=kv_dtype)
    return DisaggregatedServer([pre], [dec], max_prefill_batch=QNT_SLOTS)


def _quant_concurrency(params, cfg, kv_dtype, n_pages):
    """Peak concurrent decode requests at the FIXED HBM budget: the fp32
    engine gets QNT_POOL_PAGES, the int8 engine gets however many pages the
    same bytes buy.  Pages, not slots, are the binding limit."""
    srv = _quant_server(params, cfg, kv_dtype, n_pages)
    rng = np.random.default_rng(9)
    for i in range(QNT_SLOTS):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(16, 25)))
        srv.submit(GenRequest(i, prompt, max_new_tokens=QNT_MAX_NEW))
    srv.run()
    assert all(d.audit().ok for d in srv.decodes)
    return srv.peak_active


def _quant_logit_error(params, cfg):
    """Per-step decode logit max-abs error, int8 vs fp32, on one greedy
    request driven straight through ``M.decode_step`` (the engine API never
    exposes logits).  page_size=64 with a 40-token prompt keeps all QNT_STEPS
    writes inside the already-admitted page."""
    rng = np.random.default_rng(0)
    req_prompt = np.asarray(rng.integers(1, cfg.vocab_size, 40), np.int32)
    runs = {}
    for kv_dtype in ("fp32", "int8"):
        pre = PrefillEngine(params, cfg, bucketed=True)
        eng = DecodeEngine(params, cfg, max_slots=2, max_len=128,
                           decode_block=1, paged=True, page_size=64,
                           kv_dtype=kv_dtype)
        req = GenRequest(0, req_prompt, QNT_STEPS)
        first, kv, tl = pre.prefill(req, jax.random.PRNGKey(1))
        assert eng.admit(req, kv, first, tl) is not None
        st = eng.state
        caches, scales = st.caches, st.scales
        tokens, pos, bt = st.tokens, st.positions, st.block_tables
        logits, toks = [], []
        for _ in range(QNT_STEPS):
            if scales is not None:
                lg, caches, scales = M.decode_step(
                    params, tokens, caches, pos, cfg, block_tables=bt,
                    scales=scales)
            else:
                lg, caches = M.decode_step(
                    params, tokens, caches, pos, cfg, block_tables=bt)
            tokens = jax.numpy.argmax(lg, -1).astype(tokens.dtype)
            pos = pos + 1
            logits.append(np.asarray(lg[0], np.float32))
            toks.append(int(tokens[0]))
        runs[kv_dtype] = (np.stack(logits), toks)
    err = float(np.abs(runs["fp32"][0] - runs["int8"][0]).max())
    return err, int(runs["fp32"][1] != runs["int8"][1])


def _quant_dedup_metrics(params, cfg):
    """Batch-level prefix dedup: QNT_DEDUP_N same-batch requests share the
    2-page system prompt, so the dedup path prefills it once and fans the
    pages out — fewer dispatched prefill tokens, streams bit-identical."""
    ec = EngineConfig(paged=True, prefix_cache=True, max_slots=QNT_DEDUP_N,
                      max_len=MAX_LEN, page_size=PAGE_SIZE,
                      max_prefill_batch=QNT_DEDUP_N)
    runs = {}
    for dedup in (False, True):
        srv = DisaggregatedServer.from_config(
            params, cfg, ec.replace(batch_dedup=dedup))
        reqs = _shared_requests(cfg, QNT_DEDUP_N, max_new=MAX_NEW, seed=11)
        for r in reqs:
            srv.submit(r)
        streams = srv.run()
        audit = int(sum(len(rep.discrepancies) for rep in srv.audit()))
        runs[dedup] = (streams, dict(srv.unified_stats), audit)
    base_streams, base_stats, base_audit = runs[False]
    dd_streams, dd_stats, dd_audit = runs[True]
    mism = int(sum(base_streams[r] != dd_streams[r] for r in base_streams))
    return {
        "requests": QNT_DEDUP_N,
        "prefill_tokens": {"baseline": int(base_stats["prefill_tokens"]),
                           "dedup": int(dd_stats["prefill_tokens"])},
        "groups": int(dd_stats["dedup_groups"]),
        "saved_tokens": int(dd_stats["dedup_saved_tokens"]),
        "stream_mismatches": mism,
        "audit_discrepancies": int(base_audit + dd_audit),
    }


def _quantized_kv_metrics(params, cfg):
    """Int8 KV pages under the bounded-error contract: capacity/concurrency
    at a fixed HBM budget, decode walltime overhead of the dequantizing
    gather, the hard per-step logit-error gate, greedy stream equivalence at
    reduced scale, and the batch-dedup prefill savings."""
    int8_pages, budget = _quant_pages_at_budget(cfg)
    capacity_ratio = int8_pages / QNT_POOL_PAGES
    conc_f32 = _quant_concurrency(params, cfg, "fp32", QNT_POOL_PAGES)
    conc_i8 = _quant_concurrency(params, cfg, "int8", int8_pages)
    spt_f32, _ = _decode_walltime(params, cfg, fast=True, paged=True)
    spt_i8, _ = _decode_walltime(params, cfg, fast=True, paged=True,
                                 kv_dtype="int8")
    max_err, drive_mism = _quant_logit_error(params, cfg)
    # end-to-end greedy stream equivalence at identical topology: on the
    # reduced config the top-1/top-2 margins dwarf the bounded quant error
    f32_streams, _, _, _ = _shared_prefix_workload(
        params, cfg, prefix=True, max_new=MAX_NEW, n=N_REQUESTS)
    i8_streams = {}
    i8_srv = DisaggregatedServer(
        [PrefillEngine(params, cfg, bucketed=True)],
        [DecodeEngine(params, cfg, max_slots=MAX_SLOTS, max_len=MAX_LEN,
                      decode_block=DECODE_BLOCK, paged=True,
                      page_size=PAGE_SIZE, prefix_cache=True,
                      kv_dtype="int8")],
        max_prefill_batch=MAX_SLOTS)
    for w in range(2):
        for r in _shared_requests(cfg, N_REQUESTS, base=w * 100,
                                  max_new=MAX_NEW):
            i8_srv.submit(r)
        i8_streams.update(i8_srv.run())
    mism = int(sum(f32_streams[r] != i8_streams[r] for r in f32_streams))
    return {
        "page_size": PAGE_SIZE,
        "hbm_budget_bytes": int(budget),
        "pages_at_budget": {"fp32": QNT_POOL_PAGES, "int8": int8_pages,
                            "capacity_ratio": capacity_ratio},
        "fixed_hbm_concurrency": {"fp32": int(conc_f32), "int8": int(conc_i8),
                                  "ratio": conc_i8 / conc_f32},
        "decode_s_per_token": {"fp32": spt_f32, "int8": spt_i8,
                               "ratio": spt_i8 / spt_f32},
        "max_logit_err": max_err,
        "logit_drive_mismatches": int(drive_mism),
        "stream_mismatches": mism,
        "dedup": _quant_dedup_metrics(params, cfg),
    }


def _unified_trace(cfg, base=0):
    """UNI_SLOTS shorts that saturate decode, plus one 3-chunk long prompt
    (submitted mid-trace by the runner, not here)."""
    rng = np.random.default_rng(41)
    shorts = [
        GenRequest(base + i,
                   rng.integers(0, cfg.vocab_size, size=int(rng.integers(6, 20))),
                   max_new_tokens=UNI_MAX_NEW)
        for i in range(UNI_SLOTS)
    ]
    longr = GenRequest(base + UNI_SLOTS,
                       rng.integers(0, cfg.vocab_size, size=UNI_LONG),
                       max_new_tokens=8)
    return shorts + [longr]


def _unified_run(params, cfg, unified: bool):
    """Shorts saturate decode; the long prompt lands after round 2.  Serial
    chunked prefill interleaves a chunk into every following round (each
    decoding request's inter-token gap pays chunk + block); unified batching
    under the floor budget defers chunk work to decode-only rounds until the
    shorts drain.  Returns per-mode TBT percentiles over the shorts'
    inter-round token gaps (same-round tokens arrive as one fused block, so
    only gaps between rounds are real TBT), plus the deterministic
    round/budget stats, plus the streams for the bit-identity check."""
    ec = EngineConfig(
        max_slots=UNI_SLOTS, max_len=256, decode_block=UNI_DECODE_BLOCK,
        paged=True, page_size=PAGE_SIZE, chunk_tokens=UNI_CHUNK,
        max_prefill_batch=UNI_SLOTS, unified_batching=unified,
        token_budget=UNI_BUDGET if unified else None,
    )
    srv = DisaggregatedServer.from_config(params, cfg, ec)
    warm = _unified_trace(cfg, base=10_000)
    for r in warm[:UNI_SLOTS]:
        srv.submit(r)
    srv.run_round()
    srv.run_round()
    srv.submit(warm[UNI_SLOTS])  # warm the mid-trace compile shapes too
    srv.run()
    srv.unified_stats = {k: 0 for k in srv.unified_stats}
    reqs = _unified_trace(cfg)
    shorts, longr = reqs[:UNI_SLOTS], reqs[UNI_SLOTS]
    arrivals = {r.rid: [] for r in shorts}
    seen = {r.rid: 0 for r in shorts}
    for r in shorts:
        srv.submit(r)
    rounds = 0
    t0 = time.perf_counter()
    while srv.pending():
        rounds += 1
        srv.run_round()
        now = time.perf_counter() - t0
        for r in shorts:
            while seen[r.rid] < len(r.tokens):
                arrivals[r.rid].append(now)
                seen[r.rid] += 1
        if rounds == 2:
            srv.submit(longr)
    gaps = [g for ts in arrivals.values() for g in np.diff(ts) if g > 0]
    stats = dict(srv.unified_stats)
    out = {
        "tbt_p50_s": float(np.percentile(gaps, 50)),
        "tbt_p99_s": float(np.percentile(gaps, 99)),
        "rounds": int(rounds),
    }
    if unified:
        out["stall_rounds"] = int(stats["deferred_rounds"])
        out["chunk_rows"] = int(stats["chunk_rows"])
        out["budget_utilization"] = (
            stats["used_tokens"] / stats["budget_tokens"]
            if stats["budget_tokens"] else None
        )
    return out, {r.rid: list(r.tokens) for r in reqs}


def _unified_metrics(params, cfg):
    """Unified batching vs the chunked-but-serial baseline on the
    long-prompt-mid-trace workload: the floor token budget must convert
    chunk-inflated inter-token gaps into decode-only rounds (TBT p99
    strictly better) while every greedy stream stays bit-identical; the
    stall/round/budget numbers are deterministic and gated exactly."""
    serial, s_streams = _unified_run(params, cfg, unified=False)
    uni, u_streams = _unified_run(params, cfg, unified=True)
    mism = int(sum(s_streams[r] != u_streams[r] for r in s_streams))
    return {
        "trace": {"slots": UNI_SLOTS, "long_prompt_tokens": UNI_LONG,
                  "chunk_tokens": UNI_CHUNK, "token_budget": UNI_BUDGET,
                  "shorts": UNI_SLOTS},
        "serial": serial,
        "unified": uni,
        "tbt_p99_ratio": uni["tbt_p99_s"] / serial["tbt_p99_s"],
        "tbt_p99_improved": bool(uni["tbt_p99_s"] < serial["tbt_p99_s"]),
        "stream_mismatches": mism,
    }


def _max_concurrency(params, cfg, paged: bool):
    """Peak concurrent decode requests at a FIXED persistent KV HBM budget
    (MAX_SLOTS * MAX_LEN KV positions of pool).  The slab engine is
    hard-capped at MAX_SLOTS rows; the paged engine keeps the same pool but
    hands out pages by need, so short requests stack much deeper."""
    srv = _build_server(params, cfg, fast=True, paged=paged,
                        max_slots=MAX_SLOTS * 4 if paged else MAX_SLOTS)
    rng = np.random.default_rng(7)
    for i in range(16):
        prompt = rng.integers(0, cfg.vocab_size, size=int(rng.integers(5, 12)))
        srv.submit(GenRequest(i, prompt, max_new_tokens=12))
    srv.run()
    return srv.peak_active


def _shared_prefix_workload(params, cfg, *, prefix: bool, max_new, n, waves=2):
    """Run the shared-system-prompt workload; returns (streams, mean new-KV
    bytes reserved per request, total shared pages, wall seconds)."""
    per_pos = kv_cache_bytes(cfg, 1, 1)
    srv = _build_server(params, cfg, fast=True, paged=True, prefix=prefix)
    out = {}
    t0 = time.perf_counter()
    for w in range(waves):
        for r in _shared_requests(cfg, n, base=w * 100, max_new=max_new):
            srv.submit(r)
        out.update(srv.run())
    dt = time.perf_counter() - t0
    eng = srv.decodes[0]
    new_bytes = eng.stats["new_pages"] / eng.stats["admits"] * PAGE_SIZE * per_pos
    shared_total = eng.stats["shared_pages"]
    return out, new_bytes, shared_total, dt


def _shared_prefix_concurrency(params, cfg, *, prefix: bool, pool_pages: int = 20):
    """Peak concurrent decode requests at a FIXED small page pool: shared
    prefix pages count once, not per request, so the prefix-cached engine
    stacks more requests into the same pool.  max_new is sized so requests
    stay in flight across several scheduling rounds — pages, not the
    per-round prefill batch, must be the binding limit."""
    srv = _build_server(params, cfg, fast=True, paged=True, prefix=prefix,
                        max_slots=MAX_SLOTS * 4, n_pages=pool_pages)
    for r in _shared_requests(cfg, 16, base=0, max_new=24, seed=13):
        srv.submit(r)
    srv.run()
    return srv.peak_active


def _sched_trace(cfg):
    """Mixed short/long trace in head-of-line-blocking shape: 2 page-hungry
    requests submitted FIRST (8 pages each on the 16-page pool, so they
    serialize nothing but monopolize pages), then 14 short ones (2 pages
    each, finished in one decode block).  Under FCFS the shorts queue behind
    the longs; the KV-aware policy runs the shorts first."""
    rng = np.random.default_rng(21)
    longs = [GenRequest(i, rng.integers(0, cfg.vocab_size, size=90),
                        max_new_tokens=24) for i in range(2)]
    shorts = [GenRequest(2 + i,
                         rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 13))),
                         max_new_tokens=8) for i in range(14)]
    return longs + shorts


def _sched_server(params, cfg, sched):
    pre = PrefillEngine(params, cfg, bucketed=True)
    dec = DecodeEngine(params, cfg, max_slots=SCHED_SLOTS, max_len=MAX_LEN,
                       decode_block=DECODE_BLOCK, paged=True,
                       page_size=PAGE_SIZE, n_pages=SCHED_POOL)
    return DisaggregatedServer([pre], [dec], max_prefill_batch=SCHED_SLOTS,
                               scheduler=sched)


def _sched_policy_run(params, cfg, policy, waves=1):
    """Run the mixed trace under one policy; queue-wait percentiles are
    reported both in scheduling ROUNDS (deterministic — the smoke regression
    gate compares them exactly) and wall seconds (full-bench reporting).

    The server drains fully between waves, so every wave runs the identical
    deterministic schedule; the tokens/s is the median wave (the full bench
    uses ``waves=3`` because single ~3s CPU windows swing with machine noise
    far more than the ordering effect being measured)."""
    sched = make_scheduler(policy)
    srv = _sched_server(params, cfg, sched)
    # warm the prefill/decode compile caches with one full wave (covers both
    # bucket shapes AND both auto-sized decode-block variants: k=8 while a
    # long request lives, k=7 on shorts-only rounds)
    for r in _sched_trace(cfg):
        r.rid += 10_000
        srv.submit(r)
    srv.run()
    times, streams, reqs, round0 = [], {}, [], 0
    for wave in range(waves):
        reqs = _sched_trace(cfg)
        for r in reqs:
            r.rid += wave * 1000
            srv.submit(r)
        round0 = sched.round
        t0 = time.perf_counter()
        streams = srv.run()
        times.append(time.perf_counter() - t0)
    waits_r = [sched.queue_wait_rounds[r.rid] for r in reqs]
    waits_s = [sched.queue_wait_s[r.rid] for r in reqs]
    n_tok = sum(len(streams[r.rid]) for r in reqs)
    return {
        "queue_wait_rounds": {"p50": float(np.percentile(waits_r, 50)),
                              "p99": float(np.percentile(waits_r, 99))},
        "queue_wait_s": {"p50": float(np.percentile(waits_s, 50)),
                         "p99": float(np.percentile(waits_s, 99))},
        "tokens_per_s": n_tok / float(np.median(times)),
        "rounds": sched.round - round0,
        "preemptions": sched.stats["preemptions"],
    }, streams


def _sched_priority_metrics(params, cfg):
    """Preemption demo: 5 low-priority requests monopolize the pool, then a
    high-priority request arrives.  With swap it preempts one victim and is
    admitted promptly; without swap it waits for a natural release.  The
    preempted requests' completed streams are checked BIT-identical to an
    undisturbed run (greedy), so the swap round trip is validated end to end
    in the bench, not just in unit tests."""
    def lows():
        r = np.random.default_rng(5)
        return [GenRequest(i, r.integers(0, cfg.vocab_size, size=10),
                           max_new_tokens=24) for i in range(5)]

    ref_srv = _sched_server(params, cfg, None)  # undisturbed reference
    ref = lows()
    for r in ref:
        ref_srv.submit(r)
    ref_srv.run()

    out = {}
    for swap in (True, False):
        sched = make_scheduler("priority", swap=swap)
        srv = _sched_server(params, cfg, sched)
        ls = lows()
        for r in ls:
            srv.submit(r)
        srv.run_round()
        srv.run_round()  # lows are decoding; the pool is nearly full
        high = GenRequest(100, np.random.default_rng(6).integers(
            0, cfg.vocab_size, size=40), max_new_tokens=16, priority=1)
        srv.submit(high)
        srv.run()
        mism = int(sum(ls[i].tokens != ref[i].tokens for i in range(len(ls))))
        out["swap" if swap else "no_swap"] = {
            "preemptions": sched.stats["preemptions"],
            "swap_ins": sched.stats["swap_ins"],
            "high_wait_rounds": int(sched.queue_wait_rounds[100]),
            "preempted_stream_mismatches": mism,
        }
    return out


def _sched_metrics(params, cfg, waves=1):
    """The scheduler-policy section (shared by smoke and the full run: the
    round-based metrics are deterministic and wave-invariant, so the
    committed smoke_reference gates head-of-line blocking, not just
    throughput; the full run times extra waves for a stable tokens/s)."""
    fcfs, fcfs_streams = _sched_policy_run(params, cfg, "fcfs", waves=waves)
    kv, kv_streams = _sched_policy_run(params, cfg, "kv-aware", waves=waves)
    mism = int(sum(fcfs_streams[r] != kv_streams[r] for r in fcfs_streams))
    return {
        "trace": {"requests": len(_sched_trace(cfg)), "pool_pages": SCHED_POOL,
                  "slots": SCHED_SLOTS},
        "fcfs": fcfs,
        "kv_aware": kv,
        "stream_mismatches": mism,
        "priority": _sched_priority_metrics(params, cfg),
    }


def _chunked_trace(cfg, base=0):
    """One long prompt submitted FIRST, then a burst of shorts: the
    head-of-line shape chunked prefill exists for.  (The reduced-CPU stand-in
    for 'short requests queued behind one 8k prompt': 256 tokens vs ~10.)"""
    rng = np.random.default_rng(31)
    longr = GenRequest(base, rng.integers(0, cfg.vocab_size, size=CHUNK_LONG),
                       max_new_tokens=8)
    shorts = [
        GenRequest(base + 1 + i,
                   rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 13))),
                   max_new_tokens=8)
        for i in range(6)
    ]
    return [longr] + shorts


def _chunked_run(params, cfg, chunk):
    """Run the head-of-line trace under one prefill mode (compile-warmed);
    returns TTFT per request in wall seconds AND deterministic scheduling
    rounds, plus the prefill-call observability the gate pins (the largest
    single prefill dispatch = the head-of-line compute quantum)."""
    pre = PrefillEngine(params, cfg, bucketed=True, chunk_tokens=chunk)
    dec = DecodeEngine(params, cfg, max_slots=8, max_len=CHUNK_MAX_LEN,
                       decode_block=DECODE_BLOCK, paged=True, page_size=PAGE_SIZE)
    srv = DisaggregatedServer([pre], [dec], max_prefill_batch=8)
    for r in _chunked_trace(cfg, base=10_000):  # warm every compile shape
        srv.submit(r)
    srv.run()
    pre.stats.update(calls=0, max_call_tokens=0, chunk_calls=0)
    reqs = _chunked_trace(cfg)
    ttft_wall, ttft_round, rounds = {}, {}, 0
    t0 = time.perf_counter()
    for r in reqs:
        srv.submit(r)
    while srv.pending():
        rounds += 1
        srv.run_round()
        now = time.perf_counter() - t0
        for r in reqs:
            if r.tokens and r.rid not in ttft_wall:
                ttft_wall[r.rid] = now
                ttft_round[r.rid] = rounds
    short_ids = [r.rid for r in reqs[1:]]
    return {
        "short_ttft_wall_s": float(np.mean([ttft_wall[i] for i in short_ids])),
        "short_ttft_rounds": float(np.mean([ttft_round[i] for i in short_ids])),
        "long_ttft_rounds": int(ttft_round[reqs[0].rid]),
        "max_prefill_call_tokens": int(pre.stats["max_call_tokens"]),
        "chunk_calls": int(pre.stats["chunk_calls"]),
        "rounds": int(rounds),
    }, {r.rid: list(r.tokens) for r in reqs}


def _chunked_metrics(params, cfg):
    """Chunked vs monolithic prefill on the head-of-line trace: short-request
    TTFT (wall) must IMPROVE — shorts wait for one 64-token chunk instead of
    the whole 256-token prefill + its decode block — while every greedy
    stream stays bit-identical.  Round/call metrics are deterministic and
    compared exactly by check_regression."""
    mono, mono_streams = _chunked_run(params, cfg, None)
    ch, ch_streams = _chunked_run(params, cfg, CHUNK_TOKENS)
    mism = int(sum(mono_streams[r] != ch_streams[r] for r in mono_streams))
    return {
        "trace": {"long_prompt_tokens": CHUNK_LONG, "chunk_tokens": CHUNK_TOKENS,
                  "shorts": 6},
        "monolithic": mono,
        "chunked": ch,
        "short_ttft_ratio": ch["short_ttft_wall_s"] / mono["short_ttft_wall_s"],
        "stream_mismatches": mism,
    }


def _rob_server(params, cfg, *, faults=None, scheduler=None, audit_every=None):
    """The robustness section's server: paged + prefix-cached + chunk-enabled
    — every lifecycle seam the fault plan can hit is live."""
    pre = PrefillEngine(params, cfg, bucketed=True, chunk_tokens=ROB_CHUNK)
    dec = DecodeEngine(params, cfg, max_slots=ROB_SLOTS, max_len=MAX_LEN,
                       decode_block=4, paged=True, page_size=PAGE_SIZE,
                       prefix_cache=True)
    return DisaggregatedServer([pre], [dec], max_prefill_batch=4,
                               scheduler=scheduler, faults=faults,
                               audit_every=audit_every)


def _rob_trace(cfg):
    """One chunked long prompt + shorts: in-flight work at the crash round."""
    rng = np.random.default_rng(17)
    longr = GenRequest(0, rng.integers(0, cfg.vocab_size, size=ROB_LONG),
                       max_new_tokens=ROB_MAX_NEW)
    shorts = [
        GenRequest(1 + i,
                   rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 14))),
                   max_new_tokens=ROB_MAX_NEW)
        for i in range(ROB_SHORTS)
    ]
    return [longr] + shorts


def _robustness_metrics(params, cfg, seed=0):
    """Chaos section: run the mixed trace fault-free, then again under the
    seeded fault plan (10% failure at every lifecycle seam + an engine crash
    at round ROB_CRASH_ROUND with KV preserved) and under overload with load
    shedding.  Every surviving greedy stream must be bit-identical to the
    fault-free run and the post-drain KV audit must be clean; recovery rounds
    and shed counts are deterministic and gated exactly by check_regression
    (when the fresh run uses the committed seed)."""
    ref_srv = _rob_server(params, cfg)
    ref_reqs = _rob_trace(cfg)
    for r in ref_reqs:
        ref_srv.submit(r)
    ref = ref_srv.run()

    plan = FaultPlan(seed=seed, rates=dict(ROB_FAULT_RATES),
                     crash_round=ROB_CRASH_ROUND, preserve_kv=True)
    srv = _rob_server(params, cfg, faults=plan, audit_every=4)
    reqs = _rob_trace(cfg)
    for r in reqs:
        srv.submit(r)
    affected, recovery = set(), None
    while srv.pending():
        srv.run_round()
        if srv.crash_events and not affected:
            ev = srv.crash_events[0]
            affected = set(ev["replayed"]) | set(ev["stashed"])
        if affected and recovery is None and all(
            srv.all_requests[rid].done for rid in affected
        ):
            recovery = srv.scheduler.round - srv.crash_events[0]["round"]
    reports = srv.audit()
    mism = int(sum(ref[r.rid] != list(r.tokens) for r in reqs))

    shed_srv = _rob_server(
        params, cfg,
        scheduler=make_scheduler("fcfs", shed_after_rounds=ROB_SHED_AFTER),
    )
    rng = np.random.default_rng(23)
    shed_reqs = [
        GenRequest(i, rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 14))),
                   max_new_tokens=ROB_MAX_NEW)
        for i in range(ROB_SHED_REQUESTS)
    ]
    for r in shed_reqs:
        shed_srv.submit(r)
    shed_srv.run()
    n_shed = sum(1 for r in shed_reqs if r.status == "SHED")
    n_served = sum(1 for r in shed_reqs if r.status == "FINISHED")

    return {
        "seed": seed,
        "trace": {"long_prompt_tokens": ROB_LONG, "chunk_tokens": ROB_CHUNK,
                  "shorts": ROB_SHORTS, "fault_rates": ROB_FAULT_RATES,
                  "crash_round": ROB_CRASH_ROUND},
        "stream_mismatches": mism,
        "faults_injected": dict(srv.faults.stats["injected"]),
        "crash": {
            "round": srv.crash_events[0]["round"] if srv.crash_events else None,
            "affected": sorted(affected),
            "recovery_rounds": recovery,
        },
        "audit_discrepancies": int(sum(len(r.discrepancies) for r in reports)),
        "shed": {"submitted": ROB_SHED_REQUESTS, "shed": int(n_shed),
                 "served": int(n_served),
                 "shed_after_rounds": ROB_SHED_AFTER},
    }


def _router_config():
    """The router section's EngineConfig (the front-door layers accept only
    the config object): the smoke-sized paged + prefix-cached stack."""
    return EngineConfig(
        max_slots=MAX_SLOTS, max_len=MAX_LEN, decode_block=DECODE_BLOCK,
        paged=True, prefix_cache=True, page_size=PAGE_SIZE,
        max_prefill_batch=MAX_SLOTS,
    )


def _router_prefixes(cfg):
    rng = np.random.default_rng(29)
    return [rng.integers(0, cfg.vocab_size, size=PREFIX_LEN).tolist()
            for _ in range(2)]


def _router_reqs(cfg, n, base, prefix=None, seed=0):
    rng = np.random.default_rng(seed + base)
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(4, 16))).tolist()
        prompt = (list(prefix) + tail) if prefix is not None else tail
        out.append(GenRequest(base + i, prompt, max_new_tokens=RTR_MAX_NEW))
    return out


def _router_metrics(params, cfg):
    """Multi-replica KV-aware routing, fully deterministic (greedy streams +
    lexicographic tie-breaking => exact comparison in check_regression).

    Skewed-prefix trace: a seed wave plants prefix family A on one replica
    and family B on the other, then an interleaved matched wave must route
    EVERY request to the replica holding its pages — and the matched pages
    must be mapped (shared), never recomputed.  Unskewed control: unique
    prompts spread by free-pages/queue-depth, and the routed greedy streams
    must be bit-identical to a single-replica FCFS run of the same trace.
    """
    ec = _router_config()

    # -- skewed-prefix trace ------------------------------------------------
    router = Router(params, cfg, ec, replicas=RTR_REPLICAS)
    fam_a, fam_b = _router_prefixes(cfg)
    router.submit(_router_reqs(cfg, 1, base=0, prefix=fam_a)[0])
    router.submit(_router_reqs(cfg, 1, base=1, prefix=fam_b)[0])
    router.drain()
    holder = {"a": router.assignments[0], "b": router.assignments[1]}
    shared_before = sum(
        d.stats["shared_pages"] for s in router.servers for d in s.decodes
    )
    wave = []
    for i in range(RTR_MATCHED_PER_FAMILY):
        wave.append((_router_reqs(cfg, 1, base=100 + i, prefix=fam_a)[0], "a"))
        wave.append((_router_reqs(cfg, 1, base=200 + i, prefix=fam_b)[0], "b"))
    matched_pages, to_holder = 0, 0
    for req, fam in wave:
        router.submit(req)
        d = router.trace[-1]
        matched_pages += d.matched_pages
        to_holder += int(d.replica == holder[fam] and d.matched_pages > 0)
    router.drain()
    shared_delta = sum(
        d.stats["shared_pages"] for s in router.servers for d in s.decodes
    ) - shared_before
    counts = router.load()
    imbalance = max(counts) / (sum(counts) / len(counts))
    skewed = {
        "matched_requests": len(wave),
        "routed_to_holder": int(to_holder),
        "matched_pages": int(matched_pages),
        "shared_pages_delta": int(shared_delta),
        # pages matched at routing but NOT mapped from the holder's pool
        # would have been recomputed by prefill — the gate pins this to 0
        "matched_chunk_recompute": int(max(0, matched_pages - shared_delta)),
        "per_replica_requests": counts,
        "load_imbalance": imbalance,
        "load_imbalance_bound": RTR_IMBALANCE_BOUND,
    }

    # -- unskewed control: routing must not change streams ------------------
    def unskewed_reqs():
        return _router_reqs(cfg, RTR_UNSKEWED, base=0, seed=41)

    routed = Router(params, cfg, ec, replicas=RTR_REPLICAS)
    for r in unskewed_reqs():
        routed.submit(r)
    routed_out = routed.run()
    single = DisaggregatedServer.from_config(params, cfg, ec)
    for r in unskewed_reqs():
        single.submit(r)
    single_out = single.run()
    mism = int(sum(routed_out[r] != single_out[r] for r in single_out))
    unskewed = {
        "requests": RTR_UNSKEWED,
        "stream_mismatches": mism,
        "per_replica_requests": routed.load(),
    }

    return {
        "replicas": RTR_REPLICAS,
        "trace": {"prefix_len": PREFIX_LEN, "page_size": PAGE_SIZE,
                  "matched_per_family": RTR_MATCHED_PER_FAMILY,
                  "max_new": RTR_MAX_NEW},
        "skewed": skewed,
        "unskewed": unskewed,
    }


def _smoke_metrics(params, cfg, rob_seed=0):
    """The seconds-scale equivalence slice (also embedded in the full run as
    the committed ``smoke_reference`` for benchmarks/check_regression.py)."""
    slab_tps, _, slab_streams = _end_to_end(params, cfg, fast=True)
    paged_tps, _, paged_streams = _end_to_end(params, cfg, fast=True, paged=True)
    mismatches = int(sum(slab_streams[r] != paged_streams[r] for r in slab_streams))
    slab_step, _ = _decode_walltime(params, cfg, fast=True)
    paged_step, _ = _decode_walltime(params, cfg, fast=True, paged=True)
    base_streams, base_bytes, _, _ = _shared_prefix_workload(
        params, cfg, prefix=False, max_new=MAX_NEW, n=N_REQUESTS
    )
    shr_streams, shr_bytes, shared_total, _ = _shared_prefix_workload(
        params, cfg, prefix=True, max_new=MAX_NEW, n=N_REQUESTS
    )
    shared_mismatches = int(
        sum(base_streams[r] != shr_streams[r] for r in base_streams)
    )
    return {
        "tokens_per_s": {"slab": slab_tps, "paged": paged_tps,
                         "ratio": paged_tps / slab_tps},
        "decode_s_per_token": {"slab": slab_step, "paged": paged_step,
                               "ratio": paged_step / slab_step},
        "stream_mismatches": mismatches,
        "shared_prefix": {
            "stream_mismatches": shared_mismatches,
            "kv_new_bytes_per_request": {"paged": base_bytes, "shared": shr_bytes,
                                         "saving_frac": 1 - shr_bytes / base_bytes},
            "shared_pages_total": int(shared_total),
        },
        "scheduler": _sched_metrics(params, cfg),
        "chunked_prefill": _chunked_metrics(params, cfg),
        "robustness": _robustness_metrics(params, cfg, seed=rob_seed),
        "router": _router_metrics(params, cfg),
        "decode_tps_fixed_hbm": _fixed_hbm_speedup(params, cfg),
        "unified_batching": _unified_metrics(params, cfg),
        "quantized_kv": _quantized_kv_metrics(params, cfg),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale slice for the tier-1 rot check: "
                         "fast slab vs paged vs shared-prefix stream "
                         "equivalence, no baselines")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="with --smoke: dump the smoke metrics as JSON "
                         "(consumed by benchmarks/check_regression.py)")
    ap.add_argument("--seed", type=int, default=0,
                    help="fault-injection seed for the robustness section "
                         "(printed; any chaos result replays with the same "
                         "seed — check_regression compares the section "
                         "exactly only when the seed matches the committed "
                         "reference)")
    args, _ = ap.parse_known_args(argv)
    global MAX_NEW, N_REQUESTS

    cfg = reduced(ARCHS[ARCH])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    print(f"# robustness fault seed {args.seed} (replay: python -m "
          f"benchmarks.serving_bench {'--smoke ' if args.smoke else ''}"
          f"--seed {args.seed})")

    if args.smoke:
        b = Bench("serving bench --smoke (slab vs paged vs shared prefix)")
        MAX_NEW, N_REQUESTS = 4, 3
        sm = _smoke_metrics(params, cfg, rob_seed=args.seed)
        b.row("smoke_tokens_per_s_slab", sm["tokens_per_s"]["slab"], "")
        b.row("smoke_tokens_per_s_paged", sm["tokens_per_s"]["paged"], "")
        b.row("smoke_stream_mismatches", sm["stream_mismatches"], "acceptance: 0")
        b.row("smoke_shared_stream_mismatches",
              sm["shared_prefix"]["stream_mismatches"], "acceptance: 0")
        b.row("smoke_kv_new_bytes_saving",
              sm["shared_prefix"]["kv_new_bytes_per_request"]["saving_frac"],
              "acceptance: >= 0.30")
        sc = sm["scheduler"]
        b.row("smoke_queue_wait_p99_rounds_fcfs",
              sc["fcfs"]["queue_wait_rounds"]["p99"], "")
        b.row("smoke_queue_wait_p99_rounds_kv_aware",
              sc["kv_aware"]["queue_wait_rounds"]["p99"],
              "acceptance: < fcfs p99")
        b.row("smoke_sched_stream_mismatches", sc["stream_mismatches"],
              "acceptance: 0")
        b.row("smoke_preemptions", sc["priority"]["swap"]["preemptions"],
              "acceptance: >= 1")
        b.row("smoke_preempted_stream_mismatches",
              sc["priority"]["swap"]["preempted_stream_mismatches"],
              "acceptance: 0")
        ck = sm["chunked_prefill"]
        b.row("smoke_chunked_stream_mismatches", ck["stream_mismatches"],
              "acceptance: 0 (chunked == monolithic, bit for bit)")
        b.row("smoke_chunked_short_ttft_ratio", ck["short_ttft_ratio"],
              "acceptance: < 1.0 (shorts wait for one chunk, not the "
              "whole long prefill)")
        b.row("smoke_chunked_max_prefill_call",
              ck["chunked"]["max_prefill_call_tokens"],
              f"vs {ck['monolithic']['max_prefill_call_tokens']} monolithic")
        rb = sm["robustness"]
        b.row("smoke_robust_stream_mismatches", rb["stream_mismatches"],
              "acceptance: 0 (chaos run == fault-free run, bit for bit)")
        b.row("smoke_robust_audit_discrepancies", rb["audit_discrepancies"],
              "acceptance: 0 (KV refcounts conserved after drain)")
        b.row("smoke_robust_faults_injected",
              sum(rb["faults_injected"].values()),
              f"seed {rb['seed']}; crash at round {rb['crash']['round']}")
        b.row("smoke_robust_recovery_rounds",
              rb["crash"]["recovery_rounds"] or 0,
              f"rounds to finish {len(rb['crash']['affected'])} crash-affected "
              "request(s)")
        b.row("smoke_robust_shed",
              rb["shed"]["shed"],
              f"of {rb['shed']['submitted']} under overload "
              f"(served {rb['shed']['served']})")
        rt = sm["router"]
        b.row("smoke_router_routed_to_holder", rt["skewed"]["routed_to_holder"],
              f"of {rt['skewed']['matched_requests']} prefix-matched "
              "requests (acceptance: all)")
        b.row("smoke_router_matched_recompute",
              rt["skewed"]["matched_chunk_recompute"],
              "acceptance: 0 (matched pages mapped, never recomputed)")
        b.row("smoke_router_load_imbalance", rt["skewed"]["load_imbalance"],
              f"acceptance: <= {rt['skewed']['load_imbalance_bound']}")
        b.row("smoke_router_stream_mismatches",
              rt["unskewed"]["stream_mismatches"],
              "acceptance: 0 (routed == single-replica FCFS, bit for bit)")
        hb = sm["decode_tps_fixed_hbm"]
        b.row("smoke_fixed_hbm_speedup", hb["speedup"],
              f"acceptance: >= 0.9 (view-free paged decode, 2x slots in the "
              f"slab's pool bytes; best of {len(hb['ratios'])} pairs)")
        ub = sm["unified_batching"]
        b.row("smoke_unified_stream_mismatches", ub["stream_mismatches"],
              "acceptance: 0 (unified rounds == serial chunked, bit for bit)")
        b.row("smoke_unified_tbt_p99_ratio", ub["tbt_p99_ratio"],
              "acceptance: < 1.0 (tight budget defers chunk work off the "
              "decode rounds)")
        b.row("smoke_unified_stall_rounds", ub["unified"]["stall_rounds"],
              "decode-only rounds while chunk work waited (the TBT lever)")
        b.row("smoke_unified_budget_utilization",
              ub["unified"]["budget_utilization"],
              f"of {ub['trace']['token_budget']} tokens/round")
        qk = sm["quantized_kv"]
        b.row("smoke_quant_concurrency_ratio",
              qk["fixed_hbm_concurrency"]["ratio"],
              f"int8 {qk['fixed_hbm_concurrency']['int8']} vs fp32 "
              f"{qk['fixed_hbm_concurrency']['fp32']} requests at the same "
              f"HBM (acceptance: >= 1.8)")
        b.row("smoke_quant_capacity_ratio",
              qk["pages_at_budget"]["capacity_ratio"],
              f"{qk['pages_at_budget']['int8']} int8 pages in "
              f"{qk['pages_at_budget']['fp32']} fp32 pages' bytes")
        b.row("smoke_quant_max_logit_err", qk["max_logit_err"],
              "acceptance: <= 0.5 (per-step decode logit max-abs error)")
        b.row("smoke_quant_stream_mismatches", qk["stream_mismatches"],
              "acceptance: 0 (reduced-config greedy margins dwarf the "
              "bounded quant error)")
        b.row("smoke_quant_decode_s_per_token_ratio",
              qk["decode_s_per_token"]["ratio"],
              "int8/fp32: the dequantizing gather's overhead")
        b.row("smoke_dedup_saved_tokens", qk["dedup"]["saved_tokens"],
              f"shared prefix prefilled once across "
              f"{qk['dedup']['requests']} same-batch requests")
        b.row("smoke_dedup_stream_mismatches",
              qk["dedup"]["stream_mismatches"],
              "acceptance: 0 (dedup is compute-only)")
        b.dump()
        if args.json:
            with open(args.json, "w") as f:
                json.dump(sm, f, indent=2)
        assert sm["stream_mismatches"] == 0, "paged streams diverged from slab"
        assert sm["shared_prefix"]["stream_mismatches"] == 0, \
            "shared-prefix streams diverged from unshared paged"
        assert sc["stream_mismatches"] == 0, \
            "greedy streams diverged across scheduler policies"
        assert sc["kv_aware"]["queue_wait_rounds"]["p99"] \
            < sc["fcfs"]["queue_wait_rounds"]["p99"], \
            "KV-aware failed to cut queue-wait p99 on the mixed trace"
        assert sc["priority"]["swap"]["preemptions"] >= 1, "no preemption happened"
        assert sc["priority"]["swap"]["preempted_stream_mismatches"] == 0, \
            "preempted streams diverged after swap-in"
        assert ck["stream_mismatches"] == 0, \
            "chunked streams diverged from monolithic"
        assert ck["short_ttft_ratio"] < 1.0, \
            "chunked prefill failed to cut short-request TTFT behind the long prompt"
        assert rb["stream_mismatches"] == 0, \
            "chaos-run streams diverged from the fault-free run"
        assert rb["audit_discrepancies"] == 0, \
            "KV invariant audit found discrepancies after the chaos drain"
        assert rb["crash"]["affected"], \
            "the injected engine crash hit no in-flight work (trace too short)"
        assert rb["crash"]["recovery_rounds"] is not None, \
            "crash-affected requests never finished"
        assert rt["skewed"]["routed_to_holder"] \
            == rt["skewed"]["matched_requests"], \
            "a prefix-matched request was routed away from its page holder"
        assert rt["skewed"]["matched_chunk_recompute"] == 0, \
            "matched prefix pages were recomputed instead of mapped"
        assert rt["skewed"]["load_imbalance"] \
            <= rt["skewed"]["load_imbalance_bound"], \
            "per-replica load imbalance exceeded the committed bound"
        assert rt["unskewed"]["stream_mismatches"] == 0, \
            "routed streams diverged from the single-replica FCFS baseline"
        assert hb["speedup"] >= 0.9, \
            f"fixed-HBM paged/slab speedup {hb['speedup']:.3f} < 0.9"
        assert ub["stream_mismatches"] == 0, \
            "unified-batching streams diverged from serial chunked"
        assert ub["tbt_p99_improved"], \
            f"unified TBT p99 {ub['unified']['tbt_p99_s']:.4f}s not better " \
            f"than serial {ub['serial']['tbt_p99_s']:.4f}s"
        assert qk["fixed_hbm_concurrency"]["ratio"] >= 1.8, \
            f"int8 fixed-HBM concurrency ratio " \
            f"{qk['fixed_hbm_concurrency']['ratio']:.2f} < 1.8"
        assert qk["max_logit_err"] <= 0.5, \
            f"int8 per-step logit error {qk['max_logit_err']:.3f} > 0.5"
        assert qk["stream_mismatches"] == 0, \
            "int8 greedy streams diverged from fp32 on the reduced config"
        assert qk["dedup"]["stream_mismatches"] == 0, \
            "batch-dedup streams diverged from the dedup-free schedule"
        assert qk["dedup"]["saved_tokens"] > 0, "batch dedup never fired"
        assert qk["dedup"]["audit_discrepancies"] == 0, \
            "KV audit found discrepancies after the dedup drain"
        assert qk["dedup"]["prefill_tokens"]["dedup"] \
            + qk["dedup"]["saved_tokens"] \
            == qk["dedup"]["prefill_tokens"]["baseline"], \
            "dedup prefill-token accounting does not balance"
        print("SMOKE OK")
        return

    # seconds-scale smoke slice, committed as the CI regression reference.
    # Measured FIRST, before the full-scale sections load up the process:
    # check_regression diffs it against a fresh --smoke subprocess, so the
    # wall-clock-sensitive sections (fixed-HBM pairs, unified TBT) must be
    # taken under comparable near-fresh process conditions — at the tail of
    # a long run the paged side's pool-wide gathers lose far more to heap
    # pressure than the slab side does, deflating the committed ratios.
    full_mn, full_nr = MAX_NEW, N_REQUESTS
    MAX_NEW, N_REQUESTS = 4, 3
    smoke_reference = _smoke_metrics(params, cfg, rob_seed=args.seed)
    MAX_NEW, N_REQUESTS = full_mn, full_nr

    b = Bench("serving fast path (device-resident decode + bucketed prefill)")

    seed_tps, seed_wall, seed_streams = _end_to_end(params, cfg, fast=False)
    fast_tps, fast_wall, fast_streams = _end_to_end(params, cfg, fast=True)
    b.row("e2e_tokens_per_s_seed", seed_tps, "unbucketed prefill, step-at-a-time decode")
    b.row("e2e_tokens_per_s_fast", fast_tps, "bucketed batch prefill, fused donated decode")
    b.row("e2e_speedup", fast_tps / seed_tps, "acceptance: >= 2x")
    mismatches = sum(seed_streams[r] != fast_streams[r] for r in seed_streams)
    b.row("greedy_stream_mismatches", mismatches, "seed vs fast, same requests (FP-noise only)")

    seed_step, _ = _decode_walltime(params, cfg, fast=False)
    fast_step, _ = _decode_walltime(params, cfg, fast=True)
    b.row("decode_s_per_token_seed", seed_step, "per-step dispatch + host sync each token")
    b.row("decode_s_per_token_fast", fast_step, f"one sync per {DECODE_BLOCK}-token block")
    b.row("decode_step_speedup", seed_step / fast_step, "")

    seed_compiles, n_buckets = _prefill_recompiles(params, cfg, fast=False)
    fast_compiles, _ = _prefill_recompiles(params, cfg, fast=True)
    b.row("prefill_compiles_seed_20_prompts", seed_compiles, "jit cache keyed per exact length")
    b.row("prefill_compiles_fast_20_prompts", fast_compiles, f"<= {n_buckets} buckets in workload")

    # -- paged KV cache vs the slab fast path -------------------------------
    paged_tps, paged_wall, paged_streams = _end_to_end(params, cfg, fast=True, paged=True)
    paged_mismatches = sum(fast_streams[r] != paged_streams[r] for r in fast_streams)
    paged_step, _ = _decode_walltime(params, cfg, fast=True, paged=True)
    probe = DecodeEngine(params, cfg, max_slots=MAX_SLOTS, max_len=MAX_LEN,
                         decode_block=DECODE_BLOCK, paged=True, page_size=PAGE_SIZE)
    slab_bytes, paged_bytes = _kv_bytes_per_request(cfg, _requests(cfg, N_REQUESTS), probe)
    conc_slab = _max_concurrency(params, cfg, paged=False)
    conc_paged = _max_concurrency(params, cfg, paged=True)
    tps_hbm_slab = _decode_tps_fixed_hbm(params, cfg, paged=False)
    tps_hbm_paged = _decode_tps_fixed_hbm(params, cfg, paged=True)
    b.row("paged_stream_mismatches", paged_mismatches, "acceptance: 0 (bit-identical to slab)")
    b.row("e2e_tokens_per_s_paged", paged_tps, "same slots/workload as fast")
    b.row("decode_s_per_token_paged", paged_step,
          "like-for-like slots; XLA-path gather+writeback overhead")
    b.row("decode_tps_fixed_hbm_slab", tps_hbm_slab,
          f"{MAX_SLOTS} slots cap the slab at this HBM")
    b.row("decode_tps_fixed_hbm_paged", tps_hbm_paged,
          "acceptance: >= 0.9x slab (same persistent KV HBM, 2x slots, "
          "view-free block-table decode)")
    b.row("kv_bytes_per_request_slab", slab_bytes, f"max_len={MAX_LEN} pinned per slot")
    b.row("kv_bytes_per_request_paged", paged_bytes,
          f"prompt + growth reservation, page_size={PAGE_SIZE}")
    b.row("kv_bytes_saving", 1 - paged_bytes / slab_bytes, "fraction of slab freed")
    b.row("max_concurrent_fixed_hbm_slab", conc_slab, f"{MAX_SLOTS} slots x {MAX_LEN}")
    b.row("max_concurrent_fixed_hbm_paged", conc_paged, "same pool, paged admission")

    # -- refcounted prefix sharing vs the unshared paged engine -------------
    base_streams, base_new_bytes, _, base_wall = _shared_prefix_workload(
        params, cfg, prefix=False, max_new=MAX_NEW, n=N_REQUESTS
    )
    shr_streams, shr_new_bytes, shared_total, shr_wall = _shared_prefix_workload(
        params, cfg, prefix=True, max_new=MAX_NEW, n=N_REQUESTS
    )
    shared_mismatches = int(
        sum(base_streams[r] != shr_streams[r] for r in base_streams)
    )
    conc_base = _shared_prefix_concurrency(params, cfg, prefix=False)
    conc_shared = _shared_prefix_concurrency(params, cfg, prefix=True)
    saving = 1 - shr_new_bytes / base_new_bytes
    b.row("shared_prefix_stream_mismatches", shared_mismatches,
          "acceptance: 0 (bit-identical to unshared paged)")
    b.row("kv_new_bytes_per_request_unshared", base_new_bytes,
          f"{PREFIX_LEN}-token system prompt re-reserved per request")
    b.row("kv_new_bytes_per_request_shared", shr_new_bytes,
          "prefix pages mapped, only tail + growth reserved")
    b.row("kv_new_bytes_saving", saving, "acceptance: >= 0.30")
    b.row("shared_pages_total", shared_total, "prefix pages mapped instead of recomputed")
    b.row("max_concurrent_fixed_pool_unshared", conc_base, "20-page pool")
    b.row("max_concurrent_fixed_pool_shared", conc_shared,
          "same pool; shared pages count once, not per request")

    # -- scheduler policies on the mixed short/long trace -------------------
    sched = _sched_metrics(params, cfg, waves=3)
    fc, kv = sched["fcfs"], sched["kv_aware"]
    tps_ratio = kv["tokens_per_s"] / fc["tokens_per_s"]
    b.row("sched_queue_wait_p50_rounds_fcfs", fc["queue_wait_rounds"]["p50"], "")
    b.row("sched_queue_wait_p50_rounds_kv_aware", kv["queue_wait_rounds"]["p50"],
          "small requests stop queueing behind page-hungry ones")
    b.row("sched_queue_wait_p99_rounds_fcfs", fc["queue_wait_rounds"]["p99"], "")
    b.row("sched_queue_wait_p99_rounds_kv_aware", kv["queue_wait_rounds"]["p99"],
          "acceptance: < fcfs p99")
    b.row("sched_queue_wait_p99_s_fcfs", fc["queue_wait_s"]["p99"], "")
    b.row("sched_queue_wait_p99_s_kv_aware", kv["queue_wait_s"]["p99"], "")
    b.row("sched_tokens_per_s_fcfs", fc["tokens_per_s"], "")
    b.row("sched_tokens_per_s_kv_aware", kv["tokens_per_s"],
          "acceptance: within +-25% of fcfs (wall-clock noise)")
    b.row("sched_tokens_per_s_ratio", tps_ratio, "")
    b.row("sched_stream_mismatches", sched["stream_mismatches"],
          "acceptance: 0 (greedy tokens are policy-invariant)")
    pr = sched["priority"]
    b.row("sched_preemptions_swap", pr["swap"]["preemptions"],
          "page-level swap of the lowest-priority victim")
    b.row("sched_high_wait_rounds_swap", pr["swap"]["high_wait_rounds"],
          "high-priority admission latency WITH preemption")
    b.row("sched_high_wait_rounds_no_swap", pr["no_swap"]["high_wait_rounds"],
          "without swap: waits for a natural release")
    b.row("sched_preempted_stream_mismatches",
          pr["swap"]["preempted_stream_mismatches"],
          "acceptance: 0 (swap round trip is bit-exact)")

    # -- chunked prefill: streaming page-level KV handoff -------------------
    ck = _chunked_metrics(params, cfg)
    b.row("chunked_stream_mismatches", ck["stream_mismatches"],
          "acceptance: 0 (chunked == monolithic, bit for bit)")
    b.row("chunked_short_ttft_s", ck["chunked"]["short_ttft_wall_s"],
          f"{CHUNK_LONG}-token prompt ahead, {CHUNK_TOKENS}-token chunks")
    b.row("chunked_short_ttft_s_monolithic", ck["monolithic"]["short_ttft_wall_s"],
          "shorts wait out the whole long prefill + a decode block")
    b.row("chunked_short_ttft_ratio", ck["short_ttft_ratio"],
          "acceptance: < 1.0")
    b.row("chunked_max_prefill_call_tokens", ck["chunked"]["max_prefill_call_tokens"],
          f"head-of-line compute quantum; {ck['monolithic']['max_prefill_call_tokens']} monolithic")
    b.row("chunked_long_ttft_rounds", ck["chunked"]["long_ttft_rounds"],
          f"the cost side: first token after every chunk "
          f"({ck['monolithic']['long_ttft_rounds']} monolithic)")

    # -- request-lifecycle robustness: chaos + crash recovery + shedding ----
    rb = _robustness_metrics(params, cfg, seed=args.seed)
    b.row("robust_stream_mismatches", rb["stream_mismatches"],
          "acceptance: 0 (chaos run == fault-free run, bit for bit)")
    b.row("robust_audit_discrepancies", rb["audit_discrepancies"],
          "acceptance: 0 (KV refcounts conserved after drain)")
    b.row("robust_faults_injected", sum(rb["faults_injected"].values()),
          f"seed {rb['seed']}; 10% per lifecycle seam")
    b.row("robust_crash_recovery_rounds", rb["crash"]["recovery_rounds"] or 0,
          f"engine crash at round {rb['crash']['round']}, "
          f"{len(rb['crash']['affected'])} request(s) recovered")
    b.row("robust_shed", rb["shed"]["shed"],
          f"of {rb['shed']['submitted']} under overload "
          f"(shed after {rb['shed']['shed_after_rounds']} queued rounds)")
    b.dump()
    assert rb["stream_mismatches"] == 0
    assert rb["audit_discrepancies"] == 0
    assert ck["stream_mismatches"] == 0
    assert ck["short_ttft_ratio"] < 1.0, \
        f"chunked short TTFT ratio {ck['short_ttft_ratio']:.3f} (acceptance < 1.0)"
    assert kv["queue_wait_rounds"]["p99"] < fc["queue_wait_rounds"]["p99"]
    # wall-clock ratio on a shared CPU: use the same 25% noise tolerance the
    # regression gate applies to timing ratios (rounds-based metrics above
    # carry the exact acceptance)
    assert abs(tps_ratio - 1.0) <= 0.25, \
        f"KV-aware tokens/s drifted {tps_ratio:.3f}x vs FCFS (acceptance +-25%)"

    # -- multi-replica KV-aware router: locality, balance, stream identity --
    rt = _router_metrics(params, cfg)
    b.row("router_routed_to_holder", rt["skewed"]["routed_to_holder"],
          f"of {rt['skewed']['matched_requests']} prefix-matched requests "
          "(acceptance: all)")
    b.row("router_matched_recompute", rt["skewed"]["matched_chunk_recompute"],
          "acceptance: 0 (matched pages mapped from the holder's pool)")
    b.row("router_load_imbalance", rt["skewed"]["load_imbalance"],
          f"acceptance: <= {rt['skewed']['load_imbalance_bound']}")
    b.row("router_stream_mismatches", rt["unskewed"]["stream_mismatches"],
          "acceptance: 0 (routed == single-replica FCFS, bit for bit)")
    b.dump()
    assert rt["skewed"]["routed_to_holder"] == rt["skewed"]["matched_requests"]
    assert rt["skewed"]["matched_chunk_recompute"] == 0
    assert rt["skewed"]["load_imbalance"] <= rt["skewed"]["load_imbalance_bound"]
    assert rt["unskewed"]["stream_mismatches"] == 0

    # -- quantized KV pages + batch dedup (smoke-scale: the section is pure
    # reservation math, a deterministic logit drive, and deterministic
    # schedules — the full-scale workload adds nothing but wall time) -------
    qk = smoke_reference["quantized_kv"]
    b.row("quant_pages_at_budget_int8", qk["pages_at_budget"]["int8"],
          f"vs {qk['pages_at_budget']['fp32']} fp32 pages in the same HBM "
          f"(capacity ratio {qk['pages_at_budget']['capacity_ratio']:.2f})")
    b.row("quant_concurrency_ratio", qk["fixed_hbm_concurrency"]["ratio"],
          f"int8 {qk['fixed_hbm_concurrency']['int8']} vs fp32 "
          f"{qk['fixed_hbm_concurrency']['fp32']} (acceptance: >= 1.8)")
    b.row("quant_max_logit_err", qk["max_logit_err"],
          "acceptance: <= 0.5 per decode step (reduced granite-8b)")
    b.row("quant_decode_s_per_token_ratio", qk["decode_s_per_token"]["ratio"],
          "int8/fp32 decode walltime (dequantizing gather overhead)")
    b.row("quant_stream_mismatches", qk["stream_mismatches"],
          "acceptance: 0 (int8 == fp32 greedy at reduced scale)")
    b.row("dedup_saved_prefill_tokens", qk["dedup"]["saved_tokens"],
          f"of {qk['dedup']['prefill_tokens']['baseline']} baseline tokens "
          f"({qk['dedup']['groups']} group(s))")
    b.row("dedup_stream_mismatches", qk["dedup"]["stream_mismatches"],
          "acceptance: 0 (dedup is compute-only)")
    b.dump()
    assert qk["fixed_hbm_concurrency"]["ratio"] >= 1.8
    assert qk["max_logit_err"] <= 0.5
    assert qk["stream_mismatches"] == 0
    assert qk["dedup"]["stream_mismatches"] == 0
    assert qk["dedup"]["saved_tokens"] > 0

    results = {
        "arch": cfg.name,
        "e2e_tokens_per_s": {"seed": seed_tps, "fast": fast_tps,
                             "speedup": fast_tps / seed_tps},
        "e2e_wall_s": {"seed": seed_wall, "fast": fast_wall},
        "greedy_stream_mismatches": int(mismatches),
        "decode_s_per_token": {"seed": seed_step, "fast": fast_step,
                               "speedup": seed_step / fast_step},
        "prefill_compiles_20_mixed_prompts": {
            "seed": seed_compiles, "fast": fast_compiles, "n_buckets": n_buckets,
        },
        "paged": {
            "stream_mismatches": int(paged_mismatches),
            "e2e_tokens_per_s": paged_tps,
            "e2e_wall_s": paged_wall,
            "decode_s_per_token": paged_step,
            "decode_tokens_per_s_vs_fast": fast_step / paged_step,
            "decode_tps_fixed_hbm": {"slab": tps_hbm_slab, "paged": tps_hbm_paged,
                                     "speedup": tps_hbm_paged / tps_hbm_slab,
                                     "note": "fixed PERSISTENT KV HBM (the pool); "
                                             "view-free decode on both backends "
                                             "(Pallas paged kernel / gather-free "
                                             "one-hot XLA fallback)"},
            "kv_bytes_per_request": {"slab": slab_bytes, "paged": paged_bytes,
                                     "saving_frac": 1 - paged_bytes / slab_bytes},
            "max_concurrent_fixed_hbm": {"slab": int(conc_slab),
                                         "paged": int(conc_paged)},
            "page_size": PAGE_SIZE,
            "n_pages": MAX_SLOTS * MAX_LEN // PAGE_SIZE,
        },
        "prefix_sharing": {
            "stream_mismatches": shared_mismatches,
            "kv_new_bytes_per_request": {"unshared": base_new_bytes,
                                         "shared": shr_new_bytes,
                                         "saving_frac": saving},
            "shared_pages_total": int(shared_total),
            "e2e_wall_s": {"unshared": base_wall, "shared": shr_wall},
            "max_concurrent_fixed_pool": {"unshared": int(conc_base),
                                          "shared": int(conc_shared),
                                          "pool_pages": 20},
            "prefix_len": PREFIX_LEN,
        },
        "scheduler": dict(sched, tokens_per_s_ratio=tps_ratio),
        "chunked_prefill": ck,
        "robustness": rb,
        "router": rt,
        "quantized_kv": qk,
        "smoke_reference": smoke_reference,
        "config": {"decode_block": DECODE_BLOCK, "max_slots": MAX_SLOTS,
                   "max_len": MAX_LEN, "max_new": MAX_NEW, "n_requests": N_REQUESTS},
    }
    with open("BENCH_serving.json", "w") as f:
        json.dump(results, f, indent=2)
    print("wrote BENCH_serving.json")


if __name__ == "__main__":
    main()
