"""Serving fast-path benchmark: device-resident decode vs the seed engine.

Measures, on the reduced CPU test config, exactly what the paper's hardware
argument predicts the software loop should deliver once decode state stays
device-resident and prefill runs in big bucketed batches:

  * end-to-end generated tokens/sec through the DisaggregatedServer
    (seed mode: unbucketed single-request prefill, step-at-a-time decode
    without donation  vs  fast mode: bucketed batched prefill, donated
    fused decode blocks),
  * decode step walltime per token (steady-state, slots full),
  * prefill jit recompile count over 20 mixed-length prompts
    (seed: one compile per exact length; fast: <= number of buckets).

Writes ``BENCH_serving.json`` into the working directory.
"""
from __future__ import annotations

import json
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    PrefillEngine,
)

from .common import FAST, Bench

ARCH = "granite-8b"
DECODE_BLOCK = 8
MAX_SLOTS = 4
MAX_LEN = 128
MAX_NEW = 8 if FAST else 24
N_REQUESTS = 8 if FAST else 16


def _requests(cfg, n, max_new=MAX_NEW, seed=0):
    rng = np.random.default_rng(seed)
    return [
        GenRequest(i, rng.integers(0, cfg.vocab_size, size=int(rng.integers(5, 48))),
                   max_new_tokens=max_new)
        for i in range(n)
    ]


def _build_server(params, cfg, fast: bool) -> DisaggregatedServer:
    if fast:
        pre = PrefillEngine(params, cfg, bucketed=True)
        dec = DecodeEngine(params, cfg, max_slots=MAX_SLOTS, max_len=MAX_LEN,
                           decode_block=DECODE_BLOCK, donate=True)
        return DisaggregatedServer([pre], [dec], max_prefill_batch=MAX_SLOTS)
    pre = PrefillEngine(params, cfg, bucketed=False)
    dec = DecodeEngine(params, cfg, max_slots=MAX_SLOTS, max_len=MAX_LEN,
                       decode_block=1, donate=False)
    return DisaggregatedServer([pre], [dec], max_prefill_batch=1)


def _end_to_end(params, cfg, fast: bool):
    """Warm up compiles on a small batch, then time the real workload."""
    srv = _build_server(params, cfg, fast)
    for r in _requests(cfg, 2, max_new=4, seed=99):
        r.rid += 10_000
        srv.submit(r)
    srv.run()
    reqs = _requests(cfg, N_REQUESTS)
    for r in reqs:
        srv.submit(r)
    t0 = time.perf_counter()
    srv.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(r.tokens) for r in reqs)
    streams = {r.rid: list(r.tokens) for r in reqs}
    return n_tok / dt, dt, streams


def _decode_walltime(params, cfg, fast: bool):
    """Steady-state decode walltime per token, slots full the whole time."""
    eng = DecodeEngine(
        params, cfg, max_slots=MAX_SLOTS, max_len=MAX_LEN,
        decode_block=DECODE_BLOCK if fast else 1, donate=fast,
    )
    pre = PrefillEngine(params, cfg, bucketed=True)
    key = jax.random.PRNGKey(0)
    reqs = _requests(cfg, MAX_SLOTS)
    for r in reqs:
        r.max_new_tokens = MAX_LEN - len(r.prompt)  # never finishes mid-measurement
    for r in reqs:
        key, k = jax.random.split(key)
        tok, kv, tl = pre.prefill(r, k)
        eng.admit(r, kv, tok, tl)
    n_blocks = 4 if FAST else 8
    k_steps = DECODE_BLOCK if fast else 1
    eng.step_block(k_steps)  # warm up the block compile
    t0 = time.perf_counter()
    produced = 0
    for _ in range(n_blocks):
        produced += len(eng.step_block(k_steps))
    dt = time.perf_counter() - t0
    return dt / max(produced, 1), produced


def _prefill_recompiles(params, cfg, fast: bool):
    """20 mixed-length prompts; count distinct compiled prefill shapes."""
    rng = np.random.default_rng(1)
    lengths = rng.integers(5, 120, size=20)
    reqs = [GenRequest(i, rng.integers(0, cfg.vocab_size, size=int(s)), 1)
            for i, s in enumerate(lengths)]
    eng = PrefillEngine(params, cfg, bucketed=fast)
    key = jax.random.PRNGKey(0)
    if fast:
        from repro.serving.engine import _bucket

        by_bucket = {}
        for r in reqs:
            by_bucket.setdefault(_bucket(len(r.prompt)), []).append(r)
        for group in by_bucket.values():
            for i in range(0, len(group), MAX_SLOTS):
                key, k = jax.random.split(key)
                eng.prefill_batch(group[i : i + MAX_SLOTS], k, pad_to=MAX_SLOTS)
        n_buckets = len(by_bucket)
    else:
        for r in reqs:
            key, k = jax.random.split(key)
            eng.prefill(r, k)
        n_buckets = len({_bucket_of(len(r.prompt)) for r in reqs})
    return eng.n_compiles, n_buckets


def _bucket_of(n):
    from repro.serving.engine import _bucket

    return _bucket(n)


def main() -> None:
    b = Bench("serving fast path (device-resident decode + bucketed prefill)")
    cfg = reduced(ARCHS[ARCH])
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    seed_tps, seed_wall, seed_streams = _end_to_end(params, cfg, fast=False)
    fast_tps, fast_wall, fast_streams = _end_to_end(params, cfg, fast=True)
    b.row("e2e_tokens_per_s_seed", seed_tps, "unbucketed prefill, step-at-a-time decode")
    b.row("e2e_tokens_per_s_fast", fast_tps, "bucketed batch prefill, fused donated decode")
    b.row("e2e_speedup", fast_tps / seed_tps, "acceptance: >= 2x")
    mismatches = sum(seed_streams[r] != fast_streams[r] for r in seed_streams)
    b.row("greedy_stream_mismatches", mismatches, "seed vs fast, same requests (FP-noise only)")

    seed_step, _ = _decode_walltime(params, cfg, fast=False)
    fast_step, _ = _decode_walltime(params, cfg, fast=True)
    b.row("decode_s_per_token_seed", seed_step, "per-step dispatch + host sync each token")
    b.row("decode_s_per_token_fast", fast_step, f"one sync per {DECODE_BLOCK}-token block")
    b.row("decode_step_speedup", seed_step / fast_step, "")

    seed_compiles, n_buckets = _prefill_recompiles(params, cfg, fast=False)
    fast_compiles, _ = _prefill_recompiles(params, cfg, fast=True)
    b.row("prefill_compiles_seed_20_prompts", seed_compiles, "jit cache keyed per exact length")
    b.row("prefill_compiles_fast_20_prompts", fast_compiles, f"<= {n_buckets} buckets in workload")
    b.dump()

    results = {
        "arch": cfg.name,
        "e2e_tokens_per_s": {"seed": seed_tps, "fast": fast_tps,
                             "speedup": fast_tps / seed_tps},
        "e2e_wall_s": {"seed": seed_wall, "fast": fast_wall},
        "greedy_stream_mismatches": int(mismatches),
        "decode_s_per_token": {"seed": seed_step, "fast": fast_step,
                               "speedup": seed_step / fast_step},
        "prefill_compiles_20_mixed_prompts": {
            "seed": seed_compiles, "fast": fast_compiles, "n_buckets": n_buckets,
        },
        "config": {"decode_block": DECODE_BLOCK, "max_slots": MAX_SLOTS,
                   "max_len": MAX_LEN, "max_new": MAX_NEW, "n_requests": N_REQUESTS},
    }
    with open("BENCH_serving.json", "w") as f:
        json.dump(results, f, indent=2)
    print("wrote BENCH_serving.json")


if __name__ == "__main__":
    main()
