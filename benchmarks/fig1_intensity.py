"""Fig. 1: arithmetic intensity of prefill vs decode against chip rooflines."""
from repro.configs import get_config
from repro.core import A100, DECODE_CHIP, H100, PREFILL_CHIP, Parallelism
from repro.core.opgraph import phase_ops
from repro.core.perfmodel import run_graph

from .common import Bench


def main():
    b = Bench("fig1_intensity")
    bloom = get_config("bloom-176b")
    par = Parallelism(tp=8)
    for phase, batch in [("prefill", 2), ("decode", 64)]:
        ops = phase_ops(bloom, phase=phase, batch=batch, seq=1024, par=par)
        r = run_graph(H100, ops)
        mm = [o for o in r.ops if o.kind == "matmul"]
        flops = sum(o.flops for o in mm)
        byts = sum(o.bytes for o in mm)
        b.row(f"{phase}_intensity_flops_per_byte", flops / byts,
              f"paper fig1: prefill >> decode (batch={batch})")
    for chip in (H100, A100, PREFILL_CHIP, DECODE_CHIP):
        b.row(f"{chip.name}_ridge_flops_per_byte", chip.tensor_flops / chip.mem_bw,
              "compute/bandwidth ridge point")
    return b.dump()


if __name__ == "__main__":
    main()
