"""CI regression gate: diff a fresh ``serving_bench --smoke`` run against the
committed ``BENCH_serving.json``.

Run as a CI step (after the smoke step, so bench *breakage* and bench
*regression* fail separately)::

    PYTHONPATH=src python -m benchmarks.check_regression

What is compared — and why ratios, not absolutes: CI runners and dev machines
differ in speed by far more than any real regression, so wall-clock numbers
are only compared RELATIVE to the same run's own baseline (paged vs slab on
the same machine, same minute).  Deterministic metrics — stream mismatches
and the reservation-math KV accounting — are compared exactly.

Failure conditions (``--tolerance`` defaults to 0.25):

* any stream mismatch count > 0 (slab vs paged, shared vs unshared),
* fresh paged/slab tokens-per-s ratio worse than the committed ratio by more
  than the tolerance (decode throughput regression),
* fresh paged/slab decode-s-per-token ratio worse than committed by more
  than the tolerance,
* shared-prefix new-KV saving below the 30% acceptance floor, or drifted
  from the committed value (the accounting is deterministic — any drift
  means the reservation math changed and BENCH_serving.json must be
  regenerated deliberately).

``compare()`` is pure and imported by tier-1 tests, so the gate's logic is
itself under test without paying for a bench run.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import tempfile
from pathlib import Path
from typing import List, Tuple

REPO = Path(__file__).resolve().parent.parent
SAVING_FLOOR = 0.30


def compare(fresh: dict, reference: dict, tolerance: float = 0.25) -> List[Tuple[str, bool, str]]:
    """Diff fresh smoke metrics against the committed ``smoke_reference``.

    Returns [(check name, passed, detail)]; the run fails if any check fails.
    """
    checks: List[Tuple[str, bool, str]] = []

    def add(name: str, ok: bool, detail: str) -> None:
        checks.append((name, bool(ok), detail))

    mm = fresh.get("stream_mismatches", -1)
    add("paged_stream_mismatches", mm == 0, f"{mm} (acceptance: 0)")
    smm = fresh.get("shared_prefix", {}).get("stream_mismatches", -1)
    add("shared_stream_mismatches", smm == 0, f"{smm} (acceptance: 0)")

    # timing: scale-free ratios against the committed ratios
    f_tps = fresh["tokens_per_s"]["ratio"]
    r_tps = reference["tokens_per_s"]["ratio"]
    add(
        "tokens_per_s_ratio",
        f_tps >= r_tps * (1 - tolerance),
        f"fresh paged/slab {f_tps:.3f} vs committed {r_tps:.3f} "
        f"(floor {r_tps * (1 - tolerance):.3f})",
    )
    f_spt = fresh["decode_s_per_token"]["ratio"]
    r_spt = reference["decode_s_per_token"]["ratio"]
    add(
        "decode_s_per_token_ratio",
        f_spt <= r_spt * (1 + tolerance),
        f"fresh paged/slab {f_spt:.3f} vs committed {r_spt:.3f} "
        f"(ceiling {r_spt * (1 + tolerance):.3f})",
    )

    # deterministic reservation math: exact agreement + acceptance floor
    f_sav = fresh["shared_prefix"]["kv_new_bytes_per_request"]["saving_frac"]
    r_sav = reference["shared_prefix"]["kv_new_bytes_per_request"]["saving_frac"]
    add(
        "kv_new_bytes_saving_floor",
        f_sav >= SAVING_FLOOR,
        f"{f_sav:.4f} (acceptance: >= {SAVING_FLOOR})",
    )
    add(
        "kv_new_bytes_saving_committed",
        abs(f_sav - r_sav) < 1e-6,
        f"fresh {f_sav:.6f} vs committed {r_sav:.6f} — reservation math is "
        f"deterministic; drift means BENCH_serving.json is stale",
    )
    return checks


def run_fresh_smoke() -> dict:
    """Run ``serving_bench --smoke --json`` in a subprocess; returns metrics."""
    with tempfile.TemporaryDirectory() as td:
        out_path = Path(td) / "smoke.json"
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serving_bench", "--smoke",
             "--json", str(out_path)],
            cwd=REPO, capture_output=True, text=True, timeout=900,
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"smoke run failed (rc={proc.returncode}):\n"
                f"{proc.stdout}\n{proc.stderr}"
            )
        return json.loads(out_path.read_text())


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=str(REPO / "BENCH_serving.json"))
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--fresh-json", default=None,
                    help="use a pre-computed smoke JSON instead of running one")
    args = ap.parse_args(argv)

    baseline = json.loads(Path(args.baseline).read_text())
    reference = baseline.get("smoke_reference")
    if reference is None:
        print("FAIL: baseline has no smoke_reference section — regenerate "
              "BENCH_serving.json with the full benchmark run")
        return 1
    if args.fresh_json:
        fresh = json.loads(Path(args.fresh_json).read_text())
    else:
        fresh = run_fresh_smoke()

    checks = compare(fresh, reference, args.tolerance)
    width = max(len(n) for n, _, _ in checks)
    failed = 0
    for name, ok, detail in checks:
        print(f"{'PASS' if ok else 'FAIL'}  {name:<{width}}  {detail}")
        failed += not ok
    if failed:
        print(f"{failed} regression check(s) failed")
        return 1
    print("regression check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
