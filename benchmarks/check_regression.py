"""CI regression gate: diff a fresh ``serving_bench --smoke`` run against the
committed ``BENCH_serving.json``.

Run as a CI step (after the smoke step, so bench *breakage* and bench
*regression* fail separately)::

    PYTHONPATH=src python -m benchmarks.check_regression

What is compared — and why ratios, not absolutes: CI runners and dev machines
differ in speed by far more than any real regression, so wall-clock numbers
are only compared RELATIVE to the same run's own baseline (paged vs slab on
the same machine, same minute).  Deterministic metrics — stream mismatches
and the reservation-math KV accounting — are compared exactly.

Failure conditions (``--tolerance`` defaults to 0.25):

* any stream mismatch count > 0 (slab vs paged, shared vs unshared),
* fresh paged/slab tokens-per-s ratio worse than the committed ratio by more
  than the tolerance (decode throughput regression),
* fresh paged/slab decode-s-per-token ratio worse than committed by more
  than the tolerance,
* shared-prefix new-KV saving below the 30% acceptance floor, or drifted
  from the committed value (the accounting is deterministic — any drift
  means the reservation math changed and BENCH_serving.json must be
  regenerated deliberately),
* scheduler policies (when the committed reference carries the section):
  queue-wait p50/p99 in scheduling ROUNDS are pure queueing math — compared
  exactly — the KV-aware policy must keep beating FCFS on p99 (the
  head-of-line-blocking gate), the priority policy must still preempt, and
  every cross-policy / preempted-resume stream mismatch count must be 0,
* robustness (when the committed reference carries the section): the chaos
  run's surviving streams must be bit-identical to the fault-free run and
  the post-drain KV audit clean (always), and the fault counts / crash
  recovery rounds / shed counts must match the committed reference exactly
  when the fresh run used the committed fault seed,
* fixed-HBM decode throughput (when the committed reference carries the
  section): the fresh paged/slab tokens-per-s ratio at the same persistent
  KV HBM — best of N interleaved pairs — must clear the HARD 0.9 floor
  (the view-free decode path's acceptance bar, not a drift band),
* unified batching (when the committed reference carries the section):
  unified streams bit-identical to serial chunked, the unified TBT p99
  strictly better than the chunked-but-serial baseline, and the
  deterministic stall/round/budget-utilization shape exactly equal to the
  committed reference,
* router (when the committed reference carries the section): on the skewed
  prefix trace every matched request must route to the replica already
  holding its prefix pages with 0 matched-chunk recompute, load imbalance
  must stay under the committed bound, the unskewed routed streams must be
  bit-identical to the single-replica FCFS baseline, and the per-replica
  assignments must match the committed reference exactly (routing is a
  pure function of the trace).

``compare()`` is pure and imported by tier-1 tests, so the gate's logic is
itself under test without paying for a bench run.  With
``--github-summary`` (default: ``$GITHUB_STEP_SUMMARY`` when set, i.e.
automatically inside GitHub Actions) the check table is also appended to the
job summary as markdown.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path
from typing import List, Tuple

REPO = Path(__file__).resolve().parent.parent
SAVING_FLOOR = 0.30
# view-free paged decode at 2x slots in the slab's HBM must convert the
# wider fused block into at least this fraction of slab tokens/s (a HARD
# floor, not a drift band: the paged path regressing below parity-ish means
# the decode fast path re-grew per-block materialization or host syncs)
HBM_SPEEDUP_FLOOR = 0.9
# int8 KV pages must stack at least this many times the fp32 concurrency at
# a fixed HBM budget (HARD floor: bf16 pools halve to int8, so ~2x pages —
# falling under 1.8x means the scale leaves or the allocator re-grew
# per-request overhead) and keep the per-step decode logit error bounded
QUANT_CONCURRENCY_FLOOR = 1.8
QUANT_LOGIT_ERR_GATE = 0.5


def compare(fresh: dict, reference: dict, tolerance: float = 0.25) -> List[Tuple[str, bool, str]]:
    """Diff fresh smoke metrics against the committed ``smoke_reference``.

    Returns [(check name, passed, detail)]; the run fails if any check fails.
    """
    checks: List[Tuple[str, bool, str]] = []

    def add(name: str, ok: bool, detail: str) -> None:
        checks.append((name, bool(ok), detail))

    mm = fresh.get("stream_mismatches", -1)
    add("paged_stream_mismatches", mm == 0, f"{mm} (acceptance: 0)")
    smm = fresh.get("shared_prefix", {}).get("stream_mismatches", -1)
    add("shared_stream_mismatches", smm == 0, f"{smm} (acceptance: 0)")

    # timing: scale-free ratios against the committed ratios
    f_tps = fresh["tokens_per_s"]["ratio"]
    r_tps = reference["tokens_per_s"]["ratio"]
    add(
        "tokens_per_s_ratio",
        f_tps >= r_tps * (1 - tolerance),
        f"fresh paged/slab {f_tps:.3f} vs committed {r_tps:.3f} "
        f"(floor {r_tps * (1 - tolerance):.3f})",
    )
    f_spt = fresh["decode_s_per_token"]["ratio"]
    r_spt = reference["decode_s_per_token"]["ratio"]
    add(
        "decode_s_per_token_ratio",
        f_spt <= r_spt * (1 + tolerance),
        f"fresh paged/slab {f_spt:.3f} vs committed {r_spt:.3f} "
        f"(ceiling {r_spt * (1 + tolerance):.3f})",
    )

    # deterministic reservation math: exact agreement + acceptance floor
    f_sav = fresh["shared_prefix"]["kv_new_bytes_per_request"]["saving_frac"]
    r_sav = reference["shared_prefix"]["kv_new_bytes_per_request"]["saving_frac"]
    add(
        "kv_new_bytes_saving_floor",
        f_sav >= SAVING_FLOOR,
        f"{f_sav:.4f} (acceptance: >= {SAVING_FLOOR})",
    )
    add(
        "kv_new_bytes_saving_committed",
        abs(f_sav - r_sav) < 1e-6,
        f"fresh {f_sav:.6f} vs committed {r_sav:.6f} — reservation math is "
        f"deterministic; drift means BENCH_serving.json is stale",
    )

    # scheduler policies: round-based metrics are deterministic queueing
    # math, so they compare exactly (drift means the scheduler changed and
    # the reference must be regenerated deliberately)
    r_sched = reference.get("scheduler")
    if r_sched is not None:
        f_sched = fresh.get("scheduler", {})

        def wait_rounds(d: dict, policy: str) -> dict:
            return d.get(policy, {}).get("queue_wait_rounds", {})

        f_fc, f_kv = wait_rounds(f_sched, "fcfs"), wait_rounds(f_sched, "kv_aware")
        r_fc, r_kv = wait_rounds(r_sched, "fcfs"), wait_rounds(r_sched, "kv_aware")
        smm = f_sched.get("stream_mismatches", -1)
        add("sched_stream_mismatches", smm == 0, f"{smm} (acceptance: 0)")
        add(
            "sched_kv_aware_p99_improves",
            f_kv.get("p99", 1e9) < f_fc.get("p99", -1e9),
            f"kv-aware p99 {f_kv.get('p99')} vs fcfs p99 {f_fc.get('p99')} "
            f"rounds (acceptance: strictly lower)",
        )
        add(
            "sched_wait_rounds_committed",
            f_fc == r_fc and f_kv == r_kv,
            f"fresh fcfs {f_fc} / kv-aware {f_kv} vs committed {r_fc} / "
            f"{r_kv} — round math is deterministic",
        )
        f_pr = f_sched.get("priority", {}).get("swap", {})
        r_pr = r_sched.get("priority", {}).get("swap", {})
        pmm = f_pr.get("preempted_stream_mismatches", -1)
        add(
            "sched_preempted_streams_bitexact",
            pmm == 0,
            f"{pmm} (acceptance: 0 — swap round trip is bit-exact)",
        )
        add(
            "sched_preemptions_committed",
            f_pr.get("preemptions", -1) == r_pr.get("preemptions")
            and f_pr.get("high_wait_rounds", -1) == r_pr.get("high_wait_rounds"),
            f"fresh preemptions={f_pr.get('preemptions')} "
            f"high_wait={f_pr.get('high_wait_rounds')} vs committed "
            f"{r_pr.get('preemptions')}/{r_pr.get('high_wait_rounds')}",
        )

    # chunked prefill (when the committed reference carries the section):
    # stream equivalence and the TTFT win are re-proven fresh; the call/round
    # shape of the schedule is deterministic and compared exactly
    r_ck = reference.get("chunked_prefill")
    if r_ck is not None:
        f_ck = fresh.get("chunked_prefill", {})
        cmm = f_ck.get("stream_mismatches", -1)
        add("chunked_stream_mismatches", cmm == 0, f"{cmm} (acceptance: 0)")
        f_ratio = f_ck.get("short_ttft_ratio", 1e9)
        r_ratio = r_ck.get("short_ttft_ratio", 1.0)
        add(
            "chunked_short_ttft_improves",
            f_ratio < 1.0,
            f"chunked/monolithic short TTFT {f_ratio:.3f} fresh, "
            f"{r_ratio:.3f} committed (acceptance: < 1.0 — shorts wait for "
            f"one chunk, not the whole long prefill; the wall ratio itself "
            f"is too machine-noisy for a committed band, so the hard gate "
            f"is the improvement plus the exact schedule shape below)",
        )

        def shape(d: dict, mode: str) -> tuple:
            m = d.get(mode, {})
            return (m.get("max_prefill_call_tokens"), m.get("chunk_calls"),
                    m.get("long_ttft_rounds"), m.get("short_ttft_rounds"),
                    m.get("rounds"))

        add(
            "chunked_schedule_committed",
            shape(f_ck, "monolithic") == shape(r_ck, "monolithic")
            and shape(f_ck, "chunked") == shape(r_ck, "chunked"),
            f"fresh mono {shape(f_ck, 'monolithic')} / chunked "
            f"{shape(f_ck, 'chunked')} vs committed "
            f"{shape(r_ck, 'monolithic')} / {shape(r_ck, 'chunked')} — "
            f"call sizes and round counts are deterministic",
        )

    # robustness (when the committed reference carries the section): chaos
    # stream equivalence and a clean KV audit are unconditional; the fault /
    # recovery / shed numbers are pure functions of the fault seed, so they
    # compare exactly — but only when the fresh run used the committed seed
    # (local --seed experimentation must not false-fail the gate)
    r_rob = reference.get("robustness")
    if r_rob is not None:
        f_rob = fresh.get("robustness", {})
        rmm = f_rob.get("stream_mismatches", -1)
        add(
            "robust_stream_mismatches",
            rmm == 0,
            f"{rmm} (acceptance: 0 — every surviving stream bit-identical "
            f"to the fault-free run)",
        )
        raud = f_rob.get("audit_discrepancies", -1)
        add(
            "robust_audit_clean",
            raud == 0,
            f"{raud} (acceptance: 0 — KV refcounts conserved after the "
            f"chaos drain)",
        )
        if f_rob.get("seed") == r_rob.get("seed"):
            def rob_shape(d: dict) -> tuple:
                cr = d.get("crash", {})
                sh = d.get("shed", {})
                return (d.get("faults_injected"), cr.get("round"),
                        tuple(cr.get("affected", ())),
                        cr.get("recovery_rounds"),
                        sh.get("shed"), sh.get("served"))

            add(
                "robust_schedule_committed",
                rob_shape(f_rob) == rob_shape(r_rob),
                f"fresh {rob_shape(f_rob)} vs committed "
                f"{rob_shape(r_rob)} — the fault schedule, crash recovery "
                f"rounds, and shed counts are pure functions of the seed",
            )
        else:
            add(
                "robust_schedule_committed",
                True,
                f"skipped: fresh seed {f_rob.get('seed')} != committed "
                f"{r_rob.get('seed')} (exact compare only on the committed "
                f"seed)",
            )

    # multi-replica router: the routed trace is fully deterministic (greedy
    # streams + lexicographic tie-breaking), so locality/balance compare
    # exactly; drift means the routing policy changed and the reference
    # must be regenerated deliberately
    r_rt = reference.get("router")
    if r_rt is not None:
        f_rt = fresh.get("router", {})
        f_sk = f_rt.get("skewed", {})
        r_sk = r_rt.get("skewed", {})
        holder = f_sk.get("routed_to_holder", -1)
        matched = f_sk.get("matched_requests", 0)
        add(
            "router_routed_to_holder",
            matched > 0 and holder == matched,
            f"{holder}/{matched} (acceptance: every prefix-matched request "
            f"routes to the replica holding its pages)",
        )
        rec = f_sk.get("matched_chunk_recompute", -1)
        add(
            "router_matched_recompute",
            rec == 0,
            f"{rec} (acceptance: 0 — matched pages mapped from the "
            f"holder's pool, never recomputed)",
        )
        bound = r_sk.get("load_imbalance_bound", 0)
        imb = f_sk.get("load_imbalance", float("inf"))
        add(
            "router_load_imbalance",
            imb <= bound,
            f"{imb:.3f} (acceptance: <= committed bound {bound})",
        )
        umm = f_rt.get("unskewed", {}).get("stream_mismatches", -1)
        add(
            "router_stream_mismatches",
            umm == 0,
            f"{umm} (acceptance: 0 — routed streams bit-identical to the "
            f"single-replica FCFS baseline)",
        )

        def rt_shape(d: dict) -> tuple:
            sk, un = d.get("skewed", {}), d.get("unskewed", {})
            return (
                d.get("replicas"),
                tuple(sk.get("per_replica_requests", ())),
                sk.get("matched_pages"),
                tuple(un.get("per_replica_requests", ())),
            )

        add(
            "router_assignments_committed",
            rt_shape(f_rt) == rt_shape(r_rt),
            f"fresh {rt_shape(f_rt)} vs committed {rt_shape(r_rt)} — "
            f"replica assignments are a pure function of the trace",
        )

    # view-free paged decode at a fixed HBM budget (when the reference
    # carries the section): hard floor, measured fresh as the best of N
    # interleaved slab/paged pairs (CI co-tenant noise only deflates ratios)
    r_hbm = reference.get("decode_tps_fixed_hbm")
    if r_hbm is not None:
        f_hbm = fresh.get("decode_tps_fixed_hbm", {})
        sp = f_hbm.get("speedup", -1.0)
        add(
            "fixed_hbm_speedup_floor",
            sp >= HBM_SPEEDUP_FLOOR,
            f"paged/slab {sp:.3f} best of {len(f_hbm.get('ratios', []))} "
            f"pair(s) (hard floor {HBM_SPEEDUP_FLOOR}; committed "
            f"{r_hbm.get('speedup', 0):.3f})",
        )

    # unified batching (when the reference carries the section): streams
    # must stay bit-identical to the serial chunked schedule, the tight
    # budget must convert into a strictly better decode TBT p99, and the
    # deterministic round/budget shape must match the committed reference
    r_uni = reference.get("unified_batching")
    if r_uni is not None:
        f_uni = fresh.get("unified_batching", {})
        umm = f_uni.get("stream_mismatches", -1)
        add(
            "unified_stream_mismatches",
            umm == 0,
            f"{umm} (acceptance: 0 — unified rounds recompute nothing, they "
            f"only re-time chunk work)",
        )
        u_p99 = f_uni.get("unified", {}).get("tbt_p99_s", 1e9)
        s_p99 = f_uni.get("serial", {}).get("tbt_p99_s", -1.0)
        add(
            "unified_tbt_p99_improves",
            u_p99 < s_p99,
            f"unified {u_p99:.4f}s vs serial {s_p99:.4f}s (acceptance: "
            f"strictly lower — deferred chunk rounds keep decode gaps "
            f"chunk-free)",
        )

        def uni_shape(d: dict) -> tuple:
            u = d.get("unified", {})
            return (d.get("serial", {}).get("rounds"), u.get("rounds"),
                    u.get("stall_rounds"), u.get("chunk_rows"),
                    u.get("budget_utilization"))

        add(
            "unified_schedule_committed",
            uni_shape(f_uni) == uni_shape(r_uni),
            f"fresh {uni_shape(f_uni)} vs committed {uni_shape(r_uni)} — "
            f"round counts, stall rounds, and budget utilization are "
            f"deterministic scheduling math",
        )

    # quantized KV pages + batch dedup (when the reference carries the
    # section): hard floors on fixed-HBM concurrency and the per-step logit
    # error, unconditional stream/audit gates, and exact comparison of the
    # deterministic page-capacity math and dedup token accounting
    r_q = reference.get("quantized_kv")
    if r_q is not None:
        f_q = fresh.get("quantized_kv", {})
        ratio = f_q.get("fixed_hbm_concurrency", {}).get("ratio", -1.0)
        add(
            "quant_concurrency_floor",
            ratio >= QUANT_CONCURRENCY_FLOOR,
            f"int8/fp32 concurrency {ratio:.2f} at a fixed HBM budget "
            f"(hard floor {QUANT_CONCURRENCY_FLOOR}; committed "
            f"{r_q.get('fixed_hbm_concurrency', {}).get('ratio', 0):.2f})",
        )
        err = f_q.get("max_logit_err", 1e9)
        add(
            "quant_logit_error_gate",
            err <= QUANT_LOGIT_ERR_GATE,
            f"per-step decode logit max-abs error {err:.3f} "
            f"(hard gate {QUANT_LOGIT_ERR_GATE})",
        )
        qmm = f_q.get("stream_mismatches", -1)
        add(
            "quant_stream_mismatches",
            qmm == 0,
            f"{qmm} (acceptance: 0 — reduced-config greedy margins dwarf "
            f"the bounded quant error)",
        )
        f_spt = f_q.get("decode_s_per_token", {}).get("ratio", 1e9)
        r_spt = r_q.get("decode_s_per_token", {}).get("ratio", 1.0)
        add(
            "quant_decode_s_per_token_ratio",
            f_spt <= r_spt * (1 + tolerance),
            f"fresh int8/fp32 {f_spt:.3f} vs committed {r_spt:.3f} "
            f"(ceiling {r_spt * (1 + tolerance):.3f})",
        )
        f_dd = f_q.get("dedup", {})
        r_dd = r_q.get("dedup", {})
        dmm = f_dd.get("stream_mismatches", -1)
        add(
            "dedup_stream_mismatches",
            dmm == 0,
            f"{dmm} (acceptance: 0 — dedup is compute-only, streams replay "
            f"the dedup-free schedule bit for bit)",
        )
        daud = f_dd.get("audit_discrepancies", -1)
        add(
            "dedup_audit_clean",
            daud == 0,
            f"{daud} (acceptance: 0 — fanned-out prefix pages' refcounts "
            f"conserved after drain)",
        )
        pt = f_dd.get("prefill_tokens", {})
        balanced = (
            f_dd.get("saved_tokens", -1) > 0
            and pt.get("dedup", -1) + f_dd.get("saved_tokens", 0)
            == pt.get("baseline")
        )
        add(
            "dedup_token_accounting",
            balanced,
            f"dispatched {pt.get('dedup')} + saved {f_dd.get('saved_tokens')} "
            f"vs baseline {pt.get('baseline')} (must balance, savings > 0)",
        )

        def q_shape(d: dict) -> tuple:
            pg, dd = d.get("pages_at_budget", {}), d.get("dedup", {})
            return (pg.get("fp32"), pg.get("int8"), d.get("hbm_budget_bytes"),
                    dd.get("groups"), dd.get("saved_tokens"),
                    tuple(sorted(dd.get("prefill_tokens", {}).items())))

        add(
            "quant_capacity_committed",
            q_shape(f_q) == q_shape(r_q),
            f"fresh {q_shape(f_q)} vs committed {q_shape(r_q)} — page "
            f"capacity and dedup token accounting are deterministic "
            f"reservation math; drift means BENCH_serving.json is stale",
        )
    return checks


def run_fresh_smoke() -> dict:
    """Run ``serving_bench --smoke --json`` in a subprocess; returns metrics."""
    with tempfile.TemporaryDirectory() as td:
        out_path = Path(td) / "smoke.json"
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.serving_bench", "--smoke",
             "--json", str(out_path)],
            cwd=REPO, capture_output=True, text=True, timeout=1800,
        )
        if proc.returncode != 0:
            raise RuntimeError(
                f"smoke run failed (rc={proc.returncode}):\n"
                f"{proc.stdout}\n{proc.stderr}"
            )
        return json.loads(out_path.read_text())


def write_github_summary(path: str, checks: List[Tuple[str, bool, str]]) -> None:
    """Append the check table to a GitHub Actions job summary as markdown."""
    with open(path, "a") as f:
        f.write("### serving bench regression check\n\n")
        f.write("| check | status | detail |\n|---|---|---|\n")
        for name, ok, detail in checks:
            f.write(f"| `{name}` | {'PASS' if ok else '**FAIL**'} | {detail} |\n")
        f.write("\n")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=str(REPO / "BENCH_serving.json"))
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--fresh-json", default=None,
                    help="use a pre-computed smoke JSON instead of running one")
    ap.add_argument("--github-summary", default=os.environ.get("GITHUB_STEP_SUMMARY"),
                    help="append the check table as markdown to this file "
                         "(default: $GITHUB_STEP_SUMMARY when set, so CI job "
                         "summaries surface the diff without log spelunking)")
    args = ap.parse_args(argv)

    baseline = json.loads(Path(args.baseline).read_text())
    reference = baseline.get("smoke_reference")
    if reference is None:
        print("FAIL: baseline has no smoke_reference section — regenerate "
              "BENCH_serving.json with the full benchmark run")
        return 1
    if args.fresh_json:
        fresh = json.loads(Path(args.fresh_json).read_text())
    else:
        fresh = run_fresh_smoke()

    checks = compare(fresh, reference, args.tolerance)
    width = max(len(n) for n, _, _ in checks)
    failed = 0
    for name, ok, detail in checks:
        print(f"{'PASS' if ok else 'FAIL'}  {name:<{width}}  {detail}")
        failed += not ok
    if args.github_summary:
        write_github_summary(args.github_summary, checks)
    if failed:
        print(f"{failed} regression check(s) failed")
        return 1
    print("regression check OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
