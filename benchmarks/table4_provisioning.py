"""Table 4: SLO-constrained cluster provisioning, coding + conversation @ 70 req/s.

Designs: Sarathi (co-located H100), Splitwise-homo (H100/H100),
Splitwise-hetero (H100/A100), Splitwise-pcap (H100/450W-H100),
SPAD (PrefillChip/DecodeChip).  All simulated with the same LLMCompass-lite
model + event-driven scheduler.
"""
from repro.core import A100, DECODE_CHIP, H100, H100_PCAP, PREFILL_CHIP
from repro.core.cluster import SLOS
from repro.core.provision import provision_coloc, provision_disagg
from repro.core.trace import WORKLOADS

from .common import RATE, SIM_DURATION, Bench, perf

PAPER = {
    ("coding", "sarathi"): "36 H100",
    ("coding", "splitwise-homo"): "25 H100",
    ("coding", "splitwise-hetero"): "21+9",
    ("coding", "splitwise-pcap"): "21+4",
    ("coding", "spad"): "18P+7D cost 14.7 tdp 20.4",
    ("conversation", "sarathi"): "34 H100",
    ("conversation", "splitwise-homo"): "23 H100",
    ("conversation", "splitwise-hetero"): "13+32",
    ("conversation", "splitwise-pcap"): "6+21",
    ("conversation", "spad"): "8P+17D cost 18.7 tdp 19.1",
}


def provision_all(workload, slo, b: Bench, wl_name: str):
    h100 = perf(H100)
    kw = {"workload": workload, "rate": RATE, "slo": slo, "ref_perf": h100,
          "duration": SIM_DURATION}
    designs = {}
    designs["sarathi"] = provision_coloc(name="sarathi", perf=h100, **kw)
    designs["splitwise-homo"] = provision_disagg(
        name="splitwise-homo", prefill_perf=h100, decode_perf=h100, **kw)
    designs["splitwise-hetero"] = provision_disagg(
        name="splitwise-hetero", prefill_perf=h100, decode_perf=perf(A100), **kw)
    designs["splitwise-pcap"] = provision_disagg(
        name="splitwise-pcap", prefill_perf=h100, decode_perf=perf(H100_PCAP), **kw)
    designs["spad"] = provision_disagg(
        name="spad", prefill_perf=perf(PREFILL_CHIP), decode_perf=perf(DECODE_CHIP), **kw)
    for name, d in designs.items():
        if d is None:
            b.row(f"{wl_name}_{name}", "infeasible", PAPER.get((wl_name, name), ""))
        else:
            b.row(f"{wl_name}_{name}_cost", d.norm_cost,
                  f"{d.describe()} tdp={d.norm_tdp:.1f} | paper: {PAPER.get((wl_name, name), '')}")
    return designs


def main():
    b = Bench("table4_provisioning")
    slo = SLOS["normal"]
    all_d = {}
    for wl_name, wl in WORKLOADS.items():
        designs = provision_all(wl, slo, b, wl_name)
        all_d[wl_name] = designs
        feas = {k: d for k, d in designs.items() if d}
        spad = feas.get("spad")
        others = [d for k, d in feas.items() if k != "spad"]
        if spad and others:
            best = min(others, key=lambda d: d.norm_cost)
            b.row(f"{wl_name}_spad_hw_saving", 1 - spad.norm_cost / best.norm_cost,
                  f"vs {best.name} | paper: 41% coding / 19-31% conversation")
    return b.dump()


if __name__ == "__main__":
    main()
