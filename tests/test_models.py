"""Per-architecture smoke tests (assignment requirement f) + model math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, reduced
from repro.models import attention as A
from repro.models import model as M


def _mx(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


def _batch_for(cfg, key, B=2, S=32):
    if cfg.frontend != "none":
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


# ---------------------------------------------------------------------------
# Smoke: every assigned arch, reduced config, one forward + one train step
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(ASSIGNED_ARCHS))
def test_arch_smoke_forward(name):
    cfg = reduced(ARCHS[name])
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    B, S = 2, 32
    batch = _batch_for(cfg, key, B, S)
    logits, aux = M.forward_train(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", sorted(ASSIGNED_ARCHS))
def test_arch_smoke_train_step(name):
    cfg = reduced(ARCHS[name])
    key = jax.random.PRNGKey(1)
    params = M.init_params(key, cfg)
    B, S = 2, 16
    batch = _batch_for(cfg, key, B, S)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    def loss_fn(p):
        return M.train_loss(p, batch, labels, cfg)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0


@pytest.mark.parametrize(
    "name", [n for n in sorted(ASSIGNED_ARCHS) if not ARCHS[n].encoder_only]
)
def test_arch_decode_continuation(name):
    """prefill(S) + decode(2 steps) == forward(S+2), in f32 (exactness)."""
    cfg = dataclasses.replace(reduced(ARCHS[name]), dtype="float32")
    key = jax.random.PRNGKey(2)
    params = M.init_params(key, cfg)
    B, S = 2, 16
    if cfg.frontend != "none":
        pytest.skip("decode continuation exercised via token path")
    toks = jax.random.randint(key, (B, S + 2), 0, cfg.vocab_size)
    full, _ = M.forward_train(params, toks, cfg)
    lg, caches, _ = M.prefill(params, toks[:, :S], cfg, pad_cache_to=S + 2)
    d0, caches = M.decode_step(params, toks[:, S], caches, S, cfg)
    d1, _ = M.decode_step(params, toks[:, S + 1], caches, S + 1, cfg)
    assert _mx(full[:, S - 1], lg) < 2e-4
    assert _mx(full[:, S], d0) < 2e-4
    assert _mx(full[:, S + 1], d1) < 2e-4


def test_vector_positions_decode():
    """Per-request decode positions (continuous batching) match scalar path."""
    cfg = dataclasses.replace(reduced(ARCHS["granite-8b"]), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    _, caches, _ = M.prefill(params, toks, cfg, pad_cache_to=S + 1)
    tok = toks[:, -1]
    d_scalar, _ = M.decode_step(params, tok, caches, S, cfg)
    d_vec, _ = M.decode_step(params, tok, caches, jnp.array([S, S]), cfg)
    assert _mx(d_scalar, d_vec) < 1e-5


# ---------------------------------------------------------------------------
# Attention math
# ---------------------------------------------------------------------------


def test_alibi_slopes_bloom():
    s = A.alibi_slopes(112)  # BLOOM's non-power-of-2 head count
    assert s.shape == (112,)
    assert bool(jnp.all(s > 0)) and bool(jnp.all(s <= 1.0))
    s8 = A.alibi_slopes(8)
    np.testing.assert_allclose(
        np.asarray(s8), [2.0 ** -(i + 1) for i in range(8)], rtol=1e-6
    )


def test_rope_rotation_preserves_norm():
    pos = jnp.arange(16)
    cos, sin = A.rope_cos_sin(pos, 64, 10_000.0)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 16, 2, 64))
    y = A.apply_rope(x, cos, sin)
    nx = jnp.linalg.norm(x, axis=-1)
    ny = jnp.linalg.norm(y, axis=-1)
    assert _mx(nx, ny) < 1e-4


def test_mla_absorbed_equals_expanded():
    """MLA decode (matmul-absorbed) == prefill-style expanded attention."""
    cfg = dataclasses.replace(reduced(ARCHS["minicpm3-4b"]), dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 1, 9
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab_size)
    full, _ = M.forward_train(params, toks, cfg)
    _, caches, _ = M.prefill(params, toks[:, :S], cfg, pad_cache_to=S + 1)
    d, _ = M.decode_step(params, toks[:, S], caches, S, cfg)
    assert _mx(full[:, S], d) < 2e-4


# ---------------------------------------------------------------------------
# MoE behaviour
# ---------------------------------------------------------------------------


def test_moe_aux_losses_and_dispatch():
    cfg = reduced(ARCHS["qwen3-moe-235b-a22b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    B, S = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    _, aux = M.forward_train(params, toks, cfg)
    assert float(aux["lb_loss"]) >= 0.9  # >= 1 in expectation for balanced routing
    assert 0.0 <= float(aux["drop_frac"]) <= 1.0


def test_moe_group_invariance():
    """Group count (data-parallel dispatch granularity) must not change the
    math when capacity is not binding."""
    from repro.configs.base import MoEConfig

    cfg = reduced(ARCHS["qwen3-moe-235b-a22b"])
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=cfg.d_model, capacity_factor=8.0),
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size)
    l1, _ = M.forward_train(params, toks, cfg, n_groups=1)
    l2, _ = M.forward_train(params, toks, cfg, n_groups=4)
    assert _mx(l1, l2) < 2e-4


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def test_cross_entropy_masking():
    logits = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16))
    labels = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 16)
    full = M.cross_entropy(logits, labels)
    masked = M.cross_entropy(logits, labels.at[:, 4:].set(-1))
    only_first = M.cross_entropy(logits[:, :4], labels[:, :4])
    assert abs(float(masked) - float(only_first)) < 1e-5
    assert float(full) > 0.0


def test_param_axes_structure_matches_params():
    for name in ["qwen3-moe-235b-a22b", "jamba-1.5-large-398b", "hubert-xlarge"]:
        cfg = reduced(ARCHS[name])
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        axes = M.param_axes(cfg)
        pl = jax.tree.leaves(params)
        al = jax.tree.leaves(
            axes,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x
            ),
        )
        assert len(pl) == len(al)
        for p, a in zip(pl, al, strict=True):
            assert p.ndim == len(a), (p.shape, a)
