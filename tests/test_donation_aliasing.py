"""Layer-2 trace verifier as a tier-1 gate (CPU XLA, reduced config).

Proves — against the lowered executables, not the source — that:

- every donated leaf of ``step_block``/admit/release carries an
  ``input_output_alias`` entry in the compiled HLO (donation really is
  in-place, not a silent copy);
- the fused decode-block jaxpr contains no host-callback / transfer
  primitives (nothing inside the scanned loop talks to the host);
- the bucketed prefill's jit-cache growth is bounded by the bucket list.

The ``donate=False`` engine is the negative control: the verifier must
*report* missing aliasing there, or the check proves nothing.
"""
import jax
import pytest

from repro.analysis.trace_verify import (
    build_tiny_engines,
    compile_count_violations,
    decode_body_violations,
    donation_violations,
    engine_donation_violations,
    unified_donation_violations,
)
from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import DecodeEngine, SamplingParams


@pytest.fixture(scope="module")
def paged_setup():
    return build_tiny_engines(paged=True)


@pytest.fixture(scope="module")
def slab_setup():
    return build_tiny_engines(paged=False)


# ------------------------------------------------------- decode-body purity


def test_paged_decode_body_has_no_host_primitives(paged_setup):
    _pre, dec, _pack = paged_setup
    assert decode_body_violations(dec) == []


def test_slab_decode_body_has_no_host_primitives(slab_setup):
    _pre, dec, _pack = slab_setup
    assert decode_body_violations(dec) == []


def test_single_step_body_also_pure(paged_setup):
    _pre, dec, _pack = paged_setup
    assert decode_body_violations(dec, k=1) == []


# ------------------------------------------------------- donation aliasing


def test_paged_transitions_alias_every_donated_leaf(paged_setup):
    _pre, dec, pack = paged_setup
    assert engine_donation_violations(dec, pack) == []


def test_slab_transitions_alias_every_donated_leaf(slab_setup):
    _pre, dec, pack = slab_setup
    assert engine_donation_violations(dec, pack) == []


def test_every_kv_pool_leaf_is_aliased_in_step_block(paged_setup):
    """Belt and braces: check the caches subtree specifically — the KV pool
    is the multi-MB donation the paper's bytes-touched-once argument needs."""
    _pre, dec, _pack = paged_setup
    k = dec.decode_block
    n_cache_leaves = len(jax.tree_util.tree_leaves(dec.state.caches))
    assert n_cache_leaves > 0
    problems = donation_violations(
        dec._block_fn(k), 1, "step_block", dec.params, dec.state
    )
    assert problems == []


def test_unified_append_chunk_aliases_every_state_leaf(paged_setup):
    """The unified round's donated transition — ``append_chunk`` compiled
    against a B>1 ``prefill_chunk_group`` pack — must alias the full decode
    state; a silent copy here repeats once per rider row."""
    pre, dec, _pack = paged_setup
    assert unified_donation_violations(pre, dec) == []


def test_unified_verifier_catches_disabled_donation(paged_setup):
    """Negative control for the unified check: donate=False must flag every
    state leaf of the batched append transition."""
    pre, _dec, _pack = paged_setup
    eng = DecodeEngine(
        pre.params, pre.cfg, max_slots=2, max_len=64,
        sampling=SamplingParams(temperature=0.0),
        decode_block=2, paged=True, page_size=16, donate=False,
    )
    problems = unified_donation_violations(pre, eng)
    assert len(problems) == len(jax.tree_util.tree_leaves(eng.state))
    assert all("degraded to a copy" in p for p in problems)


def test_verifier_catches_disabled_donation():
    """Negative control: with donate=False nothing is aliased — the verifier
    must flag every state leaf, one finding each."""
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = DecodeEngine(
        params, cfg, max_slots=2, max_len=64,
        sampling=SamplingParams(temperature=0.0),
        decode_block=2, paged=True, page_size=16, donate=False,
    )
    problems = engine_donation_violations(eng)
    n_state_leaves = len(jax.tree_util.tree_leaves(eng.state))
    # step_block + release both donate the full state
    assert len(problems) == 2 * n_state_leaves
    assert all("degraded to a copy" in p for p in problems)


# --------------------------------------------------- compile-count bounded


def test_prefill_compile_count_bounded(paged_setup):
    pre, _dec, _pack = paged_setup
    assert compile_count_violations(pre, [3, 5, 9, 17, 20]) == []


def test_decode_block_jit_cache_is_k_keyed(paged_setup):
    """Paged block-fn keys are (k, page-bucket, cow): bounded by
    decode_block * log2 page buckets * 2, never by exact sequence lengths."""
    _pre, dec, _pack = paged_setup
    for k in (1, dec.decode_block):
        dec._block_fn(k, dec._n_pg_eff(k))
    assert all(k_ <= dec.decode_block for k_, _n, _cow in dec._block_fns)
    import math

    buckets = math.floor(math.log2(dec.pages_per_slot)) + 1
    assert len(dec._block_fns) <= dec.decode_block * buckets * 2
