"""Docs cannot rot: the README / docs/serving.md checker is under test.

The fast tier compiles every fenced python snippet and validates links and
repo hygiene (cheap — no model runs); the ``slow`` case executes the
snippets for real, exactly like the dedicated CI step does."""
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_docs  # noqa: E402


def test_docs_exist_with_snippets():
    for doc in check_docs.DOCS:
        assert doc.exists(), f"{doc} missing"
    # both documents carry at least one executable example
    assert all(len(check_docs.python_blocks(d)) >= 1 for d in check_docs.DOCS)


def test_snippets_compile_and_links_resolve():
    errors = []
    for doc in check_docs.DOCS:
        errors += check_docs.check_snippets(doc, compile_only=True)
        errors += check_docs.check_links(doc)
    assert not errors, "\n".join(errors)


def test_no_tracked_bytecode():
    assert not check_docs.check_no_tracked_bytecode()


def test_link_checker_catches_breakage(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("see [here](does/not/exist.md) and [ok](#anchor)\n")
    errs = check_docs.check_links(bad)
    assert len(errs) == 1 and "does/not/exist.md" in errs[0]


def test_snippet_checker_catches_breakage(tmp_path):
    bad = tmp_path / "bad.md"
    bad.write_text("```python\ndef broken(:\n```\n")
    errs = check_docs.check_snippets(bad, compile_only=True)
    assert len(errs) == 1 and "SyntaxError" in errs[0]


@pytest.mark.slow
def test_snippets_execute():
    """The real thing, in a subprocess so snippet state cannot leak into the
    test session (CI runs the same command as a dedicated step)."""
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_docs.py")],
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
