"""Chunked prefill with streaming page-level KV handoff.

The acceptance invariant: a server whose prefill engine has ``chunk_tokens``
set emits token streams BIT-IDENTICAL to monolithic prefill for the same
requests — greedy AND sampled, across attention / MLA / hybrid-mamba models,
for chunk sizes that do and do not divide the prompt — while prefill happens
in page-aligned slices whose K/V streams into the paged decode pool between
other requests' turns (``kvcache.paged_append_chunk`` + the server's
``ChunkPrefillState`` machine).  Plus the lifecycle invariants that make it
safe: chunk holds are released on every exit path, cached chunks are skipped
under a prefix cache (and streamed chunks registered), and a short request
admits between a long prompt's chunks without perturbing either stream.
"""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    PrefillEngine,
    SamplingParams,
    make_scheduler,
)
from repro.serving.prefix_cache import chunk_hashes

PAGE = 16


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def mla_setup():
    cfg = reduced(ARCHS["minicpm3-4b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def hybrid_setup():
    """jamba: the conv window and SSD state must carry across chunks."""
    cfg = reduced(ARCHS["jamba-1.5-large-398b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _server(params, cfg, *, chunk, temperature=0.0, prefix=False, n_pages=None,
            max_slots=8, scheduler=None, seed=0):
    sp = SamplingParams(temperature=temperature)
    return DisaggregatedServer(
        [PrefillEngine(params, cfg, sp, chunk_tokens=chunk)],
        [DecodeEngine(params, cfg, max_slots=max_slots, max_len=256,
                      sampling=sp, decode_block=8, paged=True, page_size=PAGE,
                      n_pages=n_pages, prefix_cache=prefix, seed=seed)],
        seed=seed, scheduler=scheduler,
    )


def _one(params, cfg, prompt, *, chunk, temperature=0.0, max_new=8):
    srv = _server(params, cfg, chunk=chunk, temperature=temperature)
    srv.submit(GenRequest(0, prompt, max_new_tokens=max_new))
    out = srv.run()
    return out[0], srv


# ---------------------------------------------------------------------------
# Acceptance: chunked streams == monolithic streams, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("temperature", [0.0, 20.0])
@pytest.mark.parametrize("prompt_len,chunk", [
    (96, 32),   # chunk divides the prompt: the final chunk is a full chunk
    (100, 32),  # ragged 4-token final chunk
    (100, 48),  # chunk larger than a page multiple of the tail
])
def test_chunked_matches_monolithic(setup, temperature, prompt_len, chunk):
    """Greedy AND sampled streams are bit-identical: every chunk runs the
    prefix-offset path at absolute positions over [streamed KV ‖ chunk], and
    the final (batch-padded) chunk samples the same first token a monolithic
    prefill would."""
    cfg, params = setup
    prompt = np.random.default_rng(1).integers(0, cfg.vocab_size, size=prompt_len)
    mono, _ = _one(params, cfg, prompt, chunk=None, temperature=temperature)
    chunked, srv = _one(params, cfg, prompt, chunk=chunk, temperature=temperature)
    assert chunked == mono
    st = srv.prefills[0].stats
    assert st["chunk_calls"] == -(-prompt_len // chunk)
    assert st["max_call_tokens"] < 96  # no call ever saw the whole prompt


@pytest.mark.slow
@pytest.mark.parametrize("temperature", [0.0, 20.0])
def test_chunked_matches_monolithic_mla(mla_setup, temperature):
    """MLA: the compressed prefix ckv is expanded through wkv_b chunk by
    chunk, exactly as the monolithic prefill expands it."""
    cfg, params = mla_setup
    prompt = np.random.default_rng(2).integers(0, cfg.vocab_size, size=100)
    mono, _ = _one(params, cfg, prompt, chunk=None, temperature=temperature)
    chunked, _ = _one(params, cfg, prompt, chunk=32, temperature=temperature)
    assert chunked == mono


@pytest.mark.slow
@pytest.mark.parametrize("temperature", [0.0, 20.0])
def test_chunked_matches_monolithic_hybrid(hybrid_setup, temperature):
    """Hybrid-mamba: the conv window and SSD state carry across chunks
    (boundaries land on SSD scan-chunk boundaries, so the recurrence replays
    the monolithic computation bit for bit)."""
    cfg, params = hybrid_setup
    prompt = np.random.default_rng(3).integers(0, cfg.vocab_size, size=100)
    mono, _ = _one(params, cfg, prompt, chunk=None, temperature=temperature)
    chunked, _ = _one(params, cfg, prompt, chunk=32, temperature=temperature)
    assert chunked == mono


def test_chunked_ragged_final_chunk_single_token(setup):
    """A prompt of k * chunk + 1 leaves a 1-token final chunk — the logits
    position — which must still reproduce the monolithic first token."""
    cfg, params = setup
    prompt = np.random.default_rng(4).integers(0, cfg.vocab_size, size=65)
    mono, _ = _one(params, cfg, prompt, chunk=None)
    chunked, _ = _one(params, cfg, prompt, chunk=32)
    assert chunked == mono


# ---------------------------------------------------------------------------
# Scheduling: chunk-granular interleaving
# ---------------------------------------------------------------------------


def test_short_admits_between_chunks(setup):
    """A short request queued behind a long prompt admits while the long is
    still prefilling (chunk rounds rotate the long to the queue tail), and
    NEITHER stream is perturbed vs an isolated run."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    long_p = rng.integers(0, cfg.vocab_size, size=100)
    shorts = [rng.integers(0, cfg.vocab_size, size=10) for _ in range(3)]

    srv = _server(params, cfg, chunk=32)
    srv.submit(GenRequest(0, long_p, max_new_tokens=8))
    for i, s in enumerate(shorts):
        srv.submit(GenRequest(1 + i, s, max_new_tokens=8))
    first_round = {}
    r = 0
    while srv.pending():
        r += 1
        srv.run_round()
        for rid, req in srv.all_requests.items():
            if req.tokens and rid not in first_round:
                first_round[rid] = r
        assert r < 100
    assert first_round[1] < first_round[0], (
        f"short got its first token in round {first_round[1]}, not before the "
        f"long's final chunk (round {first_round[0]})"
    )
    for rid, req in srv.all_requests.items():
        prompt = long_p if rid == 0 else shorts[rid - 1]
        iso, _ = _one(params, cfg, prompt, chunk=None)
        assert req.tokens == iso, f"stream {rid} perturbed by interleaving"


@pytest.mark.slow
def test_chunked_streams_under_kv_aware(setup):
    """KVAwareScheduler ranks a mid-stream long prompt by its next-chunk
    quantum; greedy streams stay bit-identical to isolated runs."""
    cfg, params = setup
    rng = np.random.default_rng(6)
    long_p = rng.integers(0, cfg.vocab_size, size=100)
    shorts = [rng.integers(0, cfg.vocab_size, size=10) for _ in range(3)]
    srv = _server(params, cfg, chunk=32, scheduler=make_scheduler("kv-aware"))
    srv.submit(GenRequest(0, long_p, max_new_tokens=8))
    for i, s in enumerate(shorts):
        srv.submit(GenRequest(1 + i, s, max_new_tokens=8))
    out = srv.run()
    assert len(out) == 4
    for rid in out:
        prompt = long_p if rid == 0 else shorts[rid - 1]
        iso, _ = _one(params, cfg, prompt, chunk=None)
        assert out[rid] == iso


def test_chunked_tiny_pool_completes(setup):
    """Pages are reserved chunk by chunk: a pool far smaller than
    (every request's full footprint at once) still drains the workload —
    blocked chunks wait at the queue head while decode frees pages."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    srv = _server(params, cfg, chunk=32, n_pages=12)
    srv.submit(GenRequest(0, rng.integers(0, cfg.vocab_size, size=100),
                          max_new_tokens=8))
    for i in range(3):
        srv.submit(GenRequest(1 + i, rng.integers(0, cfg.vocab_size, size=10),
                              max_new_tokens=8))
    out = srv.run()
    assert len(out) == 4


# ---------------------------------------------------------------------------
# Prefix-cache interaction: cached chunks skipped, streamed chunks registered
# ---------------------------------------------------------------------------


def test_chunked_prefix_cache_skips_and_registers(setup):
    """Wave 1 streams a long prompt chunk by chunk and registers its
    full-prompt chunks in the prefix index at admit; wave 2 (same prompt)
    starts its cursor past the cached pages and recomputes only the tail —
    with a bit-identical stream."""
    cfg, params = setup
    prompt = np.random.default_rng(8).integers(0, cfg.vocab_size, size=100)
    srv = _server(params, cfg, chunk=32, prefix=True)
    eng = srv.decodes[0]

    srv.submit(GenRequest(0, prompt, max_new_tokens=8))
    out1 = srv.run()
    calls1 = srv.prefills[0].stats["chunk_calls"]
    # the streamed full-prompt chunks are in the index (cap: >= 1 prompt
    # token is always recomputed, so at most (len-1)//PAGE chunks register)
    hashes = chunk_hashes(prompt, PAGE, eng.pages_per_slot)
    n_cacheable = (len(prompt) - 1) // PAGE
    registered = sum(h in eng.prefix for h in hashes[:n_cacheable])
    assert registered == n_cacheable, f"{registered}/{n_cacheable} chunks registered"

    srv.submit(GenRequest(10, prompt.copy(), max_new_tokens=8))
    out2 = srv.run()
    calls2 = srv.prefills[0].stats["chunk_calls"] - calls1
    assert out2[10] == out1[0], "prefix-skipped chunked stream diverged"
    assert calls2 < calls1, "cached chunks were not skipped"
    assert eng.stats["shared_pages"] > 0


# ---------------------------------------------------------------------------
# Lifecycle: holds, pins, and host state cannot leak
# ---------------------------------------------------------------------------


def test_chunk_holds_released_on_every_exit(setup):
    """After the workload drains, no chunk state, no host holds, no pins —
    and (without a prefix cache) every device refcount is back to zero."""
    cfg, params = setup
    rng = np.random.default_rng(9)
    srv = _server(params, cfg, chunk=32)
    for i in range(2):
        srv.submit(GenRequest(i, rng.integers(0, cfg.vocab_size, size=100),
                              max_new_tokens=6))
    srv.submit(GenRequest(5, rng.integers(0, cfg.vocab_size, size=100),
                          max_new_tokens=1))  # prefill-only long request
    out = srv.run()
    assert len(out) == 3 and len(out[5]) == 1
    eng = srv.decodes[0]
    assert not srv.chunks
    assert int((eng._href > 0).sum()) == 0
    assert not eng._pins
    assert int(np.asarray(eng.state.page_refs).sum()) == 0


def test_prefill_only_chunked_matches_monolithic(setup):
    """max_new_tokens=1 long request: the first token still comes from the
    final chunk's logits, and the streamed pages are all freed."""
    cfg, params = setup
    prompt = np.random.default_rng(10).integers(0, cfg.vocab_size, size=100)
    mono, _ = _one(params, cfg, prompt, chunk=None, max_new=1)
    chunked, srv = _one(params, cfg, prompt, chunk=32, max_new=1)
    assert chunked == mono
    assert int(np.asarray(srv.decodes[0].state.page_refs).sum()) == 0


def test_chunk_tokens_validation(setup, hybrid_setup):
    """chunk_tokens must be page-aligned (engine-side check at routing) and,
    for hybrids, a multiple of the SSD scan chunk."""
    cfg, params = setup
    with pytest.raises(ValueError, match="positive"):
        PrefillEngine(params, cfg, chunk_tokens=0)
    hcfg, hparams = hybrid_setup
    with pytest.raises(ValueError, match="SSM"):
        PrefillEngine(hparams, hcfg, chunk_tokens=24)  # not a multiple of 16
    srv = _server(params, cfg, chunk=24)  # page size 16: not page-aligned
    srv.submit(GenRequest(0, np.arange(100) % cfg.vocab_size, max_new_tokens=4))
    with pytest.raises(ValueError, match="page_size"):
        srv.run()
