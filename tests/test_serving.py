"""Serving engine behaviour: slots, handoff, continuous batching, sampling."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    MonolithicEngine,
    PrefillEngine,
    SamplingParams,
    SchedulerExhausted,
    sample,
)
from repro.serving.engine import DEFAULT_BUCKETS, _bucket
from repro.serving.kvcache import SlotState, insert_request, batch_cache


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=8):
    rng = np.random.default_rng(seed)
    return [
        GenRequest(i, rng.integers(0, cfg.vocab_size, size=int(rng.integers(5, 40))),
                   max_new_tokens=max_new)
        for i in range(n)
    ]


@pytest.mark.slow
def test_disagg_equals_monolithic_greedy(setup):
    cfg, params = setup
    srv = DisaggregatedServer([PrefillEngine(params, cfg)],
                              [DecodeEngine(params, cfg, max_slots=4, max_len=128)])
    for r in _requests(cfg, 6):
        srv.submit(r)
    out_d = srv.run()
    mono = MonolithicEngine(params, cfg, max_slots=4, max_len=128)
    for r in _requests(cfg, 6):
        mono.submit(r)
    out_m = mono.run()
    assert out_d.keys() == out_m.keys()
    for k in out_d:
        assert out_d[k] == out_m[k], f"request {k} diverged"


def test_more_requests_than_slots(setup):
    """Continuous batching: 10 requests through 3 slots."""
    cfg, params = setup
    srv = DisaggregatedServer([PrefillEngine(params, cfg)],
                              [DecodeEngine(params, cfg, max_slots=3, max_len=128)])
    for r in _requests(cfg, 10, seed=1, max_new=5):
        srv.submit(r)
    out = srv.run()
    assert len(out) == 10
    assert all(len(v) == 5 for v in out.values())


def test_two_decode_engines(setup):
    cfg, params = setup
    srv = DisaggregatedServer(
        [PrefillEngine(params, cfg)],
        [DecodeEngine(params, cfg, max_slots=2, max_len=128) for _ in range(2)],
    )
    for r in _requests(cfg, 8, seed=2, max_new=4):
        srv.submit(r)
    out = srv.run()
    assert len(out) == 8


@pytest.mark.slow
def test_decode_engine_matches_sequential(setup):
    """Batched slot decode == one-at-a-time generation (greedy)."""
    cfg0, params = setup
    cfg = dataclasses.replace(cfg0, dtype="float32")
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    reqs = _requests(cfg, 3, seed=3, max_new=6)
    # sequential reference via raw model calls
    ref_tokens = {}
    for r in reqs:
        toks = jnp.asarray(r.prompt, jnp.int32)[None]
        lg, caches, _ = M.prefill(params, toks, cfg, pad_cache_to=len(r.prompt) + 7)
        seq = [int(jnp.argmax(lg, -1)[0])]
        pos = len(r.prompt)
        for _ in range(5):
            lg, caches = M.decode_step(params, jnp.array([seq[-1]]), caches, pos, cfg)
            seq.append(int(jnp.argmax(lg, -1)[0]))
            pos += 1
        ref_tokens[r.rid] = seq
    srv = DisaggregatedServer([PrefillEngine(params, cfg)],
                              [DecodeEngine(params, cfg, max_slots=3, max_len=128)])
    for r in _requests(cfg, 3, seed=3, max_new=6):
        srv.submit(r)
    out = srv.run()
    for k in ref_tokens:
        assert out[k] == ref_tokens[k]


def test_eos_stops_generation(setup):
    cfg, params = setup
    # choose eos = the first greedy token of a probe request -> stops at 1
    probe = _requests(cfg, 1, seed=4, max_new=2)[0]
    mono = MonolithicEngine(params, cfg, max_slots=2, max_len=128)
    mono.submit(probe)
    first = mono.run()[0][0]
    mono2 = MonolithicEngine(params, cfg, max_slots=2, max_len=128)
    r = _requests(cfg, 1, seed=4, max_new=10)[0]
    r.eos_id = None  # first token comes from prefill; eos applies to decode steps
    mono2.submit(r)
    out = mono2.run()
    assert len(out[0]) == 10  # no eos -> full length


def test_run_raises_on_max_steps_with_unfinished(setup):
    """Hitting max_steps with requests in flight raises instead of silently
    returning only the finished ones; server state survives for a resume."""
    cfg, params = setup
    srv = DisaggregatedServer([PrefillEngine(params, cfg)],
                              [DecodeEngine(params, cfg, max_slots=2, max_len=128)])
    for r in _requests(cfg, 4, seed=9, max_new=8):
        srv.submit(r)
    with pytest.raises(SchedulerExhausted) as ei:
        srv.run(max_steps=1)
    assert ei.value.unfinished  # in-flight requests are named, not dropped
    assert set(ei.value.done) | set(ei.value.unfinished) == {0, 1, 2, 3}
    out = srv.run()  # state intact: a fresh run() finishes the rest
    assert len(out) == 4
    assert all(len(v) == 8 for v in out.values())


def test_monolithic_run_raises_on_max_steps(setup):
    cfg, params = setup
    mono = MonolithicEngine(params, cfg, max_slots=2, max_len=128)
    for r in _requests(cfg, 3, seed=10, max_new=8):
        mono.submit(r)
    with pytest.raises(SchedulerExhausted) as ei:
        mono.run(max_steps=1)
    assert ei.value.unfinished
    out = mono.run()
    assert len(out) == 3


def test_bucket_raises_past_largest():
    """No more silent next-power-of-two jit keys past the bucket list."""
    assert _bucket(DEFAULT_BUCKETS[-1]) == DEFAULT_BUCKETS[-1]
    with pytest.raises(ValueError, match="largest prefill bucket"):
        _bucket(DEFAULT_BUCKETS[-1] + 1)


def test_submit_rejects_oversized_prompt(setup):
    """Prompt past the largest bucket is rejected at submit, not at prefill."""
    cfg, params = setup
    srv = DisaggregatedServer([PrefillEngine(params, cfg)],
                              [DecodeEngine(params, cfg, max_slots=2, max_len=8192)])
    big = GenRequest(0, np.zeros(DEFAULT_BUCKETS[-1] + 1, np.int32), max_new_tokens=4)
    with pytest.raises(ValueError, match="largest prefill bucket"):
        srv.submit(big)
    mono = MonolithicEngine(params, cfg, max_slots=2, max_len=8192)
    with pytest.raises(ValueError, match="largest prefill bucket"):
        mono.submit(big)


def test_submit_rejects_beyond_decode_capacity(setup):
    """Prompt + max_new past every decode engine's max_len fails at submit,
    not deep inside admit."""
    cfg, params = setup
    srv = DisaggregatedServer([PrefillEngine(params, cfg)],
                              [DecodeEngine(params, cfg, max_slots=2, max_len=64)])
    with pytest.raises(ValueError, match="capacity"):
        srv.submit(GenRequest(0, np.zeros(60, np.int32), max_new_tokens=8))
    # a prefill-only request (max_new <= 1) never needs a decode slot
    srv.submit(GenRequest(1, np.zeros(60, np.int32), max_new_tokens=1))


def test_slot_state():
    s = SlotState(max_slots=3, max_len=64)
    a = s.alloc(10)
    b = s.alloc(11)
    c = s.alloc(12)
    assert {a, b, c} == {0, 1, 2}
    assert s.alloc(13) is None
    s.free(b)
    assert s.alloc(13) == b
    assert s.n_active == 3


def test_sampling_modes():
    key = jax.random.PRNGKey(0)
    logits = jnp.array([[0.0, 5.0, 1.0, -2.0]] * 4)
    greedy = sample(logits, key, SamplingParams(temperature=0.0))
    assert list(np.asarray(greedy)) == [1, 1, 1, 1]
    topk = sample(logits, key, SamplingParams(temperature=1.0, top_k=2))
    assert all(int(t) in (1, 2) for t in np.asarray(topk))
    topp = sample(logits, key, SamplingParams(temperature=1.0, top_p=0.5))
    assert all(int(t) == 1 for t in np.asarray(topp))


def test_kv_insert_preserves_other_slots(setup):
    cfg, params = setup
    batch = batch_cache(cfg, 3, 64)
    toks = jnp.arange(10, dtype=jnp.int32)[None]
    _, single, _ = M.prefill(params, toks, cfg)
    b1 = insert_request(batch, single, 1, cfg)
    # slot 0 and 2 untouched (still zeros)
    for tree in b1:
        for leaf in jax.tree.leaves(tree):
            assert float(jnp.abs(leaf[:, 0]).max()) == 0.0
            assert float(jnp.abs(leaf[:, 2]).max()) == 0.0
