"""Serving fast-path invariants: bucketed prefill compiles, KV handoff
round-trips, donated-step equivalence, fused-block == step-at-a-time.

These are the regression guards for the device-resident serving loop: if a
later change re-introduces per-length recompiles or per-step host syncs, or
breaks the donation/fusion equivalence, these fail before any benchmark
notices.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    GenRequest,
    PrefillEngine,
    SamplingParams,
)
from repro.serving.engine import _bucket
from repro.serving.kvcache import batch_cache, extract_request, insert_request


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def hybrid_setup():
    """jamba: mamba + attn mixers in one pattern (exercises both cache kinds)."""
    cfg = reduced(ARCHS["jamba-1.5-large-398b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=6, lo=5, hi=40):
    rng = np.random.default_rng(seed)
    return [
        GenRequest(i, rng.integers(0, cfg.vocab_size, size=int(rng.integers(lo, hi))),
                   max_new_tokens=max_new)
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Bucketing: compiles bounded by buckets, not prompt lengths
# ---------------------------------------------------------------------------


def test_prefill_one_compile_per_bucket(setup):
    """Distinct prompt lengths in one bucket share one jitted shape."""
    cfg, params = setup
    eng = PrefillEngine(params, cfg)
    key = jax.random.PRNGKey(0)
    for i, S in enumerate([5, 17, 23, 40, 61, 64, 70, 100, 128]):
        key, k = jax.random.split(key)
        req = GenRequest(i, np.arange(S) % cfg.vocab_size, max_new_tokens=1)
        eng.prefill(req, k)
    buckets = {_bucket(S) for S in [5, 17, 23, 40, 61, 64, 70, 100, 128]}
    assert eng.n_compiles <= len(buckets), (
        f"{eng.n_compiles} compiles for {len(buckets)} buckets"
    )


def test_prefill_batch_matches_single(setup):
    """Batched bucketed prefill (with dummy-row padding) == one-at-a-time."""
    cfg, params = setup
    eng = PrefillEngine(params, cfg)
    reqs = _requests(cfg, 3, seed=5, max_new=1)
    key = jax.random.PRNGKey(42)
    toks_b, kvb, tls = eng.prefill_batch(reqs, key, pad_to=8)
    for i, r in enumerate(reqs):
        tok_s, kv_s, tl_s = eng.prefill(r, key)
        assert tls[i] == tl_s
        assert toks_b[i] == tok_s, f"request {i}: batch {toks_b[i]} != single {tok_s}"


# ---------------------------------------------------------------------------
# KV handoff round-trips: insert -> extract identity (attn and mamba/SSM)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fixture", ["setup", "hybrid_setup"])
def test_insert_extract_roundtrip(fixture, request):
    cfg, params = request.getfixturevalue(fixture)
    max_slots, max_len = 3, 128
    toks = jnp.arange(10, dtype=jnp.int32)[None]
    _, single, _ = M.prefill(params, toks, cfg)
    batch = batch_cache(cfg, max_slots, max_len)
    batch = insert_request(batch, single, 1, cfg)
    back = extract_request(batch, 1, 10, cfg)
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        want = jax.tree.leaves(single[i])
        got = jax.tree.leaves(back[i])
        for w, g in zip(want, got, strict=True):
            if mixer == "attn":
                w = w[:, :, :10]
                g = g[:, :, :10]
            np.testing.assert_array_equal(
                np.asarray(w, np.float32), np.asarray(g, np.float32),
                err_msg=f"{mixer} cache (pattern pos {i}) round-trip mismatch",
            )


def test_extract_decode_reinsert_continuation(setup):
    """The decode->prefill chip-reallocation path (paper's longevity story):
    insert -> decode a few tokens -> extract the slot's live cache ->
    re-insert into a fresh engine -> the continuation matches the
    uninterrupted stream.  (Paged twin: tests/test_paged_kv.py.)"""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    key = jax.random.PRNGKey(0)

    def fresh():
        return DecodeEngine(params, cfg, max_slots=2, max_len=128, sampling=sp,
                            decode_block=1)

    req = _requests(cfg, 1, seed=11, max_new=10)[0]
    tok, kv, tl = pre.prefill(req, key)
    eng = fresh()
    eng.admit(req, kv, tok, tl)
    while eng.requests:
        eng.step_block()
    full = list(req.tokens)

    req2 = _requests(cfg, 1, seed=11, max_new=10)[0]
    tok, kv, tl = pre.prefill(req2, key)
    eng_a = fresh()
    slot = eng_a.admit(req2, kv, tok, tl)
    for _ in range(4):
        eng_a.step_block()
    n_dec = len(req2.tokens) - 1
    length = tl + n_dec
    assert eng_a.slots.lengths[slot] == length
    pack = extract_request(eng_a.state.caches, slot, length, cfg)
    cont = GenRequest(99, req2.prompt, max_new_tokens=10 - n_dec)
    eng_b = fresh()
    eng_b.admit(cont, pack, req2.tokens[-1], length)
    while eng_b.requests:
        eng_b.step_block()
    assert req2.tokens[:-1] + cont.tokens == full


@pytest.mark.slow
def test_hybrid_server_end_to_end(hybrid_setup):
    """Bucketed batched prefill + fused decode on a mamba/attn hybrid."""
    cfg, params = hybrid_setup
    srv = DisaggregatedServer(
        [PrefillEngine(params, cfg)],
        [DecodeEngine(params, cfg, max_slots=3, max_len=128)],
    )
    for r in _requests(cfg, 5, seed=2, max_new=4):
        srv.submit(r)
    out = srv.run()
    assert len(out) == 5
    assert all(len(v) == 4 for v in out.values())


# ---------------------------------------------------------------------------
# Donation and fusion change nothing about the tokens
# ---------------------------------------------------------------------------


def _drive(params, cfg, *, decode_block, donate, temperature=0.0, seed=7):
    sp = SamplingParams(temperature=temperature)
    pre = PrefillEngine(params, cfg, sp)
    eng = DecodeEngine(params, cfg, max_slots=3, max_len=128, sampling=sp,
                       decode_block=decode_block, donate=donate, seed=seed)
    reqs = _requests(cfg, 3, seed=3, max_new=9)
    key = jax.random.PRNGKey(0)
    for r in reqs:
        key, k = jax.random.split(key)
        tok, kv, tl = pre.prefill(r, k)
        eng.admit(r, kv, tok, tl)
    steps = 0
    while eng.requests and steps < 100:
        steps += 1
        eng.step_block()
    return {r.rid: list(r.tokens) for r in reqs}


def test_donated_step_equivalence(setup):
    """Same tokens with and without buffer donation."""
    cfg, params = setup
    a = _drive(params, cfg, decode_block=4, donate=True)
    b = _drive(params, cfg, decode_block=4, donate=False)
    assert a == b


@pytest.mark.slow
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_fused_block_equals_step_at_a_time(setup, temperature):
    """Multi-token fused decode == one-at-a-time, bit-identical streams.

    The engine's PRNG key is split once per decode step inside the fused
    scan, so the sampling noise sequence is independent of the block size."""
    cfg, params = setup
    fused = _drive(params, cfg, decode_block=4, donate=True, temperature=temperature)
    stepwise = _drive(params, cfg, decode_block=1, donate=True, temperature=temperature)
    assert fused == stepwise


def test_decode_state_stays_on_device(setup):
    """The fused block returns only the token block to the host; the state
    (cache tree, tokens, positions, key) is a device pytree throughout."""
    cfg, params = setup
    eng = DecodeEngine(params, cfg, max_slots=2, max_len=64)
    pre = PrefillEngine(params, cfg)
    req = _requests(cfg, 1, seed=4, max_new=8)[0]
    tok, kv, tl = pre.prefill(req, jax.random.PRNGKey(0))
    eng.admit(req, kv, tok, tl)
    eng.step_block()
    for leaf in jax.tree.leaves(eng.state):
        assert isinstance(leaf, jax.Array), type(leaf)


def test_unbucketed_engine_mixed_paths(setup):
    """Legacy prefill() and prefill_batch() share one unbucketed engine
    without jit-cache collisions, and agree on the first token."""
    cfg, params = setup
    eng = PrefillEngine(params, cfg, bucketed=False)
    req = _requests(cfg, 1, seed=8, max_new=1)[0]
    key = jax.random.PRNGKey(0)
    tok_s, _, tl_s = eng.prefill(req, key)
    toks_b, _, tls_b = eng.prefill_batch([req], key)
    tok_s2, _, _ = eng.prefill(req, key)  # cached legacy closure still works
    assert tok_s == toks_b[0] == tok_s2
    assert tl_s == tls_b[0]
