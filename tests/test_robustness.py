"""Request-lifecycle robustness: cancellation at every stage, deadlines,
deterministic fault injection (with the chaos churn matrix), KV invariant
auditing, engine-crash recovery, load shedding, and the SchedulerExhausted
resume contract.

The bit-exactness arguments all lean on one property: greedy decode
(temperature 0) is schedule-independent, so however faults, retries,
cancellations, or crashes reshuffle the rounds, every SURVIVING request's
token stream must equal the undisturbed run's, bit for bit.

Chaos seed: ``CHAOS_SEED`` in the environment (default 0) seeds every fault
plan here and is printed at collection, so any nightly-chaos failure replays
with ``CHAOS_SEED=<seed> pytest tests/test_robustness.py``.
"""
import os

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    FaultInjector,
    FaultPlan,
    GenRequest,
    PrefillEngine,
    SamplingParams,
    SchedulerExhausted,
    TransientFault,
    STATUS_CANCELLED,
    STATUS_DEADLINE,
    STATUS_FAILED,
    STATUS_FINISHED,
    STATUS_SHED,
    make_scheduler,
)
from repro.serving.scheduler import FCFSScheduler

PAGE = 16
CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))
print(f"[chaos] CHAOS_SEED={CHAOS_SEED} "
      f"(replay: CHAOS_SEED={CHAOS_SEED} pytest tests/test_robustness.py)")


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=6, lo=5, hi=40, base=0):
    rng = np.random.default_rng(seed)
    return [
        GenRequest(base + i,
                   rng.integers(0, cfg.vocab_size, size=int(rng.integers(lo, hi))),
                   max_new_tokens=max_new)
        for i in range(n)
    ]


def _server(params, cfg, *, scheduler=None, paged=True, prefix=False,
            chunk=None, max_slots=4, max_len=128, n_pages=None,
            decode_block=4, faults=None, audit_every=None, seed=0):
    sp = SamplingParams(temperature=0.0)
    return DisaggregatedServer(
        [PrefillEngine(params, cfg, sp, chunk_tokens=chunk)],
        [DecodeEngine(params, cfg, max_slots=max_slots, max_len=max_len,
                      sampling=sp, decode_block=decode_block, paged=paged,
                      page_size=PAGE, n_pages=n_pages, prefix_cache=prefix,
                      seed=seed)],
        seed=seed, max_prefill_batch=4, scheduler=scheduler, faults=faults,
        audit_every=audit_every,
    )


def _assert_clean(srv):
    """Zero host-side leaks and a clean device audit after drain (the churn
    invariants every exit path — finish, cancel, fail, shed — must uphold)."""
    s = srv.scheduler
    assert not s.queue and not s.waiting and not s.swapped
    assert s.submit_round == {}
    assert srv._hash_memo == {}
    assert srv.chunks == {}
    for eng in srv.decodes:
        assert eng.requests == {}
        if eng.paged:
            assert eng._pins == {}
            assert eng._chunk_holds == {}
            assert eng._reserved == [0] * eng.max_slots
            if eng.prefix is not None:
                assert eng.prefix._pins == {}
                assert eng.prefix._swap_pins == {}
        rep = eng.audit()
        assert rep.ok, rep.discrepancies
    srv.audit(strict=True)


# ---------------------------------------------------------------------------
# cancellation at every lifecycle stage
# ---------------------------------------------------------------------------


def test_cancel_queued_and_decoding(setup):
    cfg, params = setup
    ref_srv = _server(params, cfg, prefix=True)
    ref_reqs = _requests(cfg, 5, max_new=12)
    for r in ref_reqs:
        ref_srv.submit(r)
    ref = ref_srv.run()

    srv = _server(params, cfg, prefix=True)
    reqs = _requests(cfg, 5, max_new=12)
    for r in reqs:
        srv.submit(r)
    assert srv._stage_of(4) == "queued"
    assert srv.cancel(4)
    assert reqs[4].done and reqs[4].status == STATUS_CANCELLED
    for _ in range(2):
        srv.run_round()
    decoding = [r.rid for r in reqs[:4] if srv._stage_of(r.rid) == "decoding"]
    assert decoding
    victim = decoding[0]
    got_before = len(srv.all_requests[victim].tokens)
    assert srv.cancel(victim)
    assert srv.all_requests[victim].status == STATUS_CANCELLED
    # truncated, not erased
    assert len(srv.all_requests[victim].tokens) == got_before
    srv.run()
    # cancel is a no-op on terminal requests (the finish won the race)
    assert not srv.cancel(victim)
    for r in reqs:
        if r.rid not in (4, victim):
            assert list(r.tokens) == ref[r.rid], f"survivor {r.rid} diverged"
            assert r.status == STATUS_FINISHED
    _assert_clean(srv)


def test_cancel_waiting(setup):
    cfg, params = setup
    # 2 slots, 4 prefilled: some entries stay prefilled-waiting after round 1
    srv = _server(params, cfg, prefix=True, max_slots=2)
    reqs = _requests(cfg, 4, max_new=12)
    for r in reqs:
        srv.submit(r)
    srv.run_round()
    waiting = [e.req.rid for e in srv.scheduler.waiting]
    assert waiting, "expected prefilled-waiting entries with 2 slots"
    assert srv._stage_of(waiting[0]) == "waiting"
    assert srv.cancel(waiting[0])
    srv.run()
    assert srv.all_requests[waiting[0]].status == STATUS_CANCELLED
    _assert_clean(srv)


def test_cancel_mid_chunk(setup):
    cfg, params = setup
    srv = _server(params, cfg, prefix=True, chunk=32)
    rng = np.random.default_rng(3)
    long = GenRequest(0, rng.integers(0, cfg.vocab_size, size=96),
                      max_new_tokens=4)
    short = GenRequest(1, rng.integers(0, cfg.vocab_size, size=12),
                       max_new_tokens=4)
    srv.submit(long)
    srv.submit(short)
    srv.run_round()
    assert srv._stage_of(0) == "chunking"
    assert srv.cancel(0)  # drops the cursor, the chunk holds, and the pins
    srv.run()
    assert long.status == STATUS_CANCELLED
    assert short.status == STATUS_FINISHED
    _assert_clean(srv)


def test_cancel_swapped(setup):
    cfg, params = setup
    sched = make_scheduler("priority", swap=True)
    srv = _server(params, cfg, scheduler=sched, max_slots=8, n_pages=16,
                  decode_block=8)
    lows = [GenRequest(i, np.random.default_rng(5).integers(
        0, cfg.vocab_size, size=10), max_new_tokens=24) for i in range(5)]
    for r in lows:
        srv.submit(r)
    srv.run_round()
    srv.run_round()
    high = GenRequest(100, np.random.default_rng(6).integers(
        0, cfg.vocab_size, size=40), max_new_tokens=16, priority=1)
    srv.submit(high)
    while not srv.scheduler.swapped and srv.pending():
        srv.run_round()
    assert srv.scheduler.swapped, "preemption never swapped a victim out"
    victim = srv.scheduler.swapped[0].req.rid
    assert srv._stage_of(victim) == "swapped"
    assert srv.cancel(victim)
    assert srv.all_requests[victim].status == STATUS_CANCELLED
    srv.run()
    assert high.status == STATUS_FINISHED
    _assert_clean(srv)


# ---------------------------------------------------------------------------
# deadlines
# ---------------------------------------------------------------------------


def test_deadline_expiry_survivors_bitexact(setup):
    cfg, params = setup
    ref_srv = _server(params, cfg)
    ref_reqs = _requests(cfg, 6, max_new=8)
    for r in ref_reqs:
        ref_srv.submit(r)
    ref = ref_srv.run()

    # 2 slots: the last requests queue for several rounds and expire
    srv = _server(params, cfg, max_slots=2)
    reqs = _requests(cfg, 6, max_new=8)
    for r in reqs:
        r.deadline_rounds = 6
        srv.submit(r)
    srv.run()
    statuses = {r.rid: r.status for r in reqs}
    assert STATUS_DEADLINE in statuses.values(), statuses
    assert STATUS_FINISHED in statuses.values(), statuses
    for r in reqs:
        if r.status == STATUS_FINISHED:
            assert list(r.tokens) == ref[r.rid], f"survivor {r.rid} diverged"
    _assert_clean(srv)


def test_ttft_deadline(setup):
    cfg, params = setup
    srv = _server(params, cfg, max_slots=2)
    reqs = _requests(cfg, 6, max_new=8)
    for r in reqs:
        r.ttft_deadline = 3
        srv.submit(r)
    srv.run()
    statuses = {r.rid: r.status for r in reqs}
    assert STATUS_DEADLINE in statuses.values(), statuses
    # a request with a first token can never expire on the TTFT deadline
    for r in reqs:
        if r.status == STATUS_DEADLINE:
            assert r.tokens == []
    _assert_clean(srv)


# ---------------------------------------------------------------------------
# chaos churn matrix: faults x schedulers x engine modes
# ---------------------------------------------------------------------------

_REFS = {}  # mode -> fault-free reference streams (greedy: policy-invariant)

_MODES = {
    "slab": {"paged": False, "prefix": False, "chunk": None},
    "paged": {"paged": True, "prefix": False, "chunk": None},
    "prefix": {"paged": True, "prefix": True, "chunk": None},
    "chunked": {"paged": True, "prefix": True, "chunk": 32},
}


def _mode_requests(cfg, mode):
    reqs = _requests(cfg, 4, seed=1, max_new=6)
    if mode == "chunked":
        rng = np.random.default_rng(2)
        reqs.append(GenRequest(4, rng.integers(0, cfg.vocab_size, size=80),
                               max_new_tokens=6))
    return reqs


def _mode_rates(mode):
    if mode == "slab":
        return {"admit": 0.2}
    rates = {"admit": 0.15, "swap_in": 0.15, "swap_out": 0.15}
    if mode == "chunked":
        rates["chunk_append"] = 0.15
    return rates


@pytest.mark.parametrize("mode", sorted(_MODES))
@pytest.mark.parametrize("sched", ["fcfs", "kv-aware", "priority"])
def test_chaos_churn(setup, sched, mode):
    cfg, params = setup
    kw = _MODES[mode]
    if mode not in _REFS:
        ref_srv = _server(params, cfg, **kw)
        ref_reqs = _mode_requests(cfg, mode)
        for r in ref_reqs:
            ref_srv.submit(r)
        _REFS[mode] = ref_srv.run()
    ref = _REFS[mode]

    swap = sched == "priority" and kw["paged"]
    plan = FaultPlan(seed=CHAOS_SEED, rates=_mode_rates(mode))
    srv = _server(params, cfg, scheduler=make_scheduler(sched, swap=swap),
                  faults=plan, audit_every=4, **kw)
    reqs = _mode_requests(cfg, mode)
    for r in reqs:
        srv.submit(r)
    srv.run()
    for r in reqs:
        assert r.status == STATUS_FINISHED
        assert list(r.tokens) == ref[r.rid], \
            f"[{sched}/{mode}] stream {r.rid} diverged under faults"
    _assert_clean(srv)


# ---------------------------------------------------------------------------
# engine crash recovery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("preserve_kv", [False, True])
def test_engine_crash_recovery_bitexact(setup, preserve_kv):
    cfg, params = setup
    ref_srv = _server(params, cfg, prefix=True, chunk=32)
    rng = np.random.default_rng(9)
    def trace():
        r = np.random.default_rng(9)
        out = [GenRequest(0, r.integers(0, cfg.vocab_size, size=96),
                          max_new_tokens=8)]
        out += [GenRequest(1 + i, r.integers(0, cfg.vocab_size,
                                             size=int(r.integers(8, 14))),
                           max_new_tokens=8) for i in range(3)]
        return out
    for r in trace():
        ref_srv.submit(r)
    ref = ref_srv.run()

    plan = FaultPlan(seed=CHAOS_SEED, crash_round=3, preserve_kv=preserve_kv)
    srv = _server(params, cfg, prefix=True, chunk=32, faults=plan)
    reqs = trace()
    for r in reqs:
        srv.submit(r)
    srv.run()
    assert srv.crash_events, "planned crash never fired"
    ev = srv.crash_events[0]
    assert ev["replayed"] or ev["stashed"], "crash hit no in-flight work"
    if preserve_kv:
        assert ev["stashed"], "preserve_kv crash produced no host stashes"
    assert srv.decodes[0].stats.get("crashes") == 1
    for r in reqs:
        assert r.status == STATUS_FINISHED
        assert list(r.tokens) == ref[r.rid], \
            f"stream {r.rid} diverged across the crash"
    _assert_clean(srv)


# ---------------------------------------------------------------------------
# graceful degradation: give-up failures and load shedding
# ---------------------------------------------------------------------------


def test_give_up_fails_structurally(setup):
    cfg, params = setup
    plan = FaultPlan(seed=CHAOS_SEED, rates={"admit": 1.0}, max_retries=3,
                     give_up=True)
    srv = _server(params, cfg, faults=plan)
    reqs = _requests(cfg, 2)
    for r in reqs:
        srv.submit(r)
    srv.run()  # returns instead of spinning forever
    for r in reqs:
        assert r.done and r.status == STATUS_FAILED
    _assert_clean(srv)


def test_load_shedding(setup):
    cfg, params = setup
    srv = _server(params, cfg,
                  scheduler=FCFSScheduler(shed_after_rounds=3), max_slots=2)
    reqs = _requests(cfg, 10, max_new=8)
    for r in reqs:
        srv.submit(r)
    srv.run()
    statuses = [r.status for r in reqs]
    assert statuses.count(STATUS_SHED) >= 1, statuses
    assert statuses.count(STATUS_SHED) == srv.scheduler.stats["shed"]
    assert STATUS_FINISHED in statuses
    _assert_clean(srv)


# ---------------------------------------------------------------------------
# SchedulerExhausted: structured statuses + the resume contract
# ---------------------------------------------------------------------------


def test_scheduler_exhausted_statuses_and_resume(setup):
    cfg, params = setup
    srv = _server(params, cfg, max_slots=2)
    reqs = _requests(cfg, 6, max_new=8)
    for r in reqs:
        srv.submit(r)
    with pytest.raises(SchedulerExhausted) as ei:
        srv.run(max_steps=2)
    exc = ei.value
    assert set(exc.statuses) == {r.rid for r in reqs}
    stages = {"queued", "chunking", "waiting", "decoding", "swapped", "done"}
    for rid, oc in exc.statuses.items():
        assert oc.rid == rid
        assert oc.stage in stages, oc
        if oc.status == STATUS_FINISHED:
            assert oc.stage == "done"
    assert any(oc.status == "PENDING" for oc in exc.statuses.values())
    # resume: the server state is intact — just run() again
    out = srv.run()
    assert set(out) == {r.rid for r in reqs}
    assert all(r.status == STATUS_FINISHED for r in reqs)
    _assert_clean(srv)


# ---------------------------------------------------------------------------
# the KV invariant auditor itself
# ---------------------------------------------------------------------------


def test_audit_detects_corruption(setup):
    cfg, params = setup
    srv = _server(params, cfg, prefix=True)
    reqs = _requests(cfg, 3, max_new=12)
    for r in reqs:
        srv.submit(r)
    srv.run_round()
    srv.run_round()
    eng = srv.decodes[0]
    assert eng.audit().ok
    # leak a refcount on device: conservation must catch it
    st = eng.state
    eng.state = st._replace(page_refs=st.page_refs.at[0].add(1))
    rep = eng.audit()
    assert not rep.ok and rep.discrepancies
    with pytest.raises(AssertionError):
        srv.audit(strict=True)
    eng.state = eng.state._replace(page_refs=st.page_refs)  # heal
    srv.run()
    _assert_clean(srv)


def test_fault_plan_validation_and_determinism():
    with pytest.raises(ValueError):
        FaultPlan(rates={"bogus_site": 0.5})
    a = FaultInjector(FaultPlan(seed=CHAOS_SEED, rates={"admit": 0.5}))
    b = FaultInjector(FaultPlan(seed=CHAOS_SEED, rates={"admit": 0.5}))
    draws_a = [a.should_fail("admit", i) for i in range(64)]
    draws_b = [b.should_fail("admit", i) for i in range(64)]
    assert draws_a == draws_b  # the schedule is a pure function of the seed
    assert a.stats == b.stats


def test_swap_out_fault_is_transient():
    with pytest.raises(TransientFault):
        raise TransientFault("nothing mutated")
    inj = FaultInjector(FaultPlan(seed=0, rates={"swap_out": 1.0},
                                  max_retries=2))
    assert inj.should_fail("swap_out", 1)
    assert inj.should_fail("swap_out", 1)
    # bounded retry: the fault heals after max_retries attempts
    assert not inj.should_fail("swap_out", 1)
