"""HLO analyzer calibration: flops / collective bytes / trip counts are
exact on controlled programs (this underwrites the roofline numbers)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hloanalysis


def _compile(f, *specs, **kw):
    return jax.jit(f, **kw).lower(*specs).compile()


def test_matmul_flops_exact():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    comp = _compile(lambda a, b: a @ b, x, w)
    st = hloanalysis.analyze(comp.as_text())
    assert abs(st.flops - 2 * 64 * 128 * 32) / (2 * 64 * 128 * 32) < 1e-6


def test_scan_trip_count_correction():
    """10-iteration scan of one matmul -> 10x the single-matmul flops."""
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)

    def f(a, b):
        def body(c, _):
            return jnp.tanh(c @ b), None

        c, _ = jax.lax.scan(body, a, None, length=10)
        return c

    comp = _compile(f, x, w)
    st = hloanalysis.analyze(comp.as_text())
    assert st.while_trip_counts and max(st.while_trip_counts.values()) == 10
    want = 10 * 2 * 8 * 64 * 64
    assert abs(st.flops - want) / want < 0.05


def test_cost_analysis_agrees_per_device():
    """cost_analysis flops ~~ parsed flops on a single-device program."""
    x = jax.ShapeDtypeStruct((32, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    comp = _compile(lambda a, b: a @ b, x, w)
    cost = comp.cost_analysis()
    cost = cost[0] if isinstance(cost, (list, tuple)) else cost
    st = hloanalysis.analyze(comp.as_text())
    assert abs(st.flops - cost["flops"]) / cost["flops"] < 0.1


def test_roofline_terms_dominance():
    t = hloanalysis.roofline_terms(
        flops=197e12, bytes_hbm=1e9, collective_bytes=0, n_chips=1
    )
    assert t["dominant"] == "compute"
    assert abs(t["t_compute_s"] - 1.0) < 1e-6
    t = hloanalysis.roofline_terms(
        flops=1e12, bytes_hbm=819e9 * 2, collective_bytes=0, n_chips=1
    )
    assert t["dominant"] == "memory"
    assert abs(t["t_memory_s"] - 2.0) < 1e-6
    t = hloanalysis.roofline_terms(
        flops=0, bytes_hbm=0, collective_bytes=50e9 * 3, n_chips=1
    )
    assert t["dominant"] == "collective"
    assert abs(t["t_collective_s"] - 3.0) < 1e-6


def test_shape_bytes_parser():
    from repro.launch.hloanalysis import _shape_bytes

    assert _shape_bytes("f32[8,128]") == 8 * 128 * 4
    assert _shape_bytes("bf16[2,3,4]") == 24 * 2
    assert _shape_bytes("(f32[8], bf16[4])") == 32 + 8
    assert _shape_bytes("pred[]") == 1
