"""Quantized KV pages (int8 per-page scales) + batch-level prefix dedup,
under a bounded-error harness.

The contract this file machine-checks:

* **fp32 configs are bit-exact** — with the quant code merged but disabled
  (``kv_dtype="fp32"``, the default) every serving mode emits streams
  bit-identical to the slab engine, exactly as before this feature landed
  (the negative control: presence of the scale plumbing changes nothing).
* **int8 error is bounded, not vibes** — quantize→dequantize error is
  ``<= scale / 2`` elementwise for adversarial page contents; per-step decode
  logit error on reduced granite-8b stays under a hard gate; and greedy int8
  streams may diverge from fp32 ONLY at a step whose fp32 top-1/top-2 logit
  margin is smaller than the attributable dequant error (metamorphic gate —
  a divergence at a confident step would mean a real bug, not quant noise).
* **quant state lives inside the page machinery** — COW redirects copy int8
  payloads and scales bit-identically, trash-page writes never perturb live
  pages' scales, and the KV auditor validates the scale leaf (finite,
  non-negative on live pages) and flags corruption.
* **batch-level dedup is compute-only** — same-batch shared prefixes prefill
  once (fewer dispatched prefill tokens, ``unified_stats`` accounted), with
  streams bit-identical to the non-dedup path (including the categorical
  first-token draw, which is batch-shape dependent) and clean audits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import attention as A
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    EngineConfig,
    GenRequest,
    PrefillEngine,
    SamplingParams,
)
from repro.serving import kvcache

PAGE = 16

# Hard gate on per-step decode logit max-abs error (int8 vs fp32) for
# reduced granite-8b.  Measured: 0.25 max over 23 steps (bf16 activations
# quantize the observable error to coarse steps); the gate leaves 2x headroom
# without ever excusing a real bug (a wrong page/scale shows up as O(1-10)).
LOGIT_ERR_GATE = 0.5


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def mla_setup():
    cfg = reduced(ARCHS["minicpm3-4b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def hybrid_setup():
    cfg = reduced(ARCHS["jamba-1.5-large-398b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# Quantize/dequantize roundtrip: property-based error bound
# ---------------------------------------------------------------------------


def _adversarial_pages(kind: str, key, shape=(2, 3, PAGE, 2, 8)):
    """Page batches engineered to stress the absmax quantizer."""
    if kind == "normal":
        return jax.random.normal(key, shape)
    if kind == "near_zero":
        return jax.random.normal(key, shape) * 1e-30
    if kind == "all_zero":
        return jnp.zeros(shape)
    if kind == "single_outlier":
        x = jax.random.normal(key, shape)
        # one huge element per page: scale blows up to ~1e4/127, every other
        # element lands in the first couple of quant bins
        flat = x.reshape(shape[0], shape[1], -1)
        flat = flat.at[:, :, 0].set(1e4)
        return flat.reshape(shape)
    if kind == "sign_flips":
        k1, k2 = jax.random.split(key)
        mag = jnp.exp(jax.random.normal(k1, shape) * 3.0)
        sign = jnp.where(jax.random.bernoulli(k2, 0.5, shape), 1.0, -1.0)
        return mag * sign
    if kind == "rope_rotated":
        # decode-realistic K content: random head vectors rotated by RoPE at
        # scattered absolute positions (rotation preserves norm, but mixes
        # the lanes the absmax reduction sees)
        k1, k2 = jax.random.split(key)
        R, n, ps, KV, dh = shape
        v = jax.random.normal(k1, (R * n * ps, 1, KV, dh))
        pos = jax.random.randint(k2, (R * n * ps,), 0, 4096)
        cos, sin = A.rope_cos_sin(pos, dh, 10000.0)
        return A.apply_rope_vec(v, cos, sin).reshape(shape)
    raise ValueError(kind)


def _assert_roundtrip_bounded(pages):
    q, scale = A.quantize_pages(pages)
    dq = A.dequantize_pages(q, scale)
    assert q.dtype == jnp.int8 and scale.dtype == jnp.float32
    err = jnp.abs(dq - pages.astype(jnp.float32))
    bound = (scale / 2).reshape(scale.shape + (1,) * (pages.ndim - 2))
    # round-to-nearest: elementwise error <= scale/2, up to fp32 rounding
    assert bool(jnp.all(err <= bound * (1 + 1e-5) + 1e-30)), (
        float(jnp.max(err)),
        float(jnp.max(bound)),
    )


@pytest.mark.parametrize("kind", ["normal", "all_zero", "near_zero"])
def test_roundtrip_error_bound(kind):
    _assert_roundtrip_bounded(_adversarial_pages(kind, jax.random.PRNGKey(7)))


@pytest.mark.slow
@pytest.mark.parametrize(
    "kind", ["normal", "near_zero", "single_outlier", "sign_flips", "rope_rotated"]
)
@pytest.mark.parametrize("seed", range(5))
def test_roundtrip_adversarial_sweep(kind, seed):
    _assert_roundtrip_bounded(
        _adversarial_pages(kind, jax.random.PRNGKey(seed * 101 + 13))
    )


def test_all_zero_page_quantizes_safely():
    q, scale = A.quantize_pages(jnp.zeros((1, 2, PAGE, 2, 4)))
    assert bool(jnp.all(scale == 0.0))
    assert bool(jnp.all(q == 0))
    assert bool(jnp.all(A.dequantize_pages(q, scale) == 0.0))


def test_requantize_is_idempotent():
    """quantize(dequantize(q, s)) == (q, s) bit for bit: the absmax element
    reconstructs to exactly +-127 * s, so a swap-out/swap-in (or any
    extract -> re-admit round trip) re-derives the identical page."""
    pages = _adversarial_pages("sign_flips", jax.random.PRNGKey(3))
    q, s = A.quantize_pages(pages)
    q2, s2 = A.quantize_pages(A.dequantize_pages(q, s))
    assert bool(jnp.all(q2 == q))
    assert bool(jnp.all(s2 == s))


# ---------------------------------------------------------------------------
# Quant state inside the page machinery: COW, trash, audit
# ---------------------------------------------------------------------------


def _int8_state(cfg, key, *, max_slots=2, max_len=64, page_size=PAGE, n_pages=8):
    return kvcache.init_paged_decode_state(
        cfg, max_slots, max_len, page_size, n_pages, key, kv_dtype="int8"
    )


def _first_attn(cfg):
    return next(i for i, (m, _) in enumerate(cfg.block_pattern) if m == "attn")


def test_cow_redirect_copies_payload_and_scales_bitwise(setup):
    cfg, _ = setup
    st = _int8_state(cfg, jax.random.PRNGKey(0))
    i = _first_attn(cfg)
    # page 0 holds a shared prefix: random int8 payload + scales, refs == 2
    caches = list(st.caches)
    scales = list(st.scales)
    kk = jax.random.PRNGKey(1)
    new_leaf, new_sc = {}, {}
    for name, pool in st.caches[i].items():
        kk, k1, k2 = jax.random.split(kk, 3)
        new_leaf[name] = pool.at[:, 0].set(
            jax.random.randint(k1, pool.shape[:1] + pool.shape[2:], -127, 128, jnp.int32).astype(jnp.int8)
        )
        new_sc[name] = st.scales[i][name].at[:, 0].set(
            jax.random.uniform(k2, (pool.shape[0],), minval=0.01, maxval=2.0)
        )
    caches[i], scales[i] = new_leaf, new_sc
    refs = st.page_refs.at[0].set(2)
    bt = st.block_tables.at[0, 0].set(0)
    pos0 = jnp.asarray([8, 0], jnp.int32)  # slot 0 writes inside page 0
    will_write = jnp.asarray([True, False])
    refs2, bt2, caches2, scales2 = kvcache.cow_redirect(
        refs, bt, pos0, will_write, 4, PAGE, caches=caches, cfg=cfg,
        scales=scales,
    )
    fresh = int(bt2[0, 0])
    assert fresh != 0, "writer's table entry was not redirected"
    assert int(refs2[0]) == 1, "shared ref not decremented"
    for name in caches[i]:
        src = np.asarray(caches[i][name][:, 0])
        cpy = np.asarray(caches2[i][name][:, fresh])
        assert (src == cpy).all(), f"{name}: int8 payload not copied bitwise"
        s_src = np.asarray(scales[i][name][:, 0])
        s_cpy = np.asarray(scales2[i][name][:, fresh])
        assert (s_src == s_cpy).all(), f"{name}: scale not copied bitwise"


def test_trash_writes_never_perturb_live_scales(setup):
    """A decode write steered to the trash page (released slot / overshoot)
    must leave every live page's payload AND scale bit-untouched."""
    cfg, _ = setup
    st = _int8_state(cfg, jax.random.PRNGKey(0))
    i = _first_attn(cfg)
    caches = list(st.caches)
    scales = list(st.scales)
    kk = jax.random.PRNGKey(2)
    leaf, sc = {}, {}
    for name, pool in st.caches[i].items():
        kk, k1, k2 = jax.random.split(kk, 3)
        leaf[name] = pool.at[:, 1].set(
            jax.random.randint(k1, pool.shape[:1] + pool.shape[2:], -127, 128, jnp.int32).astype(jnp.int8)
        )
        sc[name] = st.scales[i][name].at[:, 1].set(
            jax.random.uniform(k2, (pool.shape[0],), minval=0.01, maxval=2.0)
        )
    caches[i], scales[i] = leaf, sc
    # both slots' tables are all-trash (released): every write lands on trash
    B = st.block_tables.shape[0]
    deltas = []
    for j, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer == "attn":
            kk, k1 = jax.random.split(kk)
            deltas.append(
                jax.tree.map(
                    lambda a: jax.random.normal(
                        jax.random.fold_in(k1, a.ndim), (a.shape[0], B) + a.shape[3:]
                    ),
                    caches[j],
                )
            )
        else:
            deltas.append(caches[j])  # mamba: replacement semantics
    new_caches, new_scales = M.merge_cache_deltas(
        cfg, caches, deltas, jnp.asarray([5, 0], jnp.int32), B,
        block_tables=st.block_tables, scales=scales,
    )
    n_pages = st.page_refs.shape[0]
    for name in caches[i]:
        before = np.asarray(caches[i][name][:, :n_pages])
        after = np.asarray(new_caches[i][name][:, :n_pages])
        assert (before == after).all(), f"{name}: live payload perturbed"
        sb = np.asarray(scales[i][name][:, :n_pages])
        sa = np.asarray(new_scales[i][name][:, :n_pages])
        assert (sb == sa).all(), f"{name}: live scale perturbed"


def _int8_engine(params, cfg, *, max_slots=2, max_len=128, page_size=64,
                 kv_dtype="int8", prefix_cache=False):
    sp = SamplingParams(temperature=0.0)
    return DecodeEngine(
        params, cfg, max_slots=max_slots, max_len=max_len, sampling=sp,
        decode_block=1, paged=True, page_size=page_size, kv_dtype=kv_dtype,
        prefix_cache=prefix_cache,
    )


def test_audit_validates_scale_leaf(setup):
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    eng = _int8_engine(params, cfg)
    rng = np.random.default_rng(0)
    req = GenRequest(0, np.asarray(rng.integers(1, cfg.vocab_size, 40), np.int32), 8)
    first, kv, tl = pre.prefill(req, jax.random.PRNGKey(1))
    assert eng.admit(req, kv, first, tl) is not None
    assert eng.audit().ok
    i = _first_attn(cfg)
    live = int(np.asarray(eng.state.block_tables)[0, 0])
    trash = eng.n_pages
    name = next(iter(eng.state.scales[i]))

    def poison(page):
        scales = list(eng.state.scales)
        leaf = dict(scales[i])
        leaf[name] = leaf[name].at[:, page].set(np.nan)
        scales[i] = leaf
        return eng.state._replace(scales=scales)

    # trash scale is write-only scratch: poisoning it stays clean
    saved = eng.state
    eng.state = poison(trash)
    assert eng.audit().ok
    # a NaN scale on a LIVE page is flagged
    eng.state = poison(live)
    rep = eng.audit()
    assert not rep.ok
    assert any("scale" in d for d in rep.discrepancies)
    eng.state = saved


def test_int8_requires_paged(setup):
    cfg, params = setup
    with pytest.raises(ValueError, match="paged"):
        DecodeEngine(params, cfg, paged=False, kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        DecodeEngine(params, cfg, paged=True, kv_dtype="int4")


def test_int8_pool_bytes_smaller_at_fixed_pages(setup):
    cfg, _ = setup
    f32 = kvcache.paged_kv_cache_bytes(cfg, 4, 64, PAGE, max_len=128)
    i8 = kvcache.paged_kv_cache_bytes(cfg, 4, 64, PAGE, max_len=128, kv_dtype="int8")
    assert i8 < f32
    # attention payload shrinks by itemsize/1; the fp32 scale leaf overhead
    # must not eat the win (it is [R, n_pages+1] vs whole pages of payload)
    assert f32 / i8 >= 1.8


# ---------------------------------------------------------------------------
# Bounded-error stream gates (reduced granite-8b)
# ---------------------------------------------------------------------------


def _drive_logits(params, cfg, eng, steps):
    """Greedy-decode ``steps`` tokens straight through M.decode_step (the
    engine API never exposes logits), staying inside the admitted pages —
    page_size=64 and prompt 40 leave 23 in-page writes before any decode-time
    page allocation would be needed."""
    st = eng.state
    caches, scales = st.caches, st.scales
    tokens, pos, bt = st.tokens, st.positions, st.block_tables
    logits_seq, toks = [], []
    for _ in range(steps):
        if scales is not None:
            lg, caches, scales = M.decode_step(
                params, tokens, caches, pos, cfg, block_tables=bt, scales=scales
            )
        else:
            lg, caches = M.decode_step(
                params, tokens, caches, pos, cfg, block_tables=bt
            )
        tokens = jnp.argmax(lg, -1).astype(tokens.dtype)
        pos = pos + 1
        logits_seq.append(np.asarray(lg[0], np.float32))
        toks.append(int(tokens[0]))
    return np.stack(logits_seq), toks


def test_int8_logit_error_bounded_and_divergence_attributable(setup):
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    rng = np.random.default_rng(0)
    req = GenRequest(0, np.asarray(rng.integers(1, cfg.vocab_size, 40), np.int32), 23)
    runs = {}
    for kv_dtype in ("fp32", "int8"):
        pre = PrefillEngine(params, cfg, sp)
        eng = _int8_engine(params, cfg, kv_dtype=kv_dtype)
        first, kv, tl = pre.prefill(req, jax.random.PRNGKey(1))
        assert eng.admit(req, kv, first, tl) is not None
        runs[kv_dtype] = (_drive_logits(params, cfg, eng, 23), first)
    (L32, t32), f32 = runs["fp32"]
    (L8, t8), f8 = runs["int8"]
    assert f32 == f8  # prefill is fp32 in both; admit quantizes afterwards
    err = np.abs(L32 - L8).max(axis=1)
    # hard gate: per-step logit max-abs error
    assert err.max() <= LOGIT_ERR_GATE, f"logit error {err.max()} > {LOGIT_ERR_GATE}"
    # metamorphic gate: greedy divergence is only legal at a step whose fp32
    # top-1/top-2 margin is within the attributable dequant error (2x the
    # measured per-step bound: both logits can move toward each other)
    for j in range(len(t32)):
        if t32[j] != t8[j]:
            srt = np.sort(L32[j])[::-1]
            margin = srt[0] - srt[1]
            assert margin <= 2 * err[j], (
                f"step {j}: streams diverged at a confident step "
                f"(margin {margin}, attributable error {2 * err[j]})"
            )
            break  # post-divergence prefixes differ; later steps incomparable


# ---------------------------------------------------------------------------
# fp32 negative control: bit-identity matrix with quant code merged
# ---------------------------------------------------------------------------


def _prompts(cfg, n=3, seed=0, shared=24, lo=4, hi=14):
    rng = np.random.default_rng(seed)
    common = rng.integers(1, cfg.vocab_size, shared)
    return [
        np.concatenate(
            [common, rng.integers(1, cfg.vocab_size, int(rng.integers(lo, hi)))]
        ).astype(np.int32)
        for _ in range(n)
    ]


def _run_server(params, cfg, ec, prompts, mnt=5):
    srv = DisaggregatedServer.from_config(params, cfg, ec)
    for i, p in enumerate(prompts):
        srv.submit(GenRequest(i, p, mnt))
    out = srv.run()
    return srv, {r: list(map(int, t)) for r, t in out.items()}


def _mode_config(mode, sampling):
    base = dict(max_slots=4, max_len=128, page_size=PAGE, sampling=sampling,
                seed=0)
    if mode == "slab":
        return EngineConfig(paged=False, **base)
    if mode == "paged":
        return EngineConfig(paged=True, **base)
    if mode == "prefix":
        return EngineConfig(paged=True, prefix_cache=True, **base)
    if mode == "chunked":
        return EngineConfig(paged=True, prefix_cache=True, chunk_tokens=PAGE,
                            **base)
    raise ValueError(mode)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_fp32_bit_identity_matrix_attn(setup, temperature):
    cfg, params = setup
    sp = SamplingParams(temperature=temperature)
    prompts = _prompts(cfg)
    # max_prefill_batch differences change the sampled key schedule, not the
    # greedy one; all four modes here share the default, so streams compare
    _, ref = _run_server(params, cfg, _mode_config("slab", sp), prompts)
    for mode in ("paged", "prefix", "chunked"):
        _, out = _run_server(params, cfg, _mode_config(mode, sp), prompts)
        assert out == ref, f"fp32 {mode} stream drifted from slab"


@pytest.mark.slow
@pytest.mark.parametrize("arch_fixture", ["mla_setup", "hybrid_setup"])
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_fp32_bit_identity_matrix_other_archs(request, arch_fixture, temperature):
    cfg, params = request.getfixturevalue(arch_fixture)
    sp = SamplingParams(temperature=temperature)
    prompts = _prompts(cfg)
    _, ref = _run_server(params, cfg, _mode_config("slab", sp), prompts)
    for mode in ("paged", "prefix"):
        _, out = _run_server(params, cfg, _mode_config(mode, sp), prompts)
        assert out == ref, f"fp32 {mode} stream drifted from slab ({arch_fixture})"


def test_int8_greedy_streams_match_fp32_end_to_end(setup):
    """Server-level smoke: on the reduced model the greedy margins dwarf the
    quant error, so int8 streams match fp32 exactly — and the audits stay
    clean through admit/decode/release with the scale leaf in the donated
    state."""
    cfg, params = setup
    prompts = _prompts(cfg)
    base = _mode_config("prefix", SamplingParams(temperature=0.0))
    _, ref = _run_server(params, cfg, base, prompts)
    srv, out = _run_server(params, cfg, base.replace(kv_dtype="int8"), prompts)
    assert out == ref
    assert all(d.audit().ok for d in srv.decodes)


# ---------------------------------------------------------------------------
# Batch-level prefix dedup
# ---------------------------------------------------------------------------


def _dedup_config(sampling=None, *, dedup, kv_dtype="fp32"):
    return EngineConfig(
        paged=True, prefix_cache=True, batch_dedup=dedup, max_slots=4,
        max_len=128, page_size=PAGE, sampling=sampling, kv_dtype=kv_dtype,
        seed=0,
    )


def test_dedup_requires_prefix_cache():
    with pytest.raises(ValueError, match="prefix_cache"):
        EngineConfig(paged=True, batch_dedup=True)


@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_dedup_streams_bit_identical_and_saves_prefill(setup, temperature):
    cfg, params = setup
    sp = SamplingParams(temperature=temperature)
    prompts = _prompts(cfg, n=3, shared=2 * PAGE)
    s0, ref = _run_server(params, cfg, _dedup_config(sp, dedup=False), prompts)
    s1, out = _run_server(params, cfg, _dedup_config(sp, dedup=True), prompts)
    # bit-identity includes the first token: the categorical draw is batch-
    # shape dependent, and dedup must not change the padded batch or the key
    assert out == ref
    st0, st1 = s0.unified_stats, s1.unified_stats
    assert st1["dedup_groups"] >= 1
    assert st1["dedup_saved_tokens"] > 0
    # the shared prefix was dispatched once, not once per duplicate
    assert st1["prefill_tokens"] + st1["dedup_saved_tokens"] == st0["prefill_tokens"]
    assert all(d.audit().ok for d in s1.decodes)


def test_dedup_refcounts_clean_across_waves(setup):
    """Wave 1 dedups in-batch; wave 2 hits the (now registered) prefix via
    the ordinary index match.  Refcounts must balance at every boundary."""
    cfg, params = setup
    srv = DisaggregatedServer.from_config(
        params, cfg, _dedup_config(dedup=True)
    )
    for w in range(2):
        for i, p in enumerate(_prompts(cfg, n=3, seed=w, shared=2 * PAGE)):
            srv.submit(GenRequest(w * 100 + i, p, 5))
        srv.run()
        assert all(d.audit().ok for d in srv.decodes), f"wave {w} audit"
    assert srv.unified_stats["dedup_groups"] >= 1
    d = srv.decodes[0]
    # everything drained: only the prefix index's cache holds remain
    assert sum(d._growth) == 0
    assert d.slots.n_active == 0


def test_dedup_int8_matches_int8_without_dedup(setup):
    cfg, params = setup
    prompts = _prompts(cfg, n=3, shared=2 * PAGE)
    _, ref = _run_server(
        params, cfg, _dedup_config(dedup=False, kv_dtype="int8"), prompts
    )
    srv, out = _run_server(
        params, cfg, _dedup_config(dedup=True, kv_dtype="int8"), prompts
    )
    assert out == ref
    assert srv.unified_stats["dedup_saved_tokens"] > 0
    assert all(d.audit().ok for d in srv.decodes)


def test_dedup_unique_prompts_noop(setup):
    """No shared prefixes -> dedup must not fire, and streams still match."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    prompts = [
        rng.integers(1, cfg.vocab_size, int(n)).astype(np.int32)
        for n in rng.integers(18, 40, 3)
    ]
    _, ref = _run_server(params, cfg, _dedup_config(dedup=False), prompts)
    srv, out = _run_server(params, cfg, _dedup_config(dedup=True), prompts)
    assert out == ref
    assert srv.unified_stats["dedup_groups"] == 0
    assert srv.unified_stats["dedup_saved_tokens"] == 0
