"""Property-based tests (hypothesis) on kernel/system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.decode_attention import decode_attention_pallas

SET = {"max_examples": 20, "deadline": None}


def _mx(a, b):
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))


@given(
    seed=st.integers(0, 2**31 - 1),
    s=st.sampled_from([32, 64, 96]),
    h=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2]),
    d=st.sampled_from([16, 32]),
)
@settings(**SET)
def test_flash_matches_ref(seed, s, h, g, d):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, s, h * g, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, s, h, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, s, h, d), jnp.float32)
    out = flash_attention_pallas(q, k, v, causal=True, block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    assert _mx(out, want) < 3e-5


@given(seed=st.integers(0, 2**31 - 1), t=st.integers(1, 30))
@settings(**SET)
def test_causality(seed, t):
    """Perturbing token t must not change attention outputs at positions < t."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    S, H, d = 32, 2, 16
    q = jax.random.normal(ks[0], (1, S, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, H, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, H, d), jnp.float32)
    o1 = ref.flash_attention_ref(q, k, v, causal=True)
    k2 = k.at[:, t].add(3.0)
    v2 = v.at[:, t].add(-2.0)
    o2 = ref.flash_attention_ref(q, k2, v2, causal=True)
    assert _mx(o1[:, :t], o2[:, :t]) == 0.0


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.1, 4.0))
@settings(**SET)
def test_softmax_value_bound(seed, scale):
    """Attention output is a convex combination: bounded by value extremes."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    S, H, d = 24, 2, 16
    q = jax.random.normal(ks[0], (1, S, H, d), jnp.float32) * scale
    k = jax.random.normal(ks[1], (1, S, H, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, H, d), jnp.float32)
    o = ref.flash_attention_ref(q, k, v, causal=False)
    assert float(o.max()) <= float(v.max()) + 1e-5
    assert float(o.min()) >= float(v.min()) - 1e-5


@given(seed=st.integers(0, 2**31 - 1), length=st.integers(1, 64))
@settings(**SET)
def test_decode_prefix_property(seed, length):
    """decode over a length-L prefix == full attention with that prefix only."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, L, H, d = 1, 64, 2, 16
    q = jax.random.normal(ks[0], (B, H, d), jnp.float32)
    kc = jax.random.normal(ks[1], (B, L, H, d), jnp.float32)
    vc = jax.random.normal(ks[2], (B, L, H, d), jnp.float32)
    out = decode_attention_pallas(q, kc, vc, jnp.array([length]), block_s=32, interpret=True)
    want = ref.decode_attention_ref(q, kc[:, :length], vc[:, :length], jnp.array([length]))
    assert _mx(out, want) < 3e-5


@given(seed=st.integers(0, 2**31 - 1), chunk=st.sampled_from([16, 32, 64, 128]))
@settings(**SET)
def test_ssd_chunk_invariance(seed, chunk):
    """SSD result must be independent of the chunk size (associativity)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    b, L, h, p, g, n = 1, 64, 2, 8, 1, 4
    x = jax.random.normal(ks[0], (b, L, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[2], (h,), jnp.float32) * 0.3)
    B = jax.random.normal(ks[3], (b, L, g, n), jnp.float32) * 0.3
    C = jax.random.normal(ks[4], (b, L, g, n), jnp.float32) * 0.3
    y1, s1 = ref.ssd_ref(x, dt, A, B, C, chunk=chunk)
    y2, s2 = ref.ssd_sequential_ref(x, dt, A, B, C)
    assert _mx(y1, y2) < 1e-3
    assert _mx(s1, s2) < 1e-3
