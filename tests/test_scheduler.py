"""Pluggable scheduler subsystem: FCFS bit-equivalence with the pre-refactor
server loop, KV-aware ordering + the aging starvation bound, priority
preemption via page-level swap, and the swap-out -> swap-in bit-identity
invariants (plain, prefix-shared, and fork-shared pages).

The FCFS anchor works two ways: ``LegacyServer`` below is a frozen copy of
the pre-refactor ``DisaggregatedServer.run_round`` scheduling loop (oldest-
first grouping, FIFO opportunistic admission), so the refactored server with
``FCFSScheduler`` must reproduce its token streams bit for bit — greedy AND
sampled, slab AND paged; and the default (no ``scheduler`` argument) must be
FCFS.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import (
    DecodeEngine,
    DisaggregatedServer,
    FCFSScheduler,
    GenRequest,
    KVAwareScheduler,
    PrefillEngine,
    PriorityScheduler,
    SamplingParams,
    SchedulerExhausted,
    make_scheduler,
)
from repro.serving import kvcache
from repro.serving.engine import _bucket

PAGE = 16


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _requests(cfg, n, seed=0, max_new=6, lo=5, hi=40, base=0, priority=0):
    rng = np.random.default_rng(seed)
    return [
        GenRequest(base + i,
                   rng.integers(0, cfg.vocab_size, size=int(rng.integers(lo, hi))),
                   max_new_tokens=max_new, priority=priority)
        for i in range(n)
    ]


def _shared_requests(cfg, n, base=0, prefix_len=32, max_new=8, seed=0):
    rng = np.random.default_rng(seed)
    common = rng.integers(0, cfg.vocab_size, size=prefix_len)
    tails = np.random.default_rng(seed + base + 1)
    return [
        GenRequest(base + i,
                   np.concatenate([common, tails.integers(0, cfg.vocab_size,
                                                          size=int(tails.integers(4, 16)))]),
                   max_new_tokens=max_new)
        for i in range(n)
    ]


def _server(params, cfg, *, scheduler=None, paged=True, max_slots=4, max_len=128,
            n_pages=None, decode_block=4, temperature=0.0, prefix=False,
            max_prefill_batch=4, seed=0):
    sp = SamplingParams(temperature=temperature)
    return DisaggregatedServer(
        [PrefillEngine(params, cfg, sp)],
        [DecodeEngine(params, cfg, max_slots=max_slots, max_len=max_len,
                      sampling=sp, decode_block=decode_block, paged=paged,
                      page_size=PAGE, n_pages=n_pages, prefix_cache=prefix,
                      seed=seed)],
        seed=seed, max_prefill_batch=max_prefill_batch, scheduler=scheduler,
    )


# ---------------------------------------------------------------------------
# FCFS bit-equivalence vs the pre-refactor scheduling loop
# ---------------------------------------------------------------------------


class LegacyServer:
    """Frozen pre-refactor scheduling loop (PR 1-3 ``run_round``, minus the
    prefix-cache routing which no test here enables): oldest request seeds a
    same-bucket prefill group, waiting requests admit FIFO-with-skip into the
    engine with most free slots, one fused decode block per engine."""

    def __init__(self, prefills, decodes, seed=0, max_prefill_batch=4):
        self.prefills, self.decodes = prefills, decodes
        self.key = jax.random.PRNGKey(seed)
        self.max_prefill_batch = max_prefill_batch
        self.queue, self.waiting = [], []
        self.all_requests = {}
        self._rr = 0

    def submit(self, req):
        self.queue.append(req)
        self.all_requests[req.rid] = req

    def _next_key(self):
        self.key, k = jax.random.split(self.key)
        return k

    def run(self, max_steps=10_000):
        steps = 0
        while (self.queue or self.waiting
               or any(d.requests for d in self.decodes)) and steps < max_steps:
            steps += 1
            free_slots = sum(d.max_slots - d.slots.n_active for d in self.decodes)
            if self.queue and len(self.waiting) < max(free_slots, 1):
                eng = self.prefills[self._rr % len(self.prefills)]
                self._rr += 1
                want = _bucket(len(self.queue[0].prompt), eng.buckets)
                group, rest = [], []
                for r in self.queue:
                    if (len(group) < self.max_prefill_batch
                            and _bucket(len(r.prompt), eng.buckets) == want):
                        group.append(r)
                    else:
                        rest.append(r)
                self.queue = rest
                toks, kvb, tls = eng.prefill_batch(
                    group, self._next_key(), pad_to=self.max_prefill_batch
                )
                for i, req in enumerate(group):
                    self.waiting.append((req, kvb, i, toks[i], tls[i]))
            still = []
            for req, kvb, bi, tok, tl in self.waiting:
                cands = [d for d in self.decodes
                         if d.can_admit(tl, req.max_new_tokens)]
                if cands:
                    dec = max(cands, key=lambda d: d.max_slots - d.slots.n_active)
                    dec.admit(req, kvb, tok, tl, batch_index=bi)
                else:
                    still.append((req, kvb, bi, tok, tl))
            self.waiting = still
            for dec in self.decodes:
                dec.step_block()
        return {rid: r.tokens for rid, r in self.all_requests.items() if r.done}


@pytest.mark.parametrize("paged,temperature", [
    (False, 0.0), (False, 0.8), (True, 0.0), (True, 0.8),
])
def test_fcfs_matches_pre_refactor_loop(setup, paged, temperature):
    """The tentpole anchor: FCFSScheduler streams are bit-identical to the
    pre-refactor hardcoded loop — greedy + sampled, slab + paged."""
    cfg, params = setup
    sp = SamplingParams(temperature=temperature)

    def engines():
        return ([PrefillEngine(params, cfg, sp)],
                [DecodeEngine(params, cfg, max_slots=3, max_len=128, sampling=sp,
                              decode_block=4, paged=paged, page_size=PAGE, seed=0)])

    legacy = LegacyServer(*engines(), seed=0, max_prefill_batch=4)
    for r in _requests(cfg, 8, seed=3):
        legacy.submit(r)
    want = legacy.run()

    pre, dec = engines()
    srv = DisaggregatedServer(pre, dec, seed=0, max_prefill_batch=4,
                              scheduler=FCFSScheduler())
    for r in _requests(cfg, 8, seed=3):
        srv.submit(r)
    got = srv.run()
    assert got == want


def test_default_scheduler_is_fcfs(setup):
    cfg, params = setup
    srv = _server(params, cfg)
    assert isinstance(srv.scheduler, FCFSScheduler)
    assert srv.scheduler.name == "fcfs"
    # the queue/waiting introspection surface still works through the policy
    srv.submit(_requests(cfg, 1, seed=1)[0])
    assert len(srv.queue) == 1


def test_make_scheduler():
    assert isinstance(make_scheduler("fcfs"), FCFSScheduler)
    assert isinstance(make_scheduler("kv-aware"), KVAwareScheduler)
    p = make_scheduler("priority", swap=False)
    assert isinstance(p, PriorityScheduler) and not p.swap
    assert not hasattr(make_scheduler("kv-aware", swap=True), "swap")  # ignored
    with pytest.raises(ValueError, match="unknown scheduler"):
        make_scheduler("sjf")


def test_greedy_streams_policy_invariant(setup):
    """Greedy token VALUES depend only on each request's own prompt/KV, so
    every policy must produce the same streams (only the order differs)."""
    cfg, params = setup
    outs = []
    for name in ("fcfs", "kv-aware", "priority"):
        srv = _server(params, cfg, scheduler=make_scheduler(name))
        for r in _requests(cfg, 7, seed=4, max_new=5):
            srv.submit(r)
        outs.append(srv.run())
    assert outs[0] == outs[1] == outs[2]


# ---------------------------------------------------------------------------
# KV-aware ordering: head-of-line blocking + the aging starvation bound
# ---------------------------------------------------------------------------


def _mixed_trace(cfg):
    """2 page-hungry requests submitted FIRST (8 pages each on a 16-page
    pool), then 14 short ones (2 pages each): under FCFS the shorts queue
    behind the longs; KV-aware runs the shorts first."""
    rng = np.random.default_rng(21)
    longs = [GenRequest(i, rng.integers(0, cfg.vocab_size, size=90),
                        max_new_tokens=24) for i in range(2)]
    shorts = [GenRequest(2 + i,
                         rng.integers(0, cfg.vocab_size, size=int(rng.integers(8, 13))),
                         max_new_tokens=8) for i in range(14)]
    return longs + shorts


@pytest.mark.slow
def test_kv_aware_cuts_queue_wait(setup):
    """On the mixed-length trace the KV-aware policy strictly reduces
    queue-wait p50 AND p99 vs FCFS while completing the same work in the
    same number of scheduling rounds (throughput preserved)."""
    cfg, params = setup
    stats = {}
    streams = {}
    for name in ("fcfs", "kv-aware"):
        sched = make_scheduler(name)
        srv = _server(params, cfg, scheduler=sched, max_slots=8, n_pages=16,
                      decode_block=8, max_prefill_batch=8)
        reqs = _mixed_trace(cfg)
        for r in reqs:
            srv.submit(r)
        streams[name] = srv.run()
        waits = [sched.queue_wait_rounds[r.rid] for r in reqs]
        stats[name] = (np.percentile(waits, 50), np.percentile(waits, 99),
                       sched.round)
    assert streams["fcfs"] == streams["kv-aware"]  # greedy: same tokens
    assert stats["kv-aware"][0] < stats["fcfs"][0]  # p50
    assert stats["kv-aware"][1] < stats["fcfs"][1]  # p99
    # same work, same rounds: ordering must not cost throughput
    assert stats["kv-aware"][2] <= stats["fcfs"][2] + 1


def test_kv_aware_aging_bound(setup):
    """A page-hungry request under a CONTINUOUS stream of small ones is
    admitted within the aging bound: once aged it ranks first and bars
    backfilling, so the pool drains to it instead of starving it."""
    cfg, params = setup
    age = 4
    sched = KVAwareScheduler(age_rounds=age)
    srv = _server(params, cfg, scheduler=sched, max_slots=4, n_pages=4,
                  decode_block=4)
    big = GenRequest(1000, np.random.default_rng(8).integers(
        0, cfg.vocab_size, size=40), max_new_tokens=8)  # needs the whole pool
    srv.submit(big)
    rid = 0
    for _ in range(3 * age):
        for r in _requests(cfg, 2, seed=rid, max_new=4, lo=5, hi=8, base=rid):
            srv.submit(r)  # 1-page requests, 2 fresh ones per round
            rid += 2
        srv.run_round()
        if big.rid in sched.queue_wait_rounds:
            break
    assert big.rid in sched.queue_wait_rounds, "page-hungry request starved"
    # admitted within the aging bound plus the drain time of the in-flight
    # small requests (their decode blocks) and one prefill round
    assert sched.queue_wait_rounds[big.rid] <= age + 4
    srv.run()
    assert big.done and len(big.tokens) == 8


# ---------------------------------------------------------------------------
# Page-level swap: out -> in round trips are bit-identical (greedy)
# ---------------------------------------------------------------------------


def _drive(eng):
    while eng.requests:
        eng.step_block()


def test_swap_roundtrip_stream_bitident(setup):
    """Swap a mid-flight request out, idle some blocks, swap it back in: the
    completed stream equals an uninterrupted run of the same seed."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    key = jax.random.PRNGKey(0)
    prompt = np.random.default_rng(7).integers(0, cfg.vocab_size, size=37)

    def fresh():
        return DecodeEngine(params, cfg, max_slots=3, max_len=128, sampling=sp,
                            decode_block=4, paged=True, page_size=PAGE)

    r_ref = GenRequest(0, prompt, max_new_tokens=12)
    eng = fresh()
    tok, kv, tl = pre.prefill(r_ref, key)
    eng.admit(r_ref, kv, tok, tl)
    _drive(eng)

    r = GenRequest(1, prompt, max_new_tokens=12)
    eng = fresh()
    tok, kv, tl = pre.prefill(r, key)
    eng.admit(r, kv, tok, tl)
    eng.step_block()
    sw = eng.swap_out(1)
    assert eng.slots.n_active == 0 and not eng.requests
    # everything released: no host pack can leak device pages
    assert bool(jnp.all(eng.state.page_refs == 0))
    assert eng.free_pages == eng.n_pages
    eng.step_block()  # idle blocks advance the engine PRNG; greedy ignores it
    assert eng.swap_in(sw) is not None
    _drive(eng)
    assert r.tokens == r_ref.tokens
    assert eng.stats["swap_outs"] == 1 and eng.stats["swap_ins"] == 1


@pytest.mark.slow
def test_swap_roundtrip_hybrid(setup):
    """Hybrid mamba/attn swap: the per-slot SSM state (a whole-prompt
    function, never paged) must ride the host pack out and back in."""
    cfg = reduced(ARCHS["jamba-1.5-large-398b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    key = jax.random.PRNGKey(0)
    prompt = np.random.default_rng(3).integers(0, cfg.vocab_size, size=30)

    def fresh():
        return DecodeEngine(params, cfg, max_slots=2, max_len=128, sampling=sp,
                            decode_block=4, paged=True, page_size=PAGE)

    r_ref = GenRequest(0, prompt, max_new_tokens=10)
    eng = fresh()
    tok, kv, tl = pre.prefill(r_ref, key)
    eng.admit(r_ref, kv, tok, tl)
    _drive(eng)

    r = GenRequest(1, prompt, max_new_tokens=10)
    eng = fresh()
    tok, kv, tl = pre.prefill(r, key)
    eng.admit(r, kv, tok, tl)
    eng.step_block()
    sw = eng.swap_out(1)
    eng.step_block()
    assert eng.swap_in(sw) is not None
    _drive(eng)
    assert r.tokens == r_ref.tokens


def test_swap_in_reservation_matches_uninterrupted(setup):
    """Off-by-one regression: the resumed reservation must equal the
    uninterrupted run's total — the re-consumed last token's KV is still
    unwritten (like first_token at a fresh admit), so dropping it from the
    budget would under-reserve one position.  Worst case: prompt + max_new
    + decode_block - 2 ≡ 1 (mod page_size), where one position is one page
    and the overshoot write would allocate outside any reservation."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    # 10 + 21 + 4 - 2 = 33 = 2 * PAGE + 1 -> 3 pages, the boundary case
    prompt = np.random.default_rng(2).integers(0, cfg.vocab_size, size=10)

    def fresh():
        return DecodeEngine(params, cfg, max_slots=2, max_len=128, sampling=sp,
                            decode_block=4, paged=True, page_size=PAGE)

    r_ref = GenRequest(0, prompt, max_new_tokens=21)
    eng = fresh()
    tok, kv, tl = pre.prefill(r_ref, jax.random.PRNGKey(0))
    slot = eng.admit(r_ref, kv, tok, tl)
    full_need = eng._pages_needed(tl, 21)
    assert full_need == 3
    assert eng._reserved[slot] == full_need
    _drive(eng)

    r = GenRequest(1, prompt, max_new_tokens=21)
    eng = fresh()
    tok, kv, tl = pre.prefill(r, jax.random.PRNGKey(0))
    eng.admit(r, kv, tok, tl)
    eng.step_block()
    sw = eng.swap_out(1)
    slot = eng.swap_in(sw)
    assert slot is not None
    # reserved (new pages + growth) + kept prefix pages == the original total
    assert eng._reserved[slot] + sw.n_keep == full_need
    _drive(eng)
    assert r.tokens == r_ref.tokens


def test_swap_out_requires_paged_and_live(setup):
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    slab = DecodeEngine(params, cfg, max_slots=2, max_len=128, sampling=sp)
    with pytest.raises(ValueError, match="paged"):
        slab.swap_out(0)
    paged = DecodeEngine(params, cfg, max_slots=2, max_len=128, sampling=sp,
                         paged=True, page_size=PAGE)
    with pytest.raises(KeyError, match="not decoding"):
        paged.swap_out(42)


def test_swap_prefix_shared_drops_ref_not_bytes(setup):
    """Swapping a request whose prefix pages are index-shared must NOT copy
    those pages: the mapping ref is dropped (decrement-only), the bytes stay
    pooled under a swap pin, and swap-in remaps them — streams of both the
    swapped request and its co-holder stay bit-identical."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    key = jax.random.PRNGKey(0)

    def fresh():
        return DecodeEngine(params, cfg, max_slots=3, max_len=128, sampling=sp,
                            decode_block=4, paged=True, page_size=PAGE,
                            prefix_cache=True)

    def pair():
        return _shared_requests(cfg, 2, prefix_len=32, max_new=10, seed=11)

    ra, rb = pair()
    eng = fresh()
    for r in (ra, rb):
        tok, kv, tl = pre.prefill(r, key)
        eng.admit(r, kv, tok, tl)
    _drive(eng)
    ref_a, ref_b = list(ra.tokens), list(rb.tokens)

    ra2, rb2 = pair()
    eng = fresh()
    for r in (ra2, rb2):
        tok, kv, tl = pre.prefill(r, key)
        eng.admit(r, kv, tok, tl)
    eng.step_block()
    sw = eng.swap_out(rb2.rid)
    # the 32-token shared prefix = 2 pages: kept in the pool, not copied
    assert sw.n_keep == 2 and len(sw.kept_pages) == 2
    refs = np.asarray(eng.state.page_refs)
    for p in sw.kept_pages:
        assert refs[p] == 2  # co-holder slot + index cache hold; rb's ref dropped
        assert eng.prefix.pinned(p)  # swap pin bridges the gap
    # the host pack holds ONLY the private tail pages (page-padded)
    n_total = -(-sw.length // PAGE)
    for leaf in jax.tree.leaves(sw.pack):
        if leaf.ndim >= 3 and leaf.shape[1] == 1:  # attn leaves [R, 1, L, ...]
            assert leaf.shape[2] == (n_total - sw.n_keep) * PAGE
    # LRU eviction under pressure must skip the pinned swap pages
    assert eng.prefix.evict_one(lambda p: p in sw.kept_pages) is None
    eng.step_block()
    assert eng.swap_in(sw) is not None
    for p in sw.kept_pages:
        assert not eng.prefix.pinned(p)  # unpinned after remap
    refs = np.asarray(eng.state.page_refs)
    for p in sw.kept_pages:
        assert refs[p] == 3  # both slots + cache hold again
    _drive(eng)
    assert ra2.tokens == ref_a
    assert rb2.tokens == ref_b


def test_swap_shared_fork_pages_regression(setup):
    """The satellite bugfix, fork flavour: extracting/preempting a request
    whose pages have refs > 1 through a fork must decrement the mapping ref,
    not free the pages — the fork keeps decoding bit-identically and the
    preempted branch resumes bit-identically."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    key = jax.random.PRNGKey(0)
    prompt = np.random.default_rng(5).integers(0, cfg.vocab_size, size=37)

    def fresh():
        return DecodeEngine(params, cfg, max_slots=3, max_len=128, sampling=sp,
                            decode_block=4, paged=True, page_size=PAGE)

    # reference: original runs alone to completion
    r_ref = GenRequest(0, prompt, max_new_tokens=12)
    eng = fresh()
    tok, kv, tl = pre.prefill(r_ref, key)
    eng.admit(r_ref, kv, tok, tl)
    _drive(eng)

    r1 = GenRequest(1, prompt, max_new_tokens=12)
    eng = fresh()
    tok, kv, tl = pre.prefill(r1, key)
    eng.admit(r1, kv, tok, tl)
    eng.step_block()
    alt = int((r_ref.tokens[4] + 1) % cfg.vocab_size)
    r2 = GenRequest(2, prompt, max_new_tokens=12)
    assert eng.fork(r2, src_rid=1, token=alt) is not None
    # preempt the ORIGINAL while its pages are shared with the fork
    sw = eng.swap_out(1)
    refs = np.asarray(eng.state.page_refs)
    fork_slot = eng.slots.request_ids.index(2)
    fork_pages = [int(p) for p in np.asarray(eng.state.block_tables[fork_slot])
                  if p < eng.n_pages]
    assert fork_pages and all(refs[p] >= 1 for p in fork_pages)  # bytes survive
    _drive(eng)  # fork finishes alone
    assert r2.tokens[:4] == r_ref.tokens[:4] and r2.tokens[4] == alt
    assert eng.swap_in(sw) is not None
    _drive(eng)
    assert r1.tokens == r_ref.tokens
    assert bool(jnp.all(eng.state.page_refs == 0))  # no leaked refs either way


def test_paged_extract_start_page_matches_tail(setup):
    """The extract fix: ``start_page`` returns exactly the tail slice of the
    full extraction (shared leading pages skipped, bytes identical)."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    eng = DecodeEngine(params, cfg, max_slots=2, max_len=128, sampling=sp,
                       decode_block=4, paged=True, page_size=PAGE)
    r = _requests(cfg, 1, seed=9, max_new=8, lo=36, hi=37)[0]
    tok, kv, tl = pre.prefill(r, jax.random.PRNGKey(0))
    slot = eng.admit(r, kv, tok, tl)
    eng.step_block()
    length = eng.slots.lengths[slot]
    full = kvcache.paged_extract_request(eng.state, slot, length, cfg,
                                         page_size=PAGE)
    tail = kvcache.paged_extract_request(eng.state, slot, length, cfg,
                                         page_size=PAGE, start_page=1)
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        for f, t in zip(jax.tree.leaves(full[i]), jax.tree.leaves(tail[i]), strict=True):
            if mixer == "attn":
                np.testing.assert_array_equal(np.asarray(f[:, :, PAGE:]),
                                              np.asarray(t))
            else:
                np.testing.assert_array_equal(np.asarray(f), np.asarray(t))


def test_paged_swap_in_reference_transition(setup):
    """The un-jitted kvcache.paged_swap_in reference reproduces the engine's
    jitted swap-in admit: same block-table mapping, same pack bytes."""
    cfg, params = setup
    sp = SamplingParams(temperature=0.0)
    pre = PrefillEngine(params, cfg, sp)
    eng = DecodeEngine(params, cfg, max_slots=2, max_len=128, sampling=sp,
                       decode_block=4, paged=True, page_size=PAGE)
    r = _requests(cfg, 1, seed=10, max_new=8, lo=20, hi=21)[0]
    tok, kv, tl = pre.prefill(r, jax.random.PRNGKey(0))
    eng.admit(r, kv, tok, tl)
    eng.step_block()
    sw = eng.swap_out(r.rid)
    st = kvcache.paged_swap_in(
        eng.state, sw.pack, 0, sw.last_token, sw.length, cfg, page_size=PAGE
    )
    assert bool(st.active[0]) and int(st.positions[0]) == sw.length
    back = kvcache.paged_extract_request(st, 0, sw.length, cfg, page_size=PAGE)
    for i, (mixer, _) in enumerate(cfg.block_pattern):
        if mixer != "attn":
            continue
        for a, b in zip(jax.tree.leaves(back[i]), jax.tree.leaves(sw.pack[i]), strict=True):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b[:, :, :sw.length]))


# ---------------------------------------------------------------------------
# Priority scheduling: preemption end-to-end through the server
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_priority_preemption_end_to_end(setup):
    """Low-priority requests fill the pool; a late high-priority request
    preempts one via swap, runs promptly, and the preempted request resumes
    and completes BIT-identically to an uninterrupted run."""
    cfg, params = setup

    def lows():
        return _requests(cfg, 5, seed=5, max_new=24, lo=10, hi=11)

    ref_srv = _server(params, cfg, max_slots=8, n_pages=16, decode_block=8,
                      max_prefill_batch=8)
    ref = lows()
    for r in ref:
        ref_srv.submit(r)
    ref_srv.run()

    def run_with(swap):
        sched = PriorityScheduler(swap=swap)
        srv = _server(params, cfg, scheduler=sched, max_slots=8, n_pages=16,
                      decode_block=8, max_prefill_batch=8)
        ls = lows()
        for r in ls:
            srv.submit(r)
        srv.run_round()
        srv.run_round()  # lows are now decoding, pool is nearly full
        high = GenRequest(100, np.random.default_rng(6).integers(
            0, cfg.vocab_size, size=40), max_new_tokens=16, priority=1)
        srv.submit(high)
        out = srv.run()
        return sched, ls, high, out

    sched, ls, high, out = run_with(swap=True)
    assert len(out) == 6
    assert sched.stats["preemptions"] >= 1
    assert sched.stats["swap_ins"] == sched.stats["preemptions"]
    assert not sched.swapped  # everything resumed
    wait_swap = sched.queue_wait_rounds[100]
    # preempted lows finish bit-identically to the uninterrupted run
    for got, want in zip(ls, ref, strict=True):
        assert got.tokens == want.tokens
    assert len(high.tokens) == 16

    # without swap there is no preemption and the high request waits longer
    sched_ns, ls_ns, high_ns, out_ns = run_with(swap=False)
    assert len(out_ns) == 6
    assert sched_ns.stats["preemptions"] == 0
    assert sched_ns.queue_wait_rounds[100] > wait_swap
    for got, want in zip(ls_ns, ref, strict=True):
        assert got.tokens == want.tokens


def test_priority_infeasible_preemption_skipped(setup):
    """Deadlock regression: preempting victims whose prefix pages survive
    under unevictable swap pins can NEVER free enough capacity for a big
    high-priority request — the policy must skip the preemption entirely
    (the victims then finish naturally, their cache-held pages become
    evictable, and the big request admits) instead of livelocking the
    request against its own victims' pins."""
    cfg, params = setup
    sched = PriorityScheduler(swap=True)
    # 17-page pool, 256-position slots: A+B (shared 2-page prefix) hold 4
    # pages + 2 growth; H needs 16 pages.  Swapping A and B would free only
    # their sole-held pages (their 2 shared pages stay swap-pinned), leaving
    # 15 < 16 forever — infeasible, so no preemption may happen.
    srv = _server(params, cfg, scheduler=sched, prefix=True, max_slots=4,
                  max_len=256, n_pages=17, decode_block=4)
    a, b = _shared_requests(cfg, 2, prefix_len=32, max_new=16, seed=11)
    for r in (a, b):
        r.prompt = r.prompt[:40]  # 40 tokens: 2 shared pages + 1 private
        srv.submit(r)
    srv.run_round()
    srv.run_round()  # A and B are decoding
    high = GenRequest(100, np.random.default_rng(4).integers(
        0, cfg.vocab_size, size=220), max_new_tokens=24, priority=1)
    srv.submit(high)
    out = srv.run()  # must complete, not SchedulerExhausted
    assert len(out) == 3
    assert sched.stats["preemptions"] == 0  # infeasible preemption skipped
    assert high.done and len(high.tokens) == 24
    assert a.done and b.done


def test_priority_orders_queue(setup):
    """Higher priority admits first even when submitted last (no preemption
    needed — just ordering)."""
    cfg, params = setup
    sched = PriorityScheduler(swap=False)
    srv = _server(params, cfg, scheduler=sched, max_slots=2, n_pages=8)
    reqs = _requests(cfg, 4, seed=12, max_new=4, lo=20, hi=30)
    reqs[-1].priority = 5
    for r in reqs:
        srv.submit(r)
    srv.run()
    waits = {r.rid: sched.queue_wait_rounds[r.rid] for r in reqs}
    assert waits[reqs[-1].rid] <= min(waits[r.rid] for r in reqs[:-1])


# ---------------------------------------------------------------------------
# Host-side bookkeeping hygiene: the churn loop (satellite regression)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_churn_no_host_leaks(setup):
    """Waves of shared-prefix + preempting requests, interrupted by
    SchedulerExhausted resumes: after the final drain every host-side
    bookkeeping structure is empty — no leaked hash memos, prefix pins, swap
    pins, or stashes — and device refcounts equal the index holds."""
    cfg, params = setup
    sched = PriorityScheduler(swap=True)
    srv = _server(params, cfg, scheduler=sched, prefix=True, max_slots=4,
                  n_pages=20, decode_block=4)
    eng = srv.decodes[0]
    for wave in range(4):
        for r in _shared_requests(cfg, 3, base=wave * 100, max_new=8,
                                  seed=3 + wave % 2):
            srv.submit(r)
        if wave % 2:
            hp = GenRequest(wave * 100 + 50, np.random.default_rng(wave).integers(
                0, cfg.vocab_size, size=40), max_new_tokens=6, priority=1)
            srv.submit(hp)
        try:
            srv.run(max_steps=2)  # interrupt mid-flight...
        except SchedulerExhausted:
            pass
        srv.run()  # ...and resume to drain
    assert srv._hash_memo == {}
    assert eng._pins == {}
    assert eng.prefix._pins == {}
    assert eng.prefix._swap_pins == {}
    assert not sched.swapped and not sched.waiting and not sched.queue
    assert sched.submit_round == {}
    # device truth: only index cache holds remain
    refs = np.asarray(eng.state.page_refs)
    assert int((refs > 0).sum()) == len(eng.prefix)
    assert all(refs[p] == 1 for p in eng.prefix.pages())
    assert eng._reserved == [0] * eng.max_slots
