"""Logical-axis partitioner rules + production mesh resolution."""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.sharding.partitioning import DEFAULT_RULES, resolve_spec

SINGLE = AbstractMesh((("data", 16), ("model", 16)))
POD = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def test_basic_resolution():
    spec = resolve_spec(("embed", "mlp"), (4096, 14336), SINGLE)
    assert spec == P("data", "model")


def test_pod_batch_spans_pod_and_data():
    spec = resolve_spec(("batch", None), (256, 4096), POD)
    assert spec == P(("pod", "data"))


def test_divisibility_fallback_kv_heads():
    # 4 kv heads cannot shard 16 ways -> falls through to head_dim
    spec = resolve_spec(("embed", "kv_heads", "head_dim"), (4096, 4, 128), SINGLE)
    assert spec == P("data", None, "model")


def test_no_double_assignment():
    # heads takes "model"; head_dim must NOT also take it
    spec = resolve_spec(("embed", "heads", "head_dim"), (4096, 64, 128), SINGLE)
    assert spec == P("data", "model")


def test_indivisible_vocab_replicates():
    spec = resolve_spec(("vocab", "embed"), (92_553, 2048), SINGLE)  # internvl2
    assert spec == P(None, "data")


def test_batch_of_one_replicates():
    spec = resolve_spec(("batch",), (1,), POD)
    assert spec == P()


def test_seq_sharding_for_long_context():
    # long_500k: batch=1 -> seq takes (pod, data)
    spec = resolve_spec(("batch", "seq", "kv_heads", "head_dim"), (1, 524_288, 8, 128), POD)
    assert spec == P(None, ("pod", "data"), None, "model")


def test_expert_sharding():
    spec = resolve_spec(("expert", "embed", "mlp"), (128, 4096, 1536), SINGLE)
    assert spec[0] == "model"
    assert spec[1] == "data"


def test_rules_cover_all_model_axes():
    """Every logical axis used by param/cache axes must have a rule entry."""
    from repro.configs import ARCHS, reduced
    from repro.models import model as M

    used = set()
    for name in ARCHS:
        cfg = reduced(ARCHS[name])
        for tree in (M.param_axes(cfg), M.cache_axes(cfg)):
            for leaf in jax.tree.leaves(
                tree,
                is_leaf=lambda x: isinstance(x, tuple) and all(
                    e is None or isinstance(e, str) for e in x
                ),
            ):
                used.update(a for a in leaf if a is not None)
    missing = used - set(DEFAULT_RULES)
    assert not missing, f"logical axes without rules: {missing}"
