"""Multi-replica KV-aware router + streaming front door (serving.router /
serving.api) and the redesigned public serving API (EngineConfig,
RequestHandle, drain).

The acceptance invariants:

* routing is DETERMINISTIC: same config + same submit sequence => identical
  replica assignments and decision traces;
* prefix LOCALITY wins: on a skewed-prefix trace every matched request
  routes to the replica already holding its pages, and the matched pages
  are mapped (shared), never recomputed;
* routing never changes streams: greedy routed streams are bit-identical
  to a single-replica FCFS run of the same workload;
* the handle path is the rid path: cancellation/deadline through
  ``RequestHandle`` matches ``server.cancel(rid)`` bit-exactly;
* TTFT/TBT are measured at the async API surface.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import model as M
from repro.serving import (
    STATUS_CANCELLED,
    STATUS_DEADLINE,
    STATUS_FINISHED,
    Client,
    DecodeEngine,
    DisaggregatedServer,
    EngineConfig,
    GenRequest,
    PrefillEngine,
    RequestHandle,
    Router,
)

PAGE = 16
PREFIX_LEN = 32  # two pages of shared system prompt


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(ARCHS["granite-8b"])
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _config(**over):
    kw = dict(max_slots=4, max_len=128, paged=True, prefix_cache=True,
              page_size=PAGE)
    kw.update(over)
    return EngineConfig(**kw)


def _requests(cfg, n, base=0, prefix=None, max_new=4, seed=0, lo=4, hi=16):
    rng = np.random.default_rng(seed + base)
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            size=int(rng.integers(lo, hi))).tolist()
        prompt = (list(prefix) + tail) if prefix is not None else tail
        out.append(GenRequest(base + i, prompt, max_new_tokens=max_new))
    return out


def _prefixes(cfg, n=2, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=PREFIX_LEN).tolist()
            for _ in range(n)]


# -- EngineConfig (satellite: the consolidated, validated config object) ----


def test_engine_config_validates():
    with pytest.raises(ValueError, match="prefix_cache"):
        EngineConfig(prefix_cache=True, paged=False)
    with pytest.raises(ValueError, match="not a multiple"):
        EngineConfig(paged=True, max_len=100, page_size=16)
    with pytest.raises(ValueError, match="chunk_tokens"):
        EngineConfig(paged=True, max_len=128, chunk_tokens=24, page_size=16)
    with pytest.raises(ValueError, match="scheduler"):
        EngineConfig(scheduler="lifo")
    # frozen: replicas derive variants via replace(), never mutation
    ec = _config()
    with pytest.raises(Exception):
        ec.max_slots = 2
    assert ec.replace(seed=3).seed == 3 and ec.seed == 0


def test_config_path_matches_kwarg_shim(setup):
    """config= and the deprecated loose kwargs build bit-identical engines."""
    cfg, params = setup
    ec = _config()
    srv_cfg = DisaggregatedServer.from_config(params, cfg, ec)
    srv_kw = DisaggregatedServer(
        [PrefillEngine(params, cfg)],
        [DecodeEngine(params, cfg, max_slots=4, max_len=128, paged=True,
                      prefix_cache=True, page_size=PAGE)],
    )
    for r in _requests(cfg, 4):
        srv_cfg.submit(r)
    for r in _requests(cfg, 4):
        srv_kw.submit(r)
    assert srv_cfg.run() == srv_kw.run()


# -- RequestHandle (satellite: submit returns a handle; rid path intact) ----


def test_submit_returns_handle(setup):
    cfg, params = setup
    srv = DisaggregatedServer.from_config(params, cfg, _config())
    handles = [srv.submit(r) for r in _requests(cfg, 3)]
    assert all(isinstance(h, RequestHandle) for h in handles)
    toks = handles[0].result()  # drives rounds for everyone
    srv.drain()
    outs = srv.outcomes()
    for h in handles:
        assert h.status() == STATUS_FINISHED
        assert h.tokens() == outs[h.rid].tokens
        assert h.outcome() == outs[h.rid]
    assert toks == outs[handles[0].rid].tokens


def test_handle_stream_matches_run(setup):
    """handle.stream() yields exactly the tokens run() would collect."""
    cfg, params = setup
    ec = _config()
    srv_a = DisaggregatedServer.from_config(params, cfg, ec)
    srv_b = DisaggregatedServer.from_config(params, cfg, ec)
    reqs = _requests(cfg, 3, max_new=5)
    handles = [srv_a.submit(r) for r in reqs]
    streamed = {h.rid: list(h.stream()) for h in handles}
    for r in _requests(cfg, 3, max_new=5):
        srv_b.submit(r)
    assert streamed == srv_b.run()


def test_handle_cancel_matches_rid_path(setup):
    """Cancellation through the handle is bit-exact with server.cancel(rid):
    same statuses, same truncated streams, at the same round."""
    cfg, params = setup
    ec = _config()
    outs = []
    for use_handle in (True, False):
        srv = DisaggregatedServer.from_config(params, cfg, ec)
        handles = [srv.submit(r) for r in _requests(cfg, 4, max_new=24)]
        for _ in range(2):
            srv.run_round()
        assert not handles[1].done()  # cancellation lands mid-stream
        if use_handle:
            assert handles[1].cancel()
        else:
            assert srv.cancel(handles[1].rid)
        srv.drain()
        assert handles[1].status() == STATUS_CANCELLED
        outs.append({h.rid: (h.status(), h.tokens()) for h in handles})
    assert outs[0] == outs[1]


def test_handle_deadline_status(setup):
    """A deadline expiry surfaces through the same handle, matching the
    rid-based outcomes() view."""
    cfg, params = setup
    srv = DisaggregatedServer.from_config(params, cfg, _config())
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=8).tolist()
    h = srv.submit(GenRequest(0, prompt, max_new_tokens=64,
                              deadline_rounds=2))
    srv.drain()
    assert h.status() == STATUS_DEADLINE
    assert srv.outcomes()[0].status == STATUS_DEADLINE
    assert h.tokens() == srv.outcomes()[0].tokens  # truncated, not erased


# -- drain (satellite: the unified run/run_round/resume contract) -----------


def test_drain_is_resumable_and_run_equivalent(setup):
    cfg, params = setup
    ec = _config()
    srv = DisaggregatedServer.from_config(params, cfg, ec)
    for r in _requests(cfg, 4, max_new=24):
        srv.submit(r)
    partial = srv.drain(max_rounds=2)  # never raises, work left intact
    assert srv.pending()
    assert set(partial) == {0, 1, 2, 3}
    final = srv.drain()  # resumes where it stopped
    assert not srv.pending()
    assert all(o.stage == "done" for o in final.values())
    # bit-exact with a straight run() of the same workload
    srv2 = DisaggregatedServer.from_config(params, cfg, ec)
    for r in _requests(cfg, 4, max_new=24):
        srv2.submit(r)
    assert {rid: o.tokens for rid, o in final.items()} == srv2.run()


# -- Router: determinism, locality, balance, stream identity ----------------


def test_routing_deterministic(setup):
    """Same seed + workload => identical replica assignment and trace."""
    cfg, params = setup
    ec = _config()
    pa, pb = _prefixes(cfg)
    runs = []
    for _ in range(2):
        router = Router(params, cfg, ec, replicas=2)
        for r in _requests(cfg, 2, base=0, prefix=pa):
            router.submit(r)
        for r in _requests(cfg, 2, base=10, prefix=pb):
            router.submit(r)
        router.drain()
        for r in _requests(cfg, 6, base=20, prefix=pa):
            router.submit(r)
        router.drain()
        runs.append((dict(router.assignments),
                     [(d.rid, d.replica, d.matched_pages, d.scores)
                      for d in router.trace]))
    assert runs[0] == runs[1]


def test_skewed_prefix_routes_to_holder(setup):
    """Skewed-prefix trace: every matched request lands on the replica
    holding its pages, matched pages are shared (0 recompute), and the
    per-replica load stays balanced."""
    cfg, params = setup
    router = Router(params, cfg, _config(), replicas=2)
    pa, pb = _prefixes(cfg)
    # seed wave: one request per family; free-page/depth tie-breaking
    # spreads them across replicas, planting family A on one and B on the
    # other
    ha = router.submit(_requests(cfg, 1, base=0, prefix=pa)[0])
    hb = router.submit(_requests(cfg, 1, base=1, prefix=pb)[0])
    router.drain()
    holder = {"a": router.assignments[0], "b": router.assignments[1]}
    assert holder["a"] != holder["b"]
    shared_before = [
        sum(d.stats["shared_pages"] for d in s.decodes)
        for s in router.servers
    ]
    # skewed wave: interleaved A/B requests, all prefix-matched
    wave = []
    for i in range(3):
        wave.append((_requests(cfg, 1, base=100 + i, prefix=pa)[0], "a"))
        wave.append((_requests(cfg, 1, base=200 + i, prefix=pb)[0], "b"))
    matched_total = 0
    for req, fam in wave:
        router.submit(req)
        d = router.trace[-1]
        assert d.matched_pages == PREFIX_LEN // PAGE, (d, fam)
        assert d.replica == holder[fam], f"rid {req.rid} missed its holder"
        matched_total += d.matched_pages
    router.drain()
    # matched pages were MAPPED in the holder's pool, not recomputed
    shared_delta = sum(
        sum(d.stats["shared_pages"] for d in s.decodes)
        for s in router.servers
    ) - sum(shared_before)
    assert shared_delta >= matched_total  # 0 matched-chunk recompute
    # the skewed trace is perfectly balanced by construction
    assert sorted(router.load()) == [4, 4]
    assert all(o.status == STATUS_FINISHED for o in router.outcomes().values())


def test_unskewed_routed_streams_match_single_replica_fcfs(setup):
    """Routing must never change what is generated: greedy routed streams
    are bit-identical to the single-replica FCFS baseline."""
    cfg, params = setup
    ec = _config()
    reqs = lambda: _requests(cfg, 6, max_new=5, seed=21)  # noqa: E731
    router = Router(params, cfg, ec, replicas=2)
    for r in reqs():
        router.submit(r)
    routed = router.run()
    baseline = DisaggregatedServer.from_config(params, cfg, ec)
    for r in reqs():
        baseline.submit(r)
    assert routed == baseline.run()
    # unskewed load spreads across replicas
    assert sorted(router.load()) == [3, 3]


def test_router_handle_cancel(setup):
    """Router-bound handles cancel through the owning replica, bit-exact
    with the router's rid path."""
    cfg, params = setup
    ec = _config()
    outs = []
    for use_handle in (True, False):
        router = Router(params, cfg, ec, replicas=2)
        handles = [router.submit(r) for r in _requests(cfg, 4, max_new=24)]
        router.run_round()
        assert not handles[2].done()  # cancellation lands mid-stream
        if use_handle:
            assert handles[2].cancel()
        else:
            assert router.cancel(handles[2].rid)
        router.drain()
        assert handles[2].status() == STATUS_CANCELLED
        outs.append({h.rid: (h.status(), h.tokens()) for h in handles})
    assert outs[0] == outs[1]


def test_router_rejects_loose_kwargs(setup):
    cfg, params = setup
    with pytest.raises(TypeError, match="EngineConfig"):
        Router(params, cfg, {"max_slots": 4}, replicas=2)


# -- streaming API: per-token generators, TTFT/TBT at the surface -----------


def test_async_streams_match_sync_run(setup):
    """Concurrent async per-token streams reproduce the synchronous drain's
    streams exactly, and TTFT/TBT are recorded at the API surface."""
    cfg, params = setup
    ec = _config()
    prompts = [r.prompt for r in _requests(cfg, 4, max_new=5, seed=33)]

    async def main():
        client = Client.from_config(params, cfg, ec, replicas=2)

        async def one(p):
            toks = []
            async for t in client.generate(p, max_new_tokens=5):
                toks.append(t)
            return toks

        results = await asyncio.gather(*[one(p) for p in prompts])
        return client, results

    client, results = asyncio.run(main())
    # reference: the same workload through the synchronous router path
    ref = Router(params, cfg, ec, replicas=2)
    for i, p in enumerate(prompts):
        ref.submit(GenRequest(i, p, max_new_tokens=5))
    ref_out = ref.run()
    assert {i: toks for i, toks in enumerate(results)} == ref_out
    # TTFT/TBT measured at the API surface, per stream
    for rid, m in client.metrics.items():
        assert m.status == STATUS_FINISHED
        assert m.n_tokens == 5
        assert m.ttft_s is not None and m.ttft_s > 0
        assert m.ttft_rounds is not None and m.ttft_rounds >= 0
        assert len(m.tbt_s) == m.n_tokens - 1
        assert all(g >= 0 for g in m.tbt_s)
        assert m.finish_s is not None and m.finish_s >= m.submit_s


def test_async_ttft_rounds_deterministic(setup):
    """The round-clock TTFT is deterministic across identical runs (the
    wall-clock one is not — that's why both exist)."""
    cfg, params = setup
    ec = _config()
    prompts = [r.prompt for r in _requests(cfg, 3, max_new=4, seed=5)]

    async def main():
        client = Client.from_config(params, cfg, ec, replicas=2)

        async def one(p):
            return [t async for t in client.generate(p, max_new_tokens=4)]

        await asyncio.gather(*[one(p) for p in prompts])
        return {rid: m.ttft_rounds for rid, m in client.metrics.items()}

    assert asyncio.run(main()) == asyncio.run(main())


def test_async_break_cancels_request(setup):
    """Breaking out of the async for cancels the in-flight request through
    the same handle; the truncated stream keeps its tokens."""
    cfg, params = setup
    ec = _config()
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, size=10).tolist()

    async def main():
        client = Client.from_config(params, cfg, ec, replicas=1)
        got = []
        async for t in client.generate(prompt, max_new_tokens=32, rid=0):
            got.append(t)
            if len(got) == 2:
                break
        return client, got

    client, got = asyncio.run(main())
    m = client.metrics[0]
    assert m.status == STATUS_CANCELLED
    assert len(got) == 2
    out = client.backend.outcomes()[0]
    assert out.status == STATUS_CANCELLED
    assert out.tokens[:2] == got
